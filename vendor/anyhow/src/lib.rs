//! Vendored, API-compatible subset of the [`anyhow`] error-handling crate.
//!
//! The WideSA evaluation environment builds from a clean checkout with no
//! crates.io access, so the one external dependency the crate relies on is
//! vendored here as a ~200-line reimplementation of the slice of the
//! `anyhow` 1.x API the codebase uses:
//!
//! * [`Error`] — an opaque error value carrying a message plus a chain of
//!   causes (outermost context first). Like the real `anyhow::Error`, it
//!   deliberately does **not** implement [`std::error::Error`], which is
//!   what makes the blanket `From` conversion below coherent.
//! * [`Result<T>`] — `std::result::Result` defaulted to [`Error`].
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the underlying error with a new outer message.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros.
//!
//! Display shows the outermost message only (matching `anyhow`); Debug
//! shows the full `Caused by:` chain, so `unwrap()` panics stay readable.
//!
//! [`anyhow`]: https://docs.rs/anyhow

use std::fmt;

/// Opaque error: outermost message plus the chain of underlying causes.
pub struct Error {
    msg: String,
    /// Causes, outermost-but-one first (each entry one `Caused by:` line).
    chain: Vec<String>,
    /// The typed error this value was converted from (when it came from a
    /// concrete [`std::error::Error`]), kept so [`Error::downcast_ref`]
    /// can recover it through any number of `context` wraps.
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a plain message (what [`anyhow!`] expands to).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self {
            msg: msg.to_string(),
            chain: Vec::new(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, context: impl fmt::Display) -> Self {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Self {
            msg: context.to_string(),
            chain,
            source: self.source,
        }
    }

    /// A reference to the typed error this value was converted from, if
    /// it is an `E` (API-compatible subset of the real crate's
    /// `downcast_ref`; survives `context` wrapping).
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }

    /// The chain of messages, outermost first (for diagnostics).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(String::as_str))
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any concrete `std` error converts into [`Error`], capturing its source
/// chain. Coherent because [`Error`] itself does not implement
/// [`std::error::Error`] (the same trick the real crate uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self {
            msg: e.to_string(),
            chain,
            source: Some(Box::new(e)),
        }
    }
}

/// `std::result::Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with a new message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with a lazily evaluated message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

/// One impl covers both plain `std` errors and already-wrapped
/// [`Error`]s: everything that can become an [`Error`].
impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, or from any single
/// [`Display`](fmt::Display) value (`anyhow!(err)`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_context_only() {
        let e: Error = io_err().into();
        let wrapped = e.context("reading manifest");
        assert_eq!(wrapped.to_string(), "reading manifest");
        assert_eq!(wrapped.root_cause(), "file missing");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = io_err().into();
        let wrapped = e.context("outer");
        let dbg = format!("{wrapped:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(f(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn downcast_ref_recovers_typed_source() {
        #[derive(Debug)]
        struct My(u32);
        impl fmt::Display for My {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "my error {}", self.0)
            }
        }
        impl std::error::Error for My {}

        let e: Error = My(7).into();
        assert_eq!(e.downcast_ref::<My>().unwrap().0, 7);
        let wrapped = e.context("outer");
        assert_eq!(wrapped.downcast_ref::<My>().unwrap().0, 7);
        assert!(wrapped.downcast_ref::<std::io::Error>().is_none());
        assert!(anyhow!("plain message").downcast_ref::<My>().is_none());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }
}
