"""Hypothesis sweep: Pallas Conv2D tile kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype):
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(-8, 8, size=shape, dtype=dtype))
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@given(
    bh=st.sampled_from([8, 16]),
    bw=st.sampled_from([8, 16]),
    gh=st.integers(1, 3),
    gw=st.integers(1, 3),
    p=st.sampled_from([2, 3, 4]),
    q=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_conv2d_f32_matches_ref(bh, bw, gh, gw, p, q, seed):
    rng = np.random.default_rng(seed)
    H, W = gh * bh, gw * bw
    x = _rand(rng, (H + p - 1, W + q - 1), np.float32)
    w = _rand(rng, (p, q), np.float32)
    acc = _rand(rng, (H, W), np.float32)
    got = conv2d.conv2d_acc(x, w, acc, bh=bh, bw=bw)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, w, acc), rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_conv2d_i32_exact(seed):
    rng = np.random.default_rng(seed)
    H = W = 32
    x = _rand(rng, (H + 3, W + 3), np.int32)
    w = _rand(rng, (4, 4), np.int32)
    acc = _rand(rng, (H, W), np.int32)
    got = conv2d.conv2d_acc(x, w, acc, bh=16, bw=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.conv2d_ref(x, w, acc)))


def test_conv2d_acc_is_additive():
    """conv(x, w, acc) == conv(x, w, 0) + acc — the property the host uses
    to split the input-channel reduction across graph tiles."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (19, 19), np.float32)
    w = _rand(rng, (4, 4), np.float32)
    acc = _rand(rng, (16, 16), np.float32)
    zero = jnp.zeros((16, 16), jnp.float32)
    base = conv2d.conv2d_acc(x, w, zero, bh=16, bw=16)
    got = conv2d.conv2d_acc(x, w, acc, bh=16, bw=16)
    np.testing.assert_allclose(got, base + acc, rtol=1e-5, atol=1e-5)


def test_conv2d_identity_kernel():
    """A delta kernel must pass the (shifted) input through unchanged."""
    rng = np.random.default_rng(4)
    x = _rand(rng, (18, 18), np.float32)
    w = jnp.zeros((3, 3), jnp.float32).at[0, 0].set(1.0)
    acc = jnp.zeros((16, 16), jnp.float32)
    got = conv2d.conv2d_acc(x, w, acc, bh=16, bw=16)
    np.testing.assert_allclose(got, x[:16, :16], rtol=1e-6, atol=1e-6)
