# pytest: kernel vs ref allclose — the CORE correctness signal.
# Quick deterministic smoke tests; the hypothesis sweeps live in the
# per-kernel test modules (test_mm.py, test_conv2d.py, ...).
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import conv2d, fft, fir, mm, ref


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def test_mm_smoke(rng):
    a = jnp.asarray(rng.standard_normal((64, 32), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((32, 64), dtype=np.float32))
    c = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
    got = mm.mm_acc(a, b, c, bn=32, bm=32, bk=32)
    np.testing.assert_allclose(got, ref.mm_acc_ref(a, b, c), rtol=1e-5, atol=1e-5)


def test_conv2d_smoke(rng):
    x = jnp.asarray(rng.standard_normal((35, 35), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((4, 4), dtype=np.float32))
    acc = jnp.asarray(rng.standard_normal((32, 32), dtype=np.float32))
    got = conv2d.conv2d_acc(x, w, acc, bh=16, bw=16)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, w, acc), rtol=1e-5, atol=1e-5)


def test_fir_smoke(rng):
    x = jnp.asarray(rng.standard_normal((512 + 14,), dtype=np.float32))
    h = jnp.asarray(rng.standard_normal((15,), dtype=np.float32))
    got = fir.fir(x, h, bn=128)
    np.testing.assert_allclose(got, ref.fir_ref(x, h), rtol=1e-5, atol=1e-5)


def test_fft_smoke(rng):
    re = jnp.asarray(rng.standard_normal((8, 64), dtype=np.float32))
    im = jnp.asarray(rng.standard_normal((8, 64), dtype=np.float32))
    gre, gim = fft.fft1d(re, im, bb=4)
    want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=1)
    np.testing.assert_allclose(gre, want.real, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gim, want.imag, rtol=1e-4, atol=1e-3)
