"""Hypothesis sweep: Pallas MM tile kernel vs pure-jnp oracle.

Sweeps shapes (multiples of the block sizes), block sizes, and dtypes —
the L1 correctness contract the whole stack rests on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mm, ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype):
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(-8, 8, size=shape, dtype=dtype))
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@given(
    bn=st.sampled_from([8, 16, 32]),
    bm=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    gn=st.integers(1, 3),
    gm=st.integers(1, 3),
    gk=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_mm_f32_matches_ref(bn, bm, bk, gn, gm, gk, seed):
    rng = np.random.default_rng(seed)
    n, m, k = gn * bn, gm * bm, gk * bk
    a = _rand(rng, (n, k), np.float32)
    b = _rand(rng, (k, m), np.float32)
    c = _rand(rng, (n, m), np.float32)
    got = mm.mm_acc(a, b, c, bn=bn, bm=bm, bk=bk)
    np.testing.assert_allclose(got, ref.mm_acc_ref(a, b, c), rtol=1e-4, atol=1e-4)


@given(
    gk=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_mm_i32_exact(gk, seed):
    rng = np.random.default_rng(seed)
    n = m = 32
    k = gk * 16
    a = _rand(rng, (n, k), np.int32)
    b = _rand(rng, (k, m), np.int32)
    c = _rand(rng, (n, m), np.int32)
    got = mm.mm_acc(a, b, c, bn=16, bm=16, bk=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.mm_acc_ref(a, b, c)))


def test_mm_accumulate_chains_along_k():
    """Chaining two half-k tiles must equal one full-k call — the property
    the rust host scheduler relies on to split K across rounds."""
    rng = np.random.default_rng(7)
    a = _rand(rng, (32, 64), np.float32)
    b = _rand(rng, (64, 32), np.float32)
    c = jnp.zeros((32, 32), jnp.float32)
    full = mm.mm_acc(a, b, c, bn=32, bm=32, bk=32)
    half1 = mm.mm_acc(a[:, :32], b[:32, :], c, bn=32, bm=32, bk=32)
    half2 = mm.mm_acc(a[:, 32:], b[32:, :], half1, bn=32, bm=32, bk=32)
    np.testing.assert_allclose(half2, full, rtol=1e-5, atol=1e-5)


def test_mm_rejects_vmem_overflow():
    """Tiles beyond the 32 KB AIE-core budget must be refused."""
    a = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(AssertionError, match="32 KB"):
        mm.mm_acc(a, a, a, bn=128, bm=128, bk=128)


def test_mm_rejects_mismatched_inner_dims():
    a = jnp.zeros((32, 32), jnp.float32)
    b = jnp.zeros((16, 32), jnp.float32)
    c = jnp.zeros((32, 32), jnp.float32)
    with pytest.raises(AssertionError, match="inner dims"):
        mm.mm_acc(a, b, c)


def test_tile_vmem_accounting():
    # 3 × 32×32 × 4 B = 12 KB
    assert mm.tile_vmem_bytes(32, 32, 32, jnp.float32) == 12 * 1024
    assert mm.tile_vmem_bytes(32, 32, 32, jnp.int8) == 3 * 1024
