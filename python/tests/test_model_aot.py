"""L2 model composition + AOT lowering tests.

Checks (1) every VARIANT evaluates with the declared signature, (2) the
functional values match the oracles, and (3) lowering produces HLO text the
rust side can parse (HloModule header, tuple root).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _materialize(sds_list, rng):
    out = []
    for s in sds_list:
        if np.issubdtype(s.dtype, np.integer):
            out.append(jnp.asarray(rng.integers(-4, 4, size=s.shape, dtype=s.dtype)))
        else:
            out.append(jnp.asarray(rng.standard_normal(s.shape).astype(s.dtype)))
    return out


@pytest.mark.parametrize("name", list(model.VARIANTS))
def test_variant_signature_consistent(name):
    fn, argf = model.VARIANTS[name]
    ins, outs = model.variant_signature(name)
    args = argf()
    assert len(ins) == len(args)
    shaped = jax.eval_shape(fn, *args)
    assert len(outs) == len(shaped)
    for enc, s in zip(outs, shaped):
        assert tuple(enc["shape"]) == s.shape
        assert enc["dtype"] == str(np.dtype(s.dtype))


def test_mm_variant_matches_oracle():
    rng = np.random.default_rng(0)
    fn, argf = model.VARIANTS["mm_f32_128"]
    a, b, c = _materialize(argf(), rng)
    (got,) = fn(a, b, c)
    np.testing.assert_allclose(got, ref.mm_acc_ref(a, b, c), rtol=1e-4, atol=1e-3)


def test_conv_variant_matches_oracle():
    rng = np.random.default_rng(1)
    fn, argf = model.VARIANTS["conv2d_i32_64x4"]
    x, w, acc = _materialize(argf(), rng)
    (got,) = fn(x, w, acc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.conv2d_ref(x, w, acc)))


def test_fft_variant_matches_numpy():
    rng = np.random.default_rng(2)
    fn, argf = model.VARIANTS["fft1d_f32_64x256"]
    re, im = _materialize(argf(), rng)
    # the artifact expects bit-reversed-order rows (host-side permute)
    rev = ref.bit_reverse_indices(re.shape[1])
    gre, gim = fn(re[:, rev], im[:, rev])
    want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=1)
    np.testing.assert_allclose(gre, want.real, rtol=1e-3, atol=5e-3)
    np.testing.assert_allclose(gim, want.imag, rtol=1e-3, atol=5e-3)


def test_dwconv_variant_matches_oracle():
    rng = np.random.default_rng(3)
    fn, argf = model.VARIANTS["dwconv2d_f32_8x64x3"]
    x, w, acc = _materialize(argf(), rng)
    (got,) = fn(x, w, acc)
    np.testing.assert_allclose(got, ref.dwconv2d_ref(x, w, acc), rtol=1e-4, atol=1e-4)


def test_trsv_variant_matches_numpy_solve():
    rng = np.random.default_rng(4)
    fn, argf = model.VARIANTS["trsv_f32_256"]
    n = 256
    # diagonally dominant lower-triangular system
    l = rng.standard_normal((n, n)).astype(np.float32) / n
    l[np.diag_indices(n)] = 4.0 + np.abs(l[np.diag_indices(n)])
    b = rng.standard_normal(n).astype(np.float32)
    (got,) = fn(jnp.asarray(l), jnp.asarray(b))
    np.testing.assert_allclose(got, ref.trsv_ref(l, b), rtol=1e-4, atol=1e-4)
    # independent oracle: the dense solver on the lower triangle
    want = np.linalg.solve(np.tril(l).astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_stencil_variant_matches_oracle():
    rng = np.random.default_rng(5)
    fn, argf = model.VARIANTS["stencil2d_f32_2x128"]
    a, _ = _materialize(argf(), rng)
    coef = jnp.asarray([0.5, 0.125, 0.125, 0.125, 0.125], jnp.float32)
    (got,) = fn(a, coef)
    np.testing.assert_allclose(got, ref.stencil2d_ref(a, coef, 2), rtol=1e-4, atol=1e-4)


def test_lower_small_variant_to_hlo_text():
    lowered = model.lower_variant("fir_f32_4096x15")
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # return_tuple=True → root is a tuple even for a single output
    assert "tuple" in text


def test_build_writes_manifest(tmp_path):
    manifest = aot.build(str(tmp_path), names=["fir_f32_4096x15"])
    assert set(manifest) == {"fir_f32_4096x15"}
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    entry = manifest["fir_f32_4096x15"]
    assert (tmp_path / entry["hlo"]).exists()
    assert entry["inputs"][0]["shape"] == [4096 + 14]
    assert entry["outputs"][0]["shape"] == [4096]
