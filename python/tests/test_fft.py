"""FFT stage kernel + composed 1D/2D FFT vs oracles and numpy.fft."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fft, ref

SETTINGS = dict(max_examples=10, deadline=None)


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@given(
    logn=st.integers(3, 7),
    stage=st.integers(0, 6),
    bb=st.sampled_from([2, 4]),
    batches=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_fft_stage_matches_ref(logn, stage, bb, batches, seed):
    if stage >= logn:
        stage = logn - 1
    rng = np.random.default_rng(seed)
    N = 1 << logn
    B = batches * bb
    re = _rand(rng, (B, N))
    im = _rand(rng, (B, N))
    twr, twi = ref.twiddles(1 << stage)
    twr, twi = jnp.asarray(twr), jnp.asarray(twi)
    gre, gim = fft.fft_stage(re, im, twr, twi, stage=stage, bb=bb)
    wre, wim = ref.fft_stage_ref(re, im, twr, twi, stage)
    np.testing.assert_allclose(gre, wre, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gim, wim, rtol=1e-5, atol=1e-5)


@given(
    logn=st.integers(3, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_fft1d_matches_numpy(logn, seed):
    rng = np.random.default_rng(seed)
    N = 1 << logn
    re = _rand(rng, (4, N))
    im = _rand(rng, (4, N))
    gre, gim = fft.fft1d(re, im, bb=2)
    want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=1)
    np.testing.assert_allclose(gre, want.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gim, want.imag, rtol=1e-3, atol=1e-3)


def test_fft1d_oracle_matches_numpy():
    """The pure-jnp fft oracle itself is validated against numpy."""
    rng = np.random.default_rng(5)
    re = _rand(rng, (8, 128))
    im = _rand(rng, (8, 128))
    gre, gim = ref.fft1d_ref(re, im)
    want = np.fft.fft(np.asarray(re) + 1j * np.asarray(im), axis=1)
    np.testing.assert_allclose(gre, want.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gim, want.imag, rtol=1e-3, atol=1e-3)


def test_fft2d_oracle_matches_numpy():
    rng = np.random.default_rng(6)
    re = _rand(rng, (32, 32))
    im = _rand(rng, (32, 32))
    gre, gim = ref.fft2d_ref(re, im)
    want = np.fft.fft2(np.asarray(re) + 1j * np.asarray(im))
    np.testing.assert_allclose(gre, want.real, rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(gim, want.imag, rtol=1e-3, atol=2e-3)


def test_bit_reverse_is_involution():
    for n in (8, 64, 256):
        rev = ref.bit_reverse_indices(n)
        assert np.array_equal(rev[rev], np.arange(n))


def test_fft_linearity():
    """FFT(a·x) == a·FFT(x) through the Pallas stage pipeline."""
    rng = np.random.default_rng(8)
    re = _rand(rng, (2, 64))
    im = _rand(rng, (2, 64))
    r1, i1 = fft.fft1d(3.0 * re, 3.0 * im, bb=2)
    r2, i2 = fft.fft1d(re, im, bb=2)
    np.testing.assert_allclose(r1, 3.0 * np.asarray(r2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(i1, 3.0 * np.asarray(i2), rtol=1e-4, atol=1e-4)
