"""Hypothesis sweep: Pallas FIR tile kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fir, ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype):
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(-8, 8, size=shape, dtype=dtype))
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@given(
    bn=st.sampled_from([32, 64, 128]),
    chunks=st.integers(1, 4),
    taps=st.sampled_from([3, 8, 15]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_fir_f32_matches_ref(bn, chunks, taps, seed):
    rng = np.random.default_rng(seed)
    n = chunks * bn
    x = _rand(rng, (n + taps - 1,), np.float32)
    h = _rand(rng, (taps,), np.float32)
    got = fir.fir(x, h, bn=bn)
    np.testing.assert_allclose(got, ref.fir_ref(x, h), rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_fir_i32_exact(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (256 + 14,), np.int32)
    h = _rand(rng, (15,), np.int32)
    got = fir.fir(x, h, bn=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.fir_ref(x, h)))


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_fir_complex_matches_ref(seed):
    rng = np.random.default_rng(seed)
    xr = _rand(rng, (128 + 14,), np.float32)
    xi = _rand(rng, (128 + 14,), np.float32)
    hr = _rand(rng, (15,), np.float32)
    hi = _rand(rng, (15,), np.float32)
    gre, gim = fir.fir_complex(xr, xi, hr, hi, bn=64)
    wre, wim = ref.fir_complex_ref(xr, xi, hr, hi)
    np.testing.assert_allclose(gre, wre, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gim, wim, rtol=1e-4, atol=1e-4)


def test_fir_complex_against_numpy_convolve():
    """Cross-check the complex FIR against numpy's convolution."""
    rng = np.random.default_rng(9)
    n, taps = 128, 15
    x = rng.standard_normal(n + taps - 1) + 1j * rng.standard_normal(n + taps - 1)
    h = rng.standard_normal(taps) + 1j * rng.standard_normal(taps)
    gre, gim = fir.fir_complex(
        jnp.asarray(x.real, jnp.float32), jnp.asarray(x.imag, jnp.float32),
        jnp.asarray(h.real, jnp.float32), jnp.asarray(h.imag, jnp.float32), bn=64,
    )
    # y[n] = Σ_t h[t] x[n+t] == correlate(x, conj(h)) pattern
    want = np.array([np.sum(h * x[i : i + taps]) for i in range(n)])
    np.testing.assert_allclose(gre, want.real, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gim, want.imag, rtol=1e-4, atol=1e-3)


def test_fir_delta_filter_is_shift():
    rng = np.random.default_rng(11)
    x = _rand(rng, (64 + 7,), np.float32)
    h = jnp.zeros((8,), jnp.float32).at[3].set(1.0)
    got = fir.fir(x, h, bn=32)
    np.testing.assert_allclose(got, x[3 : 3 + 64], rtol=1e-6, atol=1e-6)
