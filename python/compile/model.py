"""L2: graph-level computations for the workload library — the paper's
four Table II recurrences plus the expanded catalog (depthwise conv,
triangular solve, stencil chains; see docs/WORKLOADS.md).

Each function here is the computation one *graph-level tile* performs — one
full round of the mapped AIE array — composed from the L1 Pallas kernels
(the Table II four) or written directly in jnp (the expanded-catalog
tiles, pending dedicated Pallas kernels).
``aot.py`` lowers jitted instances of these to HLO text once at build time;
the rust coordinator (L3) then drives the outer host-level loops (DRAM
tiling, k-chaining, transposes between FFT passes) against the compiled
artifacts via PJRT. Python never runs on the request path.

Variant registry: ``VARIANTS`` maps artifact names to (function,
example-argument factory) pairs; both aot.py and the pytest suite iterate
it so what is tested is exactly what is shipped.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d, fft, fir, mm


# ---------------------------------------------------------------------------
# Graph-level tile computations
# ---------------------------------------------------------------------------

def mm_tile(a, b, c, *, bn=32, bm=32, bk=32):
    """One MM graph tile: C' = C + A·B (accumulate form for k-chaining)."""
    return (mm.mm_acc(a, b, c, bn=bn, bm=bm, bk=bk),)


def conv2d_tile(x, w, acc, *, bh=32, bw=32):
    """One Conv2D graph tile over a halo-extended input block."""
    return (conv2d.conv2d_acc(x, w, acc, bh=bh, bw=bw),)


def fir_tile(x, h, *, bn=256):
    """One FIR graph tile: a contiguous chunk of output samples."""
    return (fir.fir(x, h, bn=bn),)


def fir_complex_tile(x_re, x_im, h_re, h_im, *, bn=256):
    """One complex-FIR graph tile (cfloat benchmark row)."""
    return fir.fir_complex(x_re, x_im, h_re, h_im, bn=bn)


def fft1d_tile(re, im, *, bb=8):
    """One 1D-FFT graph tile: a batch of *bit-reversed-order* rows through
    all butterfly stages.

    The 2D-FFT is two of these passes with host-side bit-reversal before
    each pass and a transpose between them (L3 owns both — on the board
    they are PL data movers).
    """
    return fft.fft_stages(re, im, bb=bb)


def dwconv2d_tile(x, w, acc):
    """One depthwise-conv graph tile: per-channel valid correlation over a
    halo-extended input block, accumulate form (``acc' = acc + dwconv``).

    x: [C, H+P-1, W+Q-1], w: [C, P, Q], acc: [C, H, W]. Plain-jnp body
    (no Pallas kernel yet): the shifted-window sum lowers to the same
    HLO shape the rust stub mirrors.
    """
    C, P, Q = w.shape
    H = x.shape[1] - P + 1
    W = x.shape[2] - Q + 1
    out = jnp.zeros((C, H, W), acc.dtype)
    for p in range(P):
        for q in range(Q):
            out = out + x[:, p : p + H, q : q + W].astype(acc.dtype) * w[:, p, q][:, None, None].astype(acc.dtype)
    return (acc + out,)


def trsv_tile(l, b):
    """One forward-substitution graph tile: x = L⁻¹ b for a lower-
    triangular diagonal block (strictly upper entries of ``l`` are
    ignored because the running solution is still zero there)."""
    n = b.shape[0]

    def body(i, x):
        s = b[i] - jnp.dot(l[i], x)
        return x.at[i].set(s / l[i, i])

    return (jax.lax.fori_loop(0, n, body, jnp.zeros_like(b)),)


def ca_mm_reduce_tile(parts):
    """One CA-MM reduction graph tile: replica partial-C tiles summed in
    ascending slab order (the replication-axis merge of the 2.5D
    communication-avoiding schedule; see docs/CA_VARIANTS.md).

    parts: [rep, N, M]. The fold order matters — the rust stub and the
    ``verify::ca_mm_ref`` oracle reduce in the same slab order, so the
    replay path is bit-identical across backends.
    """
    out = parts[0]
    for r in range(1, parts.shape[0]):
        out = out + parts[r]
    return (out,)


def seidel2d_tile(a, coef, *, stages=2):
    """``stages`` Gauss–Seidel-style sweeps with zero boundary: rows are
    updated bottom-up in place, so the south neighbour is this sweep's
    fresh value while the remaining reads come from the previous sweep;
    coef = [centre, south_new, south_old, west, east]."""
    n = a.shape[0]
    for _ in range(stages):
        prev = a
        for i in range(n - 1, -1, -1):
            row = coef[0] * prev[i]
            if i + 1 < n:
                row = row + coef[1] * a[i + 1] + coef[2] * prev[i + 1]
            row = row + coef[3] * jnp.pad(prev[i, :-1], (1, 0))
            row = row + coef[4] * jnp.pad(prev[i, 1:], (0, 1))
            a = a.at[i].set(row)
    return (a,)


def stencil2d_tile(a, coef, *, stages=2):
    """``stages`` 5-point Jacobi sweeps over a grid tile with zero
    boundary; coef = [centre, north, south, west, east]."""

    def sweep(g):
        north = jnp.pad(g[:-1, :], ((1, 0), (0, 0)))  # g[i-1, j]
        south = jnp.pad(g[1:, :], ((0, 1), (0, 0)))   # g[i+1, j]
        west = jnp.pad(g[:, :-1], ((0, 0), (1, 0)))   # g[i, j-1]
        east = jnp.pad(g[:, 1:], ((0, 0), (0, 1)))    # g[i, j+1]
        return coef[0] * g + coef[1] * north + coef[2] * south + coef[3] * west + coef[4] * east

    for _ in range(stages):
        a = sweep(a)
    return (a,)


# ---------------------------------------------------------------------------
# Artifact variants (name → builder); shapes are the graph-tile sizes the
# rust executor schedules over. Tile sizes respect the 32 KB/core budget.
# ---------------------------------------------------------------------------

def _mm_args(n, m, k, dtype):
    return (
        jax.ShapeDtypeStruct((n, k), dtype),
        jax.ShapeDtypeStruct((k, m), dtype),
        jax.ShapeDtypeStruct((n, m), dtype),
    )


def _conv_args(h, w, p, q, dtype):
    return (
        jax.ShapeDtypeStruct((h + p - 1, w + q - 1), dtype),
        jax.ShapeDtypeStruct((p, q), dtype),
        jax.ShapeDtypeStruct((h, w), dtype),
    )


def _fir_args(n, taps, dtype):
    return (
        jax.ShapeDtypeStruct((n + taps - 1,), dtype),
        jax.ShapeDtypeStruct((taps,), dtype),
    )


def _fir_c_args(n, taps, dtype):
    x = jax.ShapeDtypeStruct((n + taps - 1,), dtype)
    h = jax.ShapeDtypeStruct((taps,), dtype)
    return (x, x, h, h)


def _fft_args(b, n, dtype):
    s = jax.ShapeDtypeStruct((b, n), dtype)
    return (s, s)


def _dwconv_args(c, h, w, p, q, dtype):
    return (
        jax.ShapeDtypeStruct((c, h + p - 1, w + q - 1), dtype),
        jax.ShapeDtypeStruct((c, p, q), dtype),
        jax.ShapeDtypeStruct((c, h, w), dtype),
    )


def _trsv_args(n, dtype):
    return (
        jax.ShapeDtypeStruct((n, n), dtype),
        jax.ShapeDtypeStruct((n,), dtype),
    )


def _stencil_args(stages, n, m, dtype):
    del stages  # baked into the variant's sweep count, not its shapes
    return (
        jax.ShapeDtypeStruct((n, m), dtype),
        jax.ShapeDtypeStruct((5,), dtype),
    )


def _ca_reduce_args(rep, n, m, dtype):
    return (jax.ShapeDtypeStruct((rep, n, m), dtype),)


def _seidel_args(stages, n, m, dtype):
    del stages  # baked into the variant's sweep count, not its shapes
    return (
        jax.ShapeDtypeStruct((n, m), dtype),
        jax.ShapeDtypeStruct((5,), dtype),
    )


VARIANTS = {
    # MM graph tiles: 256³ macro-tile of 32³ core tiles (f32 functional
    # path) and an i32 variant for the integer benchmark rows. A smaller
    # 128³ variant keeps quickstart latency low.
    "mm_f32_256": (functools.partial(mm_tile, bn=32, bm=32, bk=32), lambda: _mm_args(256, 256, 256, jnp.float32)),
    "mm_f32_128": (functools.partial(mm_tile, bn=32, bm=32, bk=32), lambda: _mm_args(128, 128, 128, jnp.float32)),
    "mm_i32_128": (functools.partial(mm_tile, bn=32, bm=32, bk=32), lambda: _mm_args(128, 128, 128, jnp.int32)),
    # Conv2D graph tile: 128×128 output, 4×4 kernel (Table II fp32 shape).
    "conv2d_f32_128x4": (functools.partial(conv2d_tile, bh=32, bw=32), lambda: _conv_args(128, 128, 4, 4, jnp.float32)),
    "conv2d_i32_64x4": (functools.partial(conv2d_tile, bh=32, bw=32), lambda: _conv_args(64, 64, 4, 4, jnp.int32)),
    # FIR graph tile: 4096 samples, 15 taps (Table II tap count).
    "fir_f32_4096x15": (functools.partial(fir_tile, bn=256), lambda: _fir_args(4096, 15, jnp.float32)),
    "fir_cf32_2048x15": (functools.partial(fir_complex_tile, bn=256), lambda: _fir_c_args(2048, 15, jnp.float32)),
    # FFT graph tile: 64 rows of length-256 FFTs (re/im planes).
    "fft1d_f32_64x256": (functools.partial(fft1d_tile, bb=8), lambda: _fft_args(64, 256, jnp.float32)),
    # Depthwise-conv graph tile: 8 channel groups, 64×64 output, 3×3 kernels.
    "dwconv2d_f32_8x64x3": (dwconv2d_tile, lambda: _dwconv_args(8, 64, 64, 3, 3, jnp.float32)),
    # Triangular-solve graph tile: one 256-row forward-substitution block.
    "trsv_f32_256": (trsv_tile, lambda: _trsv_args(256, jnp.float32)),
    # Stencil-chain graph tile: 2 Jacobi sweeps over a 128×128 grid.
    "stencil2d_f32_2x128": (functools.partial(stencil2d_tile, stages=2), lambda: _stencil_args(2, 128, 128, jnp.float32)),
    # CA-MM reduction graph tile: 4 replica partials of a 128×128 C tile.
    "ca_mm_f32_4x128": (ca_mm_reduce_tile, lambda: _ca_reduce_args(4, 128, 128, jnp.float32)),
    # Gauss–Seidel sweep-chain graph tile: 2 sweeps over a 64×64 grid.
    "seidel2d_f32_2x64": (functools.partial(seidel2d_tile, stages=2), lambda: _seidel_args(2, 64, 64, jnp.float32)),
}


def lower_variant(name):
    """jax.jit(...).lower(...) one variant; returns the Lowered object."""
    fn, argf = VARIANTS[name]
    return jax.jit(fn).lower(*argf())


def variant_signature(name):
    """(input shapes/dtypes, output shapes/dtypes) for the manifest."""
    fn, argf = VARIANTS[name]
    args = argf()
    outs = jax.eval_shape(fn, *args)
    def enc(s):
        return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}
    return [enc(a) for a in args], [enc(o) for o in outs]
