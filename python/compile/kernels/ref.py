"""Pure-jnp oracles for the L1 Pallas kernels.

Each function here is the *definition of correctness* for the matching
kernel in this package: pytest (python/tests/) asserts allclose between the
Pallas kernel (interpret=True) and these references across hypothesis-swept
shapes and dtypes, and the rust-side functional executor is validated
against the same semantics (coordinator/verify.rs re-implements them on the
host side).

The computations are the library's uniform recurrences: the paper's
Table II four — matrix multiplication, 2D convolution, FIR filtering, and
the radix-2 FFT stage that 2D-FFT decomposes into — plus the expanded
catalog's depthwise convolution, triangular solve and 5-point stencil
chain (see docs/WORKLOADS.md).
"""

import jax.numpy as jnp
import numpy as np


def mm_acc_ref(a, b, c):
    """C' = C + A @ B — one graph-level MM tile with accumulation.

    The accumulate form is what the systolic cascade computes: the k-loop
    carried partial sums enter as ``c`` and leave as the return value, so
    the host scheduler can chain tiles along k.
    """
    return c + jnp.matmul(a, b, preferred_element_type=c.dtype).astype(c.dtype)


def conv2d_ref(x, w, acc):
    """acc' = acc + valid 2D correlation of x with w.

    x: [H + P - 1, W + Q - 1], w: [P, Q] → out [H, W] with
    y[h, w] = Σ_{p,q} x[h+p, w+q] · k[p, q]  (the paper's uniform
    recurrence over [h, w, p, q]).
    """
    P, Q = w.shape
    H = x.shape[0] - P + 1
    W = x.shape[1] - Q + 1
    out = jnp.zeros((H, W), dtype=acc.dtype)
    for p in range(P):
        for q in range(Q):
            out = out + x[p : p + H, q : q + W].astype(acc.dtype) * w[p, q].astype(acc.dtype)
    return acc + out


def fir_ref(x, h):
    """y[n] = Σ_t h[t] · x[n + t] for n in [0, N) with len(x) = N + T - 1."""
    T = h.shape[0]
    N = x.shape[0] - T + 1
    y = jnp.zeros((N,), dtype=jnp.promote_types(x.dtype, h.dtype))
    for t in range(T):
        y = y + h[t].astype(y.dtype) * x[t : t + N].astype(y.dtype)
    return y


def fir_complex_ref(x_re, x_im, h_re, h_im):
    """Complex FIR as four real FIRs (cfloat benchmark row)."""
    rr = fir_ref(x_re, h_re)
    ii = fir_ref(x_im, h_im)
    ri = fir_ref(x_re, h_im)
    ir = fir_ref(x_im, h_re)
    return rr - ii, ri + ir


def fft_stage_ref(re, im, tw_re, tw_im, stage):
    """One radix-2 DIT butterfly stage on batched length-N signals.

    re/im: [B, N]; stage s has butterfly half-size m = 2**s; tw_*: [m]
    (twiddles W_{2m}^j = exp(-2πi·j/(2m)) for j in [0, m)).
    Inputs are in bit-reversed order before stage 0
    (see ``bit_reverse_indices``).
    """
    B, N = re.shape
    m = 1 << stage
    g = N // (2 * m)
    re3 = re.reshape(B, g, 2, m)
    im3 = im.reshape(B, g, 2, m)
    a_re, a_im = re3[:, :, 0, :], im3[:, :, 0, :]
    b_re, b_im = re3[:, :, 1, :], im3[:, :, 1, :]
    # b · tw (complex multiply)
    bt_re = b_re * tw_re - b_im * tw_im
    bt_im = b_re * tw_im + b_im * tw_re
    out_re = jnp.stack([a_re + bt_re, a_re - bt_re], axis=2).reshape(B, N)
    out_im = jnp.stack([a_im + bt_im, a_im - bt_im], axis=2).reshape(B, N)
    return out_re, out_im


def bit_reverse_indices(n):
    """Bit-reversal permutation for a power-of-two n."""
    bits = int(np.log2(n))
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int32)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def twiddles(m):
    """W_{2m}^j for j in [0, m) as (re, im) float32 arrays."""
    j = np.arange(m)
    ang = -2.0 * np.pi * j / (2 * m)
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def fft1d_ref(re, im):
    """Full batched radix-2 DIT FFT built from fft_stage_ref (oracle for
    the L2 composition). re/im: [B, N]."""
    B, N = re.shape
    rev = bit_reverse_indices(N)
    re = re[:, rev]
    im = im[:, rev]
    stages = int(np.log2(N))
    for s in range(stages):
        tw_re, tw_im = twiddles(1 << s)
        re, im = fft_stage_ref(re, im, jnp.asarray(tw_re), jnp.asarray(tw_im), s)
    return re, im


def dwconv2d_ref(x, w, acc):
    """acc' = acc + per-channel valid 2D correlation (depthwise conv).

    x: [C, H+P-1, W+Q-1], w: [C, P, Q] → out [C, H, W]; one independent
    filter per channel group — the channel loop carries no reduction.
    """
    C, P, Q = w.shape
    H = x.shape[1] - P + 1
    W = x.shape[2] - Q + 1
    out = jnp.zeros((C, H, W), dtype=acc.dtype)
    for p in range(P):
        for q in range(Q):
            out = out + x[:, p : p + H, q : q + W].astype(acc.dtype) * w[:, p, q][:, None, None].astype(acc.dtype)
    return acc + out


def trsv_ref(l, b):
    """Forward substitution x = L⁻¹ b (numpy loop; the strictly upper
    part of ``l`` is ignored)."""
    l = np.asarray(l, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = b.shape[0]
    x = np.zeros(n)
    for i in range(n):
        x[i] = (b[i] - l[i, :i] @ x[:i]) / l[i, i]
    return x.astype(np.float32)


def stencil2d_ref(a, coef, stages):
    """``stages`` Jacobi sweeps of the 5-point stencil, zero boundary;
    coef = [centre, north, south, west, east]."""
    a = np.asarray(a, dtype=np.float32)
    coef = np.asarray(coef, dtype=np.float32)
    for _ in range(stages):
        out = coef[0] * a
        out[1:, :] += coef[1] * a[:-1, :]
        out[:-1, :] += coef[2] * a[1:, :]
        out[:, 1:] += coef[3] * a[:, :-1]
        out[:, :-1] += coef[4] * a[:, 1:]
        a = out
    return a


def fft2d_ref(re, im):
    """2D FFT = row FFTs, transpose, row FFTs, transpose (the paper's
    2D-FFT decomposition into two 1D passes)."""
    re, im = fft1d_ref(re, im)
    re, im = fft1d_ref(re.T, im.T)
    return re.T, im.T
