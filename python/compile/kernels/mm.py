"""L1 Pallas kernel: the AIE-core matrix-multiply tile.

Hardware adaptation (DESIGN.md §2): on the real VCK5000 one AIE core runs a
vectorised MAC kernel over an (N2, M2, K2) tile staged into its 32 KB local
memory by the DMA cascade; neighbouring cores pass A/B operands through
shared buffers along the systolic dimensions. Here the same dataflow is
expressed as a Pallas grid: the (i, j) grid dimensions are the *space*
loops (one grid point = one AIE core's tile), the k grid dimension is the
*time* loop carried by the cascade, and the BlockSpecs are the HBM↔VMEM
staging schedule that the paper implements with DMA movers on the PL.

The inner contraction is an MXU-shaped ``jnp.dot`` so a real-TPU lowering
would hit the systolic matmul unit; on this image the kernel is lowered
with interpret=True (CPU PJRT cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# AIE-equivalent local-memory budget per core: 32 KB data memory.
AIE_LOCAL_MEM_BYTES = 32 * 1024


def tile_vmem_bytes(bn, bm, bk, dtype):
    """Working-set bytes of one grid step (A, B and C tiles resident)."""
    item = jnp.dtype(dtype).itemsize
    return (bn * bk + bk * bm + bn * bm) * item


def _mm_kernel(a_ref, b_ref, c_ref, o_ref):
    """One (space, time) grid step: o = (k == 0 ? c : o) + a @ b."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bk"))
def mm_acc(a, b, c, *, bn=32, bm=32, bk=32):
    """C' = C + A @ B over a Pallas grid of (bn, bm, bk) tiles.

    a: [N, K], b: [K, M], c: [N, M]; N/M/K must divide by the block sizes.
    This is the graph-level tile one full AIE-array round computes; the
    grid interior corresponds to the per-core space-time schedule.
    """
    n, k = a.shape
    k2, m = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert c.shape == (n, m)
    assert n % bn == 0 and m % bm == 0 and k % bk == 0, (
        f"({n},{m},{k}) not divisible by blocks ({bn},{bm},{bk})"
    )
    assert tile_vmem_bytes(bn, bm, bk, c.dtype) <= AIE_LOCAL_MEM_BYTES, (
        "tile working set exceeds the 32 KB AIE-core budget"
    )
    grid = (n // bn, m // bm, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), c.dtype),
        interpret=True,
    )(a, b, c)
