# L1: Pallas kernels for the paper's compute hot-spots (AIE-core tiles).
# Each kernel has a pure-jnp oracle in ref.py; pytest asserts equivalence.
from . import conv2d, fft, fir, mm, ref  # noqa: F401
