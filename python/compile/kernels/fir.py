"""L1 Pallas kernel: the AIE-core FIR-filter tile.

The FIR recurrence (Table II: n = 1048576, taps = 15) iterates [n, t] with
uniform dependences. WideSA maps blocks of output samples onto AIE cores
(1D systolic arrangement with the multiple-threading transform of
§III-B-4); each core computes a contiguous chunk of y with the tap loop
fully unrolled into VLIW MACs. The Pallas grid mirrors that: one grid step
per output chunk, taps unrolled, the chunk's (bn + T - 1)-sample input
window read with dynamic loads (the same shifted-window pattern as the
conv kernel — FIR is its 1D special case).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fir_kernel(T, bn, x_ref, h_ref, o_ref):
    """One output chunk: y[i·bn + s] = Σ_t h[t] · x[i·bn + s + t]."""
    i = pl.program_id(0)
    out = jnp.zeros((bn,), dtype=o_ref.dtype)
    for t in range(T):
        blk = x_ref[pl.dslice(i * bn + t, bn)]
        out = out + h_ref[t].astype(out.dtype) * blk.astype(out.dtype)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("bn",))
def fir(x, h, *, bn=256):
    """y[n] = Σ_t h[t] · x[n + t]; x: [N + T - 1], h: [T], y: [N], N % bn == 0."""
    T = h.shape[0]
    N = x.shape[0] - T + 1
    assert N % bn == 0, f"N={N} not divisible by bn={bn}"
    dtype = jnp.promote_types(x.dtype, h.dtype)
    grid = (N // bn,)
    kernel = functools.partial(_fir_kernel, T, bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,)),
            pl.BlockSpec(h.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), dtype),
        interpret=True,
    )(x, h)


@functools.partial(jax.jit, static_argnames=("bn",))
def fir_complex(x_re, x_im, h_re, h_im, *, bn=256):
    """Complex FIR (cfloat row of Table II/III) via four real FIR kernels.

    (xr + i·xi) ⊛ (hr + i·hi) = (xr⊛hr − xi⊛hi) + i·(xr⊛hi + xi⊛hr)
    """
    rr = fir(x_re, h_re, bn=bn)
    ii = fir(x_im, h_im, bn=bn)
    ri = fir(x_re, h_im, bn=bn)
    ir = fir(x_im, h_re, bn=bn)
    return rr - ii, ri + ir
