"""L1 Pallas kernel: the AIE-core 2D-convolution tile.

The paper's 2D-Conv recurrence iterates [h, w, p, q] with uniform
dependences; WideSA maps (h, w) tiles onto the AIE array and keeps the
small (p, q) kernel loops inside each core, fully unrolled into the VLIW
schedule. Here the (h, w) tile grid is the Pallas grid and the (p, q)
loops are unrolled in the kernel body — shifted multiply-accumulates over
a halo-extended input, which is exactly the AIE intrinsic pattern (vector
MAC + sliding-window reads from local memory).

Halo handling: each (h, w) tile needs a (bh+P-1, bw+Q-1) input window that
*overlaps* its neighbours — the halo exchange the PL DMA movers implement
on the board. Pallas blocks are non-overlapping, so the window is read
with dynamic loads (``pl.load`` + ``pl.dslice``) from the resident input,
offset by the grid position; the graph-level tile is sized so the input
stays within the AIE-array aggregate buffer budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(P, Q, bh, bw, x_ref, w_ref, acc_ref, o_ref):
    """One (h, w) tile: o = acc + Σ_{p,q} x[h+p, w+q] · k[p,q] (p,q unrolled)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    out = acc_ref[...]
    for p in range(P):
        for q in range(Q):
            blk = x_ref[pl.dslice(i * bh + p, bh), pl.dslice(j * bw + q, bw)]
            out = out + blk.astype(out.dtype) * w_ref[p, q].astype(out.dtype)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("bh", "bw"))
def conv2d_acc(x, w, acc, *, bh=32, bw=32):
    """acc' = acc + conv2d_valid(x, w) over a Pallas grid of (bh, bw) tiles.

    x: [H + P - 1, W + Q - 1] halo-extended input, w: [P, Q],
    acc: [H, W]; H % bh == 0 and W % bw == 0.
    """
    P, Q = w.shape
    H = x.shape[0] - P + 1
    W = x.shape[1] - Q + 1
    assert acc.shape == (H, W), f"acc shape {acc.shape} != {(H, W)}"
    assert H % bh == 0 and W % bw == 0

    grid = (H // bh, W // bw)
    kernel = functools.partial(_conv_kernel, P, Q, bh, bw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(w.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((H, W), acc.dtype),
        interpret=True,
    )(x, w, acc)
