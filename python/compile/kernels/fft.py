"""L1 Pallas kernel: one radix-2 DIT butterfly stage (2D-FFT building block).

The paper's 2D-FFT benchmark (8192×8192 cfloat/cint16) decomposes into row
FFTs + transpose + row FFTs; each 1D FFT is log2(N) butterfly stages, and
WideSA maps batches of rows across AIE cores with stages pipelined through
the array. Complex data is carried as separate re/im planes (the AIE
vector units do the same: cfloat ops are issued as real MAC pairs, and the
PJRT CPU literal path in the rust runtime is real-typed).

One Pallas grid step = one batch-block of rows through one stage: reshape
the row into (groups, 2, m) butterflies, complex-multiply the odd half by
the stage twiddles, add/subtract. Stage index and twiddles are baked at
trace time (the AIE kernel equally bakes them into its program).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref


def _stage_kernel(stage, re_ref, im_ref, twr_ref, twi_ref, ore_ref, oim_ref):
    """Butterfly stage on a [bb, N] block of rows.

    Kept rank ≤ 2: flatten the (row, group) axes together and slice the
    even/odd butterfly halves, so the lowered HLO is plain slice /
    multiply / concatenate — ops the old xla_extension 0.5.1 runtime
    executes faithfully (its rank-4 stack/reshape path does not).
    """
    bb, N = re_ref.shape
    m = 1 << stage
    g = N // (2 * m)
    x_re = re_ref[...].reshape(bb * g, 2 * m)
    x_im = im_ref[...].reshape(bb * g, 2 * m)
    a_re, b_re = x_re[:, :m], x_re[:, m:]
    a_im, b_im = x_im[:, :m], x_im[:, m:]
    twr = twr_ref[...]
    twi = twi_ref[...]
    bt_re = b_re * twr - b_im * twi
    bt_im = b_re * twi + b_im * twr
    ore_ref[...] = jnp.concatenate([a_re + bt_re, a_re - bt_re], axis=1).reshape(bb, N)
    oim_ref[...] = jnp.concatenate([a_im + bt_im, a_im - bt_im], axis=1).reshape(bb, N)


@functools.partial(jax.jit, static_argnames=("stage", "bb"))
def fft_stage(re, im, tw_re, tw_im, *, stage, bb=8):
    """One butterfly stage over batched rows. re/im: [B, N], tw: [2**stage]."""
    B, N = re.shape
    assert B % bb == 0
    m = 1 << stage
    assert tw_re.shape == (m,) and tw_im.shape == (m,)
    assert N % (2 * m) == 0

    grid = (B // bb,)
    kernel = functools.partial(_stage_kernel, stage)
    out_sds = jax.ShapeDtypeStruct((B, N), re.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, N), lambda i: (i, 0)),
            pl.BlockSpec((bb, N), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, N), lambda i: (i, 0)),
            pl.BlockSpec((bb, N), lambda i: (i, 0)),
        ],
        out_shape=[out_sds, out_sds],
        interpret=True,
    )(re, im, tw_re, tw_im)


def bit_reverse_permute(x):
    """Bit-reversal permutation along axis 1 without gathers or high-rank
    transposes.

    The xla_extension 0.5.1 CPU runtime the rust side links against
    silently mis-executes both the gather that ``jnp.take`` lowers to and
    transposes of rank > 8, so the permutation is expressed as two
    one-hot permutation matmuls: split the k index bits as k1 + k2, then
    rev_k(h·2^k2 + l) = rev_k2(l)·2^k1 + rev_k1(h), i.e. a (B, 2^k1,
    2^k2) axis swap with per-axis 4-bit-style reversals applied as exact
    0/1 matrix products (rank ≤ 3 throughout).
    """
    B, N = x.shape
    k = N.bit_length() - 1
    k1 = k // 2
    k2 = k - k1
    p1 = jnp.asarray(
        np.eye(1 << k1, dtype=np.float32)[ref.bit_reverse_indices(1 << k1)]
    )
    p2 = jnp.asarray(
        np.eye(1 << k2, dtype=np.float32)[ref.bit_reverse_indices(1 << k2)]
    )
    x3 = x.reshape(B, 1 << k1, 1 << k2)
    x1 = jnp.transpose(x3, (0, 2, 1))  # [b, l, h]
    # z[b, p, q] = Σ_{l,h} P2[p, l] · x1[b, l, h] · P1[q, h]
    z = jnp.einsum("pl,blh,qh->bpq", p2, x1.astype(jnp.float32), p1)
    return z.reshape(B, N).astype(x.dtype)


def fft_stages(re, im, *, bb=8):
    """All butterfly stages over *bit-reversed-order* rows.

    This is the AOT-artifact entry point: the bit-reversal permutation is
    pure data movement that the PL data mover performs while staging rows
    into the array on the real board, so the host (rust) side applies it
    — keeping the artifact free of the gather/batched-dot ops the old
    xla_extension 0.5.1 runtime mis-executes (see bit_reverse_permute).
    """
    B, N = re.shape
    stages = N.bit_length() - 1
    for s in range(stages):
        twr, twi = ref.twiddles(1 << s)
        re, im = fft_stage(re, im, jnp.asarray(twr), jnp.asarray(twi), stage=s, bb=bb)
    return re, im


def fft1d(re, im, *, bb=8):
    """Full batched 1D FFT: bit-reversal + staged L1 kernels."""
    re = bit_reverse_permute(re)
    im = bit_reverse_permute(im)
    return fft_stages(re, im, bb=bb)
