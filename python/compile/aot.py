"""AOT compile path: lower every L2 variant to HLO *text* + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts relative to this package):
  <name>.hlo.txt    one per VARIANTS entry
  manifest.json     name → {hlo file, inputs, outputs} consumed by
                    rust/src/runtime/artifact.rs

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import json
import os
import sys

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always unwraps a tuple, regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default HLO printer
    # elides literals of ≥16 elements as `constant({...})`, which the
    # rust side's HLO text parser silently reads back as ZEROS (we found
    # this as vanished FFT twiddles — see EXPERIMENTS.md §Gotchas).
    return comp.as_hlo_text(print_large_constants=True)


def build(out_dir: str, names=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    names = names or list(model.VARIANTS)
    for name in names:
        lowered = model.lower_variant(name)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        inputs, outputs = model.variant_signature(name)
        manifest[name] = {"hlo": fname, "inputs": inputs, "outputs": outputs}
        print(f"  lowered {name:24s} -> {fname} ({len(text)} chars)", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower WideSA L2 variants to HLO text")
    ap.add_argument("--out", default=None,
                    help="(Makefile marker) path; its parent dir is the artifact dir")
    ap.add_argument("--out-dir", default=None, help="artifact output directory")
    ap.add_argument("--only", nargs="*", default=None, help="subset of variant names")
    args = ap.parse_args()
    out_dir = args.out_dir or (os.path.dirname(args.out) if args.out else "../artifacts")
    manifest = build(out_dir, args.only)
    # Marker file for the Makefile dependency rule.
    if args.out:
        with open(args.out, "w") as f:
            f.write(json.dumps(sorted(manifest)) + "\n")
    print(f"wrote {len(manifest)} artifacts to {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
