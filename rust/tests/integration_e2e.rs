//! Integration: the full three-layer stack — map with L3, replay through
//! the L1/L2 AOT kernels via PJRT, verify against host oracles. This is
//! the automated version of `examples/mm_e2e.rs`.

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::coordinator::{exec, verify};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};
use widesa::runtime::artifact::Manifest;
use widesa::runtime::client::Runtime;
use widesa::util::rng::XorShift64;

fn runtime() -> Option<Runtime> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new().unwrap())
}

#[test]
fn mm_map_and_replay_agree() {
    let Some(mut rt) = runtime() else { return };
    let n = 256usize;
    // L3 mapping of the same (small) problem
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
        ..Default::default()
    });
    let d = ws
        .compile(&library::mm(n as u64, n as u64, n as u64, DType::F32))
        .unwrap();
    assert!(d.compile.success);

    // functional replay
    let mut rng = XorShift64::new(31);
    let mut a = vec![0f32; n * n];
    let mut b = vec![0f32; n * n];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let (c, stats) = exec::run_mm(&mut rt, &a, &b, n, n, n).unwrap();
    assert!(stats.rounds > 0);
    let want = verify::mm_ref(&a, &b, &vec![0.0; n * n], n, n, n);
    assert!(verify::max_abs_diff(&c, &want) < 1e-2);
}

#[test]
fn conv_pipeline_replay() {
    let Some(mut rt) = runtime() else { return };
    const H: usize = 128;
    const W: usize = 128;
    let mut rng = XorShift64::new(37);
    let mut x = vec![0f32; (H + 3) * (W + 3)];
    let mut k = vec![0f32; 16];
    rng.fill_f32(&mut x);
    rng.fill_f32(&mut k);
    let (y, _) = exec::run_conv2d(&mut rt, &x, &k, H, W).unwrap();
    let want = verify::conv2d_ref(&x, &k, H, W, 4, 4);
    assert!(verify::max_abs_diff(&y, &want) < 1e-3);
}

#[test]
fn fft2d_replay_matches_oracle() {
    let Some(mut rt) = runtime() else { return };
    let (rows, cols) = (256usize, 256usize);
    let mut rng = XorShift64::new(41);
    let mut re = vec![0f32; rows * cols];
    let mut im = vec![0f32; rows * cols];
    rng.fill_f32(&mut re);
    rng.fill_f32(&mut im);
    let (gre, gim, stats) = exec::run_fft2d(&mut rt, &re, &im, rows, cols).unwrap();
    assert_eq!(stats.rounds, 2 * (rows / 64) as u64);
    let mut wre = re.clone();
    let mut wim = im.clone();
    verify::fft2d_ref(&mut wre, &mut wim, rows, cols);
    // FFT magnitudes grow with N; compare with a relative-ish tolerance
    let scale = wre.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    let er = verify::max_abs_diff(&gre, &wre) / scale;
    let ei = verify::max_abs_diff(&gim, &wim) / scale;
    assert!(er < 1e-3 && ei < 1e-3, "relative errors {er} / {ei}");
}

#[test]
fn fir_replay_long_signal() {
    let Some(mut rt) = runtime() else { return };
    let n = 16384usize;
    let mut rng = XorShift64::new(43);
    let mut x = vec![0f32; n + 14];
    let mut h = vec![0f32; 15];
    rng.fill_f32(&mut x);
    rng.fill_f32(&mut h);
    let (y, stats) = exec::run_fir(&mut rt, &x, &h, n).unwrap();
    assert_eq!(stats.rounds, (n / 4096) as u64);
    let want = verify::fir_ref(&x, &h, n);
    assert!(verify::max_abs_diff(&y, &want) < 1e-3);
}
