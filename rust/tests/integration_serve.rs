//! Integration tests for the `widesa::serve` subsystem: cache behaviour,
//! single-flight deduplication under concurrent requests, determinism of
//! the parallel DSE against the serial reference, and protocol
//! round-trips through the real service.

use std::sync::Arc;
use widesa::mapping::dse::{explore_all, explore_all_parallel, DseConstraints};
use widesa::recurrence::library;
use widesa::serve::cache::design_key;
use widesa::serve::{CacheOutcome, ServeConfig, ServeHandle};
use widesa::util::json::{parse, Json};
use widesa::{DType, DseConstraints as Cons, WideSaConfig};

fn capped(max_aies: u64) -> WideSaConfig {
    WideSaConfig {
        constraints: Cons {
            max_aies: Some(max_aies),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn small_handle() -> ServeHandle {
    ServeHandle::new(ServeConfig {
        base: capped(64),
        cache_capacity: 16,
        cache_shards: 4,
        dse_threads: 4,
        request_workers: 4,
    })
}

#[test]
fn cache_hit_returns_identical_design() {
    let handle = small_handle();
    let rec = library::mm(1024, 1024, 1024, DType::F32);
    let miss = handle.compile(&rec).unwrap();
    assert_eq!(miss.outcome, CacheOutcome::Miss);
    let hit = handle.compile(&rec).unwrap();
    assert_eq!(hit.outcome, CacheOutcome::Hit);
    assert!(Arc::ptr_eq(&miss.design, &hit.design));
    assert_eq!(miss.key, hit.key);
    // and the key matches the standalone derivation
    assert_eq!(miss.key, design_key(&rec, &capped(64)));
}

#[test]
fn different_configs_get_different_cache_entries() {
    let handle = small_handle();
    let rec = library::fir(65536, 15, DType::F32);
    let a = handle.compile_with(&rec, &capped(32)).unwrap();
    let b = handle.compile_with(&rec, &capped(64)).unwrap();
    assert_ne!(a.key, b.key);
    assert_eq!(a.outcome, CacheOutcome::Miss);
    assert_eq!(b.outcome, CacheOutcome::Miss);
    assert!(!Arc::ptr_eq(&a.design, &b.design));
    // both now cached
    assert_eq!(
        handle.compile_with(&rec, &capped(32)).unwrap().outcome,
        CacheOutcome::Hit
    );
    assert_eq!(
        handle.compile_with(&rec, &capped(64)).unwrap().outcome,
        CacheOutcome::Hit
    );
}

#[test]
fn cache_eviction_recompiles_evicted_key() {
    // capacity 1 × 1 shard: the second distinct design evicts the first
    let handle = ServeHandle::new(ServeConfig {
        base: capped(32),
        cache_capacity: 1,
        cache_shards: 1,
        dse_threads: 2,
        request_workers: 2,
    });
    let rec_a = library::fir(65536, 15, DType::F32);
    let rec_b = library::fir(131072, 15, DType::F32);
    assert_eq!(handle.compile(&rec_a).unwrap().outcome, CacheOutcome::Miss);
    assert_eq!(handle.compile(&rec_b).unwrap().outcome, CacheOutcome::Miss);
    // rec_a was evicted: compiling it again is a miss, rec_b stays hot
    assert_eq!(handle.compile(&rec_a).unwrap().outcome, CacheOutcome::Miss);
    let stats = handle.stats();
    assert_eq!(stats.misses, 3);
    assert!(stats.cache.evictions >= 2);
}

#[test]
fn single_flight_dedups_concurrent_identical_requests() {
    let handle = small_handle();
    let rec = library::mm(1024, 1024, 1024, DType::I16);
    const N: usize = 8;
    let results: Vec<_> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..N {
            let handle = handle.clone();
            let rec = rec.clone();
            joins.push(s.spawn(move || handle.compile(&rec).unwrap()));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    // exactly one thread compiled; everyone shares that one design
    let stats = handle.stats();
    assert_eq!(stats.misses, 1, "single-flight must compile once");
    assert_eq!(stats.hits + stats.deduped, (N - 1) as u64);
    for r in &results {
        assert!(Arc::ptr_eq(&results[0].design, &r.design));
        assert_eq!(r.key, results[0].key);
    }
    assert_eq!(
        results.iter().filter(|r| r.outcome == CacheOutcome::Miss).count(),
        1
    );
}

#[test]
fn parallel_dse_matches_serial_on_all_library_recurrences() {
    // Acceptance criterion: identical winning candidate (and in fact the
    // identical full ranking) on every Table II recurrence.
    let cfg = WideSaConfig::default();
    let cons = DseConstraints::default();
    for rec in library::table2_benchmarks() {
        let serial = explore_all(&rec, &cfg.board, &cons);
        let parallel = explore_all_parallel(&rec, &cfg.board, &cons, 4);
        assert_eq!(serial.len(), parallel.len(), "{}", rec.name);
        assert!(!serial.is_empty(), "{}: no candidates", rec.name);
        let (sw, se) = &serial[0];
        let (pw, pe) = &parallel[0];
        assert_eq!(sw.summary(), pw.summary(), "{}: winner differs", rec.name);
        assert_eq!(
            se.tops.to_bits(),
            pe.tops.to_bits(),
            "{}: winner estimate differs",
            rec.name
        );
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.0.summary(), p.0.summary(), "{}: ranking differs", rec.name);
        }
    }
}

#[test]
fn protocol_round_trip_through_service() {
    let handle = small_handle();
    let line = r#"{"id": 42, "bench": "mm", "dtype": "f32", "dims": [1024, 1024, 1024], "max_aies": 64}"#;
    let resp = handle.handle_line(line);
    let v = parse(&resp).expect("response is valid JSON");
    assert_eq!(v.get("id").unwrap().as_f64(), Some(42.0));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("cached").unwrap().as_bool(), Some(false));
    assert_eq!(
        v.get("name").unwrap().as_str(),
        Some("mm_1024x1024x1024_Float")
    );
    assert!(v.get("tops").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("aies").unwrap().as_u64().unwrap() <= 64);
    assert_eq!(v.get("key").unwrap().as_str().unwrap().len(), 16);

    // the same request again is served from cache
    let resp2 = handle.handle_line(line);
    let v2 = parse(&resp2).unwrap();
    assert_eq!(v2.get("cached").unwrap().as_bool(), Some(true));
    assert_eq!(
        v2.get("key").unwrap().as_str(),
        v.get("key").unwrap().as_str()
    );

    // malformed requests produce protocol errors, not panics
    let err = handle.handle_line("{\"bench\": \"lu\"}");
    let ev = parse(&err).unwrap();
    assert_eq!(ev.get("ok").unwrap().as_bool(), Some(false));
    assert!(ev.get("error").unwrap().as_str().unwrap().contains("lu"));
    let err2 = handle.handle_line("not json at all");
    assert_eq!(parse(&err2).unwrap().get("ok").unwrap().as_bool(), Some(false));
}

#[test]
fn tcp_front_end_serves_requests() {
    use std::io::{BufRead, BufReader, Write};

    let handle = small_handle();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let handle = handle.clone();
        // serve_tcp loops forever; park it on a detached thread (the
        // process exit at the end of the test run reaps it).
        std::thread::spawn(move || {
            let _ = widesa::serve::serve_tcp(&handle, listener);
        });
    }
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(
        stream,
        "{}",
        r#"{"id": "tcp-1", "bench": "fir", "dims": [65536, 15], "max_aies": 32}"#
    )
    .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = parse(line.trim()).unwrap();
    assert_eq!(v.get("id").unwrap().as_str(), Some("tcp-1"));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("ok").unwrap(), &Json::Bool(true));
}
