//! Integration tests for the `widesa::serve` subsystem: cache behaviour,
//! single-flight deduplication under concurrent requests, determinism of
//! the parallel DSE against the serial reference, admission control
//! (typed `Overloaded` over both front-ends), host-blocking planner
//! rejections (typed `unplannable` over both front-ends, never a 500),
//! plan-cache sharing, and protocol round-trips through the real service.

use std::sync::Arc;
use widesa::mapping::dse::{explore_all, explore_all_parallel, DseConstraints};
use widesa::recurrence::library;
use widesa::serve::cache::design_key;
use widesa::serve::{CacheOutcome, Overloaded, ServeConfig, ServeHandle};
use widesa::util::json::{parse, Json};
use widesa::{DType, DseConstraints as Cons, WideSaConfig};

fn capped(max_aies: u64) -> WideSaConfig {
    WideSaConfig {
        constraints: Cons {
            max_aies: Some(max_aies),
            ..Default::default()
        },
        ..Default::default()
    }
}

fn small_handle() -> ServeHandle {
    ServeHandle::new(ServeConfig {
        base: capped(64),
        cache_capacity: 16,
        cache_shards: 4,
        dse_threads: 4,
        request_workers: 4,
        ..Default::default()
    })
}

#[test]
fn cache_hit_returns_identical_design() {
    let handle = small_handle();
    let rec = library::mm(1024, 1024, 1024, DType::F32);
    let miss = handle.compile(&rec).unwrap();
    assert_eq!(miss.outcome, CacheOutcome::Miss);
    let hit = handle.compile(&rec).unwrap();
    assert_eq!(hit.outcome, CacheOutcome::Hit);
    assert!(Arc::ptr_eq(&miss.design, &hit.design));
    assert_eq!(miss.key, hit.key);
    // and the key matches the standalone derivation
    assert_eq!(miss.key, design_key(&rec, &capped(64)));
}

#[test]
fn different_configs_get_different_cache_entries() {
    let handle = small_handle();
    let rec = library::fir(65536, 15, DType::F32);
    let a = handle.compile_with(&rec, &capped(32)).unwrap();
    let b = handle.compile_with(&rec, &capped(64)).unwrap();
    assert_ne!(a.key, b.key);
    assert_eq!(a.outcome, CacheOutcome::Miss);
    assert_eq!(b.outcome, CacheOutcome::Miss);
    assert!(!Arc::ptr_eq(&a.design, &b.design));
    // both now cached
    assert_eq!(
        handle.compile_with(&rec, &capped(32)).unwrap().outcome,
        CacheOutcome::Hit
    );
    assert_eq!(
        handle.compile_with(&rec, &capped(64)).unwrap().outcome,
        CacheOutcome::Hit
    );
}

#[test]
fn cache_eviction_recompiles_evicted_key() {
    // capacity 1 × 1 shard: the second distinct design evicts the first
    let handle = ServeHandle::new(ServeConfig {
        base: capped(32),
        cache_capacity: 1,
        cache_shards: 1,
        dse_threads: 2,
        request_workers: 2,
        ..Default::default()
    });
    let rec_a = library::fir(65536, 15, DType::F32);
    let rec_b = library::fir(131072, 15, DType::F32);
    assert_eq!(handle.compile(&rec_a).unwrap().outcome, CacheOutcome::Miss);
    assert_eq!(handle.compile(&rec_b).unwrap().outcome, CacheOutcome::Miss);
    // rec_a was evicted: compiling it again is a miss, rec_b stays hot
    assert_eq!(handle.compile(&rec_a).unwrap().outcome, CacheOutcome::Miss);
    let stats = handle.stats();
    assert_eq!(stats.misses, 3);
    assert!(stats.cache.evictions >= 2);
}

#[test]
fn single_flight_dedups_concurrent_identical_requests() {
    let handle = small_handle();
    let rec = library::mm(1024, 1024, 1024, DType::I16);
    const N: usize = 8;
    let results: Vec<_> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..N {
            let handle = handle.clone();
            let rec = rec.clone();
            joins.push(s.spawn(move || handle.compile(&rec).unwrap()));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    // exactly one thread compiled; everyone shares that one design
    let stats = handle.stats();
    assert_eq!(stats.misses, 1, "single-flight must compile once");
    assert_eq!(stats.hits + stats.deduped, (N - 1) as u64);
    for r in &results {
        assert!(Arc::ptr_eq(&results[0].design, &r.design));
        assert_eq!(r.key, results[0].key);
    }
    assert_eq!(
        results.iter().filter(|r| r.outcome == CacheOutcome::Miss).count(),
        1
    );
}

#[test]
fn parallel_dse_matches_serial_on_all_library_recurrences() {
    // Acceptance criterion: identical winning candidate (and in fact the
    // identical full ranking) on every Table II recurrence.
    let cfg = WideSaConfig::default();
    let cons = DseConstraints::default();
    for rec in library::table2_benchmarks() {
        let serial = explore_all(&rec, &cfg.board, &cons);
        let parallel = explore_all_parallel(&rec, &cfg.board, &cons, 4);
        assert_eq!(serial.len(), parallel.len(), "{}", rec.name);
        assert!(!serial.is_empty(), "{}: no candidates", rec.name);
        let (sw, se) = &serial[0];
        let (pw, pe) = &parallel[0];
        assert_eq!(sw.summary(), pw.summary(), "{}: winner differs", rec.name);
        assert_eq!(
            se.perf.tops.to_bits(),
            pe.perf.tops.to_bits(),
            "{}: winner estimate differs",
            rec.name
        );
        assert_eq!(
            se.power.watts.to_bits(),
            pe.power.watts.to_bits(),
            "{}: winner power differs",
            rec.name
        );
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.0.summary(), p.0.summary(), "{}: ranking differs", rec.name);
        }
    }
}

#[test]
fn protocol_round_trip_through_service() {
    let handle = small_handle();
    let line = r#"{"id": 42, "bench": "mm", "dtype": "f32", "dims": [1024, 1024, 1024], "max_aies": 64}"#;
    let resp = handle.handle_line(line);
    let v = parse(&resp).expect("response is valid JSON");
    assert_eq!(v.get("id").unwrap().as_f64(), Some(42.0));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("cached").unwrap().as_bool(), Some(false));
    assert_eq!(
        v.get("name").unwrap().as_str(),
        Some("mm_1024x1024x1024_Float")
    );
    assert!(v.get("tops").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        v.get("watts").unwrap().as_f64().unwrap() > 13.0,
        "response watts must sit above the static floor"
    );
    assert!(v.get("tops_per_watt").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("aies").unwrap().as_u64().unwrap() <= 64);
    assert_eq!(v.get("key").unwrap().as_str().unwrap().len(), 16);
    // mm successes carry the host-level blocking plan
    let b = v.get("blocking").expect("mm response embeds blocking plan");
    assert_eq!(b.get("n").unwrap().as_u64(), Some(1024));
    assert_eq!(b.get("m").unwrap().as_u64(), Some(1024));
    assert_eq!(b.get("k").unwrap().as_u64(), Some(1024));
    assert!(b.get("predicted_dram_bytes").unwrap().as_u64().unwrap() > 0);
    assert!(matches!(
        b.get("order").unwrap().as_str(),
        Some("b-resident") | Some("a-resident")
    ));

    // the same request again is served from cache
    let resp2 = handle.handle_line(line);
    let v2 = parse(&resp2).unwrap();
    assert_eq!(v2.get("cached").unwrap().as_bool(), Some(true));
    assert_eq!(
        v2.get("key").unwrap().as_str(),
        v.get("key").unwrap().as_str()
    );

    // malformed requests produce protocol errors, not panics
    let err = handle.handle_line("{\"bench\": \"lu\"}");
    let ev = parse(&err).unwrap();
    assert_eq!(ev.get("ok").unwrap().as_bool(), Some(false));
    assert!(ev.get("error").unwrap().as_str().unwrap().contains("lu"));
    let err2 = handle.handle_line("not json at all");
    assert_eq!(parse(&err2).unwrap().get("ok").unwrap().as_bool(), Some(false));
}

#[test]
fn tcp_front_end_serves_requests() {
    use std::io::{BufRead, BufReader, Write};

    let handle = small_handle();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let handle = handle.clone();
        // serve_tcp loops forever; park it on a detached thread (the
        // process exit at the end of the test run reaps it).
        std::thread::spawn(move || {
            let _ = widesa::serve::serve_tcp(&handle, listener);
        });
    }
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    writeln!(
        stream,
        "{}",
        r#"{"id": "tcp-1", "bench": "fir", "dims": [65536, 15], "max_aies": 32}"#
    )
    .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = parse(line.trim()).unwrap();
    assert_eq!(v.get("id").unwrap().as_str(), Some("tcp-1"));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("ok").unwrap(), &Json::Bool(true));
    // per-stage timings travel with every success response
    let stages = v.get("stage_ms").expect("stage_ms present");
    for stage in ["place", "assign", "route"] {
        assert!(stages.get(stage).unwrap().as_f64().unwrap() >= 0.0, "{stage}");
    }
}

#[test]
fn queue_shed_followers_get_typed_error_then_retry_compiles_once() {
    // Force the queue full deterministically: max_inflight 1 with the
    // single slot held by the test. Every concurrent requester of one
    // cold key — whichever becomes the single-flight leader, and every
    // follower waiting on its flight — must get the *typed* Overloaded
    // error (not a hang, not a stringified copy).
    let handle = ServeHandle::new(ServeConfig {
        base: capped(32),
        max_inflight: 1,
        ..Default::default()
    });
    let rec = library::fir(65536, 15, DType::F32);
    let slot = handle.debug_inflight_slot().expect("slot claimed");
    const N: usize = 6;
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..N)
            .map(|_| {
                let handle = handle.clone();
                let rec = rec.clone();
                s.spawn(move || handle.compile(&rec))
            })
            .collect();
        for j in joins {
            let err = j.join().unwrap().expect_err("queue is full");
            let o = err.downcast_ref::<Overloaded>().expect("typed Overloaded");
            assert_eq!(o.reason, "queue");
            assert!(o.retry_after_ms > 0);
        }
    });
    let stats = handle.stats();
    assert_eq!(stats.shed, N as u64, "every shed request counted");
    assert_eq!(stats.misses, 0, "nothing compiled while the queue was full");

    // Uncongested retry: the key compiles exactly once, then hits.
    drop(slot);
    assert_eq!(handle.compile(&rec).unwrap().outcome, CacheOutcome::Miss);
    assert_eq!(handle.compile(&rec).unwrap().outcome, CacheOutcome::Hit);
    assert_eq!(handle.stats().misses, 1);
}

#[test]
fn overloaded_response_round_trips_stdin_path() {
    // Queue shedding through handle_line (the stdin front-end's unit of
    // work): the response must be the structured overloaded line.
    let handle = ServeHandle::new(ServeConfig {
        base: capped(32),
        max_inflight: 1,
        ..Default::default()
    });
    let _slot = handle.debug_inflight_slot().expect("slot claimed");
    let resp =
        handle.handle_line(r#"{"id": 5, "bench": "fir", "dims": [65536, 15], "max_aies": 32}"#);
    let v = parse(&resp).unwrap();
    assert_eq!(v.get("id").unwrap().as_f64(), Some(5.0));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("overloaded").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("reason").unwrap().as_str(), Some("queue"));
    assert!(v.get("retry_after_ms").unwrap().as_u64().unwrap() > 0);
}

#[test]
fn overloaded_response_round_trips_tcp() {
    use std::io::{BufRead, BufReader, Write};

    // Per-tenant quota (burst 1, no refill) over a real socket: first
    // request admits, second sheds with reason "quota", and the
    // connection stays usable for a differently-quota'd tenant.
    let handle = ServeHandle::new(ServeConfig {
        base: capped(32),
        quota_rps: 0.0,
        quota_burst: 1.0,
        ..Default::default()
    });
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let handle = handle.clone();
        std::thread::spawn(move || {
            let _ = widesa::serve::serve_tcp(&handle, listener);
        });
    }
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut send = |req: &str| -> Json {
        writeln!(stream, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        parse(line.trim()).unwrap()
    };
    let req_a = r#"{"id": 1, "bench": "fir", "dims": [65536, 15], "max_aies": 32, "tenant": "a"}"#;
    let ok = send(req_a);
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
    let shed = send(req_a);
    assert_eq!(shed.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(shed.get("overloaded").unwrap().as_bool(), Some(true));
    assert_eq!(shed.get("reason").unwrap().as_str(), Some("quota"));
    assert!(shed.get("retry_after_ms").unwrap().as_u64().unwrap() > 0);
    // tenant b's bucket is untouched — and the key is already cached
    let other = send(
        r#"{"id": 3, "bench": "fir", "dims": [65536, 15], "max_aies": 32, "tenant": "b"}"#,
    );
    assert_eq!(other.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(other.get("cached").unwrap().as_bool(), Some(true));
    assert_eq!(handle.stats().shed, 1);
}

#[test]
fn unplannable_shape_typed_over_stdin_path() {
    // A shape the host-blocking planner cannot place (one staged matrix
    // would exceed the staging cap) must come back as the structured
    // `unplannable` line — not a stringified 500, not a panic — and the
    // handle must stay usable for the next request.
    let handle = small_handle();
    let resp = handle.handle_line(
        r#"{"id": 13, "bench": "mm", "dims": [1000000000, 1000000000, 1000000000]}"#,
    );
    let v = parse(&resp).unwrap();
    assert_eq!(v.get("id").unwrap().as_f64(), Some(13.0));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("unplannable").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("n").unwrap().as_u64(), Some(1_000_000_000));
    assert_eq!(v.get("m").unwrap().as_u64(), Some(1_000_000_000));
    assert_eq!(v.get("k").unwrap().as_u64(), Some(1_000_000_000));
    assert!(v.get("reason").unwrap().as_str().unwrap().contains("staging cap"));
    assert!(v.get("overloaded").is_none(), "not an admission shed");
    assert_eq!(handle.stats().errors, 1, "counted as a request error");
    assert_eq!(handle.stats().misses, 0, "rejected before any compile");

    // a plannable request on the same handle still succeeds
    let ok = handle.handle_line(
        r#"{"id": 14, "bench": "mm", "dims": [1024, 1024, 1024], "max_aies": 64}"#,
    );
    let v = parse(&ok).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert!(v.get("blocking").is_some());
}

#[test]
fn unplannable_shape_typed_over_tcp() {
    use std::io::{BufRead, BufReader, Write};

    let handle = small_handle();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let handle = handle.clone();
        std::thread::spawn(move || {
            let _ = widesa::serve::serve_tcp(&handle, listener);
        });
    }
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut send = |req: &str| -> Json {
        writeln!(stream, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        parse(line.trim()).unwrap()
    };
    let v = send(
        r#"{"id": "big", "bench": "mm", "dims": [1000000000, 1000000000, 1000000000]}"#,
    );
    assert_eq!(v.get("id").unwrap().as_str(), Some("big"));
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("unplannable").unwrap().as_bool(), Some(true));
    assert!(v.get("reason").unwrap().as_str().unwrap().contains("staging cap"));
    // the connection survives the rejection
    let ok = send(r#"{"id": "ok", "bench": "fir", "dims": [65536, 15], "max_aies": 32}"#);
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
}

#[test]
fn near_key_requests_share_dse_plan_work() {
    // mover_bits changes the design key (different merged graph) but not
    // the DSE plan (demarcation + space-time enumeration ignore it): the
    // second compile must be a design-cache miss yet a plan-cache hit.
    let handle = small_handle();
    let rec = library::fir(65536, 15, DType::F32);
    let mut wide = capped(32);
    wide.mover_bits = 512;
    let mut narrow = capped(32);
    narrow.mover_bits = 128;
    let a = handle.compile_with(&rec, &wide).unwrap();
    let b = handle.compile_with(&rec, &narrow).unwrap();
    assert_ne!(a.key, b.key, "mover width is part of the design key");
    assert_eq!(a.outcome, CacheOutcome::Miss);
    assert_eq!(b.outcome, CacheOutcome::Miss);
    assert!(
        handle.stats().plan_hits >= 1,
        "near-key compile must reuse the memoized plan"
    );
}
