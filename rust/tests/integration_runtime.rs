//! Integration: the PJRT runtime against every AOT artifact — every
//! manifest entry loads, compiles, executes and returns sane values.
//! Skips (with a notice) when `make artifacts` has not been run.

use widesa::runtime::artifact::Manifest;
use widesa::runtime::client::Runtime;
use widesa::runtime::executor::{Tensor, TensorData};
use widesa::util::rng::XorShift64;

fn runtime() -> Option<Runtime> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new().unwrap())
}

fn random_input(spec: &widesa::runtime::artifact::TensorSpec, rng: &mut XorShift64) -> Tensor {
    let n = spec.elements();
    match spec.dtype.as_str() {
        "float32" => {
            let mut v = vec![0f32; n];
            rng.fill_f32(&mut v);
            Tensor::f32(spec.shape.clone(), v)
        }
        "int32" => {
            let mut v = vec![0i32; n];
            rng.fill_i32(&mut v);
            Tensor::i32(spec.shape.clone(), v)
        }
        other => panic!("unsupported dtype {other}"),
    }
}

#[test]
fn every_artifact_executes_with_valid_outputs() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt.manifest.artifacts.keys().cloned().collect();
    let mut rng = XorShift64::new(99);
    assert!(names.len() >= 8, "expected the full artifact set");
    for name in names {
        let spec = rt.spec(&name).unwrap().clone();
        let inputs: Vec<Tensor> = spec
            .inputs
            .iter()
            .map(|s| random_input(s, &mut rng))
            .collect();
        let outputs = rt.run(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(outputs.len(), spec.outputs.len(), "{name}");
        for (o, s) in outputs.iter().zip(&spec.outputs) {
            assert_eq!(o.shape, s.shape, "{name}");
            assert_eq!(o.data.len(), s.elements(), "{name}");
            if let TensorData::F32(v) = &o.data {
                assert!(v.iter().all(|x| x.is_finite()), "{name}: non-finite output");
            }
        }
    }
}

#[test]
fn executable_cache_reused_across_runs() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.spec("mm_f32_128").unwrap().clone();
    let mut rng = XorShift64::new(5);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| random_input(s, &mut rng))
        .collect();
    let t0 = std::time::Instant::now();
    rt.run("mm_f32_128", &inputs).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..3 {
        rt.run("mm_f32_128", &inputs).unwrap();
    }
    let warm = t1.elapsed() / 3;
    assert_eq!(rt.cached(), 1);
    assert!(
        warm < cold,
        "warm {warm:?} should beat cold {cold:?} (compile amortised)"
    );
}

#[test]
fn mm_artifacts_agree_with_each_other() {
    // 256-tile artifact on a 256 input must equal four 128-tile calls.
    let Some(mut rt) = runtime() else { return };
    let n = 256usize;
    let mut rng = XorShift64::new(17);
    let mut a = vec![0f32; n * n];
    let mut b = vec![0f32; n * n];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let zero = vec![0f32; n * n];
    let big = rt
        .run(
            "mm_f32_256",
            &[
                Tensor::f32(vec![n, n], a.clone()),
                Tensor::f32(vec![n, n], b.clone()),
                Tensor::f32(vec![n, n], zero.clone()),
            ],
        )
        .unwrap();
    let (c_small, _) =
        widesa::coordinator::exec::run_mm(&mut rt, &a, &b, n, n, n).unwrap();
    let big_c = big[0].data.as_f32().unwrap();
    let err = widesa::coordinator::verify::max_abs_diff(big_c, &c_small);
    assert!(err < 1e-2, "artifact disagreement: {err}");
}

#[test]
fn fft_artifact_matches_host_fft() {
    let Some(mut rt) = runtime() else { return };
    let (b, n) = (64usize, 256usize);
    let mut rng = XorShift64::new(23);
    let mut re = vec![0f32; b * n];
    let mut im = vec![0f32; b * n];
    rng.fill_f32(&mut re);
    rng.fill_f32(&mut im);
    // the artifact expects bit-reversed-order rows (host-side permute)
    let bits = n.trailing_zeros();
    let rev: Vec<usize> = (0..n)
        .map(|i| ((i as u32).reverse_bits() >> (32 - bits)) as usize)
        .collect();
    let permute = |v: &[f32]| -> Vec<f32> {
        let mut out = vec![0f32; b * n];
        for row in 0..b {
            for (i, &s) in rev.iter().enumerate() {
                out[row * n + i] = v[row * n + s];
            }
        }
        out
    };
    let out = rt
        .run(
            "fft1d_f32_64x256",
            &[
                Tensor::f32(vec![b, n], permute(&re)),
                Tensor::f32(vec![b, n], permute(&im)),
            ],
        )
        .unwrap();
    // host oracle per row
    for row in 0..b {
        let mut hr = re[row * n..(row + 1) * n].to_vec();
        let mut hi = im[row * n..(row + 1) * n].to_vec();
        widesa::coordinator::verify::fft_ref(&mut hr, &mut hi);
        let gr = &out[0].data.as_f32().unwrap()[row * n..(row + 1) * n];
        let gi = &out[1].data.as_f32().unwrap()[row * n..(row + 1) * n];
        let er = widesa::coordinator::verify::max_abs_diff(gr, &hr);
        let ei = widesa::coordinator::verify::max_abs_diff(gi, &hi);
        assert!(er < 1e-2 && ei < 1e-2, "row {row}: {er} / {ei}");
    }
}
