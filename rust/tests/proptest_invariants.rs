//! Property-based tests over coordinator invariants (routing, batching,
//! scheduling state). The vendored offline crate set has no proptest, so
//! properties are swept with the crate's deterministic PRNG — hundreds of
//! random cases per property, fully reproducible. Generators live in the
//! shared [`testkit`]; the per-property case count defaults to 200 and
//! scales with `PROPTEST_CASES` (the nightly CI lane runs 512).

mod testkit;
use testkit::laws;
use testkit::{cases, random_ca_pair, random_nest};

use widesa::arch::array::{AieArray, Coord};
use widesa::arch::plio::{PlioDir, PlioSpec};
use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::{build, MappedGraph};
use widesa::graph::edge::{Edge, EdgeKind};
use widesa::graph::node::{Node, NodeKind};
use widesa::graph::packet::{merge_ports, MAX_FANIN};
use widesa::mapping::cost::CostModel;
use widesa::mapping::dse::{explore, DseConstraints};
use widesa::mapping::partition::partition;
use widesa::place_route::placement::{place, Placement};
use widesa::plio::assignment::assign;
use widesa::plio::congestion::congestion;
use widesa::plio::sat::{check, exhaustive_assign};
use widesa::polyhedral::dependence::DepKind;
use widesa::polyhedral::domain::{IterationDomain, LoopDim};
use widesa::polyhedral::legality::{is_legal_order, lex_positive};
use widesa::polyhedral::schedule::LoopNest;
use widesa::polyhedral::transform::{apply_all, Transform};
use widesa::recurrence::{dtype::DType, library};
use widesa::util::rng::XorShift64;

#[test]
fn prop_tiling_preserves_cardinality_and_legality() {
    let mut rng = XorShift64::new(1000);
    for _ in 0..cases(200) {
        let nest = random_nest(&mut rng);
        let dim = rng.gen_range(nest.rank() as u64) as usize;
        let extent = nest.domain.dims[dim].extent;
        // pick a divisor factor so cardinality is exactly preserved
        let divisors: Vec<u64> = (1..=extent).filter(|f| extent % f == 0).collect();
        let factor = divisors[rng.gen_range(divisors.len() as u64) as usize];
        let tiled = Transform::Tile { dim, factor }.apply(&nest);
        assert_eq!(tiled.cardinality(), nest.cardinality());
        assert_eq!(tiled.rank(), nest.rank() + 1);
        // legality preserved: tiling a legal nest stays legal
        assert!(is_legal_order(&nest.deps));
        assert!(
            is_legal_order(&tiled.deps),
            "tiling dim {dim} by {factor} broke legality: {:?}",
            tiled.deps
        );
    }
}

#[test]
fn prop_permutation_roundtrip_is_identity() {
    let mut rng = XorShift64::new(2000);
    for _ in 0..cases(200) {
        let nest = random_nest(&mut rng);
        let rank = nest.rank();
        // random permutation
        let mut order: Vec<usize> = (0..rank).collect();
        for i in (1..rank).rev() {
            let j = rng.gen_range(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        // inverse
        let mut inv = vec![0usize; rank];
        for (new, &old) in order.iter().enumerate() {
            inv[old] = new;
        }
        let round = apply_all(
            &nest,
            &[Transform::Permute(order.clone()), Transform::Permute(inv)],
        );
        assert_eq!(round, nest);
    }
}

#[test]
fn prop_lex_positive_total_on_nonzero() {
    let mut rng = XorShift64::new(3000);
    for _ in 0..cases(200) {
        let v: Vec<i64> = (0..4).map(|_| rng.gen_range(5) as i64 - 2).collect();
        let neg: Vec<i64> = v.iter().map(|c| -c).collect();
        if v.iter().any(|&c| c != 0) {
            assert_ne!(lex_positive(&v), lex_positive(&neg), "{v:?}");
        } else {
            assert!(!lex_positive(&v) && !lex_positive(&neg));
        }
    }
}

#[test]
fn prop_partition_respects_budget_and_covers_tiles() {
    let mut rng = XorShift64::new(4000);
    let array = AieArray::default();
    for _ in 0..cases(200) {
        let vi = 1 + rng.gen_range(300);
        let vj = 1 + rng.gen_range(300);
        let budget = 1 + rng.gen_range(400);
        let nest = LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("i", vi), LoopDim::new("j", vj)]),
            vec![],
        );
        let p = partition(&nest, &[0, 1], &array, Some(budget));
        assert!(p.active_aies() <= budget, "budget {budget}: {p:?}");
        assert!(p.phys[0] <= array.rows as u64 && p.phys[1] <= array.cols as u64);
        // rounds × active must cover all virtual tiles
        assert!(
            p.rounds * p.active_aies() >= vi * vj,
            "under-covered: {p:?}"
        );
        // and not overshoot by more than one round
        assert!((p.rounds - 1) * p.active_aies() < vi * vj);
        let eff = p.edge_efficiency();
        assert!(eff > 0.0 && eff <= 1.0 + 1e-12);
    }
}

#[test]
fn prop_packet_merge_invariants() {
    let mut rng = XorShift64::new(5000);
    let board = BoardConfig::vck5000();
    let model = CostModel::new(board.clone());
    for _ in 0..cases(24) {
        let budget = 16 + rng.gen_range(384);
        let recs = [
            library::mm(2048, 2048, 2048, DType::F32),
            library::conv2d(1024, 1024, 4, 4, DType::I8),
            library::fir(262144, 15, DType::I16),
        ];
        let rec = &recs[rng.gen_range(3) as usize];
        let cons = DseConstraints {
            max_aies: Some(budget),
            ..Default::default()
        };
        let Some((cand, _)) = explore(rec, &board, &cons) else {
            continue;
        };
        let g = build(&cand, &model);
        let (m, stats) = merge_ports(&g, model.channel_bw());
        // AIEs and edge count preserved
        assert_eq!(m.num_aies(), g.num_aies());
        assert_eq!(m.edges.len(), g.edges.len());
        // ports never increase
        assert!(stats.in_ports_after <= stats.in_ports_before);
        assert!(stats.out_ports_after <= stats.out_ports_before);
        // fan-in limit per port (excluding broadcasts)
        use std::collections::HashMap;
        let mut fanin: HashMap<usize, usize> = HashMap::new();
        for e in &m.edges {
            if e.kind == EdgeKind::Broadcast {
                continue;
            }
            if m.nodes[e.src].is_plio() {
                *fanin.entry(e.src).or_default() += 1;
            }
            if m.nodes[e.dst].is_plio() {
                *fanin.entry(e.dst).or_default() += 1;
            }
        }
        for (p, n) in fanin {
            assert!(n <= MAX_FANIN, "port {p} fanin {n}");
        }
        // all endpoints valid after reindexing
        for e in &m.edges {
            assert!(e.src < m.nodes.len() && e.dst < m.nodes.len());
        }
    }
}

/// Random small PLIO instances where greedy and exhaustive must agree on
/// feasibility (and greedy's accepted solutions must pass the checker).
#[test]
fn prop_algorithm1_sound_vs_exhaustive() {
    let mut rng = XorShift64::new(6000);
    for case in 0..cases(60) {
        // 2-4 AIEs on a 4-wide strip, 2-4 PLIOs, tight budgets
        let n_aie = 2 + rng.gen_range(3) as usize;
        let n_plio = 2 + rng.gen_range(3) as usize;
        let mut g = MappedGraph {
            replica: (1, 4),
            replicas: 1,
            ..Default::default()
        };
        let mut placement = Placement::default();
        for i in 0..n_aie {
            let col = rng.gen_range(4) as u32;
            g.nodes.push(Node {
                id: i,
                kind: NodeKind::Aie {
                    virt: Coord::new(0, col),
                },
                name: format!("k_r0_0_{col}"),
            });
            placement.insert(i, Coord::new(1 + i as u32 % 4, col));
        }
        for p in 0..n_plio {
            let id = n_aie + p;
            let dir = if p % 2 == 0 { PlioDir::In } else { PlioDir::Out };
            g.nodes.push(Node {
                id,
                kind: NodeKind::Plio { dir },
                name: format!("p{p}"),
            });
            // connect to 1-2 random AIEs
            for _ in 0..=rng.gen_range(2) {
                let a = rng.gen_range(n_aie as u64) as usize;
                let (s, d) = if dir == PlioDir::In { (id, a) } else { (a, id) };
                g.edges.push(Edge::new(s, d, EdgeKind::Stream, "X", DepKind::Read, 1.0));
            }
        }
        let spec = PlioSpec {
            in_channels: 4,
            out_channels: 4,
            columns: vec![0, 1, 2, 3],
            channels_per_column: 1,
            ..PlioSpec::default()
        };
        let rc = 1 + rng.gen_range(2) as u32;
        let greedy = assign(&g, &placement, &spec, rc, rc);
        let exact = exhaustive_assign(&g, &placement, &spec, rc, rc);
        if greedy.feasible {
            assert!(
                check(&g, &placement, &greedy.columns, &spec, rc, rc),
                "case {case}: greedy accepted an invalid assignment"
            );
            assert!(
                exact.is_some(),
                "case {case}: greedy feasible but exhaustive says impossible"
            );
        }
        // exhaustive solutions always pass the checker
        if let Some(cols) = exact {
            assert!(check(&g, &placement, &cols, &spec, rc, rc), "case {case}");
        }
    }
}

#[test]
fn prop_congestion_is_column_local() {
    // moving a PLIO to the column of its only neighbour zeroes its
    // contribution
    let mut rng = XorShift64::new(7000);
    for _ in 0..cases(200) {
        let aie_col = rng.gen_range(50) as u32;
        let mut g = MappedGraph::default();
        g.nodes.push(Node {
            id: 0,
            kind: NodeKind::Aie {
                virt: Coord::new(0, aie_col),
            },
            name: "k_r0_0_0".into(),
        });
        g.nodes.push(Node {
            id: 1,
            kind: NodeKind::Plio { dir: PlioDir::In },
            name: "p".into(),
        });
        g.edges.push(Edge::new(1, 0, EdgeKind::Stream, "X", DepKind::Read, 1.0));
        let mut placement = Placement::default();
        placement.insert(0, Coord::new(3, aie_col));
        let mut cols = std::collections::HashMap::new();
        cols.insert(1usize, aie_col);
        let prof = congestion(&g, &placement, &cols, 50);
        assert_eq!(prof.max_west() + prof.max_east(), 0);
        // and a distant column contributes |distance| boundaries
        let far = (aie_col + 10) % 50;
        cols.insert(1usize, far);
        let prof2 = congestion(&g, &placement, &cols, 50);
        let total: u32 = prof2.west.iter().chain(prof2.east.iter()).sum();
        assert_eq!(total, aie_col.abs_diff(far));
    }
}

#[test]
fn prop_placement_is_injective_and_in_bounds() {
    let mut rng = XorShift64::new(8000);
    let board = BoardConfig::vck5000();
    let model = CostModel::new(board.clone());
    for _ in 0..cases(24) {
        let budget = 8 + rng.gen_range(392);
        let cons = DseConstraints {
            max_aies: Some(budget),
            ..Default::default()
        };
        let rec = library::mm(4096, 4096, 4096, DType::I16);
        let Some((cand, _)) = explore(&rec, &board, &cons) else {
            continue;
        };
        let g = build(&cand, &model);
        let p = place(&g, &AieArray::default()).expect("placement");
        assert!(p.is_valid(&AieArray::default()));
        assert_eq!(p.len(), g.num_aies());
        assert!(g.node_ids_are_dense());
    }
}

#[test]
fn prop_ca_candidates_obey_port_and_ranking_laws() {
    // every generated replication-axis candidate: the incremental port
    // predictor (its BroadcastReduce arm) stays bit-identical to really
    // merging the built CA graph, and the scoped-thread ranking stays
    // bit-identical to the serial reference — the two determinism
    // guarantees the form-selection gate leans on
    let mut rng = XorShift64::new(10_000);
    for _ in 0..cases(12) {
        let (_, ca_rec) = random_ca_pair(&mut rng);
        let budget = 8 + rng.gen_range(71);
        let board = BoardConfig::vck5000().with_plio_budget(budget as u32);
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        laws::predictor_matches_merge(&ca_rec, &board, &cons);
        laws::serial_parallel_ranking(&ca_rec, &board, &cons, &[2, 8]);
    }
}

/// The serve design cache and the demarcation memo key on
/// `UniformRecurrence::canonical_u64`. Growing the input language (the
/// `carried` dependence field) must not shift the key of any pre-existing
/// workload, or every deployed cache entry silently goes cold. This
/// re-computes the pre-expansion key layout field by field and asserts it
/// still matches for every access-derived recurrence.
fn legacy_canonical_key(rec: &widesa::UniformRecurrence) -> u64 {
    use widesa::recurrence::AccessKind;
    use widesa::util::hash::Fnv64;
    let mut h = Fnv64::new();
    h.write_str(&rec.name);
    h.write_usize(rec.rank());
    for d in &rec.domain.dims {
        h.write_str(&d.name);
        h.write_u64(d.extent);
    }
    h.write_usize(rec.accesses.len());
    for acc in &rec.accesses {
        h.write_str(&acc.array);
        h.write_u8(match acc.kind {
            AccessKind::Read => 0,
            AccessKind::Accumulate => 1,
            AccessKind::Write => 2,
        });
        h.write_usize(acc.map.exprs.len());
        for e in &acc.map.exprs {
            h.write_usize(e.coeffs.len());
            for &c in &e.coeffs {
                h.write_i64(c);
            }
            h.write_i64(e.constant);
        }
    }
    h.write_str(rec.dtype.name());
    h.write_u64(rec.macs_per_iter);
    h.finish()
}

#[test]
fn prop_canonical_keys_stable_for_access_derived_recurrences() {
    // every Table II workload — the serve cache population that must not
    // shift — plus the carried-free members of the expanded catalog
    for rec in library::table2_benchmarks() {
        assert_eq!(
            rec.canonical_u64(),
            legacy_canonical_key(&rec),
            "{}: cache key shifted",
            rec.name
        );
    }
    for rec in library::catalog_small() {
        if rec.carried.is_empty() {
            assert_eq!(rec.canonical_u64(), legacy_canonical_key(&rec), "{}", rec.name);
        } else {
            // carried vectors are semantic: the key must move off the
            // legacy layout (they'd collide with a carried-free twin)
            assert_ne!(rec.canonical_u64(), legacy_canonical_key(&rec), "{}", rec.name);
        }
    }
}

#[test]
fn prop_library_dependences_track_canonical_keys() {
    // every library constructor, random sizes: rebuilding with the same
    // parameters reproduces both the key and the exact dependence-vector
    // list; perturbing any extent moves the key
    let mut rng = XorShift64::new(11_000);
    for _ in 0..cases(200) {
        let pick = rng.gen_range(7);
        let d2 = |r: &mut XorShift64| 4 + r.gen_range(60);
        let (a, b): (widesa::UniformRecurrence, widesa::UniformRecurrence) = match pick {
            0 => {
                let (n, m, k) = (d2(&mut rng), d2(&mut rng), d2(&mut rng));
                (library::mm(n, m, k, DType::F32), library::mm(n, m, k, DType::F32))
            }
            1 => {
                let (h, w) = (8 + rng.gen_range(56), 8 + rng.gen_range(56));
                (
                    library::conv2d(h, w, 4, 4, DType::I8),
                    library::conv2d(h, w, 4, 4, DType::I8),
                )
            }
            2 => {
                let n = 64 + rng.gen_range(4096);
                (library::fir(n, 15, DType::F32), library::fir(n, 15, DType::F32))
            }
            3 => {
                let rows = 8 + rng.gen_range(120);
                (
                    library::fft2d(rows, 64, DType::CF32),
                    library::fft2d(rows, 64, DType::CF32),
                )
            }
            4 => {
                let (c, h) = (1 + rng.gen_range(32), 8 + rng.gen_range(56));
                (
                    library::dw_conv2d(c, h, h, 3, 3, DType::F32),
                    library::dw_conv2d(c, h, h, 3, 3, DType::F32),
                )
            }
            5 => {
                let n = d2(&mut rng);
                (library::trsv(n, DType::F32), library::trsv(n, DType::F32))
            }
            _ => {
                let (t, n) = (1 + rng.gen_range(8), 8 + rng.gen_range(120));
                (
                    library::stencil2d_chain(t, n, n, DType::F32),
                    library::stencil2d_chain(t, n, n, DType::F32),
                )
            }
        };
        assert_eq!(a.canonical_u64(), b.canonical_u64(), "{}", a.name);
        assert_eq!(a.dependences(), b.dependences(), "{}", a.name);
        // perturb one extent: key must move even though the name-embedded
        // sizes are the only other discriminator
        let mut bigger = a.clone();
        let dim = rng.gen_range(bigger.rank() as u64) as usize;
        bigger.domain.dims[dim].extent += 1;
        assert_ne!(a.canonical_u64(), bigger.canonical_u64(), "{}", a.name);
    }
}

#[test]
fn prop_placement_grid_and_coords_never_disagree() {
    // The dense Placement keeps a NodeId→Coord vector mirrored by a flat
    // row-major occupancy grid. Under arbitrary insert sequences — moves,
    // re-inserts, slot steals, grid growth — the two views must stay
    // exact mirrors, and the placed count must match both.
    let mut rng = XorShift64::new(9000);
    for case in 0..cases(200) {
        let mut p = Placement::default();
        // shadow model with the same displacement semantics
        let mut model: std::collections::BTreeMap<usize, Coord> =
            std::collections::BTreeMap::new();
        for _ in 0..(1 + rng.gen_range(60)) {
            let n = rng.gen_range(24) as usize;
            // occasionally step past the default 8×50 grid to force growth
            let c = Coord::new(rng.gen_range(10) as u32, rng.gen_range(56) as u32);
            p.insert(n, c);
            model.retain(|_, &mut mc| mc != c); // displaced occupant
            model.insert(n, c);

            let placed: Vec<(usize, Coord)> = p.iter().collect();
            assert_eq!(placed.len(), p.len(), "case {case}: len drifted");
            assert_eq!(
                placed,
                model.iter().map(|(&n, &c)| (n, c)).collect::<Vec<_>>(),
                "case {case}: coords view diverged from model"
            );
            // coords → grid
            for &(n, c) in &placed {
                assert_eq!(p.node_at(c), Some(n), "case {case}: grid lost {n}");
                assert_eq!(p.coord(n), Some(c), "case {case}");
            }
            // grid → coords (every occupied slot maps back)
            let (rows, cols) = p.grid_dims();
            let mut occupied = 0;
            for r in 0..rows {
                for col in 0..cols {
                    if let Some(n) = p.node_at(Coord::new(r, col)) {
                        occupied += 1;
                        assert_eq!(
                            p.coord(n),
                            Some(Coord::new(r, col)),
                            "case {case}: slot ({r},{col}) points at unplaced node"
                        );
                    }
                }
            }
            assert_eq!(occupied, p.len(), "case {case}: grid occupancy drifted");
        }
    }
}
