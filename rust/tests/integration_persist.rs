//! Integration tests for cross-process cache persistence: a restart
//! simulation (snapshot on shutdown, warm-start on boot, bit-identical
//! protocol responses), a deterministic-PRNG property sweep over the
//! recurrence serializer, and corrupted-snapshot recovery (truncation,
//! garbage, schema bumps — skipped entry-by-entry, never a panic).

mod testkit;

use std::path::PathBuf;
use testkit::{cases, random_recurrence};
use widesa::recurrence::library;
use widesa::serve::{persist, protocol};
use widesa::serve::{CacheOutcome, ServeConfig, ServeHandle};
use widesa::util::json::{parse, Json};
use widesa::util::rng::XorShift64;
use widesa::{DType, DseConstraints as Cons, WideSaConfig};

fn capped(max_aies: u64) -> WideSaConfig {
    WideSaConfig {
        constraints: Cons {
            max_aies: Some(max_aies),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Per-process temp path so parallel test binaries never collide.
fn snap_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("widesa_snap_{}_{name}.jsonl", std::process::id()))
}

#[test]
fn restart_simulation_warm_starts_from_snapshot() {
    let path = snap_path("restart");
    let recs = [
        library::fir(65536, 15, DType::F32),
        library::mm(1024, 1024, 1024, DType::F32),
    ];

    // First server lifetime: compile cold, snapshot on the way out.
    let first = ServeHandle::new(ServeConfig {
        base: capped(64),
        ..Default::default()
    });
    let mut before = Vec::new();
    for rec in &recs {
        let r = first.compile(rec).unwrap();
        assert_eq!(r.outcome, CacheOutcome::Miss);
        before.push(r);
    }
    let saved = first.save_snapshot(&path).unwrap();
    assert_eq!(saved, recs.len());

    // "Restart": a fresh handle warm-started from the snapshot answers
    // every previously-cached key without a single cold compile.
    let second = ServeHandle::new(ServeConfig {
        base: capped(64),
        snapshot: Some(path.clone()),
        ..Default::default()
    });
    for (rec, old) in recs.iter().zip(&before) {
        let new = second.compile(rec).unwrap();
        assert_eq!(new.outcome, CacheOutcome::Hit, "{}", rec.name);
        assert_eq!(new.key, old.key);
        // Bit-identity end to end: the warm-started design renders the
        // exact same protocol response bytes as the original.
        let a = protocol::response_line(
            &Json::Null,
            old.key,
            CacheOutcome::Hit,
            &old.design,
            0.0,
            None,
        );
        let b = protocol::response_line(
            &Json::Null,
            new.key,
            CacheOutcome::Hit,
            &new.design,
            0.0,
            None,
        );
        assert_eq!(a, b, "{}", rec.name);
    }
    let stats = second.stats();
    assert_eq!(stats.misses, 0, "warm start must not cold-compile");
    assert_eq!(stats.hits, recs.len() as u64);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn prop_recurrence_serialization_preserves_canonical_key() {
    let mut rng = XorShift64::new(0xC0FFEE);
    for case in 0..cases(40) {
        let rec = random_recurrence(&mut rng);
        // through the renderer and a real parse, like a snapshot line
        let text = persist::rec_to_json(&rec).to_string();
        let back = persist::rec_from_json(&parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("case {case} ({}): {e}", rec.name));
        assert_eq!(
            back.canonical_u64(),
            rec.canonical_u64(),
            "case {case}: {}",
            rec.name
        );
        assert_eq!(persist::rec_to_json(&back).to_string(), text, "case {case}");
    }
}

#[test]
fn corrupted_snapshots_are_skipped_entry_by_entry() {
    let path = snap_path("corrupt");
    let handle = ServeHandle::new(ServeConfig {
        base: capped(64),
        ..Default::default()
    });
    handle.compile(&library::fir(65536, 15, DType::F32)).unwrap();
    handle.compile(&library::fir(32768, 15, DType::F32)).unwrap();
    handle.save_snapshot(&path).unwrap();
    let clean = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = clean.lines().collect();
    assert_eq!(lines.len(), 2);

    // Truncation mid-line: the partial entry is skipped, the intact one
    // still loads.
    let truncated = format!("{}\n{}\n", lines[0], &lines[1][..lines[1].len() / 2]);
    std::fs::write(&path, truncated).unwrap();
    let (loaded, skipped) = persist::load_snapshot(&path);
    assert_eq!((loaded.len(), skipped), (1, 1));

    // Garbage interleaved with valid entries: every valid entry
    // survives, every bad line is counted, nothing panics.
    let garbage = format!(
        "not json at all\n{}\n{{\"schema\": 1}}\n\n{}\n\u{0}\u{1}\u{2}\n",
        lines[0], lines[1]
    );
    std::fs::write(&path, garbage).unwrap();
    let (loaded, skipped) = persist::load_snapshot(&path);
    assert_eq!(loaded.len(), 2, "valid entries load around garbage");
    assert_eq!(skipped, 3, "blank lines are not errors; garbage is");

    // A future schema version is not ours to guess at: bumped entries
    // self-evict (skip), current-schema entries load.
    let bumped = format!(
        "{}\n{}\n",
        lines[0].replacen("\"schema\":1", "\"schema\":2", 1),
        lines[1]
    );
    std::fs::write(&path, bumped).unwrap();
    let (loaded, skipped) = persist::load_snapshot(&path);
    assert_eq!((loaded.len(), skipped), (1, 1));

    // A missing snapshot is a cold boot, not an error.
    let _ = std::fs::remove_file(&path);
    let (loaded, skipped) = persist::load_snapshot(&path);
    assert_eq!((loaded.len(), skipped), (0, 0));
}
