//! Cache-key and snapshot compatibility regression tests.
//!
//! The power/objective refactor widened [`DseConstraints`] and the
//! design estimate, but both are wire/disk surfaces with compatibility
//! promises:
//!
//! * `DseConstraints::fingerprint` feeds the serve cache's
//!   [`design_key`], which clients may remember across server restarts —
//!   at default `max_power_w`/`objective` it must hash to exactly the
//!   pre-refactor bytes (golden constants below, FNV-1a over the legacy
//!   byte sequence);
//! * `serve::persist` snapshots must keep the schema-1 layout: power is
//!   derived on load, never stored, so pre-refactor snapshot files keep
//!   warm-starting the cache.

use widesa::coordinator::framework::WideSaConfig;
use widesa::mapping::dse::{DseConstraints, Objective};
use widesa::recurrence::{dtype::DType, library};
use widesa::serve::cache::design_key;
use widesa::serve::persist::{entry_line, load_snapshot, save_snapshot};
use widesa::util::hash::Fnv64;
use widesa::WideSa;

fn fingerprint_of(cons: &DseConstraints) -> u64 {
    let mut h = Fnv64::new();
    cons.fingerprint(&mut h);
    h.finish()
}

/// The constraint fingerprint exactly as it was written before
/// `max_power_w` and `objective` existed: the `max_aies` tag byte (+
/// value) followed by the three ablation booleans, nothing else.
fn legacy_fingerprint(
    max_aies: Option<u64>,
    no_latency_hiding: bool,
    no_threading: bool,
    analytic_ranking: bool,
) -> u64 {
    let mut h = Fnv64::new();
    match max_aies {
        Some(v) => {
            h.write_u8(1);
            h.write_u64(v);
        }
        None => h.write_u8(0),
    }
    h.write_bool(no_latency_hiding);
    h.write_bool(no_threading);
    h.write_bool(analytic_ranking);
    h.finish()
}

#[test]
fn default_fingerprint_matches_pre_refactor_goldens() {
    // FNV-1a over [0x00, 0x00, 0x00, 0x00] — the literal byte sequence
    // DseConstraints::default() hashed to before the power refactor.
    // If this constant moves, every serve client's remembered key and
    // every schema-1 snapshot key goes stale. Do not "fix" the constant.
    assert_eq!(
        fingerprint_of(&DseConstraints::default()),
        0x4d25_767f_9dce_13f5,
        "default DseConstraints fingerprint drifted from the pre-refactor bytes"
    );
    // The common serve operating point (max_aies = 400, everything else
    // default) — same era, same promise.
    assert_eq!(
        fingerprint_of(&DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        }),
        0xe010_69cf_ed57_745d,
        "max_aies=400 fingerprint drifted from the pre-refactor bytes"
    );
}

#[test]
fn fingerprint_matches_legacy_bytes_across_the_legacy_field_space() {
    // At default max_power_w/objective, the new fingerprint must equal
    // the legacy byte sequence for *every* combination of the legacy
    // fields, not just the defaults.
    for max_aies in [None, Some(1), Some(64), Some(400)] {
        for bits in 0u8..8 {
            let (nl, nt, ar) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            let cons = DseConstraints {
                max_aies,
                no_latency_hiding: nl,
                no_threading: nt,
                analytic_ranking: ar,
                max_power_w: None,
                objective: Objective::Throughput,
            };
            assert_eq!(
                fingerprint_of(&cons),
                legacy_fingerprint(max_aies, nl, nt, ar),
                "fingerprint bytes changed for {cons:?}"
            );
        }
    }
}

#[test]
fn new_fields_shift_the_fingerprint_only_when_set() {
    let base = fingerprint_of(&DseConstraints::default());
    // explicit defaults are the same constraints
    assert_eq!(
        base,
        fingerprint_of(&DseConstraints {
            max_power_w: None,
            objective: Objective::Throughput,
            ..Default::default()
        })
    );
    // non-default values are distinct cache entries
    let capped = fingerprint_of(&DseConstraints {
        max_power_w: Some(50.0),
        ..Default::default()
    });
    let pareto = fingerprint_of(&DseConstraints {
        objective: Objective::Pareto,
        ..Default::default()
    });
    let efficiency = fingerprint_of(&DseConstraints {
        objective: Objective::Efficiency,
        ..Default::default()
    });
    assert_ne!(base, capped);
    assert_ne!(base, pareto);
    assert_ne!(base, efficiency);
    assert_ne!(pareto, efficiency);
    assert_ne!(capped, pareto);
}

#[test]
fn design_key_unchanged_at_default_constraints_and_shifted_otherwise() {
    let rec = library::mm(1024, 1024, 1024, DType::F32);
    let cfg = WideSaConfig::default();
    let base = design_key(&rec, &cfg);
    // explicitly spelling out the new fields' defaults is a no-op
    let mut explicit = cfg.clone();
    explicit.constraints.max_power_w = None;
    explicit.constraints.objective = Objective::Throughput;
    assert_eq!(base, design_key(&rec, &explicit));
    // objective / power-cap overrides get their own cache entries
    let mut pareto = cfg.clone();
    pareto.constraints.objective = Objective::Pareto;
    assert_ne!(base, design_key(&rec, &pareto));
    let mut capped = cfg.clone();
    capped.constraints.max_power_w = Some(55.0);
    assert_ne!(base, design_key(&rec, &capped));
}

#[test]
fn snapshot_layout_is_frozen_and_stale_snapshots_warm_start() {
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(32),
            ..Default::default()
        },
        ..Default::default()
    });
    let rec = library::fir(65536, 15, DType::F32);
    let d = ws.compile(&rec).expect("small FIR compiles");
    let key = design_key(&rec, &ws.config);
    let line = entry_line(key, &d);

    // The schema-1 layout is frozen: power and frontier figures are
    // derived on load, never serialized, so this line is byte-compatible
    // with files written before the power refactor.
    assert!(line.contains("\"schema\":1"), "snapshot schema must stay 1");
    assert!(!line.contains("watts"), "power must not be serialized");
    assert!(!line.contains("tops_per_watt"), "power must not be serialized");
    assert!(!line.contains("objective"), "objective is not part of a design");
    assert!(!line.contains("frontier"), "frontier summaries are per-DSE-run");

    // A pre-refactor snapshot (same bytes, since the layout never
    // changed) warm-starts: entries load, and the loader reprices power
    // to exactly what the live compile produced.
    let dir = std::env::temp_dir().join(format!("widesa-cache-compat-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("snap.jsonl");
    std::fs::write(&path, format!("{line}\n")).unwrap();
    let (mut entries, skipped) = load_snapshot(&path);
    assert_eq!(skipped, 0, "a frozen-layout snapshot must load cleanly");
    assert_eq!(entries.len(), 1);
    let (loaded_key, back) = entries.remove(0);
    assert_eq!(loaded_key, key);
    assert_eq!(back.estimate.perf.tops.to_bits(), d.estimate.perf.tops.to_bits());
    assert_eq!(back.estimate.power.watts.to_bits(), d.estimate.power.watts.to_bits());
    assert_eq!(back.sim.watts.to_bits(), d.sim.watts.to_bits());

    // and the save path reproduces the identical bytes (round-trip
    // stability is what lets a server rewrite an old snapshot without
    // churning it)
    let arc = std::sync::Arc::new(back);
    save_snapshot(&path, &[(key, arc)]).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), format!("{line}\n"));
    let _ = std::fs::remove_file(&path);
}
