//! The dense-annealer equivalence corpus (run via `make pnr-smoke`:
//! `cargo test --features legacy-hash-pnr --test pnr_equivalence`).
//!
//! The flat-array annealer ([`widesa::place_route::anneal::anneal`])
//! replaced three `HashMap`s with a dense coordinate vector, a flat slot
//! grid, CSR incidence and a bitset violated-edge worklist — but it must
//! consume the *identical* RNG trace as the retained HashMap
//! implementation, so per seed the two produce bit-identical
//! (iterations, violations, converged, final placement). That invariant
//! lives in [`testkit::laws::dense_legacy_anneal`]; this corpus drives
//! it over MM sizes × seeds × budgets, which is what keeps
//! `deterministic_for_seed`, the E5 ablation and
//! `unconstrained_fails_at_400_within_budget` meaningful without
//! retuning any iteration budget.
#![cfg(feature = "legacy-hash-pnr")]

mod testkit;

use testkit::laws;
use widesa::arch::array::AieArray;
use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::{build, MappedGraph};
use widesa::mapping::cost::CostModel;
use widesa::mapping::dse::{explore, DseConstraints};
use widesa::recurrence::dtype::DType;
use widesa::recurrence::library;

fn graph(cap: u64) -> MappedGraph {
    let board = BoardConfig::vck5000();
    let cons = DseConstraints {
        max_aies: Some(cap),
        ..Default::default()
    };
    let (cand, _) =
        explore(&library::mm(8192, 8192, 8192, DType::F32), &board, &cons).unwrap();
    build(&cand, &CostModel::new(board))
}

#[test]
fn dense_annealer_is_bit_identical_to_legacy_across_corpus() {
    let array = AieArray::default();
    // MM-16 / MM-64 / MM-400 × seeds, under budgets spanning "converges
    // quickly", "runs out mid-flight" and the E5 non-convergence regime.
    for (cap, budget) in [
        (16u64, 500_000u64),
        (64, 50_000),
        (400, 50_000),
    ] {
        let g = graph(cap);
        for seed in [1u64, 3, 7, 11, 42] {
            laws::dense_legacy_anneal(&g, &array, seed, budget, &format!("MM-{cap}"));
        }
    }
}

#[test]
fn dense_annealer_convergence_budget_unchanged() {
    // The budgets the E5 experiment and the compiler tests rely on keep
    // their meaning: a 16-core design converges (both implementations at
    // the same iteration), a 400-core design does not within 20k iters.
    let array = AieArray::default();
    let g16 = graph(16);
    let r = laws::dense_legacy_anneal(&g16, &array, 3, 2_000_000, "MM-16");
    assert!(r.converged, "MM-16 must converge within 2M iterations");

    let g400 = graph(400);
    let r = laws::dense_legacy_anneal(&g400, &array, 3, 20_000, "MM-400");
    assert!(!r.converged, "MM-400 must not converge within 20k iterations");
}
