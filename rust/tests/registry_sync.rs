//! Registry-sync test: the three places that must agree on the artifact
//! set — the python `VARIANTS` table (`python/compile/model.py`), the
//! rust builtin manifest (`Manifest::builtin()`), and the stub executor's
//! dispatch — are checked against each other here, so a variant added or
//! renamed in one place fails CI instead of failing at runtime (the
//! ROADMAP's "three places in sync" hazard).

use std::collections::BTreeSet;
use widesa::runtime::artifact::Manifest;
use widesa::runtime::stub::StubExecutable;

fn model_py() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../python/compile/model.py");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read {path} (repo layout changed?): {e}"))
}

/// Extract the `VARIANTS = { ... }` block.
fn variants_block(src: &str) -> &str {
    let start = src
        .find("VARIANTS = {")
        .expect("model.py no longer defines VARIANTS");
    let rest = &src[start..];
    // the table is a top-level dict: it ends at the first column-0 brace
    let end = rest.find("\n}").expect("unterminated VARIANTS dict");
    &rest[..end]
}

/// `"name": (...)` keys of the VARIANTS dict, in order.
fn variant_names(block: &str) -> Vec<String> {
    let mut names = Vec::new();
    for line in block.lines() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix('"') {
            if let Some(q) = rest.find('"') {
                if rest[q + 1..].trim_start().starts_with(':') {
                    names.push(rest[..q].to_string());
                }
            }
        }
    }
    names
}

/// The integer arguments of each variant's example-argument factory call
/// (e.g. `_mm_args(256, 256, 256, jnp.float32)` → `("_mm_args", [256,
/// 256, 256], "float32")`).
fn factory_call(block: &str, name: &str) -> (String, Vec<usize>, String) {
    let line = block
        .lines()
        .find(|l| l.trim_start().starts_with(&format!("\"{name}\"")))
        .unwrap_or_else(|| panic!("no VARIANTS line for {name}"));
    let lambda = line
        .split("lambda:")
        .nth(1)
        .unwrap_or_else(|| panic!("{name}: no argument factory lambda"));
    let open = lambda.find('(').expect("factory call");
    let func = lambda[..open].trim().to_string();
    let close = lambda[open..].find(')').expect("factory call close") + open;
    let args = &lambda[open + 1..close];
    let mut ints = Vec::new();
    let mut dtype = String::new();
    for a in args.split(',') {
        let a = a.trim();
        if let Ok(v) = a.parse::<usize>() {
            ints.push(v);
        } else if let Some(d) = a.strip_prefix("jnp.") {
            dtype = d.to_string();
        }
    }
    (func, ints, dtype)
}

/// Input signature the python factory produces, mirrored in rust (the
/// same shape arithmetic as model.py's `_*_args` helpers).
fn expected_inputs(func: &str, ints: &[usize]) -> Vec<Vec<usize>> {
    match func {
        "_mm_args" => {
            let (n, m, k) = (ints[0], ints[1], ints[2]);
            vec![vec![n, k], vec![k, m], vec![n, m]]
        }
        "_conv_args" => {
            let (h, w, p, q) = (ints[0], ints[1], ints[2], ints[3]);
            vec![vec![h + p - 1, w + q - 1], vec![p, q], vec![h, w]]
        }
        "_fir_args" => {
            let (n, taps) = (ints[0], ints[1]);
            vec![vec![n + taps - 1], vec![taps]]
        }
        "_fir_c_args" => {
            let (n, taps) = (ints[0], ints[1]);
            vec![
                vec![n + taps - 1],
                vec![n + taps - 1],
                vec![taps],
                vec![taps],
            ]
        }
        "_fft_args" => {
            let (b, n) = (ints[0], ints[1]);
            vec![vec![b, n], vec![b, n]]
        }
        "_dwconv_args" => {
            let (c, h, w, p, q) = (ints[0], ints[1], ints[2], ints[3], ints[4]);
            vec![vec![c, h + p - 1, w + q - 1], vec![c, p, q], vec![c, h, w]]
        }
        "_trsv_args" => {
            let n = ints[0];
            vec![vec![n, n], vec![n]]
        }
        "_stencil_args" => {
            // ints = [stages, n, m]; stages is baked into the variant's
            // sweep count, not its shapes
            let (n, m) = (ints[1], ints[2]);
            vec![vec![n, m], vec![5]]
        }
        "_ca_reduce_args" => {
            let (rep, n, m) = (ints[0], ints[1], ints[2]);
            vec![vec![rep, n, m]]
        }
        "_seidel_args" => {
            // ints = [stages, n, m]; stages is baked into the sweep count
            let (n, m) = (ints[1], ints[2]);
            vec![vec![n, m], vec![5]]
        }
        other => panic!("unknown factory {other} — extend this test"),
    }
}

#[test]
fn builtin_manifest_matches_python_variants() {
    let src = model_py();
    let block = variants_block(&src);
    let python: BTreeSet<String> = variant_names(block).into_iter().collect();
    assert!(
        !python.is_empty(),
        "parsed zero VARIANTS keys — parser out of date with model.py?"
    );
    let builtin: BTreeSet<String> = Manifest::builtin().artifacts.keys().cloned().collect();
    assert_eq!(
        python, builtin,
        "python VARIANTS and Manifest::builtin() disagree"
    );
}

#[test]
fn builtin_shapes_match_python_factories() {
    let src = model_py();
    let block = variants_block(&src);
    let manifest = Manifest::builtin();
    for name in variant_names(block) {
        let (func, ints, dtype) = factory_call(block, &name);
        let spec = manifest.get(&name).unwrap();
        let want = expected_inputs(&func, &ints);
        let got: Vec<Vec<usize>> = spec.inputs.iter().map(|t| t.shape.clone()).collect();
        assert_eq!(got, want, "{name}: input shapes disagree with model.py");
        for t in spec.inputs.iter().chain(&spec.outputs) {
            assert_eq!(t.dtype, dtype, "{name}: dtype disagrees with model.py");
        }
    }
}

#[test]
fn stub_dispatches_every_variant() {
    let manifest = Manifest::builtin();
    for (name, spec) in &manifest.artifacts {
        let exe = StubExecutable::compile(spec)
            .unwrap_or_else(|e| panic!("stub has no dispatch arm for {name}: {e}"));
        assert_eq!(exe.name(), name);
    }
}
