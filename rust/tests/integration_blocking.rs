//! Blocked-replay equivalence corpus: the oracle law
//! [`testkit::laws::blocked_matches_serial_mm`] (blocked + double-
//! buffered replay ≡ serial naive replay, bit-for-bit) driven over
//! targeted shapes — ragged, prime, smaller-than-one-tile — and
//! testkit-random (n, m, k), in the divergence-corpus style. Also pins
//! the planner's protocol behaviour: typed [`Unplannable`] errors for
//! shapes the blocking hierarchy cannot place.

mod testkit;

use testkit::{cases, laws};
use widesa::arch::vck5000::BoardConfig;
use widesa::coordinator::blocking::{plan_mm, Unplannable};
use widesa::coordinator::exec::{run_mm, NullArray};
use widesa::mapping::cost::CostModel;
use widesa::util::rng::XorShift64;

#[cfg(not(feature = "pjrt"))]
use widesa::runtime::client::Runtime;

fn random_mm(rng: &mut XorShift64, n: usize, m: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
    let mut a = vec![0f32; n * k];
    let mut b = vec![0f32; k * m];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    (a, b)
}

/// Targeted corpus: one-element, prime, sub-tile, tile-exact,
/// mixed-granularity, and ragged shapes all replay bit-identically to
/// the serial oracle on the stub runtime.
#[cfg(not(feature = "pjrt"))]
#[test]
fn blocked_law_targeted_shapes() {
    let mut rt = Runtime::with_builtin();
    let mut rng = XorShift64::new(0xB10C);
    for (n, m, k) in [
        (1usize, 1usize, 1usize),
        (10, 10, 10),
        (127, 131, 7),
        (128, 128, 128),
        (256, 128, 64),
        (300, 260, 200),
    ] {
        let (a, b) = random_mm(&mut rng, n, m, k);
        laws::blocked_matches_serial_mm(&mut rt, &a, &b, n, m, k);
    }
}

/// Random corpus: testkit-PRNG shapes in [1, 280]³ (ragged with
/// probability ≈ 1), swept `PROPTEST_CASES` deep on the nightly lane.
#[cfg(not(feature = "pjrt"))]
#[test]
fn blocked_law_random_shapes() {
    let mut rt = Runtime::with_builtin();
    let mut rng = XorShift64::new(0x60B10C);
    for _ in 0..cases(6) {
        let n = 1 + rng.gen_range(280) as usize;
        let m = 1 + rng.gen_range(280) as usize;
        let k = 1 + rng.gen_range(280) as usize;
        let (a, b) = random_mm(&mut rng, n, m, k);
        laws::blocked_matches_serial_mm(&mut rt, &a, &b, n, m, k);
    }
}

/// The law also holds on the NullArray host-path backend (what
/// `benches/bench_blocking.rs` times): both drivers degrade to the same
/// all-zero output and the blocked stats still match the plan.
#[test]
fn blocked_law_on_null_array() {
    let mut rng = XorShift64::new(0x11A);
    for (n, m, k) in [(64usize, 200usize, 130usize), (257, 129, 255)] {
        let (a, b) = random_mm(&mut rng, n, m, k);
        laws::blocked_matches_serial_mm(&mut NullArray, &a, &b, n, m, k);
    }
}

/// Shapes the planner cannot place come back as typed [`Unplannable`]
/// errors — through the planner directly and through the replay driver's
/// `anyhow` chain (what serve downcasts for its protocol response).
#[test]
fn unplannable_is_typed_end_to_end() {
    let model = CostModel::new(BoardConfig::vck5000());
    let huge = 1_000_000_000u64;
    let err = plan_mm(&model, huge, huge, huge).unwrap_err();
    assert_eq!((err.n, err.m, err.k), (huge, huge, huge));
    assert!(err.to_string().contains("staging cap"), "{err}");

    let err = run_mm(&mut NullArray, &[], &[], 0, 4, 0).unwrap_err();
    let typed = err
        .downcast_ref::<Unplannable>()
        .expect("replay surfaces Unplannable through anyhow");
    assert_eq!(typed.n, 0);
}

/// The planner is deterministic and self-consistent over a PRNG sweep:
/// same shape → bit-identical plan; every plan's predicted bytes come
/// from the shared cost model for its own geometry.
#[test]
fn planner_deterministic_over_random_shapes() {
    let model = CostModel::new(BoardConfig::vck5000());
    let mut rng = XorShift64::new(0xDE7);
    for _ in 0..cases(24) {
        let n = 1 + rng.gen_range(4096);
        let m = 1 + rng.gen_range(4096);
        let k = 1 + rng.gen_range(4096);
        let p1 = plan_mm(&model, n, m, k).unwrap();
        let p2 = plan_mm(&model, n, m, k).unwrap();
        assert_eq!(p1, p2, "plan for {n}x{m}x{k} not deterministic");
        assert_eq!(p1.predicted_dram_bytes, {
            let b_res = p1.order == widesa::coordinator::blocking::PanelOrder::BResident;
            model.blocked_mm_dram_bytes(p1.n_pad, p1.m_pad, p1.k_pad, 4, p1.kc, p1.span, b_res)
        });
        assert_eq!(p1.rounds, (p1.n_pad / p1.tile) * (p1.m_pad / p1.tile) * (p1.k_pad / p1.tile));
    }
}
