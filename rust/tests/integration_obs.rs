//! Integration tests for the `widesa::obs` layer: Chrome-trace
//! well-formedness and span-nesting invariants over random recurrences
//! (testkit generators), metric-registry determinism under concurrent
//! serve traffic, reconciliation of the `"stats"` protocol command with
//! `ServeStats`, and the committed `BENCH_trend.jsonl` seed.
//!
//! Tracing state (the event sink, the enabled flag) is process-global
//! and the test harness runs in parallel, so every tracing test filters
//! the sink by its own trace IDs and never asserts on the sink as a
//! whole.

mod testkit;

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::obs::trace::{self, Span, TraceCtx};
use widesa::obs::trend;
use widesa::serve::{ServeConfig, ServeHandle};
use widesa::util::json::{parse, Json};
use widesa::util::rng::XorShift64;
use widesa::DseConstraints;

fn small_handle() -> ServeHandle {
    ServeHandle::new(ServeConfig {
        base: WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(32),
                ..Default::default()
            },
            ..Default::default()
        },
        cache_capacity: 16,
        cache_shards: 4,
        dse_threads: 4,
        request_workers: 4,
        ..Default::default()
    })
}

/// Property: any compile — random recurrence, random AIE budget, legal
/// or not — exports a Chrome trace that passes the same validator
/// `widesa obs-check` runs: well-formed "X" events, per-thread nesting,
/// dse.*/pnr.* under their parents, one trace ID throughout.
#[test]
fn traced_compiles_export_valid_chrome_traces() {
    trace::set_enabled(true);
    let mut rng = XorShift64::new(0xB0B5);
    for case in 0..testkit::cases(6) {
        let rec = testkit::random_recurrence(&mut rng);
        let cons = testkit::random_constraints(&mut rng);
        let id = trace::next_trace_id();
        {
            let _ctx = TraceCtx::set(id);
            let root = Span::begin("map", "cli");
            // a failed mapping still closes every span it opened
            let _ = WideSa::new(WideSaConfig {
                constraints: cons,
                ..Default::default()
            })
            .compile(&rec);
            drop(root);
        }
        let evs: Vec<_> = trace::snapshot_events()
            .into_iter()
            .filter(|e| e.trace_id == id)
            .collect();
        assert!(!evs.is_empty(), "case {case} ({}): no events", rec.name);
        let doc = trace::export_chrome(&evs);
        let report = trace::validate_chrome(&doc)
            .unwrap_or_else(|e| panic!("case {case} ({}): {e:#}", rec.name));
        assert_eq!(report.root_name, "map", "case {case}");
        assert_eq!(report.trace_ids, 1, "case {case}");
    }
}

/// The serve registry snapshot is byte-stable when quiescent and its
/// counters agree with `ServeStats` after genuinely concurrent traffic.
#[test]
fn registry_snapshot_is_deterministic_under_concurrent_serve_traffic() {
    let handle = small_handle();
    let line = r#"{"id":1,"bench":"fir","dims":[65536,15],"max_aies":32}"#;
    let first = parse(&handle.handle_line(line)).unwrap();
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true));
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                for _ in 0..25 {
                    handle.handle_line(line);
                }
            });
        }
    });
    let stats = handle.stats();
    assert_eq!(
        stats.hits + stats.misses + stats.deduped,
        201,
        "every request lands in exactly one outcome counter"
    );
    let snap1 = handle.metrics().snapshot().to_string();
    let snap2 = handle.metrics().snapshot().to_string();
    assert_eq!(snap1, snap2, "quiescent snapshots must be byte-identical");
    let doc = parse(&snap1).unwrap();
    let counter = |name: &str| {
        doc.get("counters")
            .and_then(|c| c.get(name))
            .and_then(Json::as_u64)
            .unwrap_or_else(|| panic!("missing counter {name}"))
    };
    assert_eq!(counter("serve.hits"), stats.hits);
    assert_eq!(counter("serve.misses"), stats.misses);
    assert_eq!(counter("serve.deduped"), stats.deduped);
    // every handled line lands in the request-latency histogram
    let req_count = doc
        .get("histograms")
        .and_then(|h| h.get("serve.request_us"))
        .and_then(|h| h.get("count"))
        .and_then(Json::as_u64)
        .unwrap();
    assert_eq!(req_count, 201);
}

/// The in-band `{"cmd":"stats"}` answer reconciles with the
/// programmatic `ServeStats` view and carries both metric registries.
#[test]
fn stats_command_reconciles_with_serve_stats() {
    let handle = small_handle();
    let line = r#"{"id":7,"bench":"fir","dims":[131072,15],"max_aies":32}"#;
    let cold = parse(&handle.handle_line(line)).unwrap();
    assert_eq!(cold.get("cached").and_then(Json::as_bool), Some(false));
    let hit = parse(&handle.handle_line(line)).unwrap();
    assert_eq!(hit.get("cached").and_then(Json::as_bool), Some(true));
    let bad = parse(&handle.handle_line("{\"id\":8}")).unwrap();
    assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));

    let out = parse(&handle.handle_line(r#"{"cmd":"stats","id":99}"#)).unwrap();
    assert_eq!(out.get("id").and_then(Json::as_u64), Some(99));
    assert_eq!(out.get("ok").and_then(Json::as_bool), Some(true));
    let s = handle.stats();
    let got = |k: &str| out.get("stats").and_then(|v| v.get(k)).and_then(Json::as_u64);
    assert_eq!(got("hits"), Some(s.hits));
    assert_eq!(got("misses"), Some(s.misses));
    assert_eq!(got("deduped"), Some(s.deduped));
    assert_eq!(got("errors"), Some(s.errors));
    assert_eq!(got("shed"), Some(s.shed));
    assert_eq!(got("plan_hits"), Some(s.plan_hits));
    assert_eq!(got("cache_len"), Some(s.cache.len as u64));

    // the metrics payload is the same registry the handle exposes
    let m = out.get("metrics").expect("metrics in stats response");
    let serve_counters = m.get("serve").and_then(|v| v.get("counters")).unwrap();
    assert_eq!(serve_counters.get("serve.hits").and_then(Json::as_u64), Some(s.hits));
    assert!(m.get("pipeline").and_then(|v| v.get("counters")).is_some());

    // the stats line itself bypasses the request path: three data lines
    // handled, three request_us samples
    let req_count = m
        .get("serve")
        .and_then(|v| v.get("histograms"))
        .and_then(|h| h.get("serve.request_us"))
        .and_then(|h| h.get("count"))
        .and_then(Json::as_u64)
        .unwrap();
    assert_eq!(req_count, 3);
}

/// `stage_ms` in a served design is span-derived: each stage is
/// positive, and the stages partition (don't exceed) the recorded P&R
/// wall time.
#[test]
fn served_stage_timings_partition_the_pnr_wall() {
    let handle = small_handle();
    let rec = widesa::recurrence::library::fir(65536, 15, widesa::DType::F32);
    let res = handle.compile(&rec).unwrap();
    let c = &res.design.compile;
    let stages = &c.stages;
    assert!(stages.place_ms >= 0.0 && stages.assign_ms >= 0.0 && stages.route_ms >= 0.0);
    let sum_s = (stages.place_ms + stages.assign_ms + stages.route_ms) / 1e3;
    assert!(
        sum_s <= c.wall_s + 1e-3,
        "stage sum {sum_s}s exceeds P&R wall {}s",
        c.wall_s
    );
}

/// The committed trend seed parses under the same reader CI appends
/// with, and every line carries the schema + commit keys.
#[test]
fn committed_trend_seed_parses() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_trend.jsonl");
    let text = std::fs::read_to_string(&path).expect("BENCH_trend.jsonl committed at repo root");
    let lines = trend::parse_trend(&text).expect("seed parses");
    assert!(!lines.is_empty());
    for line in &lines {
        // schema 1 = latency-only era, schema 2 added the energy section;
        // the append-only seed legitimately spans eras.
        let schema = line.get("schema").and_then(Json::as_u64).expect("schema");
        assert!(
            (1..=u64::from(trend::TREND_SCHEMA)).contains(&schema),
            "unknown trend schema {schema}"
        );
        assert!(line.get("commit").and_then(Json::as_str).is_some());
        assert!(line.get("serve").is_some() && line.get("compile").is_some());
    }
}
