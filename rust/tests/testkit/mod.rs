//! Shared property-test generators for integration tests.
//!
//! The vendored offline crate set has no proptest, so properties sweep
//! deterministic-PRNG cases instead. This module is the single home for
//! the generators those sweeps share (random loop nests, random library
//! recurrences, random constraint sets) plus [`cases`], the knob that
//! lets CI run a cheap PR lane and an exhaustive nightly lane
//! (`PROPTEST_CASES=512`) from the same tests.
//!
//! Each test crate pulls this in with `mod testkit;` — not every crate
//! uses every generator, hence the file-wide `dead_code` allow.
//! [`laws`] holds the reusable oracle-equivalence law functions the
//! divergence and P&R corpora drive.
#![allow(dead_code)]

pub mod laws;

use widesa::mapping::dse::DseConstraints;
use widesa::polyhedral::dependence::{DepKind, Dependence};
use widesa::polyhedral::domain::{IterationDomain, LoopDim};
use widesa::polyhedral::schedule::LoopNest;
use widesa::recurrence::{dtype::DType, library};
use widesa::util::rng::XorShift64;
use widesa::UniformRecurrence;

/// Cases to sweep per property: `default` unless the `PROPTEST_CASES`
/// environment variable overrides it (the nightly CI lane sets 512; a
/// local `PROPTEST_CASES=10 cargo test` gives a quick smoke).
pub fn cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// A random legal loop nest: rank 2–4, modest extents, 1–3 flow
/// dependences that are lexicographically positive by construction
/// (first non-zero entry +1), so every generated nest admits a legal
/// schedule.
pub fn random_nest(rng: &mut XorShift64) -> LoopNest {
    let rank = 2 + rng.gen_range(3) as usize;
    let dims: Vec<LoopDim> = (0..rank)
        .map(|i| LoopDim::new(format!("l{i}"), 4 + rng.gen_range(60)))
        .collect();
    let ndeps = 1 + rng.gen_range(3) as usize;
    let deps: Vec<Dependence> = (0..ndeps)
        .map(|_| {
            let mut v = vec![0i64; rank];
            let lead = rng.gen_range(rank as u64) as usize;
            v[lead] = 1;
            for c in v.iter_mut().skip(lead + 1) {
                *c = rng.gen_range(3) as i64 - 1;
            }
            Dependence::new("X", DepKind::Flow, v)
        })
        .collect();
    LoopNest::new(IterationDomain::new(dims), deps)
}

/// A random library recurrence: one of the seven benchmark constructors
/// with random (constructor-legal) sizes. Covers both access-derived
/// and carried-dependence workloads.
pub fn random_recurrence(rng: &mut XorShift64) -> UniformRecurrence {
    let small = |r: &mut XorShift64| 4 + r.gen_range(60);
    match rng.gen_range(7) {
        0 => {
            let (n, m, k) = (small(rng), small(rng), small(rng));
            library::mm(n, m, k, DType::F32)
        }
        1 => {
            let (h, w) = (8 + rng.gen_range(56), 8 + rng.gen_range(56));
            library::conv2d(h, w, 4, 4, DType::I8)
        }
        2 => library::fir(64 + rng.gen_range(4096), 15, DType::F32),
        // fft2d requires power-of-two columns and a complex dtype
        3 => library::fft2d(8 + rng.gen_range(120), 64, DType::CF32),
        4 => {
            let (c, h) = (1 + rng.gen_range(32), 8 + rng.gen_range(56));
            library::dw_conv2d(c, h, h, 3, 3, DType::F32)
        }
        5 => library::trsv(small(rng), DType::F32),
        _ => {
            let (t, n) = (1 + rng.gen_range(8), 8 + rng.gen_range(120));
            library::stencil2d_chain(t, n, n, DType::F32)
        }
    }
}

/// A random standard/communication-avoiding pair over one MM problem:
/// the CA side splits a random k across 2, 4 or 8 summand replicas — the
/// replication axis, the first axis that is neither space, time, nor
/// tile. Extents are constructor-legal by construction (k divides across
/// the replicas) and small enough that both forms map on a full array.
pub fn random_ca_pair(rng: &mut XorShift64) -> (UniformRecurrence, UniformRecurrence) {
    let rep = 1u64 << (1 + rng.gen_range(3)); // 2, 4, or 8 replicas
    let n = 64 + 64 * rng.gen_range(16);
    let m = 64 + 64 * rng.gen_range(16);
    let k = rep * (16 + 16 * rng.gen_range(32));
    (
        library::mm(n, m, k, DType::F32),
        library::ca_mm_25d(n, m, k, rep, DType::F32),
    )
}

/// A random DSE constraint set: an AIE budget somewhere between a
/// handful of cores and the full VCK5000 array.
pub fn random_constraints(rng: &mut XorShift64) -> DseConstraints {
    DseConstraints {
        max_aies: Some(8 + rng.gen_range(392)),
        ..Default::default()
    }
}
