//! Reusable oracle-equivalence laws.
//!
//! Each law states one "two implementations must agree bit-for-bit"
//! invariant as a plain function over a workload, so every test crate
//! (and every future corpus) asserts the *same* property instead of
//! re-implementing its own comparison loop:
//!
//! * [`serial_parallel_ranking`] — the scoped-thread DSE is
//!   bit-identical to the serial reference, estimates included;
//! * [`predictor_matches_merge`] — the incremental port predictor equals
//!   real packet merging on every scored candidate;
//! * [`dense_legacy_anneal`] — the flat-array annealer replays the
//!   legacy HashMap implementation exactly (behind `legacy-hash-pnr`);
//! * [`pareto_frontier`] — the Pareto ranking's frontier prefix is
//!   non-dominated, membership is insertion-order independent, and the
//!   serial and scoped-thread drivers agree bit-for-bit;
//! * [`blocked_matches_serial_mm`] — the planned, double-buffered MM
//!   replay is bit-identical to the serial naive replay on any (n, m, k),
//!   including ragged, prime, and smaller-than-one-tile shapes, and its
//!   measured host traffic equals the plan's prediction;
//! * [`exact_winner_fits_after_merge`] — wherever the exact and legacy
//!   analytic rankings diverge, the exact-ranked winner still satisfies
//!   the paper's PLIO budget after real packet merging;
//! * [`ca_selected_iff_port_bound`] — [`dse::select_form`] crowns the
//!   communication-avoiding form exactly when the standard winner is
//!   port-bound, with "port-bound" re-verified against the real merge.
//!
//! `tests/divergence_corpus.rs`, `tests/pnr_equivalence.rs`, and
//! `tests/integration_blocking.rs` drive these over the Table II corpus
//! and testkit-random shapes; the laws themselves stay corpus-agnostic.

use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::build;
use widesa::graph::packet::{merge_ports_with_budget, predict_ports};
use widesa::mapping::dse::{
    self, explore_all, explore_all_parallel, DseConstraints, Objective, Ranked,
};
use widesa::recurrence::spec::UniformRecurrence;
use widesa::util::rng::XorShift64;

/// Two rankings are the same ranking: same candidates in the same order
/// with bit-identical perf *and* power estimates.
pub fn assert_rankings_bit_identical(a: &Ranked, b: &Ranked, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: ranking lengths diverged");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.0.summary(), y.0.summary(), "{what}: rank {i} candidate");
        assert_eq!(
            x.1.perf.tops.to_bits(),
            y.1.perf.tops.to_bits(),
            "{what}: rank {i} tops"
        );
        assert_eq!(
            x.1.power.watts.to_bits(),
            y.1.power.watts.to_bits(),
            "{what}: rank {i} watts"
        );
        assert_eq!(
            x.1.power.tops_per_watt.to_bits(),
            y.1.power.tops_per_watt.to_bits(),
            "{what}: rank {i} TOPS/W"
        );
    }
}

/// Law: the scoped-thread exploration driver returns the serial
/// reference ranking bit-for-bit at every thread count. Returns the
/// serial ranking so callers can chain further checks without
/// re-exploring.
pub fn serial_parallel_ranking(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
    thread_counts: &[usize],
) -> Ranked {
    let serial = explore_all(rec, board, cons);
    for &threads in thread_counts {
        let par = explore_all_parallel(rec, board, cons, threads);
        assert_rankings_bit_identical(
            &serial,
            &par,
            &format!("{} × {threads} threads", rec.name),
        );
    }
    serial
}

/// Law: on every candidate the DSE scores for `rec`, the incremental
/// port predictor is bit-identical to really merging the built graph
/// under the board's PLIO budget.
pub fn predictor_matches_merge(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) {
    let model = dse::scoring_model(board, cons);
    let plan = dse::plan(rec, board, cons);
    let (in_b, out_b) = (
        board.plio.in_channels as usize,
        board.plio.out_channels as usize,
    );
    for choice in plan.choices.clone() {
        let Some((cand, _)) = dse::score_choice(rec, &model, cons, &plan, choice) else {
            continue;
        };
        let g = build(&cand, &model);
        let (_, stats) = merge_ports_with_budget(&g, model.channel_bw(), in_b, out_b);
        let predicted = predict_ports(&cand, &model, model.channel_bw(), in_b, out_b);
        assert_eq!(
            predicted,
            stats,
            "{}: predictor diverged from merge on {}",
            rec.name,
            cand.summary()
        );
    }
}

/// Law: the dense flat-array annealer consumes the identical RNG trace
/// as the retained HashMap implementation — per seed the two produce
/// bit-identical (iterations, violations, converged, placement).
#[cfg(feature = "legacy-hash-pnr")]
pub fn dense_legacy_anneal(
    g: &widesa::graph::builder::MappedGraph,
    array: &widesa::arch::array::AieArray,
    seed: u64,
    budget: u64,
    what: &str,
) -> widesa::place_route::anneal::AnnealResult {
    use std::collections::BTreeMap;
    use widesa::arch::array::Coord;
    use widesa::graph::node::NodeId;
    use widesa::place_route::anneal::{anneal, legacy::anneal_legacy};

    let dense = anneal(g, array, seed, budget);
    let legacy = anneal_legacy(g, array, seed, budget);
    assert_eq!(
        dense.iterations, legacy.iterations,
        "{what} seed {seed}: iteration counts diverged"
    );
    assert_eq!(
        dense.violations, legacy.violations,
        "{what} seed {seed}: violation counts diverged"
    );
    assert_eq!(dense.converged, legacy.converged, "{what} seed {seed}");
    let coords = |p: &widesa::place_route::placement::Placement| -> BTreeMap<NodeId, Coord> {
        p.iter().collect()
    };
    assert_eq!(
        coords(&dense.placement),
        coords(&legacy.placement),
        "{what} seed {seed}: final placements diverged"
    );
    dense
}

/// Law: the blocked + double-buffered MM replay walks its plan to the
/// exact bits of the serial naive replay — the prefetch thread only ever
/// packs (pure `memcpy`), every per-C-tile k-chain ascends strictly, and
/// segment partials round-trip verbatim, so no float operation reorders.
/// Also pins the plan's self-consistency: the driver makes exactly
/// `plan.rounds` kernel calls and moves exactly the predicted bytes
/// (both sides count with the same convention).
pub fn blocked_matches_serial_mm<B: widesa::coordinator::exec::ArrayBackend>(
    rt: &mut B,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    k: usize,
) {
    use widesa::coordinator::exec::{run_mm, run_mm_naive};
    let (blocked, stats) = run_mm(rt, a, b, n, m, k).expect("blocked replay");
    let (serial, _) = run_mm_naive(rt, a, b, n, m, k).expect("serial replay");
    assert_eq!(blocked.len(), serial.len(), "{n}x{m}x{k}: output lengths");
    for (i, (x, y)) in blocked.iter().zip(&serial).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{n}x{m}x{k}: element {i} diverged ({x} vs {y})"
        );
    }
    let plan = stats.plan.expect("blocked replay records its plan");
    assert_eq!(
        stats.rounds, plan.rounds,
        "{n}x{m}x{k}: round count diverged from the plan"
    );
    assert_eq!(
        stats.dram_bytes, plan.predicted_dram_bytes,
        "{n}x{m}x{k}: measured host traffic diverged from the plan"
    );
}

/// Law: however far the legacy analytic ranking drifts from the exact
/// one, the exact-ranked winner must satisfy the given board's PLIO
/// budget (capped at the paper's 78) after *really merging* its built
/// graph. Both rankings must score the same candidate set. Returns a
/// description of every rank position where the two orderings disagree —
/// informative for test logs, never a failure by itself.
pub fn exact_winner_fits_after_merge(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    exact: &DseConstraints,
    analytic: &DseConstraints,
) -> Vec<String> {
    assert!(!exact.analytic_ranking && analytic.analytic_ranking);
    let exact_ranked = explore_all(rec, board, exact);
    let analytic_ranked = explore_all(rec, board, analytic);
    // both rankings score the same candidate set, just ordered (and
    // priced) differently
    assert_eq!(exact_ranked.len(), analytic_ranked.len(), "{}", rec.name);
    let budget = board.plio.in_channels;
    let divergences = exact_ranked
        .iter()
        .zip(&analytic_ranked)
        .enumerate()
        .filter(|(_, (e, a))| e.0.summary() != a.0.summary())
        .map(|(pos, (e, a))| {
            format!(
                "{} @ {budget} ch, rank {pos}: exact [{}] vs analytic [{}]",
                rec.name,
                e.0.summary(),
                a.0.summary()
            )
        })
        .collect();
    // whatever the approximation would have crowned, the exact-ranked
    // winner must fit the paper's PLIO budget once the graph is really
    // merged
    let Some((winner, _)) = exact_ranked.first() else {
        panic!("{}: empty ranking", rec.name);
    };
    let model = dse::scoring_model(board, exact);
    let (_, stats) = merge_ports_with_budget(
        &build(winner, &model),
        model.channel_bw(),
        board.plio.in_channels as usize,
        board.plio.out_channels as usize,
    );
    assert!(
        stats.in_ports_after <= 78,
        "{} @ {budget} ch: exact winner needs {} input ports",
        rec.name,
        stats.in_ports_after
    );
    assert!(
        stats.out_ports_after <= 78,
        "{} @ {budget} ch: exact winner needs {} output ports",
        rec.name,
        stats.out_ports_after
    );
    divergences
}

/// Law: [`dse::select_form`] crowns the communication-avoiding form
/// exactly when the standard winner is PLIO-bound — and "port-bound" is
/// re-verified against *really merging* the standard winner's built
/// graph under the board budget, not just against the predictor the DSE
/// consulted (which [`predictor_matches_merge`] pins separately).
/// Returns the selection so corpora can chain further checks.
pub fn ca_selected_iff_port_bound(
    std_rec: &UniformRecurrence,
    ca_rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) -> dse::FormSelection {
    let sel = dse::select_form(std_rec, ca_rec, board, cons)
        .unwrap_or_else(|| panic!("{}: no legal mapping for either form", std_rec.name));
    let model = dse::scoring_model(board, cons);
    let (in_b, out_b) = (
        board.plio.in_channels as usize,
        board.plio.out_channels as usize,
    );
    let (_, stats) = merge_ports_with_budget(
        &build(&sel.standard.0, &model),
        model.channel_bw(),
        in_b,
        out_b,
    );
    let fits = stats.in_ports_after <= in_b && stats.out_ports_after <= out_b;
    assert_eq!(
        sel.standard_fits, fits,
        "{} @ {in_b}/{out_b} ch: select_form's port verdict diverged from the real merge",
        std_rec.name
    );
    assert_eq!(
        sel.selected == dse::Form::Ca,
        !fits,
        "{} @ {in_b}/{out_b} ch: CA crowned but standard form {} port-bound",
        std_rec.name,
        if fits { "is not" } else { "is" }
    );
    sel
}

/// Frontier prefix of a Pareto ranking as a sorted membership list.
fn frontier_members(ranked: &Ranked) -> Vec<String> {
    let k = dse::frontier_size(ranked);
    let mut m: Vec<String> = ranked[..k].iter().map(|(c, _)| c.summary()).collect();
    m.sort();
    m
}

/// Law: under [`Objective::Pareto`],
///
/// 1. the ranking's frontier prefix is exactly the non-dominated set
///    over `(tops, tops_per_watt)` — nothing in the prefix is dominated,
///    everything after it is;
/// 2. frontier membership (and the full ranked sequence) is independent
///    of the order candidates were scored in — reversed and shuffled
///    insertions re-rank to the same frontier;
/// 3. the serial and scoped-thread drivers return the ranking
///    bit-for-bit.
pub fn pareto_frontier(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
    thread_counts: &[usize],
) {
    let cons = DseConstraints {
        objective: Objective::Pareto,
        ..cons.clone()
    };
    // (3) serial ≡ parallel, which also hands us the reference ranking.
    let ranked = serial_parallel_ranking(rec, board, &cons, thread_counts);
    assert!(!ranked.is_empty(), "{}: empty ranking", rec.name);

    // (1) the frontier prefix is the non-dominated set.
    let pts: Vec<(f64, f64)> = ranked
        .iter()
        .map(|(_, e)| (e.perf.tops, e.power.tops_per_watt))
        .collect();
    let dominated = |i: usize| {
        pts.iter().any(|&(t, w)| {
            t >= pts[i].0 && w >= pts[i].1 && (t > pts[i].0 || w > pts[i].1)
        })
    };
    let k = dse::frontier_size(&ranked);
    assert!(
        (1..=ranked.len()).contains(&k),
        "{}: frontier {k}/{}",
        rec.name,
        ranked.len()
    );
    for i in 0..ranked.len() {
        assert_eq!(
            i < k,
            !dominated(i),
            "{}: rank {i} ({}) on the wrong side of the frontier split",
            rec.name,
            ranked[i].0.summary()
        );
    }
    // Frontier TOPS must be descending (the prefix keeps the sort order).
    for w in pts[..k].windows(2) {
        assert!(w[0].0 >= w[1].0, "{}: frontier not TOPS-descending", rec.name);
    }

    // (2) insertion-order independence: reversed and PRNG-shuffled
    // inputs re-rank to the same frontier membership.
    let reference = frontier_members(&ranked);
    let mut reversed: Ranked = ranked.clone();
    reversed.reverse();
    let reranked = dse::rank_by(reversed, Objective::Pareto);
    assert_eq!(
        frontier_members(&reranked),
        reference,
        "{}: frontier membership changed under reversed insertion",
        rec.name
    );
    let mut shuffled: Ranked = ranked.clone();
    let mut rng = XorShift64::new(0x51DE5A);
    for i in (1..shuffled.len()).rev() {
        let j = rng.gen_range(i as u64 + 1) as usize;
        shuffled.swap(i, j);
    }
    let reranked = dse::rank_by(shuffled, Objective::Pareto);
    assert_eq!(
        frontier_members(&reranked),
        reference,
        "{}: frontier membership changed under shuffled insertion",
        rec.name
    );
}
