//! Integration: the full mapping pipeline (no PJRT) across benchmarks,
//! budgets and ablations — every stage's invariants checked against the
//! next stage's inputs.

use widesa::arch::array::AieArray;
use widesa::arch::plio::PlioDir;
use widesa::arch::vck5000::BoardConfig;
use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::graph::builder::build;
use widesa::graph::packet::merge_ports;
use widesa::mapping::cost::{CostModel, PerfBound};
use widesa::mapping::dse::{explore, DseConstraints};
use widesa::place_route::placement::place;
use widesa::plio::assignment::assign;
use widesa::recurrence::{dtype::DType, library};

fn ws(max_aies: u64) -> WideSa {
    WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(max_aies),
            ..Default::default()
        },
        ..Default::default()
    })
}

#[test]
fn every_table2_benchmark_compiles_end_to_end() {
    for rec in library::table2_benchmarks() {
        let cap = if rec.name.starts_with("fft") {
            320
        } else if rec.name.starts_with("fir") {
            256
        } else {
            400
        };
        let d = ws(cap)
            .compile(&rec)
            .unwrap_or_else(|e| panic!("{}: {e}", rec.name));
        assert!(d.compile.success, "{} failed P&R", rec.name);
        assert!(d.estimate.perf.tops > 0.0);
        assert!(d.merge_stats.in_ports_after <= 78, "{}", rec.name);
        assert!(d.merge_stats.out_ports_after <= 78, "{}", rec.name);
        assert!(d.estimate.perf.aies <= cap);
    }
}

#[test]
fn graph_matches_candidate_shape() {
    let board = BoardConfig::vck5000();
    for cap in [64, 160, 400] {
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(4096, 4096, 4096, DType::F32), &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let g = build(&cand, &model);
        assert_eq!(g.num_aies() as u64, cand.aies_used());
    }
}

#[test]
fn placement_plus_assignment_is_consistent() {
    let board = BoardConfig::vck5000();
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    let (cand, _) = explore(&library::mm(8192, 8192, 8192, DType::I8), &board, &cons).unwrap();
    let model = CostModel::new(board.clone());
    let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
    let pl = place(&g, &AieArray::default()).unwrap();
    assert!(pl.is_valid(&AieArray::default()));
    assert!(pl.shared_buffers_adjacent(&g, &AieArray::default()));
    let a = assign(&g, &pl, &board.plio, board.array.rc_west, board.array.rc_east);
    assert!(a.feasible);
    // every PLIO node got a column inside the interface range
    for n in g.plio_nodes() {
        let col = a.columns[&n.id];
        assert!(board.plio.columns.contains(&col));
    }
    // per-column capacity: ≤ channels_per_column per direction
    use std::collections::HashMap;
    let mut per: HashMap<(u32, PlioDir), u32> = HashMap::new();
    for n in g.plio_nodes() {
        *per.entry((a.columns[&n.id], n.plio_dir().unwrap()))
            .or_default() += 1;
    }
    for ((c, d), count) in per {
        assert!(
            count <= board.plio.channels_per_column,
            "column {c} {d:?} hosts {count}"
        );
    }
}

#[test]
fn ablations_order_correctly() {
    // full pipeline ≥ no-latency-hiding; threading never hurts
    let board = BoardConfig::vck5000();
    let rec = library::mm(8192, 8192, 8192, DType::F32);
    let full = explore(
        &rec,
        &board,
        &DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
    )
    .unwrap()
    .1;
    let no_lat = explore(
        &rec,
        &board,
        &DseConstraints {
            max_aies: Some(400),
            no_latency_hiding: true,
            ..Default::default()
        },
    )
    .unwrap()
    .1;
    let no_thread = explore(
        &rec,
        &board,
        &DseConstraints {
            max_aies: Some(400),
            no_threading: true,
            ..Default::default()
        },
    )
    .unwrap()
    .1;
    assert!(full.tops >= no_lat.tops);
    assert!(full.tops >= no_thread.tops * 0.999);
}

#[test]
fn sim_and_analytic_agree_across_benchmarks() {
    for (rec, cap) in [
        (library::mm(8192, 8192, 8192, DType::F32), 400u64),
        (library::conv2d(10240, 10240, 4, 4, DType::I16), 400),
        (library::fir(1048576, 15, DType::I8), 256),
        (library::fft2d(8192, 8192, DType::CI16), 320),
    ] {
        let d = ws(cap).compile(&rec).unwrap();
        let rel = (d.sim.tops - d.estimate.perf.tops).abs() / d.estimate.perf.tops;
        assert!(
            rel < 0.15,
            "{}: sim {:.3} vs analytic {:.3}",
            rec.name,
            d.sim.tops,
            d.estimate.perf.tops
        );
    }
}

#[test]
fn bound_classification_sensible() {
    // Table III operating points are compute-bound; tiny PLIO budgets
    // flip to PLIO-bound.
    let d = ws(400)
        .compile(&library::mm(8192, 8192, 8192, DType::F32))
        .unwrap();
    assert_eq!(d.estimate.perf.bound, PerfBound::Compute);

    let starved = WideSa::new(WideSaConfig {
        board: BoardConfig::vck5000().with_plio_budget(4),
        constraints: DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
        mover_bits: 128,
        ..Default::default()
    });
    let d2 = starved
        .compile(&library::mm(8192, 8192, 8192, DType::F32))
        .unwrap();
    assert_ne!(d2.estimate.perf.bound, PerfBound::Compute);
    assert!(d2.estimate.perf.tops < d.estimate.perf.tops);
}

#[test]
fn codegen_scales_with_design() {
    let small = ws(64)
        .compile(&library::mm(2048, 2048, 2048, DType::F32))
        .unwrap();
    let large = ws(400)
        .compile(&library::mm(8192, 8192, 8192, DType::F32))
        .unwrap();
    // graph code instantiates more kernels for the larger design
    assert!(large.code.adf_graph.len() > small.code.adf_graph.len());
    // one kernel program regardless of scale (the paper's reuse claim)
    assert_eq!(
        small.code.aie_kernel.lines().count(),
        large.code.aie_kernel.lines().count()
    );
}
