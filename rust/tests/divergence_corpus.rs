//! The one-port-model invariant, end to end:
//!
//! 1. the incremental port predictor ([`predict_ports`]) is bit-identical
//!    to [`merge_ports_with_budget`] on **every DSE candidate** of all 14
//!    Table II recurrences, across port-cap settings;
//! 2. a divergence corpus: sweep Table II × port caps under both the
//!    exact and the legacy analytic ranking, record every candidate where
//!    the two rankings disagree, and assert the exact-ranked winner
//!    always satisfies the paper's 78-in/78-out PLIO budget after real
//!    packet merging;
//! 3. serial and scoped-thread rankings stay bit-identical under the
//!    exact port model, including on starved boards where the models
//!    genuinely diverge.

use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::build;
use widesa::graph::packet::{merge_ports_with_budget, predict_ports};
use widesa::mapping::dse::{self, explore_all, explore_all_parallel, DseConstraints};
use widesa::recurrence::library;

fn cons(analytic: bool) -> DseConstraints {
    DseConstraints {
        max_aies: Some(400),
        analytic_ranking: analytic,
        ..Default::default()
    }
}

#[test]
fn predictor_is_bit_identical_to_merge_on_all_table2_candidates() {
    for budget in [78u32, 16, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        let constraints = cons(false);
        let model = dse::scoring_model(&board, &constraints);
        for rec in library::table2_benchmarks() {
            let plan = dse::plan(&rec, &board, &constraints);
            for choice in plan.choices.clone() {
                let Some((cand, _)) =
                    dse::score_choice(&rec, &model, &constraints, &plan, choice)
                else {
                    continue;
                };
                let g = build(&cand, &model);
                let (in_b, out_b) = (
                    board.plio.in_channels as usize,
                    board.plio.out_channels as usize,
                );
                let (_, stats) = merge_ports_with_budget(&g, model.channel_bw(), in_b, out_b);
                let predicted = predict_ports(&cand, &model, model.channel_bw(), in_b, out_b);
                assert_eq!(
                    predicted, stats,
                    "{} @ {budget} channels: predictor diverged on {}",
                    rec.name,
                    cand.summary()
                );
            }
        }
    }
}

#[test]
fn predictor_is_bit_identical_on_the_expanded_catalog() {
    // the new workload families (depthwise conv, triangular solve,
    // stencil chain) flow through the private-stream predictor arm —
    // keep it bit-identical to real merging there too
    for budget in [78u32, 16] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        let constraints = cons(false);
        let model = dse::scoring_model(&board, &constraints);
        for rec in library::catalog_small() {
            let plan = dse::plan(&rec, &board, &constraints);
            for choice in plan.choices.clone() {
                let Some((cand, _)) =
                    dse::score_choice(&rec, &model, &constraints, &plan, choice)
                else {
                    continue;
                };
                let g = build(&cand, &model);
                let (in_b, out_b) = (
                    board.plio.in_channels as usize,
                    board.plio.out_channels as usize,
                );
                let (_, stats) = merge_ports_with_budget(&g, model.channel_bw(), in_b, out_b);
                let predicted = predict_ports(&cand, &model, model.channel_bw(), in_b, out_b);
                assert_eq!(
                    predicted, stats,
                    "{} @ {budget} channels: predictor diverged on {}",
                    rec.name,
                    cand.summary()
                );
            }
        }
    }
}

#[test]
fn exact_winner_fits_budget_wherever_rankings_diverge() {
    let mut divergences: Vec<String> = Vec::new();
    for budget in [78u32, 32, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::table2_benchmarks() {
            let exact = explore_all(&rec, &board, &cons(false));
            let analytic = explore_all(&rec, &board, &cons(true));
            // both rankings score the same candidate set, just ordered
            // (and priced) differently
            assert_eq!(exact.len(), analytic.len(), "{}", rec.name);
            for (pos, (e, a)) in exact.iter().zip(&analytic).enumerate() {
                if e.0.summary() != a.0.summary() {
                    divergences.push(format!(
                        "{} @ {budget} ch, rank {pos}: exact [{}] vs analytic [{}]",
                        rec.name,
                        e.0.summary(),
                        a.0.summary()
                    ));
                }
            }
            // whatever the approximation would have crowned, the
            // exact-ranked winner must fit the paper's PLIO budget once
            // the graph is really merged
            let Some((winner, _)) = exact.first() else {
                panic!("{}: empty ranking", rec.name);
            };
            let model = dse::scoring_model(&board, &cons(false));
            let (_, stats) = merge_ports_with_budget(
                &build(winner, &model),
                model.channel_bw(),
                board.plio.in_channels as usize,
                board.plio.out_channels as usize,
            );
            assert!(
                stats.in_ports_after <= 78,
                "{} @ {budget} ch: exact winner needs {} input ports",
                rec.name,
                stats.in_ports_after
            );
            assert!(
                stats.out_ports_after <= 78,
                "{} @ {budget} ch: exact winner needs {} output ports",
                rec.name,
                stats.out_ports_after
            );
        }
    }
    // the corpus is informative, not a failure: print what diverged so a
    // ranking regression shows up in test logs
    println!(
        "analytic-vs-exact ranking divergences across the corpus: {}",
        divergences.len()
    );
    for d in &divergences {
        println!("  {d}");
    }
}

#[test]
fn parallel_ranking_bit_identical_under_exact_model() {
    // a starved board makes the exact port counts bite (the two models
    // genuinely disagree here), so this checks determinism of the exact
    // ranking itself, not just of the arithmetic both models share
    let board = BoardConfig::vck5000().with_plio_budget(16);
    let constraints = cons(false);
    for rec in library::table2_benchmarks() {
        let serial = explore_all(&rec, &board, &constraints);
        for threads in [2, 8] {
            let par = explore_all_parallel(&rec, &board, &constraints, threads);
            assert_eq!(serial.len(), par.len(), "{} × {threads}", rec.name);
            for (s, p) in serial.iter().zip(&par) {
                assert_eq!(s.0.summary(), p.0.summary(), "{} × {threads}", rec.name);
                assert_eq!(s.1.tops.to_bits(), p.1.tops.to_bits());
            }
        }
    }
}
