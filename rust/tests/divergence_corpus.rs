//! The one-port-model and one-power-model invariants, end to end, as
//! [`testkit::laws`] driven over the Table II corpus:
//!
//! 1. the incremental port predictor ([`predict_ports`]) is bit-identical
//!    to [`merge_ports_with_budget`] on **every DSE candidate** of all 14
//!    Table II recurrences, across port-cap settings
//!    ([`laws::predictor_matches_merge`]);
//! 2. a divergence corpus: sweep Table II × port caps under both the
//!    exact and the legacy analytic ranking, record every candidate where
//!    the two rankings disagree, and assert the exact-ranked winner
//!    always satisfies the paper's 78-in/78-out PLIO budget after real
//!    packet merging;
//! 3. serial and scoped-thread rankings stay bit-identical under the
//!    exact port model ([`laws::serial_parallel_ranking`]), including on
//!    starved boards where the models genuinely diverge;
//! 4. the Pareto ranking obeys [`laws::pareto_frontier`] on all 14
//!    recurrences: non-dominated frontier prefix, insertion-order
//!    independent membership, serial ≡ scoped-thread bit-for-bit.

mod testkit;

use testkit::laws;
use widesa::arch::vck5000::BoardConfig;
use widesa::graph::builder::build;
use widesa::graph::packet::merge_ports_with_budget;
use widesa::mapping::dse::{self, explore_all, DseConstraints};
use widesa::recurrence::library;

fn cons(analytic: bool) -> DseConstraints {
    DseConstraints {
        max_aies: Some(400),
        analytic_ranking: analytic,
        ..Default::default()
    }
}

#[test]
fn predictor_is_bit_identical_to_merge_on_all_table2_candidates() {
    for budget in [78u32, 16, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::table2_benchmarks() {
            laws::predictor_matches_merge(&rec, &board, &cons(false));
        }
    }
}

#[test]
fn predictor_is_bit_identical_on_the_expanded_catalog() {
    // the new workload families (depthwise conv, triangular solve,
    // stencil chain) flow through the private-stream predictor arm —
    // keep it bit-identical to real merging there too
    for budget in [78u32, 16] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::catalog_small() {
            laws::predictor_matches_merge(&rec, &board, &cons(false));
        }
    }
}

#[test]
fn exact_winner_fits_budget_wherever_rankings_diverge() {
    let mut divergences: Vec<String> = Vec::new();
    for budget in [78u32, 32, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::table2_benchmarks() {
            let exact = explore_all(&rec, &board, &cons(false));
            let analytic = explore_all(&rec, &board, &cons(true));
            // both rankings score the same candidate set, just ordered
            // (and priced) differently
            assert_eq!(exact.len(), analytic.len(), "{}", rec.name);
            for (pos, (e, a)) in exact.iter().zip(&analytic).enumerate() {
                if e.0.summary() != a.0.summary() {
                    divergences.push(format!(
                        "{} @ {budget} ch, rank {pos}: exact [{}] vs analytic [{}]",
                        rec.name,
                        e.0.summary(),
                        a.0.summary()
                    ));
                }
            }
            // whatever the approximation would have crowned, the
            // exact-ranked winner must fit the paper's PLIO budget once
            // the graph is really merged
            let Some((winner, _)) = exact.first() else {
                panic!("{}: empty ranking", rec.name);
            };
            let model = dse::scoring_model(&board, &cons(false));
            let (_, stats) = merge_ports_with_budget(
                &build(winner, &model),
                model.channel_bw(),
                board.plio.in_channels as usize,
                board.plio.out_channels as usize,
            );
            assert!(
                stats.in_ports_after <= 78,
                "{} @ {budget} ch: exact winner needs {} input ports",
                rec.name,
                stats.in_ports_after
            );
            assert!(
                stats.out_ports_after <= 78,
                "{} @ {budget} ch: exact winner needs {} output ports",
                rec.name,
                stats.out_ports_after
            );
        }
    }
    // the corpus is informative, not a failure: print what diverged so a
    // ranking regression shows up in test logs
    println!(
        "analytic-vs-exact ranking divergences across the corpus: {}",
        divergences.len()
    );
    for d in &divergences {
        println!("  {d}");
    }
}

#[test]
fn parallel_ranking_bit_identical_under_exact_model() {
    // a starved board makes the exact port counts bite (the two models
    // genuinely disagree here), so this checks determinism of the exact
    // ranking itself, not just of the arithmetic both models share
    let board = BoardConfig::vck5000().with_plio_budget(16);
    for rec in library::table2_benchmarks() {
        laws::serial_parallel_ranking(&rec, &board, &cons(false), &[2, 8]);
    }
}

#[test]
fn pareto_law_holds_on_all_table2_recurrences() {
    // the third exploration driver (serve's worker pool) shares rank_by
    // with these two and is pinned to the serial ranking by the server's
    // own pooled-vs-serial test; together the three stay bit-identical
    let board = BoardConfig::vck5000();
    for rec in library::table2_benchmarks() {
        laws::pareto_frontier(&rec, &board, &cons(false), &[2, 8]);
    }
}
