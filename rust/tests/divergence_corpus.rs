//! The one-port-model and one-power-model invariants, end to end, as
//! [`testkit::laws`] driven over the Table II corpus:
//!
//! 1. the incremental port predictor ([`predict_ports`]) is bit-identical
//!    to [`merge_ports_with_budget`] on **every DSE candidate** of all 14
//!    Table II recurrences, across port-cap settings
//!    ([`laws::predictor_matches_merge`]);
//! 2. a divergence corpus: sweep Table II × port caps under both the
//!    exact and the legacy analytic ranking, record every candidate where
//!    the two rankings disagree, and assert the exact-ranked winner
//!    always satisfies the paper's 78-in/78-out PLIO budget after real
//!    packet merging ([`laws::exact_winner_fits_after_merge`]);
//! 3. serial and scoped-thread rankings stay bit-identical under the
//!    exact port model ([`laws::serial_parallel_ranking`]), including on
//!    starved boards where the models genuinely diverge;
//! 4. the Pareto ranking obeys [`laws::pareto_frontier`] on all 14
//!    recurrences: non-dominated frontier prefix, insertion-order
//!    independent membership, serial ≡ scoped-thread bit-for-bit;
//! 5. the DSE crowns a communication-avoiding variant **iff** the
//!    standard form is PLIO-bound ([`laws::ca_selected_iff_port_bound`]),
//!    over the library's CA pairs *and* testkit-random replication-axis
//!    shapes, at every port cap.

mod testkit;

use testkit::laws;
use widesa::arch::vck5000::BoardConfig;
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::library;
use widesa::util::rng::XorShift64;

fn cons(analytic: bool) -> DseConstraints {
    DseConstraints {
        max_aies: Some(400),
        analytic_ranking: analytic,
        ..Default::default()
    }
}

#[test]
fn predictor_is_bit_identical_to_merge_on_all_table2_candidates() {
    for budget in [78u32, 16, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::table2_benchmarks() {
            laws::predictor_matches_merge(&rec, &board, &cons(false));
        }
    }
}

#[test]
fn predictor_is_bit_identical_on_the_expanded_catalog() {
    // the new workload families (depthwise conv, triangular solve,
    // stencil chain) flow through the private-stream predictor arm —
    // keep it bit-identical to real merging there too
    for budget in [78u32, 16] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::catalog_small() {
            laws::predictor_matches_merge(&rec, &board, &cons(false));
        }
    }
}

#[test]
fn exact_winner_fits_budget_wherever_rankings_diverge() {
    let mut divergences: Vec<String> = Vec::new();
    for budget in [78u32, 32, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for rec in library::table2_benchmarks() {
            divergences.extend(laws::exact_winner_fits_after_merge(
                &rec,
                &board,
                &cons(false),
                &cons(true),
            ));
        }
    }
    // the corpus is informative, not a failure: print what diverged so a
    // ranking regression shows up in test logs
    println!(
        "analytic-vs-exact ranking divergences across the corpus: {}",
        divergences.len()
    );
    for d in &divergences {
        println!("  {d}");
    }
}

#[test]
fn ca_selected_iff_port_bound_across_the_corpus() {
    // the library's curated CA pairs, plus testkit-random
    // replication-axis shapes, at every port cap: the DSE must crown the
    // communication-avoiding form exactly when the standard winner's
    // really-merged ports exceed the budget — never as a performance
    // preference, never missed when the standard form is unroutable
    let mut pairs = library::ca_pairs();
    let mut rng = XorShift64::new(0xCA_5E1EC7);
    for _ in 0..testkit::cases(6) {
        pairs.push(testkit::random_ca_pair(&mut rng));
    }
    for budget in [78u32, 16, 8] {
        let board = BoardConfig::vck5000().with_plio_budget(budget);
        for (std_rec, ca_rec) in &pairs {
            let sel = laws::ca_selected_iff_port_bound(std_rec, ca_rec, &board, &cons(false));
            // the full board never needs the CA arm for these shapes
            if budget == 78 {
                assert!(
                    sel.standard_fits,
                    "{} fits 78 channels after merging",
                    std_rec.name
                );
            }
        }
    }
}

#[test]
fn parallel_ranking_bit_identical_under_exact_model() {
    // a starved board makes the exact port counts bite (the two models
    // genuinely disagree here), so this checks determinism of the exact
    // ranking itself, not just of the arithmetic both models share
    let board = BoardConfig::vck5000().with_plio_budget(16);
    for rec in library::table2_benchmarks() {
        laws::serial_parallel_ranking(&rec, &board, &cons(false), &[2, 8]);
    }
}

#[test]
fn pareto_law_holds_on_all_table2_recurrences() {
    // the third exploration driver (serve's worker pool) shares rank_by
    // with these two and is pinned to the serial ranking by the server's
    // own pooled-vs-serial test; together the three stay bit-identical
    let board = BoardConfig::vck5000();
    for rec in library::table2_benchmarks() {
        laws::pareto_frontier(&rec, &board, &cons(false), &[2, 8]);
    }
}
