//! End-to-end coverage of the expanded workload catalog (`make
//! workloads-smoke`): every library constructor — the Table II four plus
//! depthwise conv, triangular solve and the stencil chain — must
//!
//! 1. find a legal mapping and survive the full framework back half
//!    (graph build, port merge, place & route, simulation, codegen);
//! 2. stub-execute bit-correct against its `coordinator::verify` oracle
//!    through the artifact replay drivers;
//! 3. exercise the space-time transforms the Table II corpus never
//!    picked: the triangular solve selects a **1D** (non-2D-serpentine)
//!    transform, the stencil chain's choices exist only through the
//!    neighbour-transfer legality clause (negative dependence offsets),
//!    and the Gauss–Seidel sweep chain is mappable **only** through the
//!    wavefront skew fallback (every choice skewed).

use widesa::arch::vck5000::BoardConfig;
use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::coordinator::{exec, verify};
use widesa::mapping::dse::{self, explore, DseConstraints};
use widesa::polyhedral::legality::is_legal_order;
use widesa::recurrence::{dtype::DType, library};
use widesa::runtime::client::Runtime;
use widesa::util::rng::XorShift64;

fn framework(max_aies: u64) -> WideSa {
    WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(max_aies),
            ..Default::default()
        },
        ..Default::default()
    })
}

#[test]
fn every_catalog_workload_compiles_to_a_legal_design() {
    for rec in library::catalog_small() {
        let name = rec.name.clone();
        let d = framework(400)
            .compile(&rec)
            .unwrap_or_else(|e| panic!("{name}: no legal mapping: {e}"));
        assert!(d.compile.success, "{name}: place & route failed");
        assert!(d.merge_stats.in_ports_after <= 78, "{name}");
        assert!(d.merge_stats.out_ports_after <= 78, "{name}");
        assert!(d.estimate.perf.tops > 0.0, "{name}");
        assert!(d.sim.tops > 0.0, "{name}");
        assert!(!d.code.aie_kernel.is_empty(), "{name}");
    }
}

#[test]
fn trsv_selects_a_non_2d_serpentine_transform() {
    // the acceptance assertion for the expanded catalog: at least one new
    // family must leave the 2D-serpentine comfort zone. The triangular
    // solve's wavefront bound makes its 1D linear-array mapping win (see
    // the Trsv stall model in mapping::cost), and the compiled design —
    // not just the ranking — must carry it through place & route.
    let rec = library::trsv(8192, DType::F32);
    let d = framework(400).compile(&rec).expect("trsv must compile");
    assert!(d.compile.success);
    assert_eq!(
        d.candidate.choice.dims(),
        1,
        "trsv should map to a linear array, got {}",
        d.candidate.summary()
    );
}

#[test]
fn catalog_covers_1d_and_skewed_arms_beyond_2d_serpentine() {
    // across the three new families, at least one winner is 1D or skewed
    let board = BoardConfig::vck5000();
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    let mut non_2d = 0;
    for rec in [
        library::dw_conv2d(64, 256, 256, 3, 3, DType::F32),
        library::trsv(8192, DType::F32),
        library::stencil2d_chain(2, 1024, 1024, DType::F32),
    ] {
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        if cand.choice.dims() == 1 || cand.choice.is_skewed() {
            non_2d += 1;
        }
    }
    assert!(non_2d >= 1, "no new family left the 2D-serpentine arm");
}

#[test]
fn stencil_mapping_relies_on_neighbour_transfer_legality() {
    // the stencil's legal choices carry negative dependence components
    // that the pre-expansion sequential-order check rejects outright —
    // i.e. this workload genuinely exercises the new legality clause
    let rec = library::stencil2d_chain(2, 1024, 1024, DType::F32);
    let board = BoardConfig::vck5000();
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    let plan = dse::plan(&rec, &board, &cons);
    assert!(!plan.choices.is_empty(), "stencil has no space-time choices");
    let loops = plan.scope.graph_loops();
    let grid_2d = plan
        .choices
        .iter()
        .find(|c| c.space == vec![loops[1], loops[2]])
        .expect("the (i, j) grid choice must be legal");
    assert!(
        !is_legal_order(&grid_2d.nest.deps),
        "the grid choice must NOT be sequentially legal — it exists only \
         through the neighbour-transfer clause"
    );
    assert!(grid_2d
        .nest
        .deps
        .iter()
        .any(|d| d.vector.iter().any(|&c| c < 0)));
}

#[test]
fn seidel_is_only_mappable_via_the_skew_fallback() {
    // the Gauss–Seidel sweep chain carries a same-sweep (0, −1, 0)
    // dependence: a pure backward hop with zero time advance, illegal
    // under both the sequential-order and neighbour-transfer clauses for
    // every space choice. Only the wavefront skew fallback legalises it —
    // so every enumerated choice must be skewed, and the compiled winner
    // must carry the skew through the full back half
    let rec = library::seidel2d(2, 64, 64, DType::F32);
    let board = BoardConfig::vck5000();
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    assert!(
        !is_legal_order(&rec.dependences()),
        "the raw dependence set must be sequentially illegal"
    );
    let plan = dse::plan(&rec, &board, &cons);
    assert!(!plan.choices.is_empty(), "seidel2d has no space-time choices");
    for c in &plan.choices {
        assert!(
            c.is_skewed(),
            "unskewed seidel2d choice {:?} — the sweep dependence should \
             have forced the wavefront fallback",
            c.space
        );
    }
    let d = framework(400).compile(&rec).expect("seidel2d must compile");
    assert!(d.compile.success, "place & route failed");
    assert!(d.candidate.choice.is_skewed(), "{}", d.candidate.summary());
    // the wavefront schedule's fill/drain accounting must keep the
    // simulator and the analytic estimate within the usual 15%
    let rel = (d.sim.tops - d.estimate.perf.tops).abs() / d.estimate.perf.tops;
    assert!(
        rel <= 0.15,
        "sim {} vs analytic {} TOPS diverge by {:.1}%",
        d.sim.tops,
        d.estimate.perf.tops,
        rel * 100.0
    );
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn dwconv_replay_matches_oracle_end_to_end() {
    let mut rt = Runtime::with_builtin();
    let (c, h, w) = (8usize, 64usize, 128usize);
    let mut rng = XorShift64::new(101);
    let mut x = vec![0f32; c * (h + 2) * (w + 2)];
    let mut k = vec![0f32; c * 9];
    rng.fill_f32(&mut x);
    rng.fill_f32(&mut k);
    let (y, stats) = exec::run_dwconv2d(&mut rt, &x, &k, c, h, w).unwrap();
    assert_eq!(stats.rounds, 2);
    let want = verify::dw_conv2d_ref(&x, &k, c, h, w, 3, 3);
    assert!(verify::max_abs_diff(&y, &want) < 1e-4);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn trsv_replay_matches_oracle_end_to_end() {
    let mut rt = Runtime::with_builtin();
    let n = 1024usize;
    let mut rng = XorShift64::new(103);
    let mut l = vec![0f32; n * n];
    let mut b = vec![0f32; n];
    rng.fill_f32(&mut l);
    rng.fill_f32(&mut b);
    for i in 0..n {
        for j in 0..n {
            l[i * n + j] /= n as f32;
        }
        l[i * n + i] = 4.0 + l[i * n + i].abs();
    }
    let (x, stats) = exec::run_trsv(&mut rt, &l, &b, n).unwrap();
    assert_eq!(stats.rounds, 4);
    let want = verify::trsv_ref(&l, &b, n);
    assert!(verify::max_abs_diff(&x, &want) < 1e-4);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn stencil_replay_matches_oracle_end_to_end() {
    let mut rt = Runtime::with_builtin();
    let n = 128usize;
    let mut rng = XorShift64::new(107);
    let mut a = vec![0f32; n * n];
    rng.fill_f32(&mut a);
    let coef = [0.4f32, 0.15, 0.15, 0.15, 0.15];
    let (out, stats) = exec::run_stencil2d(&mut rt, &a, n, n, 6, &coef).unwrap();
    assert_eq!(stats.rounds, 3);
    let want = verify::stencil2d_chain_ref(&a, n, n, 6, &coef);
    assert!(verify::max_abs_diff(&out, &want) < 1e-4);
}
