//! Domain example: an image-processing pipeline (the paper's intro
//! motivation — "AI and intelligent signal processing").
//!
//! Maps a 2D convolution onto the array, functionally replays a blur +
//! sharpen filter pair over a synthetic image through the AOT kernels,
//! and verifies against the host oracle.
//!
//! Run: `make artifacts && cargo run --release --example conv2d_pipeline`

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::coordinator::{exec, verify};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};
use widesa::runtime::client::Runtime;
use widesa::util::rng::XorShift64;

fn main() -> anyhow::Result<()> {
    // --- map the paper-scale conv ---------------------------------------
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
        ..Default::default()
    });
    let design = ws.compile(&library::conv2d(10240, 10240, 4, 4, DType::F32))?;
    println!("[map] 2D-Conv 10240×10240 4×4 f32:\n{}", design.report());

    // --- functional pipeline on a 256×256 image --------------------------
    const H: usize = 256;
    const W: usize = 256;
    const P: usize = 4;
    let mut rt = Runtime::new()?;
    let mut rng = XorShift64::new(7);
    let mut image = vec![0f32; (H + P - 1) * (W + P - 1)];
    rng.fill_f32(&mut image);

    // stage 1: box blur
    let blur = vec![1.0 / 16.0; 16];
    let (blurred, s1) = exec::run_conv2d(&mut rt, &image, &blur, H, W)?;

    // stage 2: sharpen the blurred image (pad it back to halo size first)
    let mut padded = vec![0f32; (H + P - 1) * (W + P - 1)];
    for r in 0..H {
        padded[r * (W + P - 1)..r * (W + P - 1) + W].copy_from_slice(&blurred[r * W..(r + 1) * W]);
    }
    let mut sharpen = vec![-0.05f32; 16];
    sharpen[5] = 1.8; // centre-heavy kernel
    let (out, s2) = exec::run_conv2d(&mut rt, &padded, &sharpen, H, W)?;

    println!(
        "[replay] blur {} rounds / {:.3}s, sharpen {} rounds / {:.3}s",
        s1.rounds, s1.seconds, s2.rounds, s2.seconds
    );

    // --- verify both stages against the oracle ---------------------------
    let want1 = verify::conv2d_ref(&image, &blur, H, W, P, P);
    let e1 = verify::max_abs_diff(&blurred, &want1);
    let want2 = verify::conv2d_ref(&padded, &sharpen, H, W, P, P);
    let e2 = verify::max_abs_diff(&out, &want2);
    println!("[verify] blur max|Δ| = {e1:.3e}, sharpen max|Δ| = {e2:.3e}");
    anyhow::ensure!(e1 < 1e-3 && e2 < 1e-3, "verification failed");
    println!("OK: two-stage conv pipeline replayed and verified.");
    Ok(())
}
