//! Emit the full heterogeneous code bundle for every benchmark family and
//! print a summary — what the "automatic mapping framework" hands to the
//! real toolchain (aiecompiler + v++ + g++).
//!
//! Run: `cargo run --release --example codegen_inspect [outdir]`

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};

fn main() -> anyhow::Result<()> {
    let outdir = std::env::args().nth(1).unwrap_or_else(|| "target/codegen".into());
    let benches = [
        ("mm", library::mm(8192, 8192, 8192, DType::F32), 400u64),
        ("conv2d", library::conv2d(10240, 10240, 4, 4, DType::I8), 400),
        ("fir", library::fir(1048576, 15, DType::I16), 256),
        ("fft2d", library::fft2d(8192, 8192, DType::CF32), 320),
    ];
    for (name, rec, aies) in benches {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(aies),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&rec)?;
        let dir = std::path::Path::new(&outdir).join(name);
        d.code.write_to(&dir)?;
        println!(
            "{name:8} → {:40} kernel {:5}B graph {:6}B movers {:5}B host {:5}B constraints {:6}B",
            dir.display(),
            d.code.aie_kernel.len(),
            d.code.adf_graph.len(),
            d.code.pl_dma.len(),
            d.code.host.len(),
            d.code.constraints_json.len(),
        );
    }
    println!("\ninspect e.g.: less {outdir}/mm/graph.cpp");
    Ok(())
}
