//! End-to-end driver (EXPERIMENTS.md §E6): exercises the FULL stack on a
//! real workload — proving all layers compose.
//!
//! 1. L3 maps the MM recurrence onto the simulated VCK5000 (systolic
//!    mapping, PLIO assignment, place & route) and predicts performance.
//! 2. The functional executor replays the mapped schedule tile-by-tile
//!    through the L1/L2 AOT kernels (Pallas → HLO → PJRT) — python never
//!    runs here.
//! 3. Results are verified against the host oracle, and the simulated
//!    board-time is reported next to the paper's operating point.
//!
//! Run: `make artifacts && cargo run --release --example mm_e2e [n]`

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::coordinator::{exec, verify};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};
use widesa::runtime::client::Runtime;
use widesa::util::rng::XorShift64;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(512);
    println!("=== WideSA end-to-end: MM {n}×{n}×{n} f32 ===\n");

    // --- 1. map + simulate the full-size design -------------------------
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
        ..Default::default()
    });
    let paper_scale = ws.compile(&library::mm(8192, 8192, 8192, DType::F32))?;
    println!("[map] paper-scale design (8192³):\n{}", paper_scale.report());

    let design = ws.compile(&library::mm(n as u64, n as u64, n as u64, DType::F32))?;
    println!("[map] this run's design ({n}³):");
    println!("  {}", design.candidate.summary());
    println!("  simulated board time: {:.3} ms ({:.3} TOPS on-chip)",
        design.sim.seconds * 1e3, design.sim.tops);
    anyhow::ensure!(design.compile.success, "place & route failed");

    // --- 2. functional replay through the AOT kernels -------------------
    let mut rt = Runtime::new()?;
    println!("\n[replay] runtime backend: {}", rt.platform());
    let mut rng = XorShift64::new(2024);
    let mut a = vec![0f32; n * n];
    let mut b = vec![0f32; n * n];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let (c, stats) = exec::run_mm(&mut rt, &a, &b, n, n, n)?;
    let replay_gflops = 2.0 * (n as f64).powi(3) / stats.seconds / 1e9;
    println!(
        "[replay] {} rounds in {:.3} s ({:.2} GFLOP/s functional throughput on this CPU)",
        stats.rounds, stats.seconds, replay_gflops
    );

    // --- 3. verify -------------------------------------------------------
    let want = verify::mm_ref(&a, &b, &vec![0.0; n * n], n, n, n);
    let err = verify::max_abs_diff(&c, &want);
    println!("[verify] max |replay − oracle| = {err:.3e}");
    anyhow::ensure!(err < 1e-2, "verification failed");

    println!("\nOK: mapping, simulation, AOT replay and verification all agree.");
    println!("    paper Table III MM fp32: 4.15 TOPS @400 AIEs — our model: {:.2} TOPS",
        paper_scale.estimate.perf.tops);
    Ok(())
}
