//! Domain example: software-radio channel filtering (the FIR benchmark's
//! natural habitat). Designs a 15-tap low-pass filter, maps the FIR
//! recurrence, replays a two-tone signal through the AOT kernel and
//! checks the stop-band tone is attenuated.
//!
//! Run: `make artifacts && cargo run --release --example fir_radio`

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::coordinator::{exec, verify};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};
use widesa::runtime::client::Runtime;

fn main() -> anyhow::Result<()> {
    // --- map the paper-scale FIR ----------------------------------------
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(256),
            ..Default::default()
        },
        ..Default::default()
    });
    let design = ws.compile(&library::fir(1048576, 15, DType::F32))?;
    println!("[map] FIR 1048576×15 f32:\n{}", design.report());

    // --- design a 15-tap windowed-sinc low-pass (cutoff 0.15 × fs) ------
    const TAPS: usize = 15;
    let fc = 0.15f64;
    let mut h = [0f32; TAPS];
    let mut sum = 0f64;
    for (i, tap) in h.iter_mut().enumerate() {
        let x = i as f64 - (TAPS - 1) as f64 / 2.0;
        let sinc = if x == 0.0 {
            2.0 * fc
        } else {
            (2.0 * std::f64::consts::PI * fc * x).sin() / (std::f64::consts::PI * x)
        };
        // Hamming window
        let w = 0.54 - 0.46 * (2.0 * std::f64::consts::PI * i as f64 / (TAPS - 1) as f64).cos();
        *tap = (sinc * w) as f32;
        sum += *tap as f64;
    }
    for tap in h.iter_mut() {
        *tap /= sum as f32; // unity DC gain
    }

    // --- two-tone input: 0.05 fs (pass) + 0.4 fs (stop) ------------------
    let n = 65536usize;
    let mut x = vec![0f32; n + TAPS - 1];
    for (i, v) in x.iter_mut().enumerate() {
        let t = i as f64;
        *v = ((2.0 * std::f64::consts::PI * 0.05 * t).sin()
            + (2.0 * std::f64::consts::PI * 0.40 * t).sin()) as f32;
    }

    let mut rt = Runtime::new()?;
    let (y, stats) = exec::run_fir(&mut rt, &x, &h, n)?;
    println!(
        "[replay] {} rounds in {:.3}s ({:.1} Msamples/s functional)",
        stats.rounds,
        stats.seconds,
        n as f64 / stats.seconds / 1e6
    );

    // --- verify + check filtering actually happened ----------------------
    let want = verify::fir_ref(&x, &h, n);
    let err = verify::max_abs_diff(&y, &want);
    println!("[verify] max|Δ| vs oracle = {err:.3e}");
    anyhow::ensure!(err < 1e-3, "verification failed");

    // crude tone-power probe via Goertzel-style correlation
    let power = |freq: f64, sig: &[f32]| -> f64 {
        let (mut re, mut im) = (0f64, 0f64);
        for (i, &v) in sig.iter().enumerate() {
            let ang = 2.0 * std::f64::consts::PI * freq * i as f64;
            re += v as f64 * ang.cos();
            im += v as f64 * ang.sin();
        }
        (re * re + im * im).sqrt() / sig.len() as f64
    };
    let pass_in = power(0.05, &x[..n]);
    let pass_out = power(0.05, &y);
    let stop_in = power(0.40, &x[..n]);
    let stop_out = power(0.40, &y);
    println!(
        "[filter] pass-band gain {:.2} dB, stop-band gain {:.2} dB",
        20.0 * (pass_out / pass_in).log10(),
        20.0 * (stop_out / stop_in).log10()
    );
    anyhow::ensure!(pass_out / pass_in > 0.7, "pass band attenuated too much");
    anyhow::ensure!(stop_out / stop_in < 0.2, "stop band not attenuated");
    println!("OK: low-pass behaviour confirmed through the mapped kernel.");
    Ok(())
}
