//! Figure 6 companion: sweep AIE counts / PLIO budgets / buffer sizes and
//! print the scalability series (CSV on stdout for plotting).
//!
//! Run: `cargo run --release --example scalability`

use widesa::eval::figure6;

fn main() {
    let (aies_plios, buffers, rendered) = figure6::run();
    println!("{rendered}");

    println!("# CSV: plios,aies,tops,tops_per_aie,bound");
    for p in &aies_plios {
        println!(
            "{},{},{:.4},{:.6},{}",
            p.plios, p.aies, p.tops, p.tops_per_aie, p.bound
        );
    }
    println!("# CSV: buffer_mb,tops,tops_per_aie,bound");
    for p in &buffers {
        println!(
            "{},{:.4},{:.6},{}",
            p.buffer_mb, p.tops, p.tops_per_aie, p.bound
        );
    }
}
