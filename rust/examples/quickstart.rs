//! Quickstart: map one matrix multiplication onto the (simulated) VCK5000
//! with WideSA and print everything the framework decides.
//!
//! Run: `cargo run --release --example quickstart`

use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::mapping::dse::DseConstraints;
use widesa::recurrence::{dtype::DType, library};

fn main() -> anyhow::Result<()> {
    // 1. Describe the computation as a uniform recurrence.
    let rec = library::mm(8192, 8192, 8192, DType::F32);
    println!("recurrence: {} ({} MACs)", rec.name, rec.total_macs());
    for dep in rec.dependences() {
        println!("  dependence: {dep}");
    }

    // 2. Configure the framework (defaults = full VCK5000, 512-bit movers).
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
        ..Default::default()
    });

    // 3. Compile: demarcation → space-time DSE → graph → PLIO assignment
    //    → place & route → simulation → code generation.
    let design = ws.compile(&rec)?;
    println!("\n{}", design.report());

    // The paper's headline metric: how much of the 8×50 array the mapping
    // actually keeps busy.
    let used = design.estimate.perf.aies;
    let total = ws.config.board.array.num_cores() as u64;
    println!(
        "AIE utilization: {used}/{total} cores = {:.1}% (MAC occupancy {:.1}%, {:.2} TOPS on-chip)",
        100.0 * used as f64 / total as f64,
        100.0 * design.estimate.perf.occupancy,
        design.estimate.perf.tops,
    );
    println!(
        "power estimate: {:.1} W → {:.4} TOPS/W (shared cost + power model)",
        design.estimate.power.watts, design.estimate.power.tops_per_watt,
    );

    // 4. Inspect the generated AIE kernel (one program serves all cores).
    println!("generated AIE kernel (first 20 lines):");
    for line in design.code.aie_kernel.lines().take(20) {
        println!("  {line}");
    }
    Ok(())
}
