//! JSON-lines request/response protocol for the compile service.
//!
//! One request per line in, one response per line out — trivially
//! scriptable (`echo '…' | widesa serve --stdin`), trivially framed over
//! TCP, and needing nothing beyond the crate's own [`crate::util::json`].
//!
//! ## Request
//!
//! ```json
//! {"id": 1, "bench": "mm", "dtype": "f32", "dims": [8192, 8192, 8192],
//!  "max_aies": 400, "mover_bits": 512, "cold_dram": false}
//! ```
//!
//! * `bench` — `mm` | `conv2d` | `fir` | `fft2d` | `dwconv2d` | `trsv` |
//!   `stencil2d` | `ca_mm` | `seidel2d` (required).
//! * `dims` — loop extents: `mm` `[n, m, k]`, `conv2d` `[h, w, p, q]`,
//!   `fir` `[n, taps]`, `fft2d` `[rows, cols]`, `dwconv2d`
//!   `[groups, h, w, p, q]`, `trsv` `[n]`, `stencil2d`
//!   `[stages, n, m]`, `ca_mm` `[n, m, k, rep]`, `seidel2d`
//!   `[stages, n, m]`. Optional; each benchmark has a sensible default.
//! * `variant` — `standard` | `ca`: route an `mm` compile through its
//!   communication-avoiding form (the 2.5D replicated-summand variant,
//!   docs/CA_VARIANTS.md) instead of the standard recurrence. Optional;
//!   absent (or `standard`) means the standard form, so existing clients
//!   see identical behaviour — and identical cache keys.
//! * `dtype` — `f32|i8|i16|i32|cf32|ci16`; defaults to `f32` (`cf32` for
//!   `fft2d`, which requires a complex type).
//! * `id` — any JSON value, echoed verbatim in the response.
//! * `tenant` — quota-accounting identity for admission control
//!   (optional; absent means the anonymous tenant `""`).
//! * `max_aies`, `mover_bits`, `cold_dram` — per-request overrides of the
//!   server's base [`crate::WideSaConfig`].
//! * `objective` — `throughput` | `efficiency` | `pareto` ranking
//!   objective override; `max_power_w` — board power cap in watts
//!   (candidates drawing more are filtered before ranking). Both
//!   optional; absent means the server's configured defaults, so
//!   existing clients see identical behaviour.
//!
//! ## Response
//!
//! ```json
//! {"id":1,"ok":true,"cached":false,"deduped":false,"key":"91ab…",
//!  "name":"mm_8192x8192x8192_Float","aies":400,"tops":4.13,
//!  "sim_tops":4.3,"bound":"compute","pnr":true,"congestion":2,
//!  "in_ports":10,"out_ports":50,
//!  "stage_ms":{"assign":0.4,"place":1.3,"route":2.0},"wall_us":812345.2}
//! ```
//!
//! `tops`/`bound`/port counts — and the `watts`/`tops_per_watt` power
//! figures — come from the exact-port estimate
//! ([`crate::CompiledDesign::estimate_exact`]) — the numbers that agree
//! with what place & route saw; `stage_ms` breaks the P&R wall time into
//! its place/assign/route stages so tail-latency regressions can be
//! attributed without rerunning benches. Errors come back as
//! `{"id":…,"ok":false,"error":"…"}`; admission-control rejections as
//! `{"id":…,"ok":false,"overloaded":true,"reason":"quota"|"queue",
//! "retry_after_ms":…}` ([`overloaded_line`]) so clients can back off
//! instead of treating shed load as failure. `mm` shapes the host-level
//! blocking planner cannot place come back as `{"id":…,"ok":false,
//! "unplannable":true,"n":…,"m":…,"k":…,"reason":"…"}`
//! ([`unplannable_line`]) — a typed, permanent property of the request,
//! never a 500. `mm` successes additionally carry a `"blocking"` object
//! with the chosen panel plan and predicted DRAM traffic. The connection
//! stays usable after any of these.
//!
//! ## Stats command
//!
//! `{"cmd": "stats", "id": …}` is answered without touching the compile
//! path: `{"id":…,"ok":true,"stats":{…},"metrics":{"serve":…,
//! "pipeline":…}}`, where `stats` mirrors [`crate::ServeStats`] and
//! `metrics` carries the per-handle and process-global
//! [`crate::obs::metrics::Registry`] snapshots ([`stats_line`]). The
//! `stats` block and `metrics.serve.counters` read the *same* registry
//! cells, so the two views reconcile by construction.

use crate::coordinator::blocking::{BlockingPlan, Unplannable};
use crate::mapping::dse::{Form, Objective};
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::recurrence::spec::UniformRecurrence;
use crate::serve::server::{CacheOutcome, Overloaded, ServeStats};
use crate::util::json::{parse, Json};
use crate::CompiledDesign;
use anyhow::{anyhow, bail, Result};

/// One parsed compile request.
#[derive(Debug, Clone)]
pub struct CompileRequest {
    /// Echoed verbatim in the response (`null` when absent).
    pub id: Json,
    pub bench: String,
    pub dtype: DType,
    pub dims: Vec<u64>,
    /// Quota-accounting identity (`None` = the anonymous tenant).
    pub tenant: Option<String>,
    pub max_aies: Option<u64>,
    pub mover_bits: Option<u64>,
    pub cold_dram: Option<bool>,
    /// Ranking objective override (`None` = server default).
    pub objective: Option<Objective>,
    /// Board power cap in watts (`None` = uncapped).
    pub max_power_w: Option<f64>,
    /// Mapping-form routing: `Some(Form::Ca)` compiles the request's
    /// communication-avoiding variant (`None` ≡ `Form::Standard`).
    pub variant: Option<Form>,
}

pub fn parse_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "f32" => DType::F32,
        "i8" => DType::I8,
        "i16" => DType::I16,
        "i32" => DType::I32,
        "cf32" => DType::CF32,
        "ci16" => DType::CI16,
        _ => bail!("unknown dtype {s:?} (f32|i8|i16|i32|cf32|ci16)"),
    })
}

fn get_u64(obj: &Json, key: &str) -> Result<Option<u64>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| anyhow!("field {key:?} must be a number"))?;
            if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0) {
                bail!("field {key:?} must be a non-negative integer, got {n}");
            }
            Ok(Some(n as u64))
        }
    }
}

/// Parse one JSON-line request.
pub fn parse_request(line: &str) -> Result<CompileRequest> {
    let root = parse(line.trim()).map_err(|e| anyhow!("bad request JSON: {e}"))?;
    if root.as_obj().is_none() {
        bail!("request must be a JSON object");
    }
    let bench = root
        .get("bench")
        .and_then(Json::as_str)
        .ok_or_else(|| {
            anyhow!(
                "missing required field \"bench\" \
                 (mm|conv2d|fir|fft2d|dwconv2d|trsv|stencil2d|ca_mm|seidel2d)"
            )
        })?
        .to_string();
    let dtype = match root.get("dtype").and_then(Json::as_str) {
        Some(s) => parse_dtype(s)?,
        // FFT operates on complex data; everything else defaults real.
        None if bench == "fft2d" => DType::CF32,
        None => DType::F32,
    };
    let dims = match root.get("dims") {
        None | Some(Json::Null) => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow!("field \"dims\" must be an array of integers"))?
            .iter()
            .map(|d| {
                let n = d.as_f64().unwrap_or(-1.0);
                if n.is_finite() && n >= 1.0 && n.fract() == 0.0 {
                    Ok(n as u64)
                } else {
                    Err(anyhow!("every dim must be an integer ≥ 1"))
                }
            })
            .collect::<Result<Vec<u64>>>()?,
    };
    let cold_dram = match root.get("cold_dram") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_bool()
                .ok_or_else(|| anyhow!("field \"cold_dram\" must be a boolean"))?,
        ),
    };
    let tenant = match root.get("tenant") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| anyhow!("field \"tenant\" must be a string"))?
                .to_string(),
        ),
    };
    let objective = match root.get("objective") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("field \"objective\" must be a string"))?;
            Some(Objective::parse(s).ok_or_else(|| {
                anyhow!("unknown objective {s:?} (throughput|efficiency|pareto)")
            })?)
        }
    };
    let variant = match root.get("variant") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("field \"variant\" must be a string"))?;
            Some(
                Form::parse(s)
                    .ok_or_else(|| anyhow!("unknown variant {s:?} (standard|ca)"))?,
            )
        }
    };
    let max_power_w = match root.get("max_power_w") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let w = v
                .as_f64()
                .ok_or_else(|| anyhow!("field \"max_power_w\" must be a number"))?;
            if !(w.is_finite() && w > 0.0) {
                bail!("field \"max_power_w\" must be a positive number of watts, got {w}");
            }
            Some(w)
        }
    };
    Ok(CompileRequest {
        id: root.get("id").cloned().unwrap_or(Json::Null),
        bench,
        dtype,
        dims,
        tenant,
        max_aies: get_u64(&root, "max_aies")?,
        mover_bits: get_u64(&root, "mover_bits")?,
        cold_dram,
        objective,
        max_power_w,
        variant,
    })
}

/// Materialize the request's recurrence from the benchmark library,
/// validating arity and benchmark-specific constraints (so malformed
/// requests become protocol errors, never panics inside a worker).
pub fn request_recurrence(req: &CompileRequest) -> Result<UniformRecurrence> {
    let dims = |n: usize, default: &[u64]| -> Result<Vec<u64>> {
        if req.dims.is_empty() {
            Ok(default.to_vec())
        } else if req.dims.len() == n {
            Ok(req.dims.clone())
        } else {
            bail!(
                "bench {:?} takes {} dims, got {}",
                req.bench,
                n,
                req.dims.len()
            )
        }
    };
    // `variant: "ca"` swaps an mm compile onto its communication-avoiding
    // recurrence; the CA name/replicate feed the cache key, so standard
    // and CA designs never collide in the design cache.
    if req.variant == Some(Form::Ca) && req.bench != "mm" {
        bail!(
            "variant \"ca\" is only defined for bench \"mm\" (got {:?}); \
             use bench \"ca_mm\" for an explicit CA compile",
            req.bench
        );
    }
    Ok(match req.bench.as_str() {
        "mm" if req.variant == Some(Form::Ca) => {
            let d = dims(3, &[8192, 8192, 8192])?;
            if d[2] % 4 != 0 {
                bail!("variant \"ca\" splits k across 4 replicas; k = {} must divide", d[2]);
            }
            library::ca_mm_25d(d[0], d[1], d[2], 4, req.dtype)
        }
        "mm" => {
            let d = dims(3, &[8192, 8192, 8192])?;
            library::mm(d[0], d[1], d[2], req.dtype)
        }
        "ca_mm" => {
            let d = dims(4, &[1024, 1024, 1024, 4])?;
            if d[3] < 2 {
                bail!("ca_mm needs at least two replicas, got rep={}", d[3]);
            }
            if d[2] % d[3] != 0 {
                bail!("ca_mm reduction extent k={} must divide across rep={} replicas", d[2], d[3]);
            }
            library::ca_mm_25d(d[0], d[1], d[2], d[3], req.dtype)
        }
        "seidel2d" => {
            let d = dims(3, &[2, 64, 64])?;
            if d[0] == 0 {
                bail!("seidel2d needs at least one sweep, got stages=0");
            }
            library::seidel2d(d[0], d[1], d[2], req.dtype)
        }
        "conv2d" => {
            let d = dims(4, &[10240, 10240, 4, 4])?;
            if d[2] > d[0] || d[3] > d[1] {
                bail!("conv2d kernel ({}x{}) larger than image ({}x{})", d[2], d[3], d[0], d[1]);
            }
            library::conv2d(d[0], d[1], d[2], d[3], req.dtype)
        }
        "fir" => {
            let d = dims(2, &[1048576, 15])?;
            if d[1] > d[0] {
                bail!("fir taps ({}) exceed signal length ({})", d[1], d[0]);
            }
            library::fir(d[0], d[1], req.dtype)
        }
        "fft2d" => {
            let d = dims(2, &[8192, 8192])?;
            if !req.dtype.is_complex() {
                bail!("fft2d requires a complex dtype (cf32|ci16), got {}", req.dtype);
            }
            if !d[1].is_power_of_two() || d[1] < 2 {
                bail!("fft2d cols must be a power of two ≥ 2, got {}", d[1]);
            }
            library::fft2d(d[0], d[1], req.dtype)
        }
        "dwconv2d" => {
            let d = dims(5, &[64, 2048, 2048, 3, 3])?;
            if d[3] > d[1] || d[4] > d[2] {
                bail!(
                    "dwconv2d kernel ({}x{}) larger than image ({}x{})",
                    d[3],
                    d[4],
                    d[1],
                    d[2]
                );
            }
            library::dw_conv2d(d[0], d[1], d[2], d[3], d[4], req.dtype)
        }
        "trsv" => {
            let d = dims(1, &[8192])?;
            library::trsv(d[0], req.dtype)
        }
        "stencil2d" => {
            let d = dims(3, &[2, 4096, 4096])?;
            // parse_request already rejects dims < 1, but this fn is pub:
            // keep the constructor's stages assert unreachable from here
            if d[0] == 0 {
                bail!("stencil2d needs at least one sweep, got stages=0");
            }
            library::stencil2d_chain(d[0], d[1], d[2], req.dtype)
        }
        other => bail!(
            "unknown bench {other:?} (mm|conv2d|fir|fft2d|dwconv2d|trsv|stencil2d|ca_mm|seidel2d)"
        ),
    })
}

/// Render a success response line (no trailing newline). `blocking`
/// carries the host-level panel plan for benches the coordinator blocks
/// at replay time (`mm`): when present it is embedded verbatim as the
/// `"blocking"` object ([`BlockingPlan::to_json`]) so clients see the
/// chosen loop order, panel geometry, and predicted DRAM traffic
/// alongside the compile result.
pub fn response_line(
    id: &Json,
    key: u64,
    outcome: CacheOutcome,
    design: &CompiledDesign,
    wall_s: f64,
    blocking: Option<&BlockingPlan>,
) -> String {
    let est = &design.estimate_exact;
    let mut fields = vec![
        ("id", id.clone()),
        ("ok", Json::Bool(true)),
        ("cached", Json::Bool(outcome == CacheOutcome::Hit)),
        ("deduped", Json::Bool(outcome == CacheOutcome::Deduped)),
        ("key", Json::Str(format!("{key:016x}"))),
        ("name", Json::Str(design.candidate.rec.name.clone())),
        ("aies", Json::Num(est.perf.aies as f64)),
        ("tops", Json::Num(est.perf.tops)),
        ("tops_per_aie", Json::Num(est.perf.tops_per_aie)),
        ("bound", Json::Str(est.perf.bound.to_string())),
        ("watts", Json::Num(est.power.watts)),
        ("tops_per_watt", Json::Num(est.power.tops_per_watt)),
        ("sim_tops", Json::Num(design.sim.tops)),
        ("pnr", Json::Bool(design.compile.success)),
        (
            "congestion",
            design
                .compile
                .max_congestion
                .map_or(Json::Null, |c| Json::Num(c as f64)),
        ),
        ("in_ports", Json::Num(design.merge_stats.in_ports_after as f64)),
        ("out_ports", Json::Num(design.merge_stats.out_ports_after as f64)),
        (
            "stage_ms",
            Json::obj(vec![
                ("place", Json::Num(design.compile.stages.place_ms)),
                ("assign", Json::Num(design.compile.stages.assign_ms)),
                ("route", Json::Num(design.compile.stages.route_ms)),
            ]),
        ),
        ("wall_us", Json::Num(wall_s * 1e6)),
    ];
    if let Some(plan) = blocking {
        fields.push(("blocking", plan.to_json()));
    }
    Json::obj(fields).to_string()
}

/// If `line` is a `{"cmd": "stats"}` command, return its echoed id.
/// Any other line (including unparseable ones) returns `None` and flows
/// to the normal request path. Callers on the hot path should gate this
/// behind a cheap `line.contains("\"cmd\"")` check to avoid a second
/// JSON parse per compile request.
pub fn stats_request(line: &str) -> Option<Json> {
    let root = parse(line.trim()).ok()?;
    if root.get("cmd")?.as_str()? != "stats" {
        return None;
    }
    Some(root.get("id").cloned().unwrap_or(Json::Null))
}

/// Render the `"stats"` command response: the [`ServeStats`] snapshot
/// plus both metric-registry snapshots (per-handle `serve`, process
/// `pipeline`).
pub fn stats_line(id: &Json, stats: &ServeStats, serve_metrics: Json, pipeline_metrics: Json) -> String {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(true)),
        (
            "stats",
            Json::obj(vec![
                ("hits", Json::num_u64(stats.hits)),
                ("misses", Json::num_u64(stats.misses)),
                ("deduped", Json::num_u64(stats.deduped)),
                ("errors", Json::num_u64(stats.errors)),
                ("shed", Json::num_u64(stats.shed)),
                ("plan_hits", Json::num_u64(stats.plan_hits)),
                ("cache_len", Json::num_usize(stats.cache.len)),
                ("cache_evictions", Json::num_u64(stats.cache.evictions)),
            ]),
        ),
        (
            "metrics",
            Json::obj(vec![
                ("serve", serve_metrics),
                ("pipeline", pipeline_metrics),
            ]),
        ),
    ])
    .to_string()
}

/// Render an error response line (no trailing newline).
pub fn error_line(id: &Json, msg: &str) -> String {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
    .to_string()
}

/// Render an admission-control rejection line (no trailing newline).
/// Distinguished from compile errors by `"overloaded": true` plus a
/// machine-readable back-off hint.
pub fn overloaded_line(id: &Json, o: &Overloaded) -> String {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(false)),
        ("overloaded", Json::Bool(true)),
        ("reason", Json::Str(o.reason.clone())),
        ("retry_after_ms", Json::num_u64(o.retry_after_ms)),
        ("error", Json::Str(o.to_string())),
    ])
    .to_string()
}

/// Render a planner rejection line (no trailing newline). Distinguished
/// from compile errors by `"unplannable": true` plus the echoed problem
/// geometry: the request parsed fine and the server is healthy, but no
/// host-blocking plan exists for the shape (e.g. a single staged matrix
/// would blow the staging cap). Clients should treat this as a permanent
/// property of the request, not a retryable fault.
pub fn unplannable_line(id: &Json, u: &Unplannable) -> String {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(false)),
        ("unplannable", Json::Bool(true)),
        ("n", Json::num_u64(u.n)),
        ("m", Json::num_u64(u.m)),
        ("k", Json::num_u64(u.k)),
        ("reason", Json::Str(u.reason.clone())),
        ("error", Json::Str(u.to_string())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_request() {
        let req = parse_request(
            r#"{"id": 7, "bench": "mm", "dtype": "i8", "dims": [1024, 512, 256],
                "max_aies": 100, "mover_bits": 128, "cold_dram": true}"#,
        )
        .unwrap();
        assert_eq!(req.id, Json::Num(7.0));
        assert_eq!(req.bench, "mm");
        assert_eq!(req.dtype, DType::I8);
        assert_eq!(req.dims, vec![1024, 512, 256]);
        assert_eq!(req.max_aies, Some(100));
        assert_eq!(req.mover_bits, Some(128));
        assert_eq!(req.cold_dram, Some(true));
        let rec = request_recurrence(&req).unwrap();
        assert_eq!(rec.name, "mm_1024x512x256_Int8");
    }

    #[test]
    fn defaults_fill_in() {
        let req = parse_request(r#"{"bench": "fft2d"}"#).unwrap();
        assert_eq!(req.id, Json::Null);
        assert_eq!(req.dtype, DType::CF32, "fft defaults complex");
        let rec = request_recurrence(&req).unwrap();
        assert!(rec.name.starts_with("fft2d_8192x8192"));

        let req = parse_request(r#"{"bench": "fir"}"#).unwrap();
        assert_eq!(req.dtype, DType::F32);
        assert_eq!(request_recurrence(&req).unwrap().name, "fir_1048576x15_Float");
    }

    #[test]
    fn expanded_catalog_benches_parse() {
        let req = parse_request(r#"{"bench": "trsv", "dims": [4096]}"#).unwrap();
        assert_eq!(request_recurrence(&req).unwrap().name, "trsv_4096_Float");

        let req = parse_request(r#"{"bench": "dwconv2d"}"#).unwrap();
        assert!(request_recurrence(&req)
            .unwrap()
            .name
            .starts_with("dwconv2d_64x2048x2048"));

        let req =
            parse_request(r#"{"bench": "stencil2d", "dims": [4, 1024, 1024]}"#).unwrap();
        let rec = request_recurrence(&req).unwrap();
        assert_eq!(rec.name, "stencil2d_4x1024x1024_Float");
        assert!(!rec.carried.is_empty());

        // arity and geometry validation still bites
        let bad = parse_request(r#"{"bench": "trsv", "dims": [8, 8]}"#).unwrap();
        assert!(request_recurrence(&bad).is_err());
        let bad = parse_request(r#"{"bench": "dwconv2d", "dims": [8, 4, 4, 9, 9]}"#).unwrap();
        assert!(request_recurrence(&bad).is_err());
        // a hand-built zero-stage request errors instead of panicking
        // (parse_request rejects dims < 1, but request_recurrence is pub)
        let zero = CompileRequest {
            id: Json::Null,
            bench: "stencil2d".into(),
            dtype: DType::F32,
            dims: vec![0, 64, 64],
            tenant: None,
            max_aies: None,
            mover_bits: None,
            cold_dram: None,
            objective: None,
            max_power_w: None,
            variant: None,
        };
        assert!(request_recurrence(&zero).is_err());
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"[1,2]"#).is_err());
        assert!(parse_request(r#"{"dtype":"f32"}"#).is_err(), "bench required");
        assert!(parse_request(r#"{"bench":"mm","dims":[0,1,2]}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","dims":[1.5,2,3]}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","max_aies":-4}"#).is_err());

        let bad_arity = parse_request(r#"{"bench":"mm","dims":[8,8]}"#).unwrap();
        assert!(request_recurrence(&bad_arity).is_err());
        let bad_bench = parse_request(r#"{"bench":"lu"}"#).unwrap();
        assert!(request_recurrence(&bad_bench).is_err());
        let real_fft = parse_request(r#"{"bench":"fft2d","dtype":"f32"}"#).unwrap();
        assert!(request_recurrence(&real_fft).is_err());
        let odd_fft = parse_request(r#"{"bench":"fft2d","dims":[64,100]}"#).unwrap();
        assert!(request_recurrence(&odd_fft).is_err());
    }

    #[test]
    fn objective_and_power_cap_parse_and_validate() {
        let req = parse_request(
            r#"{"bench":"mm","objective":"pareto","max_power_w":45.5}"#,
        )
        .unwrap();
        assert_eq!(req.objective, Some(Objective::Pareto));
        assert_eq!(req.max_power_w, Some(45.5));

        let req = parse_request(r#"{"bench":"mm","objective":"efficiency"}"#).unwrap();
        assert_eq!(req.objective, Some(Objective::Efficiency));
        assert_eq!(req.max_power_w, None);

        // absent and null both mean "server default"
        let req = parse_request(r#"{"bench":"mm","objective":null,"max_power_w":null}"#).unwrap();
        assert_eq!(req.objective, None);
        assert_eq!(req.max_power_w, None);
        let req = parse_request(r#"{"bench":"mm"}"#).unwrap();
        assert_eq!(req.objective, None);
        assert_eq!(req.max_power_w, None);

        // typed per-field errors, not silent coercion
        assert!(parse_request(r#"{"bench":"mm","objective":"fastest"}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","objective":3}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","max_power_w":-5}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","max_power_w":0}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","max_power_w":"55w"}"#).is_err());
    }

    #[test]
    fn variant_field_routes_mm_onto_the_ca_form() {
        // absent and "standard" are byte-for-byte the same compile
        let plain = parse_request(r#"{"bench":"mm","dims":[1024,1024,1024]}"#).unwrap();
        assert_eq!(plain.variant, None);
        let std_form = parse_request(
            r#"{"bench":"mm","dims":[1024,1024,1024],"variant":"standard"}"#,
        )
        .unwrap();
        assert_eq!(std_form.variant, Some(Form::Standard));
        let a = request_recurrence(&plain).unwrap();
        let b = request_recurrence(&std_form).unwrap();
        assert_eq!(a.name, b.name);
        assert_eq!(a.canonical_u64(), b.canonical_u64());

        // "ca" swaps onto the replicated-summand recurrence — and onto a
        // different cache key
        let ca = parse_request(
            r#"{"bench":"mm","dims":[1024,1024,1024],"variant":"ca"}"#,
        )
        .unwrap();
        assert_eq!(ca.variant, Some(Form::Ca));
        let rec = request_recurrence(&ca).unwrap();
        assert!(rec.name.starts_with("ca_mm_25d_1024x1024x1024_r4"));
        assert_eq!(rec.replicate, 4);
        assert_ne!(rec.canonical_u64(), a.canonical_u64());

        // typed errors: bad variant string, non-mm bench, indivisible k
        assert!(parse_request(r#"{"bench":"mm","variant":"avoiding"}"#).is_err());
        assert!(parse_request(r#"{"bench":"mm","variant":3}"#).is_err());
        let fir = parse_request(r#"{"bench":"fir","variant":"ca"}"#).unwrap();
        assert!(request_recurrence(&fir).is_err());
        let odd = parse_request(
            r#"{"bench":"mm","dims":[64,64,66],"variant":"ca"}"#,
        )
        .unwrap();
        assert!(request_recurrence(&odd).is_err());
    }

    #[test]
    fn ca_benches_parse_with_dims_and_defaults() {
        let req = parse_request(r#"{"bench": "ca_mm"}"#).unwrap();
        let rec = request_recurrence(&req).unwrap();
        assert_eq!(rec.name, "ca_mm_25d_1024x1024x1024_r4_Float");

        let req = parse_request(r#"{"bench": "ca_mm", "dims": [512, 512, 512, 8]}"#).unwrap();
        assert_eq!(
            request_recurrence(&req).unwrap().name,
            "ca_mm_25d_512x512x512_r8_Float"
        );

        let req = parse_request(r#"{"bench": "seidel2d"}"#).unwrap();
        let rec = request_recurrence(&req).unwrap();
        assert!(rec.name.starts_with("seidel2d_2x64x64"));
        assert!(!rec.carried.is_empty());

        // arity and geometry validation still bites
        let bad = parse_request(r#"{"bench": "ca_mm", "dims": [512, 512, 512]}"#).unwrap();
        assert!(request_recurrence(&bad).is_err());
        let one_rep = parse_request(r#"{"bench": "ca_mm", "dims": [512, 512, 512, 1]}"#).unwrap();
        assert!(request_recurrence(&one_rep).is_err());
        let odd = parse_request(r#"{"bench": "ca_mm", "dims": [512, 512, 510, 4]}"#).unwrap();
        assert!(request_recurrence(&odd).is_err());
    }

    #[test]
    fn tenant_field_parses_and_validates() {
        let req = parse_request(r#"{"bench":"mm","tenant":"team-a"}"#).unwrap();
        assert_eq!(req.tenant.as_deref(), Some("team-a"));
        let req = parse_request(r#"{"bench":"mm"}"#).unwrap();
        assert_eq!(req.tenant, None);
        assert!(parse_request(r#"{"bench":"mm","tenant":7}"#).is_err());
    }

    #[test]
    fn overloaded_line_round_trips() {
        let line = overloaded_line(
            &Json::Num(9.0),
            &Overloaded {
                reason: "quota".into(),
                retry_after_ms: 250,
            },
        );
        let v = parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_f64(), Some(9.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("overloaded").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("reason").unwrap().as_str(), Some("quota"));
        assert_eq!(v.get("retry_after_ms").unwrap().as_u64(), Some(250));
    }

    #[test]
    fn unplannable_line_round_trips() {
        let line = unplannable_line(
            &Json::Num(11.0),
            &Unplannable {
                n: 1_000_000_000,
                m: 1_000_000_000,
                k: 1_000_000_000,
                reason: "a staged matrix would exceed the staging cap".into(),
            },
        );
        let v = parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_f64(), Some(11.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("unplannable").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1_000_000_000));
        assert_eq!(v.get("m").unwrap().as_u64(), Some(1_000_000_000));
        assert_eq!(v.get("k").unwrap().as_u64(), Some(1_000_000_000));
        assert!(v.get("reason").unwrap().as_str().unwrap().contains("staging cap"));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("no host-blocking plan"));
        assert!(v.get("overloaded").is_none(), "distinct from shed load");
    }

    #[test]
    fn response_line_embeds_blocking_plan() {
        use crate::arch::vck5000::BoardConfig;
        use crate::coordinator::blocking::plan_mm;
        use crate::mapping::cost::CostModel;
        use crate::mapping::dse::DseConstraints;
        use crate::{WideSa, WideSaConfig};
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(32),
                ..Default::default()
            },
            ..Default::default()
        });
        let design = ws.compile(&library::fir(65536, 15, DType::F32)).unwrap();
        let model = CostModel::new(BoardConfig::vck5000());
        let plan = plan_mm(&model, 256, 128, 128).unwrap();
        let line = response_line(
            &Json::Num(1.0),
            0xBEEF,
            CacheOutcome::Miss,
            &design,
            0.5,
            Some(&plan),
        );
        let v = parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let b = v.get("blocking").expect("blocking object present");
        assert_eq!(b.get("tile").unwrap().as_u64(), Some(128));
        assert_eq!(b.get("order").unwrap().as_str(), Some("b-resident"));
        assert_eq!(
            b.get("predicted_dram_bytes").unwrap().as_u64(),
            Some(plan.predicted_dram_bytes)
        );
        // Without a plan the field is absent, not null — old clients
        // never see an unknown key.
        let line = response_line(&Json::Num(1.0), 0xBEEF, CacheOutcome::Miss, &design, 0.5, None);
        assert!(parse(&line).unwrap().get("blocking").is_none());
    }

    #[test]
    fn stats_command_detected_and_rendered() {
        assert!(stats_request(r#"{"cmd":"stats","id":4}"#).is_some());
        assert_eq!(
            stats_request(r#"{"cmd":"stats"}"#),
            Some(Json::Null),
            "missing id echoes null"
        );
        assert!(stats_request(r#"{"bench":"mm"}"#).is_none());
        assert!(stats_request(r#"{"cmd":"shutdown"}"#).is_none());
        assert!(stats_request("not json").is_none());

        let stats = ServeStats {
            hits: 3,
            misses: 2,
            deduped: 1,
            ..Default::default()
        };
        let line = stats_line(
            &Json::Num(4.0),
            &stats,
            Json::obj(vec![("counters", Json::obj(vec![]))]),
            Json::obj(vec![("counters", Json::obj(vec![]))]),
        );
        let v = parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let s = v.get("stats").unwrap();
        assert_eq!(s.get("hits").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("misses").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("deduped").unwrap().as_u64(), Some(1));
        assert!(v.get("metrics").unwrap().get("serve").is_some());
        assert!(v.get("metrics").unwrap().get("pipeline").is_some());
    }

    #[test]
    fn error_line_round_trips() {
        let line = error_line(&Json::Num(3.0), "no legal mapping for \"x\"");
        let v = parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("no legal mapping"));
    }
}
