//! Fixed-size worker pool on std threads + channels (no external deps).
//!
//! Two pools live inside the serve layer: one runs protocol requests
//! concurrently, the other shards DSE candidate scoring ([`WorkerPool`]
//! is deliberately generic — a job is any `FnOnce`). Keeping them
//! separate is what makes the system deadlock-free by construction: a
//! request job may *wait* on scoring jobs, so scoring must never queue
//! behind requests on the same executor. Admission control bounds how
//! many cold compiles can occupy the scoring pool at once
//! (`ServeConfig::max_inflight`) — the pool itself never rejects work,
//! it only queues, so shedding happens above it in the serve layer.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed set of worker threads consuming jobs from one shared queue.
///
/// Dropping the pool closes the queue and joins every worker, so all
/// submitted jobs finish before `drop` returns — `serve --stdin` relies
/// on this to flush responses for every request read before exiting.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("widesa-worker-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn worker thread")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Queue a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(job))
            .expect("worker queue closed");
    }

    /// Run a batch of jobs across the pool and return their results **in
    /// submission order** (the deterministic-merge guarantee the sharded
    /// DSE builds on). Blocks until every job has finished; if a job
    /// panicked, the panic is re-raised here on the caller's thread.
    pub fn scatter<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx: Sender<(usize, std::thread::Result<T>)> = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                // receiver may be gone if the caller already panicked
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = Vec::new();
        slots.resize_with(n, || None);
        for _ in 0..n {
            let (i, out) = rrx.recv().expect("worker pool dropped mid-scatter");
            match out {
                Ok(v) => slots[i] = Some(v),
                Err(payload) => resume_unwind(payload),
            }
        }
        slots.into_iter().map(|s| s.expect("slot filled")).collect()
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // Hold the lock only while dequeuing, never while running a job.
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // a peer panicked while holding the lock
        };
        match job {
            Ok(job) => {
                // A panicking job must not kill the worker: scatter()
                // observes the panic through its result channel instead.
                let _ = catch_unwind(AssertUnwindSafe(job));
            }
            Err(_) => return, // queue closed: pool is shutting down
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn scatter_preserves_submission_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64)
            .map(|i| {
                Box::new(move || {
                    // stagger completion so out-of-order finish is likely
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    i * i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = pool.scatter(jobs);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_runs_all_pending_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn panicking_job_does_not_kill_workers() {
        let pool = WorkerPool::new(1);
        pool.execute(|| panic!("job panic"));
        let out = pool.scatter(vec![
            Box::new(|| 41usize) as Box<dyn FnOnce() -> usize + Send>
        ]);
        assert_eq!(out, vec![41]);
    }

    #[test]
    fn scatter_propagates_panics() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scatter(vec![
                Box::new(|| 1usize) as Box<dyn FnOnce() -> usize + Send>,
                Box::new(|| panic!("boom")) as Box<dyn FnOnce() -> usize + Send>,
            ])
        }));
        assert!(result.is_err());
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
    }
}
