//! Server lifecycle: graceful shutdown, periodic snapshots, and the
//! final observability export.
//!
//! `widesa serve` is a long-lived process, so "the run ended" has three
//! distinct triggers — stdin EOF, SIGTERM/SIGINT from a supervisor, and
//! (for TCP mode) only the signals — and all of them must leave the same
//! artifacts behind: the design-cache snapshot (so the next boot
//! warm-starts), the metrics JSON (`--metrics-out`), and the Chrome
//! trace (`--trace-out`). This module centralizes that in
//! [`final_export`], with a watchdog thread ([`spawn_watchdog`]) that
//! polls a process-wide shutdown flag and also writes **periodic**
//! snapshots every `--snapshot-interval-s` so a crash loses at most one
//! interval of cache warmth.
//!
//! The signal handler itself ([`install_signal_handlers`]) does the only
//! thing that is async-signal-safe: a single atomic store into
//! [`SHUTDOWN`]'s cell. Everything with side effects (file I/O, metric
//! updates, `process::exit`) happens on the watchdog thread.
//!
//! Health of the snapshot loop is observable through two registry
//! handles on the serve registry ([`ServeHandle::metrics`]):
//! `serve.snapshot_saved` (counter, periodic + final saves) and
//! `serve.snapshot_age_s` (gauge, seconds since the last successful
//! save — a supervisor alerting on this catches a wedged disk long
//! before a restart does).

use crate::obs::metrics;
use crate::obs::trace;
use crate::serve::server::ServeHandle;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;
use std::time::{Duration, Instant};

/// Process-wide shutdown flag; set by the signal handler (or
/// [`request_shutdown`]) and polled by the watchdog.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Watchdog poll period: the latency ceiling on reacting to SIGTERM.
const POLL: Duration = Duration::from_millis(200);

/// True once SIGTERM/SIGINT arrived or [`request_shutdown`] was called.
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::Acquire)
}

/// Programmatic equivalent of SIGTERM (used by tests and the stdin EOF
/// path).
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::Release);
}

/// Clear the shutdown flag. The flag is process-global, so tests that
/// exercise the watchdog must reset it; production code never does.
#[doc(hidden)]
pub fn reset_shutdown_for_tests() {
    SHUTDOWN.store(false, Ordering::Release);
}

/// Route SIGTERM and SIGINT to the shutdown flag. The handler performs
/// exactly one atomic store — no allocation, locking, or I/O — which is
/// the whole async-signal-safe budget; the watchdog thread does the rest.
#[cfg(unix)]
pub fn install_signal_handlers() {
    extern "C" fn on_signal(_signum: i32) {
        SHUTDOWN.store(true, Ordering::Release);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    type Handler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: Handler) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_signal);
        signal(SIGINT, on_signal);
    }
}

/// No signals to install off unix; `widesa serve` still shuts down via
/// stdin EOF or [`request_shutdown`].
#[cfg(not(unix))]
pub fn install_signal_handlers() {}

/// What the watchdog and [`final_export`] write, and where.
#[derive(Debug, Clone, Default)]
pub struct LifecycleConfig {
    /// Periodic snapshot cadence (requires `ServeConfig::snapshot` to
    /// name a path). `None` = final snapshot only.
    pub snapshot_interval: Option<Duration>,
    /// Metrics JSON destination (`{"serve": …, "pipeline": …}`).
    pub metrics_out: Option<PathBuf>,
    /// Chrome trace-event JSON destination.
    pub trace_out: Option<PathBuf>,
}

impl LifecycleConfig {
    /// Anything to do at shutdown beyond the snapshot itself?
    pub fn wants_export(&self) -> bool {
        self.metrics_out.is_some() || self.trace_out.is_some()
    }
}

/// Start the lifecycle watchdog thread. It ticks every [`POLL`]:
/// refreshes `serve.snapshot_age_s`, writes a periodic snapshot when
/// `snapshot_interval` has elapsed, and on [`shutdown_requested`] runs
/// [`final_export`] then either exits the process (`exit_on_shutdown`,
/// the production SIGTERM path — the request loop is blocked in a read
/// and can't observe the flag) or returns so the caller can join (tests,
/// and callers that own their own exit).
pub fn spawn_watchdog(
    handle: ServeHandle,
    cfg: LifecycleConfig,
    exit_on_shutdown: bool,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name("serve-lifecycle".into())
        .spawn(move || watchdog_loop(&handle, &cfg, exit_on_shutdown))
        .expect("spawn serve lifecycle watchdog")
}

fn watchdog_loop(handle: &ServeHandle, cfg: &LifecycleConfig, exit_on_shutdown: bool) {
    let saved = handle.metrics().counter("serve.snapshot_saved");
    let age = handle.metrics().gauge("serve.snapshot_age_s");
    let mut last_save = Instant::now();
    loop {
        if shutdown_requested() {
            if let Err(e) = final_export(handle, cfg) {
                eprintln!("widesa serve: shutdown export failed: {e:#}");
            }
            if exit_on_shutdown {
                std::process::exit(0);
            }
            return;
        }
        age.set(last_save.elapsed().as_secs_f64());
        if let (Some(interval), Some(path)) =
            (cfg.snapshot_interval, handle.config().snapshot.as_ref())
        {
            if last_save.elapsed() >= interval {
                match handle.save_snapshot(path) {
                    Ok(_) => {
                        saved.inc();
                        last_save = Instant::now();
                        age.set(0.0);
                    }
                    Err(e) => eprintln!("widesa serve: periodic snapshot failed: {e:#}"),
                }
            }
        }
        thread::sleep(POLL);
    }
}

/// Write every configured shutdown artifact: design-cache snapshot (when
/// `ServeConfig::snapshot` is set), metrics JSON, and the Chrome trace.
/// Idempotent apart from draining the trace buffer — calling it twice
/// rewrites snapshot/metrics identically and leaves a shorter trace.
pub fn final_export(handle: &ServeHandle, cfg: &LifecycleConfig) -> Result<()> {
    if let Some(path) = handle.config().snapshot.clone() {
        let n = handle
            .save_snapshot(&path)
            .with_context(|| format!("saving snapshot to {}", path.display()))?;
        handle.metrics().counter("serve.snapshot_saved").inc();
        handle.metrics().gauge("serve.snapshot_age_s").set(0.0);
        eprintln!("widesa serve: snapshot — {n} designs to {}", path.display());
    }
    if let Some(path) = &cfg.metrics_out {
        let doc = Json::obj(vec![
            ("serve", handle.metrics().snapshot()),
            ("pipeline", metrics::global().snapshot()),
        ]);
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing metrics to {}", path.display()))?;
    }
    if let Some(path) = &cfg.trace_out {
        let doc = trace::export_chrome(&trace::drain_events());
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing trace to {}", path.display()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::server::ServeConfig;

    /// One combined test: the shutdown flag, the watchdog's periodic
    /// snapshot + age bookkeeping, and `final_export`'s three artifacts
    /// all share the process-global `SHUTDOWN`, so exercising them in a
    /// single function keeps the flag's state unambiguous even when the
    /// test harness runs modules in parallel.
    #[test]
    fn watchdog_snapshots_periodically_and_exports_on_shutdown() {
        let dir = std::env::temp_dir().join(format!("widesa-lifecycle-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let snap = dir.join("cache.snapshot");
        let metrics_out = dir.join("metrics.json");
        let trace_out = dir.join("trace.json");

        reset_shutdown_for_tests();
        assert!(!shutdown_requested());

        let handle = ServeHandle::new(ServeConfig {
            snapshot: Some(snap.clone()),
            ..Default::default()
        });
        let cfg = LifecycleConfig {
            snapshot_interval: Some(Duration::from_millis(0)),
            metrics_out: Some(metrics_out.clone()),
            trace_out: Some(trace_out.clone()),
        };
        assert!(cfg.wants_export());
        let watchdog = spawn_watchdog(handle.clone(), cfg.clone(), false);

        // interval 0 ⇒ a snapshot on every poll tick; wait for at least
        // one, bounded rather than flaky-fixed.
        let saved = handle.metrics().counter("serve.snapshot_saved");
        let deadline = Instant::now() + Duration::from_secs(10);
        while saved.get() == 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(20));
        }
        assert!(saved.get() >= 1, "watchdog never wrote a periodic snapshot");
        assert!(snap.exists());

        request_shutdown();
        watchdog.join().unwrap();
        assert!(metrics_out.exists(), "final export skipped metrics_out");
        assert!(trace_out.exists(), "final export skipped trace_out");

        // Both artifacts must parse, and the metrics doc must carry the
        // serve/pipeline split with our snapshot counter inside.
        let m = crate::util::json::parse(&std::fs::read_to_string(&metrics_out).unwrap()).unwrap();
        let count = m
            .get("serve")
            .and_then(|s| s.get("counters"))
            .and_then(|c| c.get("serve.snapshot_saved"))
            .and_then(Json::as_u64)
            .unwrap();
        assert!(count >= 2, "periodic + final saves should both count");
        assert!(m.get("pipeline").is_some());
        let t = crate::util::json::parse(&std::fs::read_to_string(&trace_out).unwrap()).unwrap();
        assert!(t.get("traceEvents").and_then(Json::as_arr).is_some());

        // Age gauge was reset by the final save.
        let age = handle.metrics().gauge("serve.snapshot_age_s");
        assert_eq!(age.get(), 0.0);

        reset_shutdown_for_tests();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
