//! `widesa::serve` — the long-lived compile service.
//!
//! The ROADMAP's north star is a system that serves heavy traffic, not a
//! one-shot CLI: the same recurrence shapes get mapped over and over
//! (framework studies, autotuners, multi-tenant schedulers re-requesting
//! Table II-class kernels), while `WideSa::compile`'s pipeline —
//! demarcation → space-time DSE → port merging → place & route →
//! simulation → codegen — is pure and deterministic. That combination is
//! exactly what this subsystem exploits:
//!
//! * [`cache`] — a **sharded LRU design cache** keyed by a canonical
//!   FNV-1a hash of `(recurrence, board, constraints, mover width, DRAM
//!   mode)` ([`cache::design_key`]). A cache hit returns the shared
//!   `Arc<CompiledDesign>` in microseconds; `bench_serve` demonstrates
//!   the ≥100× gap to a cold compile.
//! * [`server`] — [`server::ServeHandle`], the thread-safe programmatic
//!   API with **single-flight deduplication**: concurrent identical
//!   requests compile once, followers wait on the leader's result.
//!   Plus the `widesa serve` front-ends: JSON-lines over stdin
//!   ([`server::serve_stdin`]) or TCP ([`server::serve_tcp`]).
//! * [`pool`] — fixed worker pools on std threads + channels. The
//!   handle shards DSE candidate scoring across its pool with
//!   order-preserving scatter, so the parallel search returns the
//!   **bit-identical ranking** of the serial `explore_all`.
//! * [`protocol`] — the JSON-lines request/response format (see its
//!   module docs for the full schema).
//! * [`persist`] — **snapshot persistence**: the design cache serializes
//!   to a JSON-lines file and warm-starts a restarted server, with
//!   schema-versioned, canonically-stamped entries that self-evict when
//!   stale ([`persist::SNAPSHOT_SCHEMA`]).
//! * [`lifecycle`] — **graceful shutdown + periodic snapshots**: SIGTERM
//!   and SIGINT flip an async-signal-safe flag; a watchdog thread writes
//!   snapshots every `--snapshot-interval-s` and, at shutdown, the
//!   metrics JSON (`--metrics-out`) and Chrome trace (`--trace-out`)
//!   via [`lifecycle::final_export`]. Every request runs under a
//!   `serve.request` span with a per-request trace ID that follows the
//!   work across the DSE/P&R pools; `{"cmd": "stats"}` lines answer from
//!   the metric registries ([`server::ServeHandle::metrics`]).
//!
//! Production admission control wraps the whole path: per-tenant
//! token-bucket quotas and cold-compile queue-depth shedding reject with
//! the typed [`server::Overloaded`] error (a structured protocol
//! response, not a stringified failure), and
//! [`server::ServeHandle::compile_batch`] coalesces identical-key
//! requests while a plan cache ([`cache::plan_key`]) shares DSE plan
//! work between near-identical ones. `bench_serve_load` drives the
//! whole stack open-loop and reports p50/p99/p999 + shed rate into
//! `BENCH_serve.json`.
//!
//! ```text
//!   request line ──parse──▶ quota? ──shed──▶ overloaded response
//!                             │admit
//!                         design_key ──▶ cache? ──hit──▶ response
//!                                            │miss
//!                                     single-flight leader?
//!                                      │yes          │no
//!                               inflight slot?    wait for leader
//!                               │free    │full        │
//!                          DSE over pool └▶ overloaded │
//!                          P&R + sim + codegen         │
//!                                      ▼               ▼
//!                                 cache fill ─────▶ response
//!                                      │
//!                                  snapshot (save/warm-start)
//! ```

pub mod cache;
pub mod lifecycle;
pub mod persist;
pub mod pool;
pub mod protocol;
pub mod server;

pub use cache::{design_key, plan_key, CacheStats, ShardedCache};
pub use lifecycle::LifecycleConfig;
pub use persist::SNAPSHOT_SCHEMA;
pub use pool::WorkerPool;
pub use protocol::CompileRequest;
pub use server::{
    serve_stdin, serve_tcp, CacheOutcome, Overloaded, ServeConfig, ServeHandle, ServeResult,
    ServeStats,
};
