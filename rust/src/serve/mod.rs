//! `widesa::serve` — the long-lived compile service.
//!
//! The ROADMAP's north star is a system that serves heavy traffic, not a
//! one-shot CLI: the same recurrence shapes get mapped over and over
//! (framework studies, autotuners, multi-tenant schedulers re-requesting
//! Table II-class kernels), while `WideSa::compile`'s pipeline —
//! demarcation → space-time DSE → port merging → place & route →
//! simulation → codegen — is pure and deterministic. That combination is
//! exactly what this subsystem exploits:
//!
//! * [`cache`] — a **sharded LRU design cache** keyed by a canonical
//!   FNV-1a hash of `(recurrence, board, constraints, mover width, DRAM
//!   mode)` ([`cache::design_key`]). A cache hit returns the shared
//!   `Arc<CompiledDesign>` in microseconds; `bench_serve` demonstrates
//!   the ≥100× gap to a cold compile.
//! * [`server`] — [`server::ServeHandle`], the thread-safe programmatic
//!   API with **single-flight deduplication**: concurrent identical
//!   requests compile once, followers wait on the leader's result.
//!   Plus the `widesa serve` front-ends: JSON-lines over stdin
//!   ([`server::serve_stdin`]) or TCP ([`server::serve_tcp`]).
//! * [`pool`] — fixed worker pools on std threads + channels. The
//!   handle shards DSE candidate scoring across its pool with
//!   order-preserving scatter, so the parallel search returns the
//!   **bit-identical ranking** of the serial `explore_all`.
//! * [`protocol`] — the JSON-lines request/response format (see its
//!   module docs for the full schema).
//!
//! ```text
//!   request line ──parse──▶ design_key ──▶ cache? ──hit──▶ response
//!                                            │miss
//!                                     single-flight leader?
//!                                      │yes          │no
//!                               DSE over pool     wait for leader
//!                               P&R + sim + codegen     │
//!                                      ▼                ▼
//!                                 cache fill ──────▶ response
//! ```

pub mod cache;
pub mod pool;
pub mod protocol;
pub mod server;

pub use cache::{design_key, CacheStats, ShardedCache};
pub use pool::WorkerPool;
pub use protocol::CompileRequest;
pub use server::{serve_stdin, serve_tcp, CacheOutcome, ServeConfig, ServeHandle, ServeResult, ServeStats};
