//! The long-lived compile service: [`ServeHandle`] (programmatic API)
//! plus the stdin / TCP front-ends behind `widesa serve`.
//!
//! A request travels: canonical key ([`crate::serve::cache::design_key`])
//! → sharded LRU cache probe → single-flight registration (concurrent
//! identical requests compile **once**; followers block until the leader
//! publishes) → cold compile with DSE candidate scoring *and* the
//! framework back half (P&R per fallback candidate) sharded over the
//! handle's dedicated worker pool → cache fill → response.
//!
//! Request handling and DSE scoring never share an executor — stdin
//! requests run on their own [`WorkerPool`], TCP connections each get a
//! thread, and scoring has the handle's dedicated pool — so a request
//! waiting on scoring can never deadlock behind other request jobs
//! (see [`crate::serve::pool`]).

use crate::coordinator::framework::{
    CompiledDesign, NoLegalMapping, WideSa, WideSaConfig, FALLBACK_CANDIDATES,
};
use crate::mapping::cost::{CostModel, PerfEstimate};
use crate::mapping::dse::{self, Ranked};
use crate::mapping::MappingCandidate;
use crate::recurrence::spec::UniformRecurrence;
use crate::serve::cache::{design_key, CacheStats, ShardedCache};
use crate::serve::pool::WorkerPool;
use crate::serve::protocol::{self, CompileRequest};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// How a request was satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Served straight from the design cache.
    Hit,
    /// This request compiled the design (the single-flight leader).
    Miss,
    /// Another in-flight request was already compiling the same key;
    /// this one waited for it instead of compiling again.
    Deduped,
}

/// One served compile: the shared design plus how it was obtained.
pub struct ServeResult {
    pub design: Arc<CompiledDesign>,
    pub outcome: CacheOutcome,
    /// Canonical design key (stable across server restarts).
    pub key: u64,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Base compile configuration; per-request fields (`max_aies`,
    /// `mover_bits`, `cold_dram`) override it.
    pub base: WideSaConfig,
    /// Total design-cache entries.
    pub cache_capacity: usize,
    /// Independent cache locks.
    pub cache_shards: usize,
    /// Worker threads sharding DSE candidate scoring per compile.
    pub dse_threads: usize,
    /// Worker threads running protocol requests (stdin / TCP loops).
    pub request_workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map_or(4, |n| n.get());
        Self {
            base: WideSaConfig::default(),
            cache_capacity: 64,
            cache_shards: 8,
            dse_threads: cores.clamp(1, 8),
            request_workers: cores.clamp(1, 8),
        }
    }
}

/// Service statistics snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub hits: u64,
    pub misses: u64,
    pub deduped: u64,
    pub errors: u64,
    pub cache: CacheStats,
}

/// Clonable error image for single-flight followers: `anyhow::Error` is
/// not `Clone`, but the typed [`NoLegalMapping`] case must survive
/// deduplication so every requester of a doomed key sees the same error
/// type as the leader, not a stringified copy.
#[derive(Clone)]
enum FlightError {
    NoLegalMapping(NoLegalMapping),
    Other(String),
}

impl FlightError {
    fn of(e: &anyhow::Error) -> Self {
        match e.downcast_ref::<NoLegalMapping>() {
            Some(t) => FlightError::NoLegalMapping(t.clone()),
            None => FlightError::Other(e.to_string()),
        }
    }

    fn into_error(self) -> anyhow::Error {
        match self {
            FlightError::NoLegalMapping(t) => t.into(),
            FlightError::Other(msg) => anyhow!(msg),
        }
    }
}

/// A single-flight slot: the leader publishes here, followers wait.
struct Flight {
    /// `None` until resolved; errors travel as [`FlightError`] because
    /// every follower needs its own copy.
    slot: Mutex<Option<Result<Arc<CompiledDesign>, FlightError>>>,
    done: Condvar,
}

impl Flight {
    fn new() -> Self {
        Self {
            slot: Mutex::new(None),
            done: Condvar::new(),
        }
    }

    fn wait(&self) -> Result<Arc<CompiledDesign>, FlightError> {
        let mut slot = self.slot.lock().unwrap();
        while slot.is_none() {
            slot = self.done.wait(slot).unwrap();
        }
        slot.clone().unwrap()
    }
}

struct Inner {
    cfg: ServeConfig,
    cache: ShardedCache<Arc<CompiledDesign>>,
    flights: Mutex<HashMap<u64, Arc<Flight>>>,
    dse_pool: WorkerPool,
    hits: AtomicU64,
    misses: AtomicU64,
    deduped: AtomicU64,
    errors: AtomicU64,
}

/// Resolves a flight on drop so follower requests can never hang, even
/// if the leader's compile panics.
struct FlightGuard<'a> {
    inner: &'a Inner,
    key: u64,
    flight: Arc<Flight>,
    resolved: bool,
}

impl FlightGuard<'_> {
    fn resolve(&mut self, result: Result<Arc<CompiledDesign>, FlightError>) {
        *self.flight.slot.lock().unwrap() = Some(result);
        self.flight.done.notify_all();
        self.inner.flights.lock().unwrap().remove(&self.key);
        self.resolved = true;
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.resolved {
            self.resolve(Err(FlightError::Other("compile panicked".into())));
        }
    }
}

/// The long-lived, thread-safe compile service. Cheap to clone (all
/// clones share the cache, the in-flight table and the scoring pool), so
/// one handle can serve stdin, a TCP listener and library callers at
/// the same time.
///
/// ```
/// use widesa::{library, CacheOutcome, DType, DseConstraints, ServeConfig, ServeHandle,
///              WideSaConfig};
///
/// let handle = ServeHandle::new(ServeConfig {
///     base: WideSaConfig {
///         constraints: DseConstraints {
///             max_aies: Some(32), // small budget keeps the doctest fast
///             ..Default::default()
///         },
///         ..Default::default()
///     },
///     cache_capacity: 8,
///     ..Default::default()
/// });
/// let rec = library::fir(65536, 15, DType::F32);
/// let first = handle.compile(&rec).unwrap();
/// assert_eq!(first.outcome, CacheOutcome::Miss);
/// let second = handle.compile(&rec).unwrap();
/// assert_eq!(second.outcome, CacheOutcome::Hit);
/// // both requests share one compiled design
/// assert!(std::sync::Arc::ptr_eq(&first.design, &second.design));
/// ```
#[derive(Clone)]
pub struct ServeHandle {
    inner: Arc<Inner>,
}

impl ServeHandle {
    pub fn new(cfg: ServeConfig) -> Self {
        let cache = ShardedCache::new(cfg.cache_capacity, cfg.cache_shards);
        let dse_pool = WorkerPool::new(cfg.dse_threads);
        Self {
            inner: Arc::new(Inner {
                cfg,
                cache,
                flights: Mutex::new(HashMap::new()),
                dse_pool,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                deduped: AtomicU64::new(0),
                errors: AtomicU64::new(0),
            }),
        }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            deduped: self.inner.deduped.load(Ordering::Relaxed),
            errors: self.inner.errors.load(Ordering::Relaxed),
            cache: self.inner.cache.stats(),
        }
    }

    /// Compile under the service's base configuration.
    pub fn compile(&self, rec: &UniformRecurrence) -> Result<ServeResult> {
        self.compile_with(rec, &self.inner.cfg.base)
    }

    /// Compile under an explicit configuration (cache-keyed on it).
    pub fn compile_with(&self, rec: &UniformRecurrence, cfg: &WideSaConfig) -> Result<ServeResult> {
        let key = design_key(rec, cfg);
        let inner = &*self.inner;

        if let Some(design) = inner.cache.get(key) {
            inner.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(ServeResult {
                design,
                outcome: CacheOutcome::Hit,
                key,
            });
        }

        // Single-flight: exactly one thread becomes the leader for a key.
        let (flight, leader) = {
            let mut flights = inner.flights.lock().unwrap();
            match flights.get(&key) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Flight::new());
                    flights.insert(key, Arc::clone(&f));
                    (f, true)
                }
            }
        };

        if !leader {
            inner.deduped.fetch_add(1, Ordering::Relaxed);
            return match flight.wait() {
                Ok(design) => Ok(ServeResult {
                    design,
                    outcome: CacheOutcome::Deduped,
                    key,
                }),
                Err(fe) => {
                    inner.errors.fetch_add(1, Ordering::Relaxed);
                    Err(fe.into_error())
                }
            };
        }

        let mut guard = FlightGuard {
            inner,
            key,
            flight,
            resolved: false,
        };
        // Leader double-check: between this thread's cache probe and its
        // flight registration, a previous leader may have published (it
        // fills the cache *before* deregistering its flight, so "no
        // flight found" + "cache now full" is a completed compile, not a
        // cold key). Without this, a request racing the tail of another
        // compile would compile the same design twice.
        if let Some(design) = inner.cache.get(key) {
            inner.hits.fetch_add(1, Ordering::Relaxed);
            guard.resolve(Ok(Arc::clone(&design)));
            return Ok(ServeResult {
                design,
                outcome: CacheOutcome::Hit,
                key,
            });
        }
        inner.misses.fetch_add(1, Ordering::Relaxed);
        let compiled = self.cold_compile(rec, cfg);
        let published: Result<Arc<CompiledDesign>, FlightError> = match &compiled {
            Ok(design) => {
                inner.cache.insert(key, Arc::clone(design));
                Ok(Arc::clone(design))
            }
            Err(e) => {
                inner.errors.fetch_add(1, Ordering::Relaxed);
                Err(FlightError::of(e))
            }
        };
        guard.resolve(published);
        compiled.map(|design| ServeResult {
            design,
            outcome: CacheOutcome::Miss,
            key,
        })
    }

    /// The cold path: DSE with candidate scoring scattered over the
    /// handle's worker pool (deterministic merge — identical ranking to
    /// the serial `explore_all`), then the framework back half — P&R per
    /// fallback candidate scattered over the *same* pool, with the
    /// deterministic first-success selection picking the design the
    /// serial loop would.
    fn cold_compile(
        &self,
        rec: &UniformRecurrence,
        cfg: &WideSaConfig,
    ) -> Result<Arc<CompiledDesign>> {
        let ranked = self.explore_all_pooled(rec, cfg);
        let ws = WideSa::new(cfg.clone());
        if self.inner.dse_pool.workers() <= 1 || ranked.len() <= 1 {
            return ws.compile_ranked(rec, ranked).map(Arc::new);
        }
        let model = ws.cost_model();
        let mut top: Vec<_> = ranked
            .into_iter()
            .take(FALLBACK_CANDIDATES)
            .map(|(candidate, _)| candidate)
            .collect();
        // Top candidate first: the common first-success case costs one
        // evaluation (like the serial loop); only a P&R failure pays for
        // the speculative fallback fan-out.
        let first = ws.evaluate_candidate(&model, top.remove(0));
        if first.compile.success || top.is_empty() {
            return Ok(Arc::new(first));
        }
        let ws = Arc::new(ws);
        let model = Arc::new(model);
        type EvalJob = Box<dyn FnOnce() -> CompiledDesign + Send>;
        let jobs: Vec<EvalJob> = top
            .into_iter()
            .map(|candidate| {
                let (ws, model) = (Arc::clone(&ws), Arc::clone(&model));
                Box::new(move || ws.evaluate_candidate(&model, candidate)) as EvalJob
            })
            .collect();
        let mut designs = self.inner.dse_pool.scatter(jobs);
        designs.insert(0, first);
        WideSa::select_design(designs).map(Arc::new).ok_or_else(|| {
            NoLegalMapping {
                recurrence: rec.name.clone(),
            }
            .into()
        })
    }

    /// `explore_all` with per-candidate scoring as pool jobs. Results
    /// come back in submission (= enumeration) order via
    /// [`WorkerPool::scatter`], then go through the canonical
    /// [`dse::rank`] — bit-identical to the serial path.
    fn explore_all_pooled(&self, rec: &UniformRecurrence, cfg: &WideSaConfig) -> Ranked {
        if self.inner.dse_pool.workers() <= 1 {
            return dse::explore_all(rec, &cfg.board, &cfg.constraints);
        }
        let mut plan = dse::plan(rec, &cfg.board, &cfg.constraints);
        let choices = std::mem::take(&mut plan.choices);
        if choices.len() <= 1 {
            return dse::score_serial(rec, &cfg.board, &cfg.constraints, &plan, choices);
        }
        // Pool jobs are 'static: share the invariants behind Arcs.
        type ScoreJob = Box<dyn FnOnce() -> Option<(MappingCandidate, PerfEstimate)> + Send>;
        let rec = Arc::new(rec.clone());
        let model: Arc<CostModel> = Arc::new(dse::scoring_model(&cfg.board, &cfg.constraints));
        let cons = Arc::new(cfg.constraints.clone());
        let plan = Arc::new(plan);
        let jobs: Vec<ScoreJob> = choices
            .into_iter()
            .map(|choice| {
                let (rec, model, cons, plan) =
                    (Arc::clone(&rec), Arc::clone(&model), Arc::clone(&cons), Arc::clone(&plan));
                Box::new(move || dse::score_choice(&rec, &model, &cons, &plan, choice))
                    as ScoreJob
            })
            .collect();
        let scored = self.inner.dse_pool.scatter(jobs);
        dse::rank(scored.into_iter().flatten().collect())
    }

    /// Effective per-request configuration: the base with the request's
    /// overrides applied.
    pub fn effective_config(&self, req: &CompileRequest) -> WideSaConfig {
        let mut cfg = self.inner.cfg.base.clone();
        if let Some(aies) = req.max_aies {
            cfg.constraints.max_aies = Some(aies);
        }
        if let Some(bits) = req.mover_bits {
            cfg.mover_bits = bits;
        }
        if let Some(cold) = req.cold_dram {
            cfg.cold_dram = cold;
        }
        cfg
    }

    /// Handle one protocol line end-to-end; always returns a response
    /// line (success, protocol error, or — if the compile itself
    /// panicked — an error carrying the request's own id), never panics
    /// outward. The one-response-per-request contract holds even for the
    /// single-flight leader whose compile dies: followers get the
    /// `FlightGuard` error, the leader's requester gets this one.
    pub fn handle_line(&self, line: &str) -> String {
        let req = match protocol::parse_request(line) {
            Ok(req) => req,
            Err(e) => return protocol::error_line(&crate::util::json::Json::Null, &e.to_string()),
        };
        let rec = match protocol::request_recurrence(&req) {
            Ok(rec) => rec,
            Err(e) => return protocol::error_line(&req.id, &e.to_string()),
        };
        let cfg = self.effective_config(&req);
        let t0 = Instant::now();
        let compiled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.compile_with(&rec, &cfg)
        }));
        match compiled {
            Ok(Ok(res)) => protocol::response_line(
                &req.id,
                res.key,
                res.outcome,
                &res.design,
                t0.elapsed().as_secs_f64(),
            ),
            Ok(Err(e)) => protocol::error_line(&req.id, &e.to_string()),
            Err(_) => protocol::error_line(&req.id, "internal error: compile panicked"),
        }
    }
}

/// Serve JSON-lines over stdin/stdout until EOF. Requests run
/// concurrently on the request pool; every request read gets a response
/// before this returns (pool drop joins).
pub fn serve_stdin(handle: &ServeHandle) -> Result<()> {
    let pool = WorkerPool::new(handle.config().request_workers);
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let handle = handle.clone();
        pool.execute(move || {
            // println! takes the stdout lock per call: one response per
            // line, never interleaved mid-line.
            println!("{}", handle.handle_line(&line));
        });
    }
    drop(pool); // join: flush every pending response
    Ok(())
}

/// Serve JSON-lines over TCP: one thread per connection (connections are
/// few and spend their life blocked on reads — parking one on a
/// fixed-size pool would let `request_workers` idle keep-alive clients
/// starve every later connection), one request/response pair per line,
/// until the peer closes. Per-request work still shares the handle's
/// design cache, single-flight table and DSE pool. Runs forever.
pub fn serve_tcp(handle: &ServeHandle, listener: TcpListener) -> Result<()> {
    if let Ok(addr) = listener.local_addr() {
        eprintln!("widesa serve: listening on {addr}");
    }
    for stream in listener.incoming() {
        let stream = stream?;
        let handle = handle.clone();
        std::thread::spawn(move || {
            let _ = serve_connection(&handle, stream);
        });
    }
    Ok(())
}

fn serve_connection(handle: &ServeHandle, stream: TcpStream) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = std::io::BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        writeln!(writer, "{}", handle.handle_line(&line))?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::dse::{explore_all, DseConstraints};
    use crate::recurrence::{dtype::DType, library};

    fn small_cfg() -> WideSaConfig {
        WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(64),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn miss_then_hit_shares_one_design() {
        let handle = ServeHandle::new(ServeConfig {
            base: small_cfg(),
            ..Default::default()
        });
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let a = handle.compile(&rec).unwrap();
        assert_eq!(a.outcome, CacheOutcome::Miss);
        let b = handle.compile(&rec).unwrap();
        assert_eq!(b.outcome, CacheOutcome::Hit);
        assert_eq!(a.key, b.key);
        assert!(Arc::ptr_eq(&a.design, &b.design));
        let stats = handle.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn pooled_dse_matches_serial_ranking() {
        let handle = ServeHandle::new(ServeConfig {
            dse_threads: 4,
            ..Default::default()
        });
        let cfg = WideSaConfig::default();
        for rec in [
            library::mm(2048, 2048, 2048, DType::F32),
            library::fir(65536, 15, DType::I16),
        ] {
            let serial = explore_all(&rec, &cfg.board, &cfg.constraints);
            let pooled = handle.explore_all_pooled(&rec, &cfg);
            assert_eq!(serial.len(), pooled.len());
            for (s, p) in serial.iter().zip(&pooled) {
                assert_eq!(s.0.summary(), p.0.summary());
                assert_eq!(s.1.tops.to_bits(), p.1.tops.to_bits());
            }
        }
    }

    #[test]
    fn pooled_back_half_matches_framework_serial() {
        // the serve pool's sharded P&R-over-fallbacks must return the
        // exact design the serial framework loop picks — including the
        // fallback case where the top-ranked candidate fails P&R
        let handle = ServeHandle::new(ServeConfig {
            base: WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(400),
                    ..Default::default()
                },
                ..Default::default()
            },
            dse_threads: 4,
            ..Default::default()
        });
        for rec in [
            library::mm(512, 512, 512, DType::F32),
            library::mm(2048, 2048, 2048, DType::F32),
        ] {
            let served = handle.compile(&rec).unwrap();
            let serial = WideSa::new(handle.config().base.clone()).compile(&rec).unwrap();
            assert_eq!(
                served.design.candidate.summary(),
                serial.candidate.summary(),
                "{}",
                rec.name
            );
            assert_eq!(served.design.compile.success, serial.compile.success);
            assert_eq!(served.design.merge_stats, serial.merge_stats);
            assert_eq!(
                served.design.estimate.tops.to_bits(),
                serial.estimate.tops.to_bits()
            );
        }
    }

    #[test]
    fn typed_error_survives_single_flight_dedup() {
        // whether a thread ends up the single-flight leader or a
        // follower, an unmappable request must yield the same *typed*
        // NoLegalMapping error (followers receive a clonable image, not
        // a stringified copy)
        let handle = ServeHandle::new(ServeConfig {
            base: WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(0),
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        });
        let rec = library::mm(64, 64, 64, DType::F32);
        std::thread::scope(|s| {
            let workers: Vec<_> = (0..4)
                .map(|_| {
                    let handle = handle.clone();
                    let rec = rec.clone();
                    s.spawn(move || handle.compile(&rec))
                })
                .collect();
            for w in workers {
                let err = w
                    .join()
                    .unwrap()
                    .expect_err("a 0-AIE budget cannot map anything");
                assert!(
                    err.downcast_ref::<NoLegalMapping>().is_some(),
                    "typed error lost: {err}"
                );
            }
        });
        assert!(handle.inner.flights.lock().unwrap().is_empty());
    }

    #[test]
    fn failed_compile_reports_error_and_is_not_cached() {
        let handle = ServeHandle::new(ServeConfig::default());
        // rank-1 recurrence with a single iteration: the DSE has no
        // space loops with extent > 1, so no legal mapping exists.
        let rec = library::fir(1, 1, DType::F32);
        let err = handle.compile(&rec);
        // whether this errors or degenerately maps, the service must not
        // be wedged afterwards: a follow-up normal request still works.
        let ok = handle.compile(&library::fir(65536, 15, DType::F32));
        assert!(ok.is_ok());
        if err.is_err() {
            assert_eq!(handle.stats().errors, 1);
        }
        assert!(handle.inner.flights.lock().unwrap().is_empty(), "no leaked flights");
    }
}
