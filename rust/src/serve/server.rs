//! The long-lived compile service: [`ServeHandle`] (programmatic API)
//! plus the stdin / TCP front-ends behind `widesa serve`.
//!
//! An admitted request travels: canonical key
//! ([`crate::serve::cache::design_key`]) → sharded LRU cache probe →
//! single-flight registration (concurrent identical requests compile
//! **once**; followers block until the leader publishes) → cold compile
//! with DSE candidate scoring *and* the framework back half (P&R per
//! fallback candidate) sharded over the handle's dedicated worker pool →
//! cache fill → response.
//!
//! Production-serve extensions around that path:
//!
//! * **Admission control** — per-tenant token-bucket quotas
//!   (`quota_rps`/`quota_burst`, checked before any work) and
//!   queue-depth load-shedding on the cold path (`max_inflight`). Both
//!   reject with the typed [`Overloaded`] error, which survives
//!   single-flight deduplication and renders as a structured protocol
//!   response (`overloaded: true` + `retry_after_ms`) on both front-ends.
//! * **Persistence** — the design cache snapshots to a JSON-lines file
//!   ([`crate::serve::persist`]); a new handle warm-starts from
//!   `ServeConfig::snapshot` so a restarted server answers previously
//!   cached keys without recompiling. Invalid entries self-evict.
//! * **Batching** — [`ServeHandle::compile_batch`] coalesces
//!   identical-key requests (N followers cost one evaluation), and
//!   near-key requests (same recurrence/board/constraints, different
//!   mover or DRAM flags) share memoized DSE plan work via a second
//!   plan cache keyed on [`crate::serve::cache::plan_key`].
//!
//! Request handling and DSE scoring never share an executor — stdin
//! requests run on their own [`WorkerPool`], TCP connections each get a
//! thread, and scoring has the handle's dedicated pool — so a request
//! waiting on scoring can never deadlock behind other request jobs
//! (see [`crate::serve::pool`]).

use crate::coordinator::framework::{
    CompiledDesign, FrontierSummary, NoLegalMapping, WideSa, WideSaConfig, FALLBACK_CANDIDATES,
};
use crate::mapping::cost::{CostModel, Estimate};
use crate::mapping::dse::{self, Ranked};
use crate::mapping::MappingCandidate;
use crate::obs::metrics::{Counter, Histogram, Registry};
use crate::obs::trace::{self, Span, TraceCtx};
use crate::recurrence::spec::UniformRecurrence;
use crate::serve::cache::{self, design_key, CacheStats, ShardedCache};
use crate::serve::persist;
use crate::serve::pool::WorkerPool;
use crate::serve::protocol::{self, CompileRequest};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// How a request was satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Served straight from the design cache.
    Hit,
    /// This request compiled the design (the single-flight leader).
    Miss,
    /// Another in-flight request was already compiling the same key;
    /// this one waited for it instead of compiling again.
    Deduped,
}

/// One served compile: the shared design plus how it was obtained.
pub struct ServeResult {
    pub design: Arc<CompiledDesign>,
    pub outcome: CacheOutcome,
    /// Canonical design key (stable across server restarts).
    pub key: u64,
}

// Manual impl: `CompiledDesign` (intentionally) has no Debug, and tests
// want `Result<ServeResult>::expect_err` — identify the design by name
// and key rather than dumping it.
impl std::fmt::Debug for ServeResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeResult")
            .field("name", &self.design.candidate.rec.name)
            .field("outcome", &self.outcome)
            .field("key", &format_args!("{:016x}", self.key))
            .finish()
    }
}

/// Typed admission-control rejection. Travels through single-flight
/// deduplication intact (every shed follower sees this type, not a
/// string) and renders as `{"ok": false, "overloaded": true, …}` on the
/// protocol front-ends so clients can back off instead of treating shed
/// load as a compile failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Overloaded {
    /// What rejected the request: `"quota"` (per-tenant token bucket) or
    /// `"queue"` (cold-compile queue depth at `max_inflight`).
    pub reason: String,
    /// Client back-off hint. For quota sheds this is the time until the
    /// bucket refills one token; for queue sheds a fixed nominal delay.
    pub retry_after_ms: u64,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "overloaded ({}): retry in {} ms",
            self.reason, self.retry_after_ms
        )
    }
}

impl std::error::Error for Overloaded {}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Base compile configuration; per-request fields (`max_aies`,
    /// `mover_bits`, `cold_dram`) override it.
    pub base: WideSaConfig,
    /// Total design-cache entries.
    pub cache_capacity: usize,
    /// Independent cache locks.
    pub cache_shards: usize,
    /// Worker threads sharding DSE candidate scoring per compile.
    pub dse_threads: usize,
    /// Worker threads running protocol requests (stdin / TCP loops).
    pub request_workers: usize,
    /// Snapshot file to warm-start the design cache from on construction
    /// (and for `widesa serve --snapshot` to write back on shutdown).
    /// `None` disables persistence.
    pub snapshot: Option<PathBuf>,
    /// Cold compiles allowed in flight at once before further misses are
    /// shed with [`Overloaded`] (`reason: "queue"`). Cache hits and
    /// single-flight followers are never queue-shed. 0 = unbounded.
    pub max_inflight: usize,
    /// Per-tenant steady-state request rate (tokens/second refill).
    pub quota_rps: f64,
    /// Per-tenant burst capacity (token-bucket depth). <= 0 disables
    /// quota admission entirely.
    pub quota_burst: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map_or(4, |n| n.get());
        Self {
            base: WideSaConfig::default(),
            cache_capacity: 64,
            cache_shards: 8,
            dse_threads: cores.clamp(1, 8),
            request_workers: cores.clamp(1, 8),
            snapshot: None,
            max_inflight: 0,
            quota_rps: 0.0,
            quota_burst: 0.0,
        }
    }
}

/// Service statistics snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub hits: u64,
    pub misses: u64,
    pub deduped: u64,
    pub errors: u64,
    /// Requests rejected by admission control (quota or queue depth).
    pub shed: u64,
    /// DSE plans reused from the plan cache by near-key requests.
    pub plan_hits: u64,
    pub cache: CacheStats,
}

/// Token bucket state for one tenant (guarded by the tenants map lock).
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

/// Tokens a bucket must hold to admit one request. Nominally 1.0; the
/// `WIDESA_MUTATE=quota-grant` mutation seam drops it to 0.0 so
/// `make mutation-smoke` can prove the quota tests actually bite (a
/// zero threshold admits everything — tokens drift negative — and the
/// shed assertions must fail).
fn grant_threshold() -> f64 {
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("WIDESA_MUTATE") {
        Ok(v) if v == "quota-grant" => 0.0,
        _ => 1.0,
    })
}

/// Clonable error image for single-flight followers: `anyhow::Error` is
/// not `Clone`, but the typed [`NoLegalMapping`] and [`Overloaded`]
/// cases must survive deduplication so every requester of a doomed key
/// sees the same error type as the leader, not a stringified copy.
#[derive(Clone)]
enum FlightError {
    NoLegalMapping(NoLegalMapping),
    Overloaded(Overloaded),
    Other(String),
}

impl FlightError {
    fn of(e: &anyhow::Error) -> Self {
        if let Some(t) = e.downcast_ref::<NoLegalMapping>() {
            return FlightError::NoLegalMapping(t.clone());
        }
        if let Some(o) = e.downcast_ref::<Overloaded>() {
            return FlightError::Overloaded(o.clone());
        }
        FlightError::Other(e.to_string())
    }

    fn into_error(self) -> anyhow::Error {
        match self {
            FlightError::NoLegalMapping(t) => t.into(),
            FlightError::Overloaded(o) => o.into(),
            FlightError::Other(msg) => anyhow!(msg),
        }
    }
}

/// A single-flight slot: the leader publishes here, followers wait.
struct Flight {
    /// `None` until resolved; errors travel as [`FlightError`] because
    /// every follower needs its own copy.
    slot: Mutex<Option<Result<Arc<CompiledDesign>, FlightError>>>,
    done: Condvar,
}

impl Flight {
    fn new() -> Self {
        Self {
            slot: Mutex::new(None),
            done: Condvar::new(),
        }
    }

    fn wait(&self) -> Result<Arc<CompiledDesign>, FlightError> {
        let mut slot = self.slot.lock().unwrap();
        while slot.is_none() {
            slot = self.done.wait(slot).unwrap();
        }
        slot.clone().unwrap()
    }
}

/// The handle's metric cells: every [`ServeStats`] counter *is* a
/// registry counter (one source of truth — the `"stats"` protocol
/// command and [`ServeHandle::stats`] read the same atomics), with
/// handles resolved once at construction so the hot path records
/// lock-free. Per-handle (not global) so tests see deterministic counts
/// under parallel test execution.
struct Metrics {
    registry: Arc<Registry>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    deduped: Arc<Counter>,
    errors: Arc<Counter>,
    shed: Arc<Counter>,
    plan_hits: Arc<Counter>,
    batch_coalesced: Arc<Counter>,
    /// Requests carrying an explicit `objective` override (the rest rank
    /// under the server's configured default).
    objective: Arc<Counter>,
    /// Cold-compile latency (µs), recorded by the single-flight leader.
    compile_us: Arc<Histogram>,
    /// End-to-end protocol request latency (µs), recorded per line.
    request_us: Arc<Histogram>,
}

impl Metrics {
    fn new() -> Self {
        let registry = Arc::new(Registry::new());
        Self {
            hits: registry.counter("serve.hits"),
            misses: registry.counter("serve.misses"),
            deduped: registry.counter("serve.deduped"),
            errors: registry.counter("serve.errors"),
            shed: registry.counter("serve.shed"),
            plan_hits: registry.counter("serve.plan_hits"),
            batch_coalesced: registry.counter("serve.batch_coalesced"),
            objective: registry.counter("serve.objective"),
            compile_us: registry.histogram("serve.compile_us"),
            request_us: registry.histogram("serve.request_us"),
            registry,
        }
    }
}

struct Inner {
    cfg: ServeConfig,
    cache: ShardedCache<Arc<CompiledDesign>>,
    /// Memoized DSE plans keyed on [`cache::plan_key`]: near-key
    /// requests (same recurrence/board/constraints, different mover or
    /// DRAM flags) share demarcation + space-time enumeration work.
    plans: ShardedCache<Arc<dse::DsePlan>>,
    flights: Mutex<HashMap<u64, Arc<Flight>>>,
    dse_pool: WorkerPool,
    tenants: Mutex<HashMap<String, TokenBucket>>,
    inflight: AtomicU64,
    metrics: Metrics,
}

/// Occupies one cold-compile slot; releases it on drop (any exit path).
struct InflightSlot<'a> {
    inner: &'a Inner,
}

impl Drop for InflightSlot<'_> {
    fn drop(&mut self) {
        self.inner.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Inner {
    /// Token-bucket admission for one tenant. Disabled (always admits)
    /// when `quota_burst <= 0`.
    fn admit_quota(&self, tenant: &str) -> Result<(), Overloaded> {
        let burst = self.cfg.quota_burst;
        if burst <= 0.0 {
            return Ok(());
        }
        let rps = self.cfg.quota_rps;
        let now = Instant::now();
        let mut tenants = self.tenants.lock().unwrap();
        let bucket = tenants.entry(tenant.to_string()).or_insert(TokenBucket {
            tokens: burst,
            last: now,
        });
        let elapsed = now.duration_since(bucket.last).as_secs_f64();
        bucket.last = now;
        bucket.tokens = (bucket.tokens + elapsed * rps).min(burst);
        let need = grant_threshold();
        if bucket.tokens >= need {
            bucket.tokens -= 1.0;
            return Ok(());
        }
        let retry_after_ms = if rps > 0.0 {
            ((need - bucket.tokens) / rps * 1e3).ceil() as u64
        } else {
            1000
        };
        Err(Overloaded {
            reason: "quota".into(),
            retry_after_ms,
        })
    }

    /// Claim a cold-compile slot, or shed if `max_inflight` are already
    /// running. `Ok(None)` means shedding is disabled.
    fn acquire_inflight(&self) -> Result<Option<InflightSlot<'_>>, Overloaded> {
        let max = self.cfg.max_inflight;
        if max == 0 {
            return Ok(None);
        }
        let prev = self.inflight.fetch_add(1, Ordering::AcqRel);
        if prev as usize >= max {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(Overloaded {
                reason: "queue".into(),
                retry_after_ms: 100,
            });
        }
        Ok(Some(InflightSlot { inner: self }))
    }
}

/// Resolves a flight on drop so follower requests can never hang, even
/// if the leader's compile panics.
struct FlightGuard<'a> {
    inner: &'a Inner,
    key: u64,
    flight: Arc<Flight>,
    resolved: bool,
}

impl FlightGuard<'_> {
    fn resolve(&mut self, result: Result<Arc<CompiledDesign>, FlightError>) {
        *self.flight.slot.lock().unwrap() = Some(result);
        self.flight.done.notify_all();
        self.inner.flights.lock().unwrap().remove(&self.key);
        self.resolved = true;
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.resolved {
            self.resolve(Err(FlightError::Other("compile panicked".into())));
        }
    }
}

/// The long-lived, thread-safe compile service. Cheap to clone (all
/// clones share the cache, the in-flight table and the scoring pool), so
/// one handle can serve stdin, a TCP listener and library callers at
/// the same time.
///
/// ```
/// use widesa::{library, CacheOutcome, DType, DseConstraints, ServeConfig, ServeHandle,
///              WideSaConfig};
///
/// let handle = ServeHandle::new(ServeConfig {
///     base: WideSaConfig {
///         constraints: DseConstraints {
///             max_aies: Some(32), // small budget keeps the doctest fast
///             ..Default::default()
///         },
///         ..Default::default()
///     },
///     cache_capacity: 8,
///     ..Default::default()
/// });
/// let rec = library::fir(65536, 15, DType::F32);
/// let first = handle.compile(&rec).unwrap();
/// assert_eq!(first.outcome, CacheOutcome::Miss);
/// let second = handle.compile(&rec).unwrap();
/// assert_eq!(second.outcome, CacheOutcome::Hit);
/// // both requests share one compiled design
/// assert!(std::sync::Arc::ptr_eq(&first.design, &second.design));
/// ```
#[derive(Clone)]
pub struct ServeHandle {
    inner: Arc<Inner>,
}

impl ServeHandle {
    pub fn new(cfg: ServeConfig) -> Self {
        let cache = ShardedCache::new(cfg.cache_capacity, cfg.cache_shards);
        let plans = ShardedCache::new(cfg.cache_capacity.max(8), 4);
        let dse_pool = WorkerPool::new(cfg.dse_threads);
        let handle = Self {
            inner: Arc::new(Inner {
                cfg,
                cache,
                plans,
                flights: Mutex::new(HashMap::new()),
                dse_pool,
                tenants: Mutex::new(HashMap::new()),
                inflight: AtomicU64::new(0),
                metrics: Metrics::new(),
            }),
        };
        if let Some(path) = handle.inner.cfg.snapshot.clone() {
            let (loaded, skipped) = handle.load_snapshot(&path);
            if loaded > 0 || skipped > 0 {
                eprintln!(
                    "widesa serve: warm start — {loaded} designs from {} ({skipped} skipped)",
                    path.display()
                );
            }
        }
        handle
    }

    pub fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }

    pub fn stats(&self) -> ServeStats {
        let m = &self.inner.metrics;
        ServeStats {
            hits: m.hits.get(),
            misses: m.misses.get(),
            deduped: m.deduped.get(),
            errors: m.errors.get(),
            shed: m.shed.get(),
            plan_hits: m.plan_hits.get(),
            cache: self.inner.cache.stats(),
        }
    }

    /// The handle's metric registry (the cells behind [`ServeStats`],
    /// plus latency histograms like `serve.compile_us`). Snapshot it via
    /// [`Registry::snapshot`] — that is exactly what the `"stats"`
    /// protocol command and `widesa serve --metrics-out` emit.
    pub fn metrics(&self) -> &Registry {
        &self.inner.metrics.registry
    }

    /// Warm-start the design cache from a snapshot file. Returns
    /// `(loaded, skipped)`; a missing file loads nothing. Entries that
    /// fail to parse or validate are skipped one by one
    /// (see [`crate::serve::persist`]).
    pub fn load_snapshot(&self, path: &Path) -> (usize, usize) {
        let (entries, skipped) = persist::load_snapshot(path);
        let loaded = entries.len();
        for (key, design) in entries {
            self.inner.cache.insert(key, Arc::new(design));
        }
        (loaded, skipped)
    }

    /// Persist the current design cache to `path` (atomic
    /// write-then-rename). Returns the number of entries written.
    pub fn save_snapshot(&self, path: &Path) -> Result<usize> {
        persist::save_snapshot(path, &self.inner.cache.entries())
    }

    /// Compile under the service's base configuration.
    pub fn compile(&self, rec: &UniformRecurrence) -> Result<ServeResult> {
        self.compile_as("", rec, &self.inner.cfg.base)
    }

    /// Compile under an explicit configuration (cache-keyed on it).
    pub fn compile_with(&self, rec: &UniformRecurrence, cfg: &WideSaConfig) -> Result<ServeResult> {
        self.compile_as("", rec, cfg)
    }

    /// Compile on behalf of a tenant: quota admission first (before any
    /// cache or compile work), then the cached single-flight path, with
    /// queue-depth shedding guarding the cold compile. The anonymous
    /// tenant `""` is a tenant like any other.
    pub fn compile_as(
        &self,
        tenant: &str,
        rec: &UniformRecurrence,
        cfg: &WideSaConfig,
    ) -> Result<ServeResult> {
        let inner = &*self.inner;
        let quota_span = Span::begin("serve.quota", "serve");
        let admitted = inner.admit_quota(tenant);
        drop(quota_span);
        if let Err(o) = admitted {
            inner.metrics.shed.inc();
            return Err(o.into());
        }
        let probe_span = Span::begin("serve.cache_probe", "serve");
        let key = design_key(rec, cfg);
        let probed = inner.cache.get(key);
        drop(probe_span);

        if let Some(design) = probed {
            inner.metrics.hits.inc();
            return Ok(ServeResult {
                design,
                outcome: CacheOutcome::Hit,
                key,
            });
        }

        // Single-flight: exactly one thread becomes the leader for a key.
        let (flight, leader) = {
            let mut flights = inner.flights.lock().unwrap();
            match flights.get(&key) {
                Some(f) => (Arc::clone(f), false),
                None => {
                    let f = Arc::new(Flight::new());
                    flights.insert(key, Arc::clone(&f));
                    (f, true)
                }
            }
        };

        if !leader {
            inner.metrics.deduped.inc();
            let _wait_span = Span::begin("serve.flight_wait", "serve");
            return match flight.wait() {
                Ok(design) => Ok(ServeResult {
                    design,
                    outcome: CacheOutcome::Deduped,
                    key,
                }),
                Err(fe) => {
                    // Sheds propagate typed to followers but count as
                    // shed load, not compile errors.
                    match &fe {
                        FlightError::Overloaded(_) => inner.metrics.shed.inc(),
                        _ => inner.metrics.errors.inc(),
                    };
                    Err(fe.into_error())
                }
            };
        }

        let mut guard = FlightGuard {
            inner,
            key,
            flight,
            resolved: false,
        };
        // Leader double-check: between this thread's cache probe and its
        // flight registration, a previous leader may have published (it
        // fills the cache *before* deregistering its flight, so "no
        // flight found" + "cache now full" is a completed compile, not a
        // cold key). Without this, a request racing the tail of another
        // compile would compile the same design twice.
        if let Some(design) = inner.cache.get(key) {
            inner.metrics.hits.inc();
            guard.resolve(Ok(Arc::clone(&design)));
            return Ok(ServeResult {
                design,
                outcome: CacheOutcome::Hit,
                key,
            });
        }
        // Queue-depth shedding guards the cold compile only: hits and
        // followers above never consume a slot. The shed resolves the
        // flight so every follower of this key receives the same typed
        // Overloaded instead of hanging.
        let _slot = match inner.acquire_inflight() {
            Ok(slot) => slot,
            Err(o) => {
                inner.metrics.shed.inc();
                guard.resolve(Err(FlightError::Overloaded(o.clone())));
                return Err(o.into());
            }
        };
        inner.metrics.misses.inc();
        let compile_span = Span::begin("serve.cold_compile", "serve");
        let compiled = self.cold_compile(rec, cfg);
        inner
            .metrics
            .compile_us
            .record((compile_span.end_ms() * 1e3) as u64);
        let published: Result<Arc<CompiledDesign>, FlightError> = match &compiled {
            Ok(design) => {
                inner.cache.insert(key, Arc::clone(design));
                Ok(Arc::clone(design))
            }
            Err(e) => {
                inner.metrics.errors.inc();
                Err(FlightError::of(e))
            }
        };
        guard.resolve(published);
        compiled.map(|design| ServeResult {
            design,
            outcome: CacheOutcome::Miss,
            key,
        })
    }

    /// Compile a batch, coalescing duplicate keys: the first occurrence
    /// of each key compiles (or hits the cache) and every later
    /// duplicate reuses its design (or its error) as
    /// [`CacheOutcome::Deduped`] without touching the compile path.
    /// Results come back in request order.
    pub fn compile_batch(
        &self,
        reqs: &[(UniformRecurrence, WideSaConfig)],
    ) -> Vec<Result<ServeResult>> {
        let mut first: HashMap<u64, Result<Arc<CompiledDesign>, FlightError>> = HashMap::new();
        let mut out = Vec::with_capacity(reqs.len());
        for (rec, cfg) in reqs {
            let key = design_key(rec, cfg);
            if let Some(prev) = first.get(&key) {
                self.inner.metrics.deduped.inc();
                self.inner.metrics.batch_coalesced.inc();
                out.push(match prev {
                    Ok(design) => Ok(ServeResult {
                        design: Arc::clone(design),
                        outcome: CacheOutcome::Deduped,
                        key,
                    }),
                    Err(fe) => Err(fe.clone().into_error()),
                });
                continue;
            }
            let res = self.compile_with(rec, cfg);
            match &res {
                Ok(r) => {
                    first.insert(key, Ok(Arc::clone(&r.design)));
                }
                Err(e) => {
                    first.insert(key, Err(FlightError::of(e)));
                }
            }
            out.push(res);
        }
        out
    }

    /// Test hook: claim one cold-compile slot (and hold it until the
    /// returned value drops). Admission-control tests use this to force
    /// deterministic queue-full shedding without racing real compiles.
    #[doc(hidden)]
    pub fn debug_inflight_slot(&self) -> Option<impl Drop + '_> {
        self.inner.acquire_inflight().ok().flatten()
    }

    /// The cold path: DSE with candidate scoring scattered over the
    /// handle's worker pool (deterministic merge — identical ranking to
    /// the serial `explore_all`), then the framework back half — P&R per
    /// fallback candidate scattered over the *same* pool, with the
    /// deterministic first-success selection picking the design the
    /// serial loop would.
    fn cold_compile(
        &self,
        rec: &UniformRecurrence,
        cfg: &WideSaConfig,
    ) -> Result<Arc<CompiledDesign>> {
        let ranked = self.explore_all_pooled(rec, cfg);
        let ws = WideSa::new(cfg.clone());
        if self.inner.dse_pool.workers() <= 1 || ranked.len() <= 1 {
            return ws.compile_ranked(rec, ranked).map(Arc::new);
        }
        // Same frontier summary the serial compile_ranked path attaches:
        // the pooled fallback fan-out must not lose it.
        let summary = FrontierSummary {
            frontier: dse::frontier_size(&ranked),
            candidates: ranked.len(),
        };
        let model = ws.cost_model();
        let mut top: Vec<_> = ranked
            .into_iter()
            .take(FALLBACK_CANDIDATES)
            .map(|(candidate, _)| candidate)
            .collect();
        // Top candidate first: the common first-success case costs one
        // evaluation (like the serial loop); only a P&R failure pays for
        // the speculative fallback fan-out.
        let mut first = ws.evaluate_candidate(&model, top.remove(0));
        first.frontier = summary;
        if first.compile.success || top.is_empty() {
            return Ok(Arc::new(first));
        }
        let ws = Arc::new(ws);
        let model = Arc::new(model);
        // carry the request's trace ID into the pool so the fallback
        // P&R spans correlate with this request across worker threads
        let trace_id = trace::current_trace();
        type EvalJob = Box<dyn FnOnce() -> CompiledDesign + Send>;
        let jobs: Vec<EvalJob> = top
            .into_iter()
            .map(|candidate| {
                let (ws, model) = (Arc::clone(&ws), Arc::clone(&model));
                Box::new(move || {
                    let _ctx = TraceCtx::set(trace_id);
                    ws.evaluate_candidate(&model, candidate)
                }) as EvalJob
            })
            .collect();
        let mut designs = self.inner.dse_pool.scatter(jobs);
        designs.insert(0, first);
        WideSa::select_design(designs)
            .map(|mut d| {
                d.frontier = summary;
                Arc::new(d)
            })
            .ok_or_else(|| {
                NoLegalMapping {
                    recurrence: rec.name.clone(),
                }
                .into()
            })
    }

    /// The memoized DSE plan for a request's (recurrence, board,
    /// constraints) triple. Mover width and DRAM flags don't enter plan
    /// construction, so near-key requests reuse the cached plan
    /// ([`cache::plan_key`] deliberately ignores those fields).
    fn plan_for(&self, rec: &UniformRecurrence, cfg: &WideSaConfig) -> Arc<dse::DsePlan> {
        let key = cache::plan_key(rec, cfg);
        if let Some(plan) = self.inner.plans.get(key) {
            self.inner.metrics.plan_hits.inc();
            return plan;
        }
        let plan = Arc::new(dse::plan(rec, &cfg.board, &cfg.constraints));
        self.inner.plans.insert(key, Arc::clone(&plan));
        plan
    }

    /// `explore_all` with the plan memoized across requests and
    /// per-candidate scoring as pool jobs. Results come back in
    /// submission (= enumeration) order via [`WorkerPool::scatter`],
    /// then go through the canonical objective dispatch
    /// ([`dse::rank_by`]) — bit-identical to the serial path under
    /// every [`dse::Objective`].
    fn explore_all_pooled(&self, rec: &UniformRecurrence, cfg: &WideSaConfig) -> Ranked {
        let _dse = Span::begin("dse", "dse");
        let plan = self.plan_for(rec, cfg);
        let choices = plan.choices.clone();
        if self.inner.dse_pool.workers() <= 1 || choices.len() <= 1 {
            return dse::score_serial(rec, &cfg.board, &cfg.constraints, &plan, choices);
        }
        // Pool jobs are 'static: share the invariants behind Arcs. Each
        // job re-installs this request's trace ID on its worker thread
        // so its dse.score span correlates across the pool.
        type ScoreJob = Box<dyn FnOnce() -> Option<(MappingCandidate, Estimate)> + Send>;
        let rec = Arc::new(rec.clone());
        let model: Arc<CostModel> = Arc::new(dse::scoring_model(&cfg.board, &cfg.constraints));
        let cons = Arc::new(cfg.constraints.clone());
        let trace_id = trace::current_trace();
        let jobs: Vec<ScoreJob> = choices
            .into_iter()
            .map(|choice| {
                let (rec, model, cons, plan) =
                    (Arc::clone(&rec), Arc::clone(&model), Arc::clone(&cons), Arc::clone(&plan));
                Box::new(move || {
                    let _ctx = TraceCtx::set(trace_id);
                    let _span = Span::begin("dse.score", "dse");
                    dse::score_choice(&rec, &model, &cons, &plan, choice)
                }) as ScoreJob
            })
            .collect();
        let scored = self.inner.dse_pool.scatter(jobs);
        dse::rank_by(
            scored.into_iter().flatten().collect(),
            cfg.constraints.objective,
        )
    }

    /// Effective per-request configuration: the base with the request's
    /// overrides applied.
    pub fn effective_config(&self, req: &CompileRequest) -> WideSaConfig {
        let mut cfg = self.inner.cfg.base.clone();
        if let Some(aies) = req.max_aies {
            cfg.constraints.max_aies = Some(aies);
        }
        if let Some(bits) = req.mover_bits {
            cfg.mover_bits = bits;
        }
        if let Some(cold) = req.cold_dram {
            cfg.cold_dram = cold;
        }
        if let Some(obj) = req.objective {
            cfg.constraints.objective = obj;
            self.inner.metrics.objective.inc();
        }
        if let Some(w) = req.max_power_w {
            cfg.constraints.max_power_w = Some(w);
        }
        cfg
    }

    /// Handle one protocol line end-to-end; always returns a response
    /// line (success, overloaded, protocol error, or — if the compile
    /// itself panicked — an error carrying the request's own id), never
    /// panics outward. The one-response-per-request contract holds even
    /// for the single-flight leader whose compile dies: followers get
    /// the `FlightGuard` error, the leader's requester gets this one.
    ///
    /// Each line gets a fresh trace ID and runs under a `serve.request`
    /// root span; the ID rides into the DSE/P&R pool jobs so one
    /// request's spans correlate across threads in a Chrome-trace
    /// export. `{"cmd": "stats"}` lines are answered from the metric
    /// registries without touching the compile path.
    pub fn handle_line(&self, line: &str) -> String {
        // cheap precheck: compile requests have no "cmd" field, so the
        // hot path never parses twice
        if line.contains("\"cmd\"") {
            if let Some(id) = protocol::stats_request(line) {
                return protocol::stats_line(
                    &id,
                    &self.stats(),
                    self.inner.metrics.registry.snapshot(),
                    crate::obs::metrics::global().snapshot(),
                );
            }
        }
        let _ctx = TraceCtx::set(trace::next_trace_id());
        let root = Span::begin("serve.request", "serve");
        let out = self.handle_request_line(line);
        self.inner.metrics.request_us.record((root.end_ms() * 1e3) as u64);
        out
    }

    fn handle_request_line(&self, line: &str) -> String {
        let parse_span = Span::begin("serve.parse", "serve");
        let req = match protocol::parse_request(line) {
            Ok(req) => req,
            Err(e) => return protocol::error_line(&crate::util::json::Json::Null, &e.to_string()),
        };
        let rec = match protocol::request_recurrence(&req) {
            Ok(rec) => rec,
            Err(e) => return protocol::error_line(&req.id, &e.to_string()),
        };
        drop(parse_span);
        let cfg = self.effective_config(&req);
        // mm requests carry a host-level blocking plan in the response;
        // shapes the planner cannot place are rejected *before* any
        // compile work with the typed `unplannable` protocol line. CA
        // variants replay the planner per k-slab instead, so their
        // responses carry no whole-problem blocking object.
        let blocking_plan = if req.bench == "mm"
            && req.variant != Some(crate::mapping::dse::Form::Ca)
        {
            let d: &[u64] = if req.dims.is_empty() {
                &[8192, 8192, 8192]
            } else {
                &req.dims
            };
            let model = CostModel::new(cfg.board.clone());
            match crate::coordinator::blocking::plan_mm(&model, d[0], d[1], d[2]) {
                Ok(plan) => Some(plan),
                Err(u) => {
                    self.inner.metrics.errors.inc();
                    return protocol::unplannable_line(&req.id, &u);
                }
            }
        } else {
            None
        };
        let tenant = req.tenant.clone().unwrap_or_default();
        let t0 = Instant::now();
        let compiled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.compile_as(&tenant, &rec, &cfg)
        }));
        match compiled {
            Ok(Ok(res)) => protocol::response_line(
                &req.id,
                res.key,
                res.outcome,
                &res.design,
                t0.elapsed().as_secs_f64(),
                blocking_plan.as_ref(),
            ),
            Ok(Err(e)) => match e.downcast_ref::<Overloaded>() {
                Some(o) => protocol::overloaded_line(&req.id, o),
                None => protocol::error_line(&req.id, &e.to_string()),
            },
            Err(_) => protocol::error_line(&req.id, "internal error: compile panicked"),
        }
    }
}

/// Serve JSON-lines over stdin/stdout until EOF. Requests run
/// concurrently on the request pool; every request read gets a response
/// before this returns (pool drop joins).
pub fn serve_stdin(handle: &ServeHandle) -> Result<()> {
    let pool = WorkerPool::new(handle.config().request_workers);
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let handle = handle.clone();
        pool.execute(move || {
            // println! takes the stdout lock per call: one response per
            // line, never interleaved mid-line.
            println!("{}", handle.handle_line(&line));
        });
    }
    drop(pool); // join: flush every pending response
    Ok(())
}

/// Serve JSON-lines over TCP: one thread per connection (connections are
/// few and spend their life blocked on reads — parking one on a
/// fixed-size pool would let `request_workers` idle keep-alive clients
/// starve every later connection), one request/response pair per line,
/// until the peer closes. Per-request work still shares the handle's
/// design cache, single-flight table and DSE pool. Runs forever.
pub fn serve_tcp(handle: &ServeHandle, listener: TcpListener) -> Result<()> {
    if let Ok(addr) = listener.local_addr() {
        eprintln!("widesa serve: listening on {addr}");
    }
    for stream in listener.incoming() {
        let stream = stream?;
        let handle = handle.clone();
        std::thread::spawn(move || {
            let _ = serve_connection(&handle, stream);
        });
    }
    Ok(())
}

fn serve_connection(handle: &ServeHandle, stream: TcpStream) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = std::io::BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        writeln!(writer, "{}", handle.handle_line(&line))?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::dse::{explore_all, DseConstraints, Objective};
    use crate::recurrence::{dtype::DType, library};

    fn small_cfg() -> WideSaConfig {
        WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(64),
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn miss_then_hit_shares_one_design() {
        let handle = ServeHandle::new(ServeConfig {
            base: small_cfg(),
            ..Default::default()
        });
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let a = handle.compile(&rec).unwrap();
        assert_eq!(a.outcome, CacheOutcome::Miss);
        let b = handle.compile(&rec).unwrap();
        assert_eq!(b.outcome, CacheOutcome::Hit);
        assert_eq!(a.key, b.key);
        assert!(Arc::ptr_eq(&a.design, &b.design));
        let stats = handle.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn pooled_dse_matches_serial_ranking() {
        let handle = ServeHandle::new(ServeConfig {
            dse_threads: 4,
            ..Default::default()
        });
        let cfg = WideSaConfig::default();
        for rec in [
            library::mm(2048, 2048, 2048, DType::F32),
            library::fir(65536, 15, DType::I16),
        ] {
            let serial = explore_all(&rec, &cfg.board, &cfg.constraints);
            let pooled = handle.explore_all_pooled(&rec, &cfg);
            assert_eq!(serial.len(), pooled.len());
            for (s, p) in serial.iter().zip(&pooled) {
                assert_eq!(s.0.summary(), p.0.summary());
                assert_eq!(s.1.perf.tops.to_bits(), p.1.perf.tops.to_bits());
                assert_eq!(s.1.power.watts.to_bits(), p.1.power.watts.to_bits());
            }
        }
        // rescoring the same recurrences hit the memoized plan cache
        for rec in [
            library::mm(2048, 2048, 2048, DType::F32),
            library::fir(65536, 15, DType::I16),
        ] {
            handle.explore_all_pooled(&rec, &cfg);
        }
        assert_eq!(handle.stats().plan_hits, 2);
    }

    #[test]
    fn pooled_back_half_matches_framework_serial() {
        // the serve pool's sharded P&R-over-fallbacks must return the
        // exact design the serial framework loop picks — including the
        // fallback case where the top-ranked candidate fails P&R
        let handle = ServeHandle::new(ServeConfig {
            base: WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(400),
                    ..Default::default()
                },
                ..Default::default()
            },
            dse_threads: 4,
            ..Default::default()
        });
        for rec in [
            library::mm(512, 512, 512, DType::F32),
            library::mm(2048, 2048, 2048, DType::F32),
        ] {
            let served = handle.compile(&rec).unwrap();
            let serial = WideSa::new(handle.config().base.clone()).compile(&rec).unwrap();
            assert_eq!(
                served.design.candidate.summary(),
                serial.candidate.summary(),
                "{}",
                rec.name
            );
            assert_eq!(served.design.compile.success, serial.compile.success);
            assert_eq!(served.design.merge_stats, serial.merge_stats);
            assert_eq!(
                served.design.estimate.perf.tops.to_bits(),
                serial.estimate.perf.tops.to_bits()
            );
            assert_eq!(
                served.design.frontier, serial.frontier,
                "pooled path must attach the same frontier summary"
            );
        }
    }

    #[test]
    fn objective_and_power_cap_overrides_flow_into_config() {
        let handle = ServeHandle::new(ServeConfig {
            base: small_cfg(),
            ..Default::default()
        });
        let req = protocol::parse_request(
            r#"{"bench":"mm","objective":"pareto","max_power_w":50}"#,
        )
        .unwrap();
        let cfg = handle.effective_config(&req);
        assert_eq!(cfg.constraints.objective, Objective::Pareto);
        assert_eq!(cfg.constraints.max_power_w, Some(50.0));
        assert_eq!(handle.inner.metrics.objective.get(), 1);
        // a plain request leaves the defaults (and the counter) alone
        let plain = protocol::parse_request(r#"{"bench":"mm"}"#).unwrap();
        let cfg = handle.effective_config(&plain);
        assert_eq!(cfg.constraints.objective, Objective::Throughput);
        assert_eq!(cfg.constraints.max_power_w, None);
        assert_eq!(handle.inner.metrics.objective.get(), 1);
        // the override shifts the cache key, so objective variants of
        // one workload cache as distinct designs
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let base = handle.config().base.clone();
        let pareto = handle.effective_config(&req);
        assert_ne!(design_key(&rec, &base), design_key(&rec, &pareto));
    }

    #[test]
    fn typed_error_survives_single_flight_dedup() {
        // whether a thread ends up the single-flight leader or a
        // follower, an unmappable request must yield the same *typed*
        // NoLegalMapping error (followers receive a clonable image, not
        // a stringified copy)
        let handle = ServeHandle::new(ServeConfig {
            base: WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(0),
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        });
        let rec = library::mm(64, 64, 64, DType::F32);
        std::thread::scope(|s| {
            let workers: Vec<_> = (0..4)
                .map(|_| {
                    let handle = handle.clone();
                    let rec = rec.clone();
                    s.spawn(move || handle.compile(&rec))
                })
                .collect();
            for w in workers {
                let err = w
                    .join()
                    .unwrap()
                    .expect_err("a 0-AIE budget cannot map anything");
                assert!(
                    err.downcast_ref::<NoLegalMapping>().is_some(),
                    "typed error lost: {err}"
                );
            }
        });
        assert!(handle.inner.flights.lock().unwrap().is_empty());
    }

    #[test]
    fn failed_compile_reports_error_and_is_not_cached() {
        let handle = ServeHandle::new(ServeConfig::default());
        // rank-1 recurrence with a single iteration: the DSE has no
        // space loops with extent > 1, so no legal mapping exists.
        let rec = library::fir(1, 1, DType::F32);
        let err = handle.compile(&rec);
        // whether this errors or degenerately maps, the service must not
        // be wedged afterwards: a follow-up normal request still works.
        let ok = handle.compile(&library::fir(65536, 15, DType::F32));
        assert!(ok.is_ok());
        if err.is_err() {
            assert_eq!(handle.stats().errors, 1);
        }
        assert!(handle.inner.flights.lock().unwrap().is_empty(), "no leaked flights");
    }

    #[test]
    fn quota_admission_is_per_tenant() {
        // burst 1, refill 0: each tenant gets exactly one admission, the
        // second request sheds with a typed quota error — independently
        // per tenant.
        let handle = ServeHandle::new(ServeConfig {
            base: small_cfg(),
            quota_rps: 0.0,
            quota_burst: 1.0,
            ..Default::default()
        });
        let rec = library::fir(65536, 15, DType::F32);
        assert!(handle.compile_as("a", &rec, &handle.config().base.clone()).is_ok());
        let err = handle
            .compile_as("a", &rec, &handle.config().base.clone())
            .expect_err("tenant a's bucket is empty");
        let o = err.downcast_ref::<Overloaded>().expect("typed Overloaded");
        assert_eq!(o.reason, "quota");
        assert!(o.retry_after_ms > 0);
        // tenant b is unaffected by a's exhaustion
        assert!(handle.compile_as("b", &rec, &handle.config().base.clone()).is_ok());
        assert_eq!(handle.stats().shed, 1);
    }

    #[test]
    fn batch_coalesces_duplicate_keys() {
        let handle = ServeHandle::new(ServeConfig {
            base: small_cfg(),
            ..Default::default()
        });
        let cfg = handle.config().base.clone();
        let rec = library::fir(65536, 15, DType::F32);
        let other = library::fir(32768, 15, DType::F32);
        let reqs = vec![
            (rec.clone(), cfg.clone()),
            (rec.clone(), cfg.clone()),
            (other.clone(), cfg.clone()),
            (rec.clone(), cfg.clone()),
        ];
        let results = handle.compile_batch(&reqs);
        let outcomes: Vec<_> = results
            .iter()
            .map(|r| r.as_ref().unwrap().outcome)
            .collect();
        assert_eq!(
            outcomes,
            vec![
                CacheOutcome::Miss,
                CacheOutcome::Deduped,
                CacheOutcome::Miss,
                CacheOutcome::Deduped,
            ]
        );
        // duplicates share the leader's design, order is preserved
        assert!(Arc::ptr_eq(
            &results[0].as_ref().unwrap().design,
            &results[1].as_ref().unwrap().design
        ));
        assert_eq!(results[2].as_ref().unwrap().key, design_key(&other, &cfg));
        assert_eq!(handle.stats().misses, 2);
        assert_eq!(handle.stats().deduped, 2);
    }
}
