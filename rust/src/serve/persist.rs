//! Cross-process snapshot persistence for the design cache.
//!
//! The compile pipeline is pure, so a served design is exactly
//! reconstructible from its serialized form — which makes the sharded
//! LRU cache portable across server restarts. A snapshot is a JSON-lines
//! file, one self-contained entry per line:
//!
//! ```json
//! {"schema":1,"key":"91ab…16hex","rec":"34cd…16hex","design":{…}}
//! ```
//!
//! * `schema` — [`SNAPSHOT_SCHEMA`]; bumping it on any layout change
//!   makes every older entry self-evict on load.
//! * `key` — the [`crate::serve::cache::design_key`] the entry was
//!   cached under, as 16 hex digits (full 64 bits; JSON numbers only
//!   carry 53).
//! * `rec` — the recurrence's [`canonical_u64`]
//!   [`crate::recurrence::spec::UniformRecurrence::canonical_u64`]
//!   stamp. On load the recurrence is deserialized and its canonical
//!   key recomputed; a mismatch (bit-rot, a hand-edited file, or a
//!   canonicalization change) evicts the entry.
//!
//! Every validation failure — parse error, truncated line, schema bump,
//! stamp mismatch — skips **that entry only** and never panics: a
//! corrupt snapshot degrades to a colder start, not a dead server.
//!
//! Power figures are **derived, never stored**: estimates serialize
//! their 14 performance fields exactly as before the power refactor,
//! and the loader reprices [`crate::arch::power::PowerEstimate`]s (and
//! the sim report's watts) through the default
//! [`crate::arch::power::PowerModel`] — a pure function of the stored
//! fields. Pre-refactor schema-1 snapshots therefore still warm-start,
//! and within-version round-trips stay byte-identical.

use crate::arch::array::Coord;
use crate::arch::plio::PlioDir;
use crate::arch::power::{design_activity, PowerModel};
use crate::codegen::CodeBundle;
use crate::coordinator::framework::{CompiledDesign, FrontierSummary};
use crate::graph::builder::MappedGraph;
use crate::graph::edge::{Edge, EdgeKind};
use crate::graph::node::{Node, NodeKind};
use crate::graph::packet::MergeStats;
use crate::mapping::candidate::{Kind, MappingCandidate};
use crate::mapping::cost::{price_power, Estimate, PerfBound, PerfEstimate};
use crate::mapping::latency::LatencyHiding;
use crate::mapping::partition::ArrayPartition;
use crate::mapping::spacetime::SpaceTimeChoice;
use crate::mapping::threading::Threading;
use crate::place_route::compiler::{CompileOutcome, StageTimings};
use crate::place_route::constraints::ConstraintSet;
use crate::place_route::placement::Placement;
use crate::polyhedral::affine::{AffineExpr, AffineMap};
use crate::polyhedral::dependence::{DepKind, Dependence};
use crate::polyhedral::domain::{IterationDomain, LoopDim};
use crate::polyhedral::schedule::{LoopNest, LoopRole};
use crate::recurrence::dtype::DType;
use crate::recurrence::spec::{Access, AccessKind, UniformRecurrence};
use crate::recurrence::tiling::KernelScope;
use crate::sim::metrics::SimReport;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Result};
use std::path::Path;
use std::sync::Arc;

/// Bump on any change to the serialized design layout; older entries
/// then self-evict on load instead of deserializing garbage.
pub const SNAPSHOT_SCHEMA: u64 = 1;

// ---------------------------------------------------------------------
// typed field access (all failures become per-entry skips in the loader)

fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key)
        .ok_or_else(|| anyhow!("snapshot entry missing field {key:?}"))
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    Ok(field(v, key)?
        .as_str()
        .ok_or_else(|| anyhow!("field {key:?} must be a string"))?
        .to_string())
}

fn f64_field(v: &Json, key: &str) -> Result<f64> {
    field(v, key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field {key:?} must be a number"))
}

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    Ok(f64_field(v, key)? as u64)
}

fn u32_field(v: &Json, key: &str) -> Result<u32> {
    Ok(f64_field(v, key)? as u32)
}

fn usize_field(v: &Json, key: &str) -> Result<usize> {
    Ok(f64_field(v, key)? as usize)
}

fn i64_field(v: &Json, key: &str) -> Result<i64> {
    Ok(f64_field(v, key)? as i64)
}

fn bool_field(v: &Json, key: &str) -> Result<bool> {
    field(v, key)?
        .as_bool()
        .ok_or_else(|| anyhow!("field {key:?} must be a boolean"))
}

fn arr_field<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    field(v, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("field {key:?} must be an array"))
}

fn i64_vec(v: &Json, key: &str) -> Result<Vec<i64>> {
    arr_field(v, key)?
        .iter()
        .map(|x| x.as_i64().ok_or_else(|| anyhow!("field {key:?} must hold integers")))
        .collect()
}

fn u64_vec(v: &Json, key: &str) -> Result<Vec<u64>> {
    arr_field(v, key)?
        .iter()
        .map(|x| x.as_u64().ok_or_else(|| anyhow!("field {key:?} must hold integers")))
        .collect()
}

fn usize_vec(v: &Json, key: &str) -> Result<Vec<usize>> {
    arr_field(v, key)?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("field {key:?} must hold integers")))
        .collect()
}

// ---------------------------------------------------------------------
// polyhedral layer

fn domain_to_json(d: &IterationDomain) -> Json {
    Json::Arr(
        d.dims
            .iter()
            .map(|dim| {
                Json::obj(vec![
                    ("name", Json::str(dim.name.clone())),
                    ("extent", Json::num_u64(dim.extent)),
                ])
            })
            .collect(),
    )
}

fn domain_from_json(v: &Json) -> Result<IterationDomain> {
    let dims = v
        .as_arr()
        .ok_or_else(|| anyhow!("domain must be an array"))?
        .iter()
        .map(|d| Ok(LoopDim::new(str_field(d, "name")?, u64_field(d, "extent")?)))
        .collect::<Result<Vec<_>>>()?;
    Ok(IterationDomain::new(dims))
}

fn dep_kind_str(k: DepKind) -> &'static str {
    match k {
        DepKind::Read => "read",
        DepKind::Flow => "flow",
        DepKind::Output => "output",
    }
}

fn dep_kind_from(s: &str) -> Result<DepKind> {
    Ok(match s {
        "read" => DepKind::Read,
        "flow" => DepKind::Flow,
        "output" => DepKind::Output,
        _ => bail!("unknown dependence kind {s:?}"),
    })
}

fn dep_to_json(d: &Dependence) -> Json {
    Json::obj(vec![
        ("array", Json::str(d.array.clone())),
        ("kind", Json::str(dep_kind_str(d.kind))),
        ("vector", Json::Arr(d.vector.iter().map(|&c| Json::num_i64(c)).collect())),
    ])
}

fn dep_from_json(v: &Json) -> Result<Dependence> {
    Ok(Dependence::new(
        str_field(v, "array")?,
        dep_kind_from(&str_field(v, "kind")?)?,
        i64_vec(v, "vector")?,
    ))
}

fn map_to_json(m: &AffineMap) -> Json {
    Json::Arr(
        m.exprs
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("coeffs", Json::Arr(e.coeffs.iter().map(|&c| Json::num_i64(c)).collect())),
                    ("constant", Json::num_i64(e.constant)),
                ])
            })
            .collect(),
    )
}

fn map_from_json(v: &Json) -> Result<AffineMap> {
    let exprs = v
        .as_arr()
        .ok_or_else(|| anyhow!("affine map must be an array"))?
        .iter()
        .map(|e| Ok(AffineExpr::new(i64_vec(e, "coeffs")?, i64_field(e, "constant")?)))
        .collect::<Result<Vec<_>>>()?;
    Ok(AffineMap { exprs })
}

fn role_from(s: &str) -> Result<LoopRole> {
    Ok(match s {
        "unassigned" => LoopRole::Unassigned,
        "space" => LoopRole::Space,
        "partition" => LoopRole::Partition,
        "time" => LoopRole::Time,
        "latency" => LoopRole::Latency,
        "thread" => LoopRole::Thread,
        "kernel" => LoopRole::Kernel,
        _ => bail!("unknown loop role {s:?}"),
    })
}

fn nest_to_json(n: &LoopNest) -> Json {
    Json::obj(vec![
        ("domain", domain_to_json(&n.domain)),
        ("deps", Json::Arr(n.deps.iter().map(dep_to_json).collect())),
        (
            "roles",
            Json::Arr(n.roles.iter().map(|r| Json::str(r.to_string())).collect()),
        ),
    ])
}

fn nest_from_json(v: &Json) -> Result<LoopNest> {
    let domain = domain_from_json(field(v, "domain")?)?;
    let deps = arr_field(v, "deps")?
        .iter()
        .map(dep_from_json)
        .collect::<Result<Vec<_>>>()?;
    let roles = arr_field(v, "roles")?
        .iter()
        .map(|r| role_from(r.as_str().ok_or_else(|| anyhow!("role must be a string"))?))
        .collect::<Result<Vec<_>>>()?;
    let rank = domain.rank();
    if roles.len() != rank {
        bail!("nest has {} roles for rank {rank}", roles.len());
    }
    if let Some(d) = deps.iter().find(|d| d.rank() != rank) {
        bail!("dependence on {:?} has rank {} in a rank-{rank} nest", d.array, d.rank());
    }
    Ok(LoopNest { domain, deps, roles })
}

// ---------------------------------------------------------------------
// recurrence layer

fn access_kind_str(k: AccessKind) -> &'static str {
    match k {
        AccessKind::Read => "read",
        AccessKind::Accumulate => "accumulate",
        AccessKind::Write => "write",
    }
}

fn access_kind_from(s: &str) -> Result<AccessKind> {
    Ok(match s {
        "read" => AccessKind::Read,
        "accumulate" => AccessKind::Accumulate,
        "write" => AccessKind::Write,
        _ => bail!("unknown access kind {s:?}"),
    })
}

/// Serialize a recurrence (the snapshot's innermost identity: its
/// canonical key is recomputed from exactly this on load).
pub fn rec_to_json(r: &UniformRecurrence) -> Json {
    let mut fields = vec![
        ("name", Json::str(r.name.clone())),
        ("domain", domain_to_json(&r.domain)),
        (
            "accesses",
            Json::Arr(
                r.accesses
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("array", Json::str(a.array.clone())),
                            ("kind", Json::str(access_kind_str(a.kind))),
                            ("map", map_to_json(&a.map)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("dtype", Json::str(r.dtype.code())),
        ("macs_per_iter", Json::num_u64(r.macs_per_iter)),
        ("carried", Json::Arr(r.carried.iter().map(dep_to_json).collect())),
    ];
    // replication is written only when present, mirroring the canonical
    // key's stability contract: standard-form snapshots are byte-stable.
    if r.replicate > 1 {
        fields.push(("replicate", Json::num_u64(r.replicate)));
    }
    Json::obj(fields)
}

/// Inverse of [`rec_to_json`].
pub fn rec_from_json(v: &Json) -> Result<UniformRecurrence> {
    let accesses = arr_field(v, "accesses")?
        .iter()
        .map(|a| {
            Ok(Access::new(
                str_field(a, "array")?,
                access_kind_from(&str_field(a, "kind")?)?,
                map_from_json(field(a, "map")?)?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let dtype_code = str_field(v, "dtype")?;
    let dtype = DType::from_code(&dtype_code)
        .ok_or_else(|| anyhow!("unknown dtype code {dtype_code:?}"))?;
    Ok(UniformRecurrence {
        name: str_field(v, "name")?,
        domain: domain_from_json(field(v, "domain")?)?,
        accesses,
        dtype,
        macs_per_iter: u64_field(v, "macs_per_iter")?,
        carried: arr_field(v, "carried")?
            .iter()
            .map(dep_from_json)
            .collect::<Result<Vec<_>>>()?,
        // absent ≡ 1 (standard form): pre-CA snapshots load unchanged.
        replicate: v.get("replicate").and_then(|j| j.as_u64()).unwrap_or(1),
    })
}

fn scope_to_json(s: &KernelScope) -> Json {
    Json::obj(vec![
        ("core_factors", Json::Arr(s.core_factors.iter().map(|&f| Json::num_u64(f)).collect())),
        ("graph_nest", nest_to_json(&s.graph_nest)),
        ("core_bytes", Json::num_u64(s.core_bytes)),
        ("core_macs", Json::num_u64(s.core_macs)),
    ])
}

fn scope_from_json(v: &Json) -> Result<KernelScope> {
    Ok(KernelScope {
        core_factors: u64_vec(v, "core_factors")?,
        graph_nest: nest_from_json(field(v, "graph_nest")?)?,
        core_bytes: u64_field(v, "core_bytes")?,
        core_macs: u64_field(v, "core_macs")?,
    })
}

// ---------------------------------------------------------------------
// mapping layer

fn choice_to_json(c: &SpaceTimeChoice) -> Json {
    Json::obj(vec![
        ("space", Json::Arr(c.space.iter().map(|&i| Json::num_usize(i)).collect())),
        (
            "skews",
            Json::Arr(
                c.skews
                    .iter()
                    .map(|&(t, s, f)| {
                        Json::Arr(vec![Json::num_usize(t), Json::num_usize(s), Json::num_i64(f)])
                    })
                    .collect(),
            ),
        ),
        ("nest", nest_to_json(&c.nest)),
    ])
}

fn choice_from_json(v: &Json) -> Result<SpaceTimeChoice> {
    let skews = arr_field(v, "skews")?
        .iter()
        .map(|s| {
            let t = s.as_arr().ok_or_else(|| anyhow!("skew must be [t, s, f]"))?;
            if t.len() != 3 {
                bail!("skew must be [t, s, f], got {} elements", t.len());
            }
            let get = |i: usize| t[i].as_f64().ok_or_else(|| anyhow!("skew holds numbers"));
            Ok((get(0)? as usize, get(1)? as usize, get(2)? as i64))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(SpaceTimeChoice {
        space: usize_vec(v, "space")?,
        skews,
        nest: nest_from_json(field(v, "nest")?)?,
    })
}

fn candidate_to_json(c: &MappingCandidate) -> Json {
    Json::obj(vec![
        ("rec", rec_to_json(&c.rec)),
        // `kind` is derived (Kind::of) — recomputed on load, not stored
        ("scope", scope_to_json(&c.scope)),
        ("choice", choice_to_json(&c.choice)),
        (
            "partition",
            Json::obj(vec![
                ("virt", Json::Arr(c.partition.virt.iter().map(|&x| Json::num_u64(x)).collect())),
                ("phys", Json::Arr(c.partition.phys.iter().map(|&x| Json::num_u64(x)).collect())),
                ("rounds", Json::num_u64(c.partition.rounds)),
            ]),
        ),
        (
            "latency",
            Json::obj(vec![
                (
                    "factors",
                    Json::Arr(
                        c.latency
                            .factors
                            .iter()
                            .map(|&(i, f)| Json::Arr(vec![Json::num_usize(i), Json::num_u64(f)]))
                            .collect(),
                    ),
                ),
                ("chains", Json::num_u64(c.latency.chains)),
            ]),
        ),
        (
            "threading",
            Json::obj(vec![
                ("dim", c.threading.dim.map_or(Json::Null, Json::num_usize)),
                ("factor", Json::num_u64(c.threading.factor)),
                ("is_reduction", Json::Bool(c.threading.is_reduction)),
            ]),
        ),
    ])
}

fn candidate_from_json(v: &Json) -> Result<MappingCandidate> {
    let rec = rec_from_json(field(v, "rec")?)?;
    let kind = Kind::of(&rec);
    let p = field(v, "partition")?;
    let l = field(v, "latency")?;
    let factors = arr_field(l, "factors")?
        .iter()
        .map(|f| {
            let t = f.as_arr().ok_or_else(|| anyhow!("latency factor must be [i, f]"))?;
            if t.len() != 2 {
                bail!("latency factor must be [i, f]");
            }
            let i = t[0].as_usize().ok_or_else(|| anyhow!("factor index"))?;
            let f = t[1].as_u64().ok_or_else(|| anyhow!("factor value"))?;
            Ok((i, f))
        })
        .collect::<Result<Vec<_>>>()?;
    let t = field(v, "threading")?;
    let dim = match field(t, "dim")? {
        Json::Null => None,
        d => Some(d.as_usize().ok_or_else(|| anyhow!("threading dim must be a number"))?),
    };
    Ok(MappingCandidate {
        scope: scope_from_json(field(v, "scope")?)?,
        choice: choice_from_json(field(v, "choice")?)?,
        partition: ArrayPartition {
            virt: u64_vec(p, "virt")?,
            phys: u64_vec(p, "phys")?,
            rounds: u64_field(p, "rounds")?,
        },
        latency: LatencyHiding {
            factors,
            chains: u64_field(l, "chains")?,
        },
        threading: Threading {
            dim,
            factor: u64_field(t, "factor")?,
            is_reduction: bool_field(t, "is_reduction")?,
        },
        rec,
        kind,
    })
}

fn bound_str(b: PerfBound) -> String {
    b.to_string()
}

fn bound_from(s: &str) -> Result<PerfBound> {
    Ok(match s {
        "compute" => PerfBound::Compute,
        "plio-in" => PerfBound::PlioIn,
        "plio-out" => PerfBound::PlioOut,
        "dram" => PerfBound::Dram,
        _ => bail!("unknown perf bound {s:?}"),
    })
}

/// Exactly the 14 performance fields, exactly this order — the layout
/// predates the power refactor and is frozen so older snapshots keep
/// warm-starting (power is repriced on load, never stored); guarded by
/// `tests/cache_compat.rs`.
fn estimate_to_json(e: &PerfEstimate) -> Json {
    Json::obj(vec![
        ("tops", Json::Num(e.tops)),
        ("tops_e2e", Json::Num(e.tops_e2e)),
        ("seconds", Json::Num(e.seconds)),
        ("aies", Json::num_u64(e.aies)),
        ("tops_per_aie", Json::Num(e.tops_per_aie)),
        ("bound", Json::str(bound_str(e.bound))),
        ("compute_s", Json::Num(e.compute_s)),
        ("plio_in_s", Json::Num(e.plio_in_s)),
        ("plio_out_s", Json::Num(e.plio_out_s)),
        ("dram_s", Json::Num(e.dram_s)),
        ("plio_in_ports", Json::num_u64(e.plio_in_ports as u64)),
        ("plio_out_ports", Json::num_u64(e.plio_out_ports as u64)),
        ("dram_bytes", Json::num_u64(e.dram_bytes)),
        ("occupancy", Json::Num(e.occupancy)),
    ])
}

fn estimate_from_json(v: &Json) -> Result<PerfEstimate> {
    Ok(PerfEstimate {
        tops: f64_field(v, "tops")?,
        tops_e2e: f64_field(v, "tops_e2e")?,
        seconds: f64_field(v, "seconds")?,
        aies: u64_field(v, "aies")?,
        tops_per_aie: f64_field(v, "tops_per_aie")?,
        bound: bound_from(&str_field(v, "bound")?)?,
        compute_s: f64_field(v, "compute_s")?,
        plio_in_s: f64_field(v, "plio_in_s")?,
        plio_out_s: f64_field(v, "plio_out_s")?,
        dram_s: f64_field(v, "dram_s")?,
        plio_in_ports: u32_field(v, "plio_in_ports")?,
        plio_out_ports: u32_field(v, "plio_out_ports")?,
        dram_bytes: u64_field(v, "dram_bytes")?,
        occupancy: f64_field(v, "occupancy")?,
    })
}

// ---------------------------------------------------------------------
// graph layer

fn node_to_json(n: &Node) -> Json {
    let mut pairs = vec![("id", Json::num_usize(n.id)), ("name", Json::str(n.name.clone()))];
    match n.kind {
        NodeKind::Aie { virt } => {
            pairs.push(("kind", Json::str("aie")));
            pairs.push(("row", Json::num_u64(virt.row as u64)));
            pairs.push(("col", Json::num_u64(virt.col as u64)));
        }
        NodeKind::Plio { dir } => {
            pairs.push(("kind", Json::str("plio")));
            pairs.push(("dir", Json::str(if dir == PlioDir::In { "in" } else { "out" })));
        }
    }
    Json::obj(pairs)
}

fn node_from_json(v: &Json) -> Result<Node> {
    let kind = match str_field(v, "kind")?.as_str() {
        "aie" => NodeKind::Aie {
            virt: Coord::new(u32_field(v, "row")?, u32_field(v, "col")?),
        },
        "plio" => NodeKind::Plio {
            dir: match str_field(v, "dir")?.as_str() {
                "in" => PlioDir::In,
                "out" => PlioDir::Out,
                d => bail!("unknown plio dir {d:?}"),
            },
        },
        k => bail!("unknown node kind {k:?}"),
    };
    Ok(Node {
        id: usize_field(v, "id")?,
        kind,
        name: str_field(v, "name")?,
    })
}

fn edge_kind_str(k: EdgeKind) -> &'static str {
    match k {
        EdgeKind::SharedBuffer => "buffer",
        EdgeKind::Stream => "stream",
        EdgeKind::Broadcast => "broadcast",
    }
}

fn edge_kind_from(s: &str) -> Result<EdgeKind> {
    Ok(match s {
        "buffer" => EdgeKind::SharedBuffer,
        "stream" => EdgeKind::Stream,
        "broadcast" => EdgeKind::Broadcast,
        _ => bail!("unknown edge kind {s:?}"),
    })
}

fn edge_to_json(e: &Edge) -> Json {
    Json::obj(vec![
        ("src", Json::num_usize(e.src)),
        ("dst", Json::num_usize(e.dst)),
        ("kind", Json::str(edge_kind_str(e.kind))),
        ("array", Json::str(e.array.clone())),
        ("dep", Json::str(dep_kind_str(e.dep))),
        ("rate", Json::Num(e.rate)),
        ("group", e.packet_group.map_or(Json::Null, |g| Json::num_u64(g as u64))),
    ])
}

fn edge_from_json(v: &Json) -> Result<Edge> {
    let group = match field(v, "group")? {
        Json::Null => None,
        g => Some(g.as_u64().ok_or_else(|| anyhow!("packet group must be a number"))? as u32),
    };
    let mut e = Edge::new(
        usize_field(v, "src")?,
        usize_field(v, "dst")?,
        edge_kind_from(&str_field(v, "kind")?)?,
        str_field(v, "array")?,
        dep_kind_from(&str_field(v, "dep")?)?,
        f64_field(v, "rate")?,
    );
    e.packet_group = group;
    Ok(e)
}

fn graph_to_json(g: &MappedGraph) -> Json {
    Json::obj(vec![
        ("nodes", Json::Arr(g.nodes.iter().map(node_to_json).collect())),
        ("edges", Json::Arr(g.edges.iter().map(edge_to_json).collect())),
        ("replica_rows", Json::num_u64(g.replica.0 as u64)),
        ("replica_cols", Json::num_u64(g.replica.1 as u64)),
        ("replicas", Json::num_u64(g.replicas as u64)),
    ])
}

fn graph_from_json(v: &Json) -> Result<MappedGraph> {
    let g = MappedGraph {
        nodes: arr_field(v, "nodes")?.iter().map(node_from_json).collect::<Result<Vec<_>>>()?,
        edges: arr_field(v, "edges")?.iter().map(edge_from_json).collect::<Result<Vec<_>>>()?,
        replica: (u32_field(v, "replica_rows")?, u32_field(v, "replica_cols")?),
        replicas: u32_field(v, "replicas")?,
    };
    if !g.node_ids_are_dense() {
        bail!("graph node ids are not dense");
    }
    if let Some(e) = g.edges.iter().find(|e| e.src >= g.nodes.len() || e.dst >= g.nodes.len()) {
        bail!("edge {} → {} references a missing node", e.src, e.dst);
    }
    Ok(g)
}

// ---------------------------------------------------------------------
// place & route / sim / codegen layer

fn placement_to_json(p: &Placement) -> Json {
    let (rows, cols) = p.grid_dims();
    let mut nodes: Vec<(usize, Coord)> = p.iter().collect();
    nodes.sort_unstable_by_key(|&(n, _)| n);
    Json::obj(vec![
        ("rows", Json::num_u64(rows as u64)),
        ("cols", Json::num_u64(cols as u64)),
        (
            "nodes",
            Json::Arr(
                nodes
                    .into_iter()
                    .map(|(n, c)| {
                        Json::Arr(vec![
                            Json::num_usize(n),
                            Json::num_u64(c.row as u64),
                            Json::num_u64(c.col as u64),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn placement_from_json(v: &Json) -> Result<Placement> {
    let mut p = Placement::with_grid(u32_field(v, "rows")?, u32_field(v, "cols")?);
    for entry in arr_field(v, "nodes")? {
        let t = entry.as_arr().ok_or_else(|| anyhow!("placement entry must be [n, row, col]"))?;
        if t.len() != 3 {
            bail!("placement entry must be [n, row, col]");
        }
        let n = t[0].as_usize().ok_or_else(|| anyhow!("placement node id"))?;
        let row = t[1].as_u64().ok_or_else(|| anyhow!("placement row"))? as u32;
        let col = t[2].as_u64().ok_or_else(|| anyhow!("placement col"))? as u32;
        p.insert(n, Coord::new(row, col));
    }
    Ok(p)
}

fn constraints_to_json(c: &ConstraintSet) -> Json {
    Json::obj(vec![
        (
            "kernels",
            Json::Arr(
                c.kernel_locations
                    .iter()
                    .map(|(name, r, col)| {
                        Json::Arr(vec![
                            Json::str(name.clone()),
                            Json::num_u64(*r as u64),
                            Json::num_u64(*col as u64),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "plios",
            Json::Arr(
                c.plio_columns
                    .iter()
                    .map(|(name, col)| {
                        Json::Arr(vec![Json::str(name.clone()), Json::num_u64(*col as u64)])
                    })
                    .collect(),
            ),
        ),
        (
            "buffers",
            Json::Arr(
                c.buffer_bindings
                    .iter()
                    .map(|(s, d)| Json::Arr(vec![Json::str(s.clone()), Json::str(d.clone())]))
                    .collect(),
            ),
        ),
    ])
}

fn constraints_from_json(v: &Json) -> Result<ConstraintSet> {
    let tuple3 = |e: &Json| -> Result<(String, u32, u32)> {
        let t = e.as_arr().ok_or_else(|| anyhow!("kernel location must be [name, row, col]"))?;
        if t.len() != 3 {
            bail!("kernel location must be [name, row, col]");
        }
        Ok((
            t[0].as_str().ok_or_else(|| anyhow!("kernel name"))?.to_string(),
            t[1].as_u64().ok_or_else(|| anyhow!("kernel row"))? as u32,
            t[2].as_u64().ok_or_else(|| anyhow!("kernel col"))? as u32,
        ))
    };
    let tuple2 = |e: &Json| -> Result<(String, u32)> {
        let t = e.as_arr().ok_or_else(|| anyhow!("plio column must be [name, col]"))?;
        if t.len() != 2 {
            bail!("plio column must be [name, col]");
        }
        Ok((
            t[0].as_str().ok_or_else(|| anyhow!("plio name"))?.to_string(),
            t[1].as_u64().ok_or_else(|| anyhow!("plio col"))? as u32,
        ))
    };
    let pair = |e: &Json| -> Result<(String, String)> {
        let t = e.as_arr().ok_or_else(|| anyhow!("buffer binding must be [src, dst]"))?;
        if t.len() != 2 {
            bail!("buffer binding must be [src, dst]");
        }
        Ok((
            t[0].as_str().ok_or_else(|| anyhow!("buffer src"))?.to_string(),
            t[1].as_str().ok_or_else(|| anyhow!("buffer dst"))?.to_string(),
        ))
    };
    Ok(ConstraintSet {
        kernel_locations: arr_field(v, "kernels")?.iter().map(tuple3).collect::<Result<Vec<_>>>()?,
        plio_columns: arr_field(v, "plios")?.iter().map(tuple2).collect::<Result<Vec<_>>>()?,
        buffer_bindings: arr_field(v, "buffers")?.iter().map(pair).collect::<Result<Vec<_>>>()?,
    })
}

fn compile_to_json(c: &CompileOutcome) -> Json {
    Json::obj(vec![
        ("success", Json::Bool(c.success)),
        ("wall_s", Json::Num(c.wall_s)),
        ("iterations", Json::num_u64(c.iterations)),
        ("placement", c.placement.as_ref().map_or(Json::Null, placement_to_json)),
        ("constraints", c.constraints.as_ref().map_or(Json::Null, constraints_to_json)),
        ("max_congestion", c.max_congestion.map_or(Json::Null, |x| Json::num_u64(x as u64))),
        (
            "stages",
            Json::obj(vec![
                ("place_ms", Json::Num(c.stages.place_ms)),
                ("assign_ms", Json::Num(c.stages.assign_ms)),
                ("route_ms", Json::Num(c.stages.route_ms)),
            ]),
        ),
    ])
}

fn compile_from_json(v: &Json) -> Result<CompileOutcome> {
    let placement = match field(v, "placement")? {
        Json::Null => None,
        p => Some(placement_from_json(p)?),
    };
    let constraints = match field(v, "constraints")? {
        Json::Null => None,
        c => Some(constraints_from_json(c)?),
    };
    let max_congestion = match field(v, "max_congestion")? {
        Json::Null => None,
        x => Some(x.as_u64().ok_or_else(|| anyhow!("max_congestion must be a number"))? as u32),
    };
    let s = field(v, "stages")?;
    Ok(CompileOutcome {
        success: bool_field(v, "success")?,
        wall_s: f64_field(v, "wall_s")?,
        iterations: u64_field(v, "iterations")?,
        placement,
        constraints,
        max_congestion,
        stages: StageTimings {
            place_ms: f64_field(s, "place_ms")?,
            assign_ms: f64_field(s, "assign_ms")?,
            route_ms: f64_field(s, "route_ms")?,
        },
    })
}

fn sim_to_json(s: &SimReport) -> Json {
    Json::obj(vec![
        ("seconds", Json::Num(s.seconds)),
        ("cycles", Json::num_u64(s.cycles)),
        ("tops", Json::Num(s.tops)),
        ("aies", Json::num_u64(s.aies)),
        ("tops_per_aie", Json::Num(s.tops_per_aie)),
        ("stall_fraction", Json::Num(s.stall_fraction)),
        ("bound", Json::str(bound_str(s.bound))),
        ("rounds", Json::num_u64(s.rounds)),
    ])
}

/// Inverse of [`sim_to_json`], with watts replayed rather than read:
/// the engine's own activity derivation (same shared [`PowerModel`], sim
/// occupancy = 1 − stall, the design estimate's port/DRAM figures — the
/// engine derives its internal estimate from the same model, so the two
/// coincide bit-for-bit) is a pure function of the stored fields, so the
/// restored report carries the identical power numbers without widening
/// the snapshot layout.
fn sim_from_json(v: &Json, power: &PowerModel, dtype: DType, est: &PerfEstimate) -> Result<SimReport> {
    let seconds = f64_field(v, "seconds")?;
    let tops = f64_field(v, "tops")?;
    let aies = u64_field(v, "aies")?;
    let stall_fraction = f64_field(v, "stall_fraction")?;
    let p = power.estimate(
        tops,
        seconds,
        &design_activity(
            dtype,
            aies.max(1),
            est.plio_in_ports + est.plio_out_ports,
            est.dram_bytes,
            seconds,
            (1.0 - stall_fraction).clamp(0.0, 1.0),
        ),
    );
    Ok(SimReport {
        seconds,
        cycles: u64_field(v, "cycles")?,
        tops,
        aies,
        tops_per_aie: f64_field(v, "tops_per_aie")?,
        stall_fraction,
        bound: bound_from(&str_field(v, "bound")?)?,
        rounds: u64_field(v, "rounds")?,
        watts: p.watts,
        tops_per_watt: p.tops_per_watt,
    })
}

fn code_to_json(c: &CodeBundle) -> Json {
    Json::obj(vec![
        ("aie_kernel", Json::str(c.aie_kernel.clone())),
        ("adf_graph", Json::str(c.adf_graph.clone())),
        ("pl_dma", Json::str(c.pl_dma.clone())),
        ("host", Json::str(c.host.clone())),
        ("constraints_json", Json::str(c.constraints_json.clone())),
    ])
}

fn code_from_json(v: &Json) -> Result<CodeBundle> {
    Ok(CodeBundle {
        aie_kernel: str_field(v, "aie_kernel")?,
        adf_graph: str_field(v, "adf_graph")?,
        pl_dma: str_field(v, "pl_dma")?,
        host: str_field(v, "host")?,
        constraints_json: str_field(v, "constraints_json")?,
    })
}

// ---------------------------------------------------------------------
// whole designs and snapshot files

/// Serialize a complete compiled design. `parse(to_string())` of the
/// result round-trips bit-identically (Rust's shortest-decimal f64
/// rendering is exact), so a restored design answers protocol requests
/// with the same bytes the original produced.
pub fn design_to_json(d: &CompiledDesign) -> Json {
    Json::obj(vec![
        ("candidate", candidate_to_json(&d.candidate)),
        ("estimate", estimate_to_json(&d.estimate.perf)),
        ("estimate_exact", estimate_to_json(&d.estimate_exact.perf)),
        ("graph", graph_to_json(&d.graph)),
        (
            "merge_stats",
            Json::obj(vec![
                ("in_before", Json::num_usize(d.merge_stats.in_ports_before)),
                ("in_after", Json::num_usize(d.merge_stats.in_ports_after)),
                ("out_before", Json::num_usize(d.merge_stats.out_ports_before)),
                ("out_after", Json::num_usize(d.merge_stats.out_ports_after)),
            ]),
        ),
        ("compile", compile_to_json(&d.compile)),
        ("sim", sim_to_json(&d.sim)),
        ("code", code_to_json(&d.code)),
    ])
}

/// Inverse of [`design_to_json`]. Power estimates (and the sim report's
/// watts) are repriced through the default [`PowerModel`] — the same
/// pure derivation the compile pipeline used — rather than read from the
/// file. The frontier summary is a DSE-session artifact, not part of the
/// design's identity, so restored designs report the empty summary.
pub fn design_from_json(v: &Json) -> Result<CompiledDesign> {
    let m = field(v, "merge_stats")?;
    let candidate = candidate_from_json(field(v, "candidate")?)?;
    let dtype = candidate.rec.dtype;
    let power_model = PowerModel::default();
    let reprice = |perf: PerfEstimate| -> Estimate {
        let power = price_power(&power_model, dtype, &perf);
        Estimate { perf, power }
    };
    let estimate = reprice(estimate_from_json(field(v, "estimate")?)?);
    let estimate_exact = reprice(estimate_from_json(field(v, "estimate_exact")?)?);
    let sim = sim_from_json(field(v, "sim")?, &power_model, dtype, &estimate.perf)?;
    Ok(CompiledDesign {
        candidate,
        estimate,
        estimate_exact,
        frontier: FrontierSummary::default(),
        graph: graph_from_json(field(v, "graph")?)?,
        merge_stats: MergeStats {
            in_ports_before: usize_field(m, "in_before")?,
            in_ports_after: usize_field(m, "in_after")?,
            out_ports_before: usize_field(m, "out_before")?,
            out_ports_after: usize_field(m, "out_after")?,
        },
        compile: compile_from_json(field(v, "compile")?)?,
        sim,
        code: code_from_json(field(v, "code")?)?,
    })
}

/// One snapshot line: schema + key + canonical-recurrence stamp + design.
pub fn entry_line(key: u64, design: &CompiledDesign) -> String {
    Json::obj(vec![
        ("schema", Json::num_u64(SNAPSHOT_SCHEMA)),
        ("key", Json::str(format!("{key:016x}"))),
        ("rec", Json::str(format!("{:016x}", design.candidate.rec.canonical_u64()))),
        ("design", design_to_json(design)),
    ])
    .to_string()
}

/// Parse and validate one snapshot line. Errors mean "skip this entry":
/// bad JSON, wrong schema version, or a canonical-key stamp that the
/// deserialized recurrence no longer hashes to.
pub fn parse_entry(line: &str) -> Result<(u64, CompiledDesign)> {
    let v = parse(line).map_err(|e| anyhow!("bad snapshot JSON: {e}"))?;
    let schema = u64_field(&v, "schema")?;
    if schema != SNAPSHOT_SCHEMA {
        bail!("snapshot schema {schema} != {SNAPSHOT_SCHEMA}; entry evicted");
    }
    let key = u64::from_str_radix(&str_field(&v, "key")?, 16)?;
    let stamp = u64::from_str_radix(&str_field(&v, "rec")?, 16)?;
    let design = design_from_json(field(&v, "design")?)?;
    let actual = design.candidate.rec.canonical_u64();
    if actual != stamp {
        bail!("canonical key mismatch: stamped {stamp:016x}, recomputed {actual:016x}");
    }
    Ok((key, design))
}

/// Write a snapshot of `entries` (atomically: temp file + rename, so a
/// crash mid-write leaves the previous snapshot intact). Counts
/// `persist.snapshots_saved` / `persist.entries_saved` in the global
/// registry and runs under a `persist.save` span.
pub fn save_snapshot(path: &Path, entries: &[(u64, Arc<CompiledDesign>)]) -> Result<usize> {
    let _span = crate::obs::trace::Span::begin("persist.save", "persist");
    let mut out = String::new();
    for (key, design) in entries {
        out.push_str(&entry_line(*key, design));
        out.push('\n');
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, out)?;
    std::fs::rename(&tmp, path)?;
    let m = crate::obs::metrics::global();
    m.counter("persist.snapshots_saved").inc();
    m.counter("persist.entries_saved").add(entries.len() as u64);
    Ok(entries.len())
}

/// Load a snapshot: `(valid entries, skipped count)`. A missing or
/// unreadable file is an empty snapshot (cold start), not an error, and
/// invalid entries are skipped one by one — this function never panics
/// on file content.
pub fn load_snapshot(path: &Path) -> (Vec<(u64, CompiledDesign)>, usize) {
    let _span = crate::obs::trace::Span::begin("persist.load", "persist");
    let Ok(text) = std::fs::read_to_string(path) else {
        return (Vec::new(), 0);
    };
    let mut out = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match parse_entry(line) {
            Ok(entry) => out.push(entry),
            Err(_) => skipped += 1,
        }
    }
    let m = crate::obs::metrics::global();
    m.counter("persist.entries_loaded").add(out.len() as u64);
    m.counter("persist.entries_skipped").add(skipped as u64);
    (out, skipped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::dse::DseConstraints;
    use crate::recurrence::{dtype::DType, library};
    use crate::{WideSa, WideSaConfig};

    fn small_design() -> CompiledDesign {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(32),
                ..Default::default()
            },
            ..Default::default()
        });
        ws.compile(&library::fir(65536, 15, DType::F32)).unwrap()
    }

    #[test]
    fn recurrence_round_trips_with_canonical_key() {
        for rec in library::table2_benchmarks() {
            let j = rec_to_json(&rec);
            let back = rec_from_json(&parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(back.name, rec.name);
            assert_eq!(back.canonical_u64(), rec.canonical_u64(), "{}", rec.name);
        }
        // carried dependences survive too
        let rec = library::stencil2d_chain(2, 128, 128, DType::F32);
        let back = rec_from_json(&parse(&rec_to_json(&rec).to_string()).unwrap()).unwrap();
        assert_eq!(back.carried, rec.carried);
        assert_eq!(back.canonical_u64(), rec.canonical_u64());
        // the replication axis survives, and standard forms never write it
        let ca = library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32);
        assert!(rec_to_json(&ca).to_string().contains("\"replicate\""));
        let ca_back = rec_from_json(&parse(&rec_to_json(&ca).to_string()).unwrap()).unwrap();
        assert_eq!(ca_back.replicate, 4);
        assert_eq!(ca_back.canonical_u64(), ca.canonical_u64());
        let std = library::mm(1024, 1024, 1024, DType::F32);
        assert!(!rec_to_json(&std).to_string().contains("replicate"));
    }

    #[test]
    fn design_round_trips_bit_identically() {
        let d = small_design();
        let line = entry_line(7, &d);
        let (key, back) = parse_entry(&line).unwrap();
        assert_eq!(key, 7);
        assert_eq!(back.candidate.summary(), d.candidate.summary());
        assert_eq!(back.candidate.kind, d.candidate.kind, "kind recomputed via Kind::of");
        assert_eq!(back.estimate.perf.tops.to_bits(), d.estimate.perf.tops.to_bits());
        assert_eq!(
            back.estimate_exact.perf.tops.to_bits(),
            d.estimate_exact.perf.tops.to_bits()
        );
        // power is repriced on load, not stored — and lands bit-identical
        // because the derivation is a pure function of the stored fields
        assert_eq!(
            back.estimate.power.watts.to_bits(),
            d.estimate.power.watts.to_bits()
        );
        assert_eq!(
            back.estimate_exact.power.tops_per_watt.to_bits(),
            d.estimate_exact.power.tops_per_watt.to_bits()
        );
        assert_eq!(back.sim.watts.to_bits(), d.sim.watts.to_bits());
        assert_eq!(back.graph.nodes.len(), d.graph.nodes.len());
        assert_eq!(back.graph.edges.len(), d.graph.edges.len());
        assert_eq!(back.merge_stats, d.merge_stats);
        assert_eq!(back.compile.success, d.compile.success);
        assert_eq!(back.sim.cycles, d.sim.cycles);
        assert_eq!(back.code.aie_kernel, d.code.aie_kernel);
        // serializing the restored design reproduces the exact bytes
        assert_eq!(entry_line(7, &back), line);
    }

    #[test]
    fn invalid_entries_are_skipped_never_panic() {
        let d = small_design();
        let good = entry_line(1, &d);
        // schema bump
        let bumped = good.replacen("\"schema\":1", "\"schema\":999", 1);
        assert!(parse_entry(&bumped).is_err());
        // stamp mismatch
        let restamped = {
            let stamp = format!("{:016x}", d.candidate.rec.canonical_u64());
            good.replacen(&stamp, "0000000000000000", 1)
        };
        assert!(parse_entry(&restamped).is_err());
        // truncation and garbage
        assert!(parse_entry(&good[..good.len() / 2]).is_err());
        assert!(parse_entry("not json").is_err());
        assert!(parse_entry("{}").is_err());
    }
}
