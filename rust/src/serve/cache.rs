//! Sharded LRU design cache + the canonical design key.
//!
//! The serve layer amortizes `WideSa::compile` across repeated requests:
//! the compile pipeline is a pure function of `(recurrence, board, DSE
//! constraints, mover width, DRAM mode)`, so its output can be cached
//! under a stable hash of exactly those inputs ([`design_key`]). The
//! cache is sharded — each shard owns an independent mutex — so hits
//! from concurrent request workers don't serialize on one lock.

use crate::arch::vck5000::BoardConfig;
use crate::coordinator::framework::WideSaConfig;
use crate::recurrence::spec::UniformRecurrence;
use crate::util::hash::Fnv64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fold every mapping-relevant board parameter into the key. Exhaustive
/// over [`BoardConfig`]: two boards hash equal iff the compile pipeline
/// cannot distinguish them.
fn board_fingerprint(h: &mut Fnv64, b: &BoardConfig) {
    h.write_str(&b.name);
    h.write_u32(b.array.rows);
    h.write_u32(b.array.cols);
    h.write_u32(b.array.rc_west);
    h.write_u32(b.array.rc_east);
    let c = &b.array.core;
    h.write_f64(c.freq_hz);
    h.write_u64(c.local_mem_bytes);
    h.write_u64(c.dma_bits);
    h.write_u64(c.dma_ports);
    h.write_u64(c.stream_bits);
    h.write_u64(c.acc_registers);
    h.write_u64(c.mac_pipeline_depth);
    h.write_u32(b.plio.in_channels);
    h.write_u32(b.plio.out_channels);
    h.write_u64(b.plio.bits);
    h.write_f64(b.plio.freq_hz);
    h.write_usize(b.plio.columns.len());
    for &col in &b.plio.columns {
        h.write_u32(col);
    }
    h.write_u32(b.plio.channels_per_column);
    h.write_u32(b.pl.dsp58);
    h.write_u64(b.pl.bram_bits);
    h.write_u64(b.pl.uram_bits);
    h.write_f64(b.pl.freq_hz);
    h.write_u32(b.pl.dram_channels);
    h.write_f64(b.pl.dram_bw_per_channel);
}

/// Canonical cache key for one compile request: recurrence × board ×
/// constraints × mover width × DRAM mode. Stable across processes (pure
/// FNV-1a over explicit field bytes), so keys may be logged, compared
/// between server runs, and echoed over the wire.
pub fn design_key(rec: &UniformRecurrence, cfg: &WideSaConfig) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(rec.canonical_u64());
    board_fingerprint(&mut h, &cfg.board);
    cfg.constraints.fingerprint(&mut h);
    h.write_u64(cfg.mover_bits);
    h.write_bool(cfg.cold_dram);
    // dse_threads deliberately excluded: it changes how fast the answer
    // arrives, never what the answer is (deterministic-merge guarantee).
    h.finish()
}

/// Key for the DSE *plan* cache: [`design_key`] minus the mover width
/// and DRAM mode. The plan (demarcation, space-time enumeration, the
/// latency plan, the AIE budget) depends on neither, so near-key
/// requests — same recurrence/board/constraints, different `mover_bits`
/// or `cold_dram` — share one plan instead of redoing the enumeration.
pub fn plan_key(rec: &UniformRecurrence, cfg: &WideSaConfig) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(rec.canonical_u64());
    board_fingerprint(&mut h, &cfg.board);
    cfg.constraints.fingerprint(&mut h);
    h.finish()
}

struct Entry<V> {
    value: V,
    last_used: u64,
}

struct Shard<V> {
    map: HashMap<u64, Entry<V>>,
    /// Monotone per-shard access clock for LRU ordering.
    tick: u64,
}

/// Cache statistics snapshot (all counters process-lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub len: usize,
}

/// A sharded LRU map from [`design_key`] to a cheaply-cloneable value
/// (the serve layer stores `Arc<CompiledDesign>`).
///
/// Keys distribute over shards by residue; each shard evicts its own
/// least-recently-used entry when it exceeds `capacity / shards`
/// (rounded up), so total occupancy is bounded by roughly `capacity`.
pub struct ShardedCache<V> {
    shards: Vec<Mutex<Shard<V>>>,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

impl<V: Clone> ShardedCache<V> {
    /// `capacity` total entries spread over `shards` independent locks
    /// (both clamped to ≥ 1).
    pub fn new(capacity: usize, shards: usize) -> Self {
        let shards = shards.max(1);
        let per_shard_cap = capacity.max(1).div_ceil(shards);
        Self {
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        tick: 0,
                    })
                })
                .collect(),
            per_shard_cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<Shard<V>> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    /// Look up a key, refreshing its recency on hit.
    pub fn get(&self, key: u64) -> Option<V> {
        let mut shard = self.shard(key).lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        match shard.map.get_mut(&key) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a key, evicting the shard's LRU entry if the
    /// shard is full.
    pub fn insert(&self, key: u64, value: V) {
        let mut shard = self.shard(key).lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        if !shard.map.contains_key(&key) && shard.map.len() >= self.per_shard_cap {
            let victim = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k);
            if let Some(victim) = victim {
                shard.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.map.insert(
            key,
            Entry {
                value,
                last_used: tick,
            },
        );
        self.insertions.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot every live entry (the persistence path). Shards are
    /// locked one at a time — consistent per shard, not globally — and
    /// the result is key-sorted so snapshot files are deterministic for
    /// a given cache state.
    pub fn entries(&self) -> Vec<(u64, V)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let shard = s.lock().unwrap();
            out.extend(shard.map.iter().map(|(&k, e)| (k, e.value.clone())));
        }
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total entry budget (shards × per-shard capacity; ≥ requested).
    pub fn capacity(&self) -> usize {
        self.per_shard_cap * self.shards.len()
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            len: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::{dtype::DType, library};

    #[test]
    fn hit_miss_and_stats() {
        let c: ShardedCache<u32> = ShardedCache::new(8, 2);
        assert_eq!(c.get(1), None);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.get(1), Some(10));
        assert_eq!(c.get(2), Some(20));
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.insertions, 2);
        assert_eq!(s.len, 2);
    }

    #[test]
    fn lru_eviction_keeps_recently_used() {
        // single shard so the LRU order is fully observable
        let c: ShardedCache<u32> = ShardedCache::new(3, 1);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(3, 3);
        // touch 1 so 2 becomes the LRU
        assert_eq!(c.get(1), Some(1));
        c.insert(4, 4); // evicts 2
        assert_eq!(c.get(2), None, "LRU entry must be evicted");
        assert_eq!(c.get(1), Some(1));
        assert_eq!(c.get(3), Some(3));
        assert_eq!(c.get(4), Some(4));
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn reinsert_same_key_does_not_evict() {
        let c: ShardedCache<u32> = ShardedCache::new(2, 1);
        c.insert(1, 1);
        c.insert(2, 2);
        c.insert(1, 11); // refresh, not a new entry
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(1), Some(11));
        assert_eq!(c.get(2), Some(2));
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn occupancy_bounded_across_shards() {
        let c: ShardedCache<u64> = ShardedCache::new(16, 4);
        for k in 0..1000u64 {
            c.insert(k, k);
        }
        assert!(c.len() <= c.capacity(), "{} > {}", c.len(), c.capacity());
        assert!(c.stats().evictions >= 1000 - c.capacity() as u64);
    }

    #[test]
    fn entries_snapshot_is_sorted_and_complete() {
        let c: ShardedCache<u32> = ShardedCache::new(8, 3);
        for k in [9u64, 2, 5, 7] {
            c.insert(k, k as u32 * 10);
        }
        let e = c.entries();
        assert_eq!(e, vec![(2, 20), (5, 50), (7, 70), (9, 90)]);
    }

    #[test]
    fn plan_key_ignores_mover_and_dram_only() {
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let cfg = WideSaConfig::default();
        let base = plan_key(&rec, &cfg);
        // mover width and DRAM mode share a plan…
        let mut c = cfg.clone();
        c.mover_bits = 128;
        c.cold_dram = true;
        assert_eq!(base, plan_key(&rec, &c));
        // …but constraints and recurrence do not
        let mut c = cfg.clone();
        c.constraints.max_aies = Some(64);
        assert_ne!(base, plan_key(&rec, &c));
        let other = library::mm(2048, 1024, 1024, DType::F32);
        assert_ne!(base, plan_key(&other, &cfg));
    }

    #[test]
    fn design_key_sensitivity() {
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let cfg = WideSaConfig::default();
        let base = design_key(&rec, &cfg);
        // deterministic
        assert_eq!(base, design_key(&rec, &cfg));

        // recurrence changes the key
        let other_rec = library::mm(2048, 1024, 1024, DType::F32);
        assert_ne!(base, design_key(&other_rec, &cfg));

        // each config axis changes the key
        let mut c = cfg.clone();
        c.constraints.max_aies = Some(64);
        assert_ne!(base, design_key(&rec, &c));
        let mut c = cfg.clone();
        c.mover_bits = 128;
        assert_ne!(base, design_key(&rec, &c));
        let mut c = cfg.clone();
        c.cold_dram = true;
        assert_ne!(base, design_key(&rec, &c));
        let mut c = cfg.clone();
        c.board = c.board.with_plio_budget(8);
        assert_ne!(base, design_key(&rec, &c));

        // dse_threads is a how-fast knob, not a what-answer knob
        let mut c = cfg.clone();
        c.dse_threads = 8;
        assert_eq!(base, design_key(&rec, &c));
    }
}
