//! Kernel scope demarcation (paper §III-A, Figure 2).
//!
//! Splits the recurrence's loops into the *core scope* (the innermost
//! tile executed by one AIE kernel invocation) and the *graph scope*
//! (the outer nest mapped across the AIE array and over time). The tiling
//! factors are chosen so the core tile's working set fits the AIE local
//! data memory and the tile carries enough MACs to amortise kernel
//! start-up — after this demarcation, graph-level and kernel-level
//! mapping are independent problems (as the paper observes).

use crate::polyhedral::dependence::Dependence;
use crate::polyhedral::legality::lex_nonnegative;
use crate::polyhedral::schedule::{LoopNest, LoopRole};
use crate::polyhedral::transform::Transform;
use crate::recurrence::spec::UniformRecurrence;
use crate::util::math::divisors;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Result of demarcation: tiling factors and both scopes' loop nests.
#[derive(Debug, Clone)]
pub struct KernelScope {
    /// Per-original-loop core-tile factor (1 = not tiled into the core).
    pub core_factors: Vec<u64>,
    /// The graph-level nest (tile loops only, roles unassigned).
    pub graph_nest: LoopNest,
    /// Working-set bytes of one core tile.
    pub core_bytes: u64,
    /// MACs per core-kernel invocation.
    pub core_macs: u64,
}

/// AIE data memory available to a kernel's buffers: 32 KB minus stack and
/// system reservations; double-buffered I/O halves the usable window.
pub const CORE_BUDGET_BYTES: u64 = 32 * 1024;
pub const CORE_USABLE_BYTES: u64 = 24 * 1024; // after stack + runtime
pub const DOUBLE_BUFFER_FACTOR: u64 = 2;

/// Bytes of the core tile's working set for a recurrence, given per-loop
/// tile factors: sum over arrays of the tile footprint of each access.
///
/// Two halo sources are counted: conv-style accesses that put two loops
/// on one subscript (`X[h+p]` → extents − 1), and explicitly
/// [`carried`](UniformRecurrence::carried) neighbour offsets — a 5-point
/// stencil tile of `(fi, fj)` must stage `(fi+2)(fj+2)` inputs, and
/// pricing that perimeter is what steers demarcation towards square-ish
/// stencil tiles instead of degenerate 1×N strips (the greedy ascent's
/// density tie-break would otherwise elongate freely).
pub fn core_tile_bytes(rec: &UniformRecurrence, factors: &[u64]) -> u64 {
    let mut total = 0u64;
    for acc in &rec.accesses {
        let mut elems = 1u64;
        for e in &acc.map.exprs {
            let mut ext = 1u64;
            for (d, &c) in e.coeffs.iter().enumerate() {
                if c != 0 {
                    // carried-dep halo on this array along this loop:
                    // widen the tile by the offset bound on both sides
                    let halo: u64 = rec
                        .carried
                        .iter()
                        .filter(|dep| dep.array == acc.array)
                        .map(|dep| dep.vector[d].unsigned_abs())
                        .max()
                        .unwrap_or(0);
                    let dim_ext = factors[d] + 2 * halo;
                    // conv-style halo: two loops on one subscript add
                    // extents − 1
                    ext = if ext == 1 { dim_ext } else { ext + dim_ext - 1 };
                }
            }
            elems = elems.saturating_mul(ext.max(1));
        }
        total = total.saturating_mul(1).saturating_add(elems.saturating_mul(rec.dtype.bytes()));
    }
    total
}

/// MACs of one core tile.
pub fn core_tile_macs(rec: &UniformRecurrence, factors: &[u64]) -> u64 {
    factors
        .iter()
        .product::<u64>()
        .saturating_mul(rec.macs_per_iter)
}

/// May the loops be strip-mined by `factors` without creating a backward
/// tile-level dependence?
///
/// Rectangular tiling of a band is only legal when every dependence's
/// possible *tile projections* stay lexicographically non-negative: a
/// component `c` on a loop tiled by `f` splits into tile-component `0`
/// (same tile) and `sign(c)` (crossing a boundary), and every combination
/// across dims must survive. Componentwise non-negative dependence sets —
/// all of Table II — pass trivially, so demarcation is unchanged for
/// them. Stencil chains (`(1, −1, 0)` etc.) reject core-tiling of the
/// sweep loop `t`: splitting `t` into the tile would make neighbouring
/// `(i, j)` tiles at the same `t`-tile depend on each other *mutually*
/// (the halo of sweep `s` needs sweep `s−1` of both neighbours), which no
/// atomic kernel schedule can honour. Distances larger than the factor
/// are rejected outright (strip-mining cannot express them).
pub fn tiling_preserves_order(deps: &[Dependence], factors: &[u64]) -> bool {
    for d in deps {
        // Enumerate the tile-level projections this dep can take.
        let mut combos: Vec<Vec<i64>> = vec![Vec::with_capacity(d.vector.len())];
        for (dim, &c) in d.vector.iter().enumerate() {
            let f = factors.get(dim).copied().unwrap_or(1);
            let opts: Vec<i64> = if f <= 1 {
                vec![c] // untiled: the component survives verbatim
            } else if c == 0 {
                vec![0]
            } else if c.unsigned_abs() > f {
                return false; // distance exceeds the tile edge
            } else if c.unsigned_abs() == f {
                vec![c.signum()] // exactly one boundary crossing
            } else {
                vec![0, c.signum()]
            };
            combos = combos
                .into_iter()
                .flat_map(|v| {
                    opts.iter().map(move |&o| {
                        let mut v2 = v.clone();
                        v2.push(o);
                        v2
                    })
                })
                .collect();
        }
        if combos.iter().any(|v| !lex_nonnegative(v)) {
            return false;
        }
    }
    true
}

/// Choose core-tile factors maximising MACs per tile subject to the
/// double-buffered local-memory budget, preferring square-ish tiles
/// (better reuse per byte moved). Factors are divisors of the extents so
/// the graph nest stays rectangular, and a bump is only taken when the
/// resulting tiling keeps every dependence's tile projection
/// lexicographically non-negative ([`tiling_preserves_order`]) — the
/// guard that stops stencil chains from tiling their sweep loop into the
/// core.
pub fn demarcate(rec: &UniformRecurrence) -> KernelScope {
    let nest = rec.loop_nest();
    let rank = nest.rank();
    let budget = CORE_USABLE_BYTES / DOUBLE_BUFFER_FACTOR;

    // Candidate factors per loop: divisors capped at 4096 (a single DMA
    // descriptor's practical burst; the memory budget is what actually
    // stops the ascent for multi-dimensional tiles).
    let cands: Vec<Vec<u64>> = (0..rank)
        .map(|d| {
            divisors(nest.domain.dims[d].extent)
                .into_iter()
                .filter(|&f| f <= 4096)
                .collect()
        })
        .collect();

    // Greedy ascent: start at all-1s, repeatedly bump the loop whose next
    // divisor gives the best MAC/byte gain while staying within budget.
    let mut idx = vec![0usize; rank];
    loop {
        let mut best: Option<(usize, f64)> = None;
        let current: Vec<u64> = (0..rank).map(|d| cands[d][idx[d]]).collect();
        for d in 0..rank {
            if idx[d] + 1 >= cands[d].len() {
                continue;
            }
            let mut trial = current.clone();
            trial[d] = cands[d][idx[d] + 1];
            let bytes = core_tile_bytes(rec, &trial);
            if bytes > budget {
                continue;
            }
            if !tiling_preserves_order(&nest.deps, &trial) {
                continue;
            }
            let macs = core_tile_macs(rec, &trial) as f64;
            let density = macs / bytes.max(1) as f64;
            if best.map_or(true, |(_, b)| density > b) {
                best = Some((d, density));
            }
        }
        match best {
            Some((d, _)) => idx[d] += 1,
            None => break,
        }
    }
    let core_factors: Vec<u64> = (0..rank).map(|d| cands[d][idx[d]]).collect();
    let core_bytes = core_tile_bytes(rec, &core_factors);
    let core_macs = core_tile_macs(rec, &core_factors);

    // Build the graph nest: tile each loop by its core factor; the point
    // loops become Kernel-role loops which we then *drop* from the graph
    // nest (they live inside the AIE kernel).
    let mut gn = nest.clone();
    // Tile from innermost to outermost so indices stay valid.
    for d in (0..rank).rev() {
        if core_factors[d] > 1 {
            gn = Transform::Tile {
                dim: d,
                factor: core_factors[d],
            }
            .apply(&gn);
            // mark the point loop as kernel scope
            gn.roles[d + 1] = LoopRole::Kernel;
        }
    }
    KernelScope {
        core_factors,
        graph_nest: gn,
        core_bytes,
        core_macs,
    }
}

/// Process-wide memo for [`demarcate`], keyed by
/// [`UniformRecurrence::canonical_u64`].
static DEMARCATE_CACHE: OnceLock<Mutex<HashMap<u64, KernelScope>>> = OnceLock::new();

/// Number of distinct recurrences memoized before the cache resets (a
/// DSE sweep touches a handful; this only guards pathological callers).
const DEMARCATE_CACHE_MAX: usize = 512;

/// Memoized [`demarcate`]: demarcation depends only on the recurrence
/// (not the board or DSE constraints), yet every `explore_all` call — and
/// there are many per served compile, and many more across the Figure 6
/// sweeps — used to recompute the same divisor ascent. The memo makes
/// repeated exploration of one recurrence pay the greedy search once per
/// process.
pub fn demarcate_cached(rec: &UniformRecurrence) -> KernelScope {
    let key = rec.canonical_u64();
    let cache = DEMARCATE_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(scope) = cache.lock().unwrap().get(&key) {
        return scope.clone();
    }
    // Compute outside the lock: demarcation is the expensive part, and
    // concurrent misses on *different* recurrences must not serialize.
    let scope = demarcate(rec);
    let mut map = cache.lock().unwrap();
    if map.len() >= DEMARCATE_CACHE_MAX {
        map.clear();
    }
    map.entry(key).or_insert_with(|| scope.clone());
    scope
}

impl KernelScope {
    /// Graph-scope loops (everything not marked Kernel), outermost first.
    pub fn graph_loops(&self) -> Vec<usize> {
        (0..self.graph_nest.rank())
            .filter(|&i| self.graph_nest.roles[i] != LoopRole::Kernel)
            .collect()
    }

    /// Cycles one AIE core needs per kernel invocation at peak issue,
    /// before pipeline-efficiency derating.
    pub fn core_peak_cycles(&self, rec: &UniformRecurrence) -> u64 {
        self.core_macs
            .div_ceil(rec.dtype.macs_per_cycle_aie())
            .max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn mm_core_tile_fits_budget() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let scope = demarcate(&rec);
        assert!(scope.core_bytes <= CORE_USABLE_BYTES / DOUBLE_BUFFER_FACTOR);
        assert!(scope.core_macs >= 32 * 32 * 8, "tile too small: {scope:?}");
        // all factors divide the extents
        for (f, d) in scope.core_factors.iter().zip(&rec.domain.dims) {
            assert_eq!(d.extent % f, 0);
        }
    }

    #[test]
    fn mm_int8_tile_is_larger_than_f32() {
        let f32t = demarcate(&library::mm(8192, 8192, 8192, DType::F32));
        let i8t = demarcate(&library::mm(10240, 10240, 10240, DType::I8));
        assert!(i8t.core_macs >= f32t.core_macs);
    }

    #[test]
    fn core_bytes_formula_mm() {
        let rec = library::mm(64, 64, 64, DType::F32);
        // factors (8, 8, 8): A 8×8 + B 8×8 + C 8×8 = 192 elems × 4 B
        assert_eq!(core_tile_bytes(&rec, &[8, 8, 8]), 192 * 4);
    }

    #[test]
    fn conv_halo_counted() {
        let rec = library::conv2d(64, 64, 4, 4, DType::F32);
        // factors (8, 8, 4, 4): X (8+4-1)² + K 4·4 + Y 8·8 elements
        let expect = (11 * 11 + 16 + 64) * 4;
        assert_eq!(core_tile_bytes(&rec, &[8, 8, 4, 4]), expect);
    }

    #[test]
    fn graph_nest_drops_kernel_loops_from_graph_scope() {
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let scope = demarcate(&rec);
        let graph_loops = scope.graph_loops();
        // kernel point loops excluded
        assert!(graph_loops.len() < scope.graph_nest.rank());
        // graph loops have whole-tile extents
        for &g in &graph_loops {
            assert!(scope.graph_nest.domain.dims[g].extent >= 1);
        }
    }

    #[test]
    fn peak_cycles_positive() {
        let rec = library::fir(1048576, 15, DType::F32);
        let scope = demarcate(&rec);
        assert!(scope.core_peak_cycles(&rec) > 0);
    }

    #[test]
    fn stencil_sweep_loop_is_never_core_tiled() {
        // Tiling t would make same-sweep neighbour tiles mutually
        // dependent; the order guard must pin its core factor at 1 while
        // still tiling the grid loops.
        let rec = library::stencil2d_chain(4, 1024, 1024, DType::F32);
        let scope = demarcate(&rec);
        assert_eq!(scope.core_factors[0], 1, "{:?}", scope.core_factors);
        assert!(scope.core_factors[1] > 1 && scope.core_factors[2] > 1);
        assert!(scope.core_bytes <= CORE_USABLE_BYTES / DOUBLE_BUFFER_FACTOR);
    }

    #[test]
    fn order_guard_semantics() {
        use crate::polyhedral::dependence::{DepKind, Dependence};
        let stencil = vec![
            Dependence::new("A", DepKind::Flow, vec![1, -1, 0]),
            Dependence::new("A", DepKind::Flow, vec![1, 1, 0]),
        ];
        // tiling only the grid loop keeps t leading every projection
        assert!(tiling_preserves_order(&stencil, &[1, 8, 8]));
        // tiling t exposes the (0, -1, 0) projection → rejected
        assert!(!tiling_preserves_order(&stencil, &[2, 8, 8]));
        // componentwise non-negative sets always pass (Table II shape)
        let mm = vec![Dependence::new("C", DepKind::Flow, vec![0, 0, 1])];
        assert!(tiling_preserves_order(&mm, &[8, 8, 8]));
        // distances beyond the tile edge cannot be strip-mined
        let far = vec![Dependence::new("X", DepKind::Flow, vec![4, 0])];
        assert!(!tiling_preserves_order(&far, &[2, 2]));
        assert!(tiling_preserves_order(&far, &[4, 2]));
    }

    #[test]
    fn memoized_demarcation_matches_direct() {
        let rec = library::conv2d(1024, 1024, 4, 4, DType::I16);
        let direct = demarcate(&rec);
        let cached1 = demarcate_cached(&rec);
        let cached2 = demarcate_cached(&rec); // hit path
        for got in [&cached1, &cached2] {
            assert_eq!(got.core_factors, direct.core_factors);
            assert_eq!(got.core_bytes, direct.core_bytes);
            assert_eq!(got.core_macs, direct.core_macs);
            assert_eq!(got.graph_nest.rank(), direct.graph_nest.rank());
        }
        // a different recurrence must not collide
        let other = demarcate_cached(&library::conv2d(2048, 2048, 4, 4, DType::I16));
        assert!(other.core_macs > 0);
    }
}
