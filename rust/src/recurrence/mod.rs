//! Uniform-recurrence specifications: the paper's four benchmarks
//! (Table II) expressed as loop nests with typed accesses, plus the
//! kernel-scope tiling of §III-A.

pub mod dtype;
pub mod library;
pub mod spec;
pub mod tiling;

pub use dtype::DType;
pub use spec::{Access, AccessKind, UniformRecurrence};
pub use tiling::{demarcate, KernelScope};
