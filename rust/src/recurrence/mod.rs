//! Uniform-recurrence specifications: the workload library — the paper's
//! four Table II benchmarks plus the expanded catalog (depthwise conv,
//! triangular solve, stencil chains; see `docs/WORKLOADS.md`) — expressed
//! as loop nests with typed accesses and explicitly carried dependence
//! vectors, plus the dependence-aware kernel-scope tiling of §III-A.

pub mod dtype;
pub mod library;
pub mod spec;
pub mod tiling;

pub use dtype::DType;
pub use spec::{Access, AccessKind, UniformRecurrence};
pub use tiling::{demarcate, KernelScope};
