//! Data types of the paper's benchmarks and their AIE execution widths.
//!
//! Per-cycle MAC counts are the AIE (AIE-ML v1, VC1902) vector-unit
//! widths the paper's §I/§II quote (128 int8 MACs/cycle; the other widths
//! follow from the 1024-bit vector datapath): int16 = 32, int32 = 8
//! (32×32→64 via MAC intrinsics), fp32 = 8, cfloat = 2 complex = 8 real,
//! cint16 = 8 complex MACs/cycle.


use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    I16,
    I32,
    /// Complex float (two f32 planes).
    CF32,
    /// Complex int16 (two i16 planes).
    CI16,
}

impl DType {
    /// Storage bytes per element.
    pub fn bytes(self) -> u64 {
        match self {
            DType::I8 => 1,
            DType::I16 => 2,
            DType::F32 | DType::I32 | DType::CI16 => 4,
            DType::CF32 => 8,
        }
    }

    /// MAC operations one AIE core issues per cycle for this type.
    /// (For complex types this counts *complex* MACs.)
    pub fn macs_per_cycle_aie(self) -> u64 {
        match self {
            DType::I8 => 128,
            DType::I16 => 32,
            DType::I32 => 8,
            DType::F32 => 8,
            DType::CF32 => 2,
            DType::CI16 => 8,
        }
    }

    /// Arithmetic ops counted per MAC when reporting TOPS (mul + add; a
    /// complex MAC is 4 mul + 4 add = 8 real ops, the convention the
    /// paper's FFT/FIR cfloat rows use).
    pub fn ops_per_mac(self) -> u64 {
        match self {
            DType::CF32 | DType::CI16 => 8,
            _ => 2,
        }
    }

    /// DSP58 slices per MAC for a PL-only implementation (Table IV's
    /// AutoSA baselines; fp32 MACs cost ~3 DSP58 + fabric, int8 packs two
    /// MACs per DSP58 — the calibration DESIGN.md §1 documents).
    pub fn dsp_per_mac_pl(self) -> f64 {
        match self {
            DType::I8 => 0.5,
            DType::I16 => 1.0,
            DType::I32 => 2.0,
            DType::F32 => 3.0,
            DType::CF32 => 12.0,
            DType::CI16 => 4.0,
        }
    }

    pub fn is_complex(self) -> bool {
        matches!(self, DType::CF32 | DType::CI16)
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "Float",
            DType::I8 => "Int8",
            DType::I16 => "Int16",
            DType::I32 => "Int32",
            DType::CF32 => "Cfloat",
            DType::CI16 => "Cint16",
        }
    }

    /// Short wire code — the spelling the serve protocol and cache
    /// snapshots use (`f32|i8|i16|i32|cf32|ci16`).
    pub fn code(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::I16 => "i16",
            DType::I32 => "i32",
            DType::CF32 => "cf32",
            DType::CI16 => "ci16",
        }
    }

    /// Inverse of [`DType::code`].
    pub fn from_code(s: &str) -> Option<DType> {
        Some(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i16" => DType::I16,
            "i32" => DType::I32,
            "cf32" => DType::CF32,
            "ci16" => DType::CI16,
            _ => return None,
        })
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_hardware() {
        assert_eq!(DType::I8.bytes(), 1);
        assert_eq!(DType::CF32.bytes(), 8);
        assert_eq!(DType::I8.macs_per_cycle_aie(), 128);
        assert_eq!(DType::F32.macs_per_cycle_aie(), 8);
    }

    #[test]
    fn peak_int8_tops_of_full_array() {
        // 400 AIEs × 128 MACs × 2 ops × 1.25 GHz = 128 TOPS peak — the
        // headroom against which the paper's 32.49 TOPS is ~25 %.
        let peak: f64 = 400.0 * 128.0 * 2.0 * 1.25e9 / 1e12;
        assert!((peak - 128.0).abs() < 1e-9);
    }

    #[test]
    fn wire_codes_round_trip() {
        for d in [DType::F32, DType::I8, DType::I16, DType::I32, DType::CF32, DType::CI16] {
            assert_eq!(DType::from_code(d.code()), Some(d));
        }
        assert_eq!(DType::from_code("f16"), None);
    }

    #[test]
    fn complex_ops_counting() {
        assert_eq!(DType::CF32.ops_per_mac(), 8);
        assert_eq!(DType::F32.ops_per_mac(), 2);
        assert!(DType::CI16.is_complex());
        assert!(!DType::I16.is_complex());
    }
}
