//! The workload library: the paper's Table II recurrences plus the
//! expanded catalog (depthwise/grouped conv, triangular solve, stencil
//! chains) as [`UniformRecurrence`]s.
//!
//! Every constructor is documented in `docs/WORKLOADS.md` (the recurrence
//! cookbook): equations, dependence vectors, which mapping shapes the DSE
//! selects, and the 5-step recipe for adding a new workload.
//!
//! ```
//! use widesa::{library, DType};
//!
//! let rec = library::mm(8, 8, 8, DType::F32);
//! assert_eq!(rec.rank(), 3);
//! assert_eq!(rec.total_macs(), 512);
//! // MACs count 2 ops (mul + add) in the paper's TOPS convention.
//! assert_eq!(rec.total_ops(), 1024.0);
//! ```

use crate::polyhedral::affine::{AffineExpr, AffineMap};
use crate::polyhedral::dependence::{DepKind, Dependence};
use crate::polyhedral::domain::{IterationDomain, LoopDim};
use crate::recurrence::dtype::DType;
use crate::recurrence::spec::{Access, AccessKind, UniformRecurrence};

/// Matrix multiplication `C[i,j] += A[i,k] · B[k,j]` over `[n, m, k]`.
pub fn mm(n: u64, m: u64, k: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![
        LoopDim::new("i", n),
        LoopDim::new("j", m),
        LoopDim::new("k", k),
    ]);
    UniformRecurrence {
        name: format!("mm_{n}x{m}x{k}_{dtype}"),
        domain,
        accesses: vec![
            Access::new("A", AccessKind::Read, AffineMap::select(&[0, 2], &[0, 0], 3)),
            Access::new("B", AccessKind::Read, AffineMap::select(&[2, 1], &[0, 0], 3)),
            Access::new(
                "C",
                AccessKind::Accumulate,
                AffineMap::select(&[0, 1], &[0, 0], 3),
            ),
        ],
        dtype,
        macs_per_iter: 1,
        carried: vec![],
        replicate: 1,
    }
}

/// 2D convolution `Y[h,w] += X[h+p, w+q] · K[p,q]` over `[h, w, p, q]`
/// (the paper's 10240×10240 image with a p×q kernel).
pub fn conv2d(h: u64, w: u64, p: u64, q: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![
        LoopDim::new("h", h),
        LoopDim::new("w", w),
        LoopDim::new("p", p),
        LoopDim::new("q", q),
    ]);
    UniformRecurrence {
        name: format!("conv2d_{h}x{w}_{p}x{q}_{dtype}"),
        domain,
        accesses: vec![
            // X[h+p, w+q]: linear part selects (h,w) with +p/+q halo terms;
            // modelled with unit coefficients on both loops of each dim.
            Access::new(
                "X",
                AccessKind::Read,
                AffineMap::new(vec![
                    crate::polyhedral::affine::AffineExpr::new(vec![1, 0, 1, 0], 0),
                    crate::polyhedral::affine::AffineExpr::new(vec![0, 1, 0, 1], 0),
                ]),
            ),
            Access::new(
                "K",
                AccessKind::Read,
                AffineMap::select(&[2, 3], &[0, 0], 4),
            ),
            Access::new(
                "Y",
                AccessKind::Accumulate,
                AffineMap::select(&[0, 1], &[0, 0], 4),
            ),
        ],
        dtype,
        macs_per_iter: 1,
        carried: vec![],
        replicate: 1,
    }
}

/// FIR filter `y[n] += h[t] · x[n+t]` over `[n, taps]`.
pub fn fir(n: u64, taps: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![LoopDim::new("n", n), LoopDim::new("t", taps)]);
    UniformRecurrence {
        name: format!("fir_{n}x{taps}_{dtype}"),
        domain,
        accesses: vec![
            Access::new(
                "x",
                AccessKind::Read,
                AffineMap::new(vec![crate::polyhedral::affine::AffineExpr::new(
                    vec![1, 1],
                    0,
                )]),
            ),
            Access::new("h", AccessKind::Read, AffineMap::select(&[1], &[0], 2)),
            Access::new("y", AccessKind::Accumulate, AffineMap::select(&[0], &[0], 2)),
        ],
        dtype,
        macs_per_iter: 1,
        carried: vec![],
        replicate: 1,
    }
}

/// 2D FFT over an `rows × cols` grid, decomposed as batched radix-2
/// stages: iteration space `[pass, row, stage, butterfly]` where pass 0
/// does row FFTs and pass 1 column FFTs (after transpose). Each butterfly
/// is one complex MAC (twiddle multiply) plus adds.
pub fn fft2d(rows: u64, cols: u64, dtype: DType) -> UniformRecurrence {
    assert!(cols.is_power_of_two(), "FFT size must be a power of two");
    assert!(dtype.is_complex(), "FFT operates on complex data");
    let stages = cols.trailing_zeros() as u64;
    let domain = IterationDomain::new(vec![
        LoopDim::new("pass", 2),
        LoopDim::new("row", rows),
        LoopDim::new("stage", stages),
        LoopDim::new("bfly", cols / 2),
    ]);
    UniformRecurrence {
        name: format!("fft2d_{rows}x{cols}_{dtype}"),
        domain,
        accesses: vec![
            // the working vector is read and rewritten every stage: an
            // accumulate-like carried dependence along `stage` (and along
            // `pass` at the macro level)
            Access::new(
                "X",
                AccessKind::Accumulate,
                AffineMap::select(&[1, 3], &[0, 0], 4),
            ),
            Access::new("W", AccessKind::Read, AffineMap::select(&[3], &[0], 4)),
        ],
        dtype,
        macs_per_iter: 1,
        carried: vec![],
        replicate: 1,
    }
}

/// Depthwise (grouped) 2D convolution
/// `Y[g,h,w] += X[g, h+p, w+q] · K[g,p,q]` over `[g, h, w, p, q]` —
/// one independent p×q filter per channel group, the MobileNet-style
/// workload whose channel loop carries *no* reduction.
///
/// Compared with [`conv2d`], the kernel is not shared across the whole
/// array: `K[g,·,·]` is reused only along `h` and `w`, and the group loop
/// `g` is embarrassingly parallel (no dependence touches it), so the DSE
/// can spend it as a space dimension or as threading replicas — the
/// scenario the Table II corpus never exercises.
///
/// ```
/// use widesa::{library, DType};
/// use widesa::polyhedral::dependence::DepKind;
///
/// let rec = library::dw_conv2d(64, 256, 256, 3, 3, DType::F32);
/// assert_eq!(rec.rank(), 5);
/// assert_eq!(rec.total_macs(), 64 * 256 * 256 * 9);
/// // the group loop is dependence-free: every vector is 0 on g
/// assert!(rec.dependences().iter().all(|d| d.vector[0] == 0));
/// assert!(rec.dependences().iter().any(|d| d.array == "Y"
///     && d.kind == DepKind::Flow && d.vector == vec![0, 0, 0, 1, 0]));
/// ```
pub fn dw_conv2d(groups: u64, h: u64, w: u64, p: u64, q: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![
        LoopDim::new("g", groups),
        LoopDim::new("h", h),
        LoopDim::new("w", w),
        LoopDim::new("p", p),
        LoopDim::new("q", q),
    ]);
    UniformRecurrence {
        name: format!("dwconv2d_{groups}x{h}x{w}_{p}x{q}_{dtype}"),
        domain,
        accesses: vec![
            // X[g, h+p, w+q]: per-group halo-extended input plane.
            Access::new(
                "X",
                AccessKind::Read,
                AffineMap::new(vec![
                    AffineExpr::var(0, 5),
                    AffineExpr::new(vec![0, 1, 0, 1, 0], 0),
                    AffineExpr::new(vec![0, 0, 1, 0, 1], 0),
                ]),
            ),
            // K[g, p, q]: reused along h, w only (not across groups).
            Access::new("K", AccessKind::Read, AffineMap::select(&[0, 3, 4], &[0, 0, 0], 5)),
            Access::new(
                "Y",
                AccessKind::Accumulate,
                AffineMap::select(&[0, 1, 2], &[0, 0, 0], 5),
            ),
        ],
        dtype,
        macs_per_iter: 1,
        carried: vec![],
        replicate: 1,
    }
}

/// Triangular solve (forward substitution) `x = L⁻¹ b` as a uniform
/// recurrence over the rectangular hull `[i: n, j: n]` — the classic
/// Kung–Leiserson linear-solver systolization:
///
/// ```text
/// y(i,j) = y(i,j−1) + L[i,j] · x[j]        (j < i)
/// x(i)   = (b[i] − y(i,i−1)) / L[i,i]
/// ```
///
/// Dependences: the partial sum `y` is carried along `j` (flow `(0,1)`)
/// and each solved `x[j]` propagates down the rows (read `(1,0)`). The
/// rectangular hull over-approximates the triangular domain by 2× —
/// mapping and scheduling see the hull; the functional references
/// ([`crate::coordinator::verify::trsv_ref`]) and the stub kernel compute
/// the real triangular solve. `L` has *no* reuse (every element is
/// consumed exactly once), so the workload is PLIO-bound, and the solve's
/// wavefront (x(j) depends on x(j−1)) caps usable concurrency at one
/// block-column — which is why the DSE's 1D arm wins, as in the classic
/// Kung–Leiserson linear solver arrays: a 1D chain sits near the
/// wavefront bound, while 2D hull mappings instantiate far more tiles
/// than the wave and idle against it (the Trsv stall term in
/// [`crate::mapping::cost`]).
///
/// ```
/// use widesa::{library, DType};
/// use widesa::polyhedral::dependence::DepKind;
///
/// let rec = library::trsv(4096, DType::F32);
/// assert_eq!(rec.rank(), 2);
/// assert_eq!(rec.total_macs(), 4096 * 4096); // rectangular hull
/// let deps = rec.dependences();
/// assert!(deps.iter().any(|d| d.array == "x"
///     && d.kind == DepKind::Read && d.vector == vec![1, 0]));
/// assert!(deps.iter().any(|d| d.array == "y"
///     && d.kind == DepKind::Flow && d.vector == vec![0, 1]));
/// ```
pub fn trsv(n: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![LoopDim::new("i", n), LoopDim::new("j", n)]);
    UniformRecurrence {
        name: format!("trsv_{n}_{dtype}"),
        domain,
        accesses: vec![
            // L[i,j]: fully indexed, no reuse — n² unique bytes.
            Access::new("L", AccessKind::Read, AffineMap::select(&[0, 1], &[0, 0], 2)),
            // x[j]: the solved prefix, propagated down the rows.
            Access::new("x", AccessKind::Read, AffineMap::select(&[1], &[0], 2)),
            // y[i]: the row's partial sum, carried along j.
            Access::new("y", AccessKind::Accumulate, AffineMap::select(&[0], &[0], 2)),
        ],
        dtype,
        macs_per_iter: 1,
        carried: vec![],
        replicate: 1,
    }
}

/// 2D stencil chain: `stages` Jacobi/advection sweeps of a 5-point
/// stencil over an `n × m` grid, pipelined as one recurrence over
/// `[t, i, j]` (the workload class of Brown's Versal advection study,
/// arXiv:2301.13016, and EA4RCA's regular communication-avoiding
/// kernels, arXiv:2407.05621):
///
/// ```text
/// A(t,i,j) = c₀·A(t−1,i,j) + c₁·A(t−1,i−1,j) + c₂·A(t−1,i+1,j)
///          + c₃·A(t−1,i,j−1) + c₄·A(t−1,i,j+1)
/// ```
///
/// The neighbour reads carry the *negative-offset* dependence vectors
/// `(1,±1,0)` / `(1,0,±1)` — stated explicitly via
/// [`UniformRecurrence::carried`], since access reuse can only derive
/// positive unit vectors. No loop permutation makes `(1,−1,0)`
/// lexicographically positive with `i` outermost, so these deps are
/// mappable only through the space-time enumerator's neighbour-transfer
/// realisation (and, where that fails, its wavefront skew fallback) —
/// exactly the machinery the Table II corpus never stressed.
///
/// ```
/// use widesa::{library, DType};
///
/// let rec = library::stencil2d_chain(2, 1024, 1024, DType::F32);
/// assert_eq!(rec.rank(), 3);
/// assert_eq!(rec.total_macs(), 2 * 1024 * 1024 * 5); // 5 MACs per point
/// assert!(rec.dependences().iter().any(|d| d.vector == vec![1, -1, 0]));
/// assert!(rec.dependences().iter().any(|d| d.vector == vec![1, 0, 1]));
/// ```
pub fn stencil2d_chain(stages: u64, n: u64, m: u64, dtype: DType) -> UniformRecurrence {
    assert!(stages >= 1, "a stencil chain needs at least one sweep");
    let domain = IterationDomain::new(vec![
        LoopDim::new("t", stages),
        LoopDim::new("i", n),
        LoopDim::new("j", m),
    ]);
    let carried = [[1i64, 1, 0], [1, -1, 0], [1, 0, 1], [1, 0, -1]]
        .iter()
        .map(|v| Dependence::new("A", DepKind::Flow, v.to_vec()))
        .collect();
    UniformRecurrence {
        name: format!("stencil2d_{stages}x{n}x{m}_{dtype}"),
        domain,
        accesses: vec![
            // A[i,j] in-place across sweeps: centre-point flow along t.
            Access::new(
                "A",
                AccessKind::Accumulate,
                AffineMap::select(&[1, 2], &[0, 0], 3),
            ),
            // the 5 stencil coefficients: loop-invariant broadcast.
            Access::new("c", AccessKind::Read, AffineMap::new(vec![])),
        ],
        dtype,
        macs_per_iter: 5,
        carried,
        replicate: 1,
    }
}

/// Communication-avoiding 2.5D (replicated-summand) matrix multiply:
/// the same computation as [`mm`] — `C[i,j] += A[i,k] · B[k,j]` — but
/// with the reduction loop `k` *split across `rep` on-chip replicas*
/// (Solomonik–Demmel's "c" dimension, EA4RCA's regular CA recipe).
/// Each replica computes a partial `C` over its `k/rep` slab; the
/// partials are reduced across the replication axis by the
/// broadcast-reduction mover shape in `graph::builder`, so the array
/// drains `L` reduced streams instead of one stream per core.
///
/// The domain is the *full* problem (total MACs are unchanged — the
/// replicas split it); only [`UniformRecurrence::replicate`] differs
/// from the standard form, which is exactly why the DSE can price the
/// two head-to-head: CA buys fewer PLIO output streams with on-chip
/// partial-sum reduction traffic, and wins precisely when the port
/// predictor says the standard form is PLIO-bound (see
/// `docs/CA_VARIANTS.md`).
///
/// ```
/// use widesa::{library, DType};
///
/// let rec = library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32);
/// assert_eq!(rec.replicate, 4);
/// // same total work as the standard form
/// assert_eq!(rec.total_macs(), library::mm(1024, 1024, 1024, DType::F32).total_macs());
/// ```
pub fn ca_mm_25d(n: u64, m: u64, k: u64, rep: u64, dtype: DType) -> UniformRecurrence {
    assert!(rep >= 2, "a CA variant needs at least two replicas");
    assert!(k % rep == 0, "the reduction extent must divide across replicas");
    let mut rec = mm(n, m, k, dtype);
    rec.name = format!("ca_mm_25d_{n}x{m}x{k}_r{rep}_{dtype}");
    rec.replicate = rep;
    rec
}

/// Communication-avoiding block-recursive matrix multiply: `levels`
/// rounds of the classic 2×2×2 block split, with the `k`-halvings
/// realised as summand replication — one level splits `C = A·B` into
/// eight half-size products whose `k`-paired partials sum, so `levels`
/// levels leave `2^levels` replicated summand slabs reduced on chip.
/// The `i`/`j` halvings are ordinary space tiling the mapper already
/// performs, which is why the recurrence is [`mm`]'s domain plus a
/// [`UniformRecurrence::replicate`] factor of `2^levels` — the same
/// replication axis as [`ca_mm_25d`], reached by a different algorithm
/// recursion (see `docs/CA_VARIANTS.md` for the equations).
///
/// ```
/// use widesa::{library, DType};
///
/// let rec = library::ca_mm_blockrec(512, 3, DType::F32);
/// assert_eq!(rec.replicate, 8);
/// assert_eq!(rec.total_macs(), 512u64.pow(3));
/// ```
pub fn ca_mm_blockrec(n: u64, levels: u32, dtype: DType) -> UniformRecurrence {
    assert!(levels >= 1, "block recursion needs at least one level");
    let rep = 1u64 << levels;
    assert!(n % rep == 0, "n must divide across the recursive halvings");
    let mut rec = mm(n, n, n, dtype);
    rec.name = format!("ca_mm_blockrec_{n}_l{levels}_{dtype}");
    rec.replicate = rep;
    rec
}

/// Gauss–Seidel-style 2D sweep chain over `[t, i, j]`: `stages` in-place
/// relaxation sweeps where each point combines the *current* sweep's
/// already-updated neighbour below with the previous sweep's stencil:
///
/// ```text
/// A(t,i,j) = c₀·A(t,i+1,j)            (same sweep — runs against i)
///          + c₁·A(t−1,i,j) + c₂·A(t−1,i+1,j)
///          + c₃·A(t−1,i,j−1) + c₄·A(t−1,i,j+1)
/// ```
///
/// The same-sweep term carries the dependence `(0,−1,0)` — backward in
/// `i` with *zero* time advance — so, unlike [`stencil2d_chain`], no
/// rectangular core tile is legal (neighbouring tiles would be mutually
/// dependent: demarcation degenerates to point kernels) and no loop
/// permutation alone realises the transfer: every space-time choice the
/// enumerator keeps is legalised by the wavefront **skew fallback**
/// (`SpaceTimeChoice::skews` is non-empty on all of them), the machinery
/// that was previously reachable only from synthetic nests.
///
/// ```
/// use widesa::{library, DType};
///
/// let rec = library::seidel2d(2, 64, 64, DType::F32);
/// assert_eq!(rec.rank(), 3);
/// assert_eq!(rec.total_macs(), 2 * 64 * 64 * 5);
/// assert!(rec.dependences().iter().any(|d| d.vector == vec![0, -1, 0]));
/// ```
pub fn seidel2d(stages: u64, n: u64, m: u64, dtype: DType) -> UniformRecurrence {
    assert!(stages >= 1, "a sweep chain needs at least one sweep");
    let carried = [[0i64, -1, 0], [1, -1, 0], [1, 0, 1], [1, 0, -1]]
        .iter()
        .map(|v| Dependence::new("A", DepKind::Flow, v.to_vec()))
        .collect();
    let domain = IterationDomain::new(vec![
        LoopDim::new("t", stages),
        LoopDim::new("i", n),
        LoopDim::new("j", m),
    ]);
    UniformRecurrence {
        name: format!("seidel2d_{stages}x{n}x{m}_{dtype}"),
        domain,
        accesses: vec![
            // A[i,j] in-place across sweeps: centre-point flow along t.
            Access::new(
                "A",
                AccessKind::Accumulate,
                AffineMap::select(&[1, 2], &[0, 0], 3),
            ),
            // the 5 relaxation coefficients: loop-invariant broadcast.
            Access::new("c", AccessKind::Read, AffineMap::new(vec![])),
        ],
        dtype,
        macs_per_iter: 5,
        carried,
        replicate: 1,
    }
}

/// Table II problem instances, in paper order.
pub fn table2_benchmarks() -> Vec<UniformRecurrence> {
    vec![
        mm(8192, 8192, 8192, DType::F32),
        mm(10240, 10240, 10240, DType::I8),
        mm(9600, 9600, 9600, DType::I16),
        mm(8192, 8192, 8192, DType::I32),
        conv2d(10240, 10240, 4, 4, DType::F32),
        conv2d(10240, 10240, 8, 8, DType::I8),
        conv2d(10240, 10240, 4, 4, DType::I16),
        conv2d(10240, 10240, 4, 4, DType::I32),
        fft2d(8192, 8192, DType::CF32),
        fft2d(8192, 8192, DType::CI16),
        fir(1048576, 15, DType::F32),
        fir(1048576, 15, DType::I8),
        fir(1048576, 15, DType::I16),
        fir(1048576, 15, DType::CF32),
    ]
}

/// One instance of every library constructor at a small, fast-to-compile
/// size — the workload-coverage corpus behind `widesa workloads`,
/// `make workloads-smoke` and the `docs/WORKLOADS.md` cookbook. Sizes are
/// chosen so every family finds a legal mapping on the full 400-AIE board
/// within a test-friendly compile budget.
pub fn catalog_small() -> Vec<UniformRecurrence> {
    vec![
        mm(1024, 1024, 1024, DType::F32),
        conv2d(512, 512, 4, 4, DType::I16),
        fir(65536, 15, DType::F32),
        fft2d(512, 512, DType::CF32),
        dw_conv2d(64, 256, 256, 3, 3, DType::F32),
        trsv(8192, DType::F32),
        stencil2d_chain(2, 1024, 1024, DType::F32),
        ca_mm_25d(1024, 1024, 1024, 4, DType::F32),
        ca_mm_blockrec(512, 3, DType::F32),
        seidel2d(2, 64, 64, DType::F32),
    ]
}

/// Pair every communication-avoiding MM variant with the standard-form
/// recurrence it replaces, at matched problem shape — the selection
/// corpus behind the `ca_selected_iff_port_bound` law, `widesa ca`, and
/// `make ca-smoke`: the DSE must crown the CA member exactly when the
/// port predictor says the standard member is PLIO-bound.
pub fn ca_pairs() -> Vec<(UniformRecurrence, UniformRecurrence)> {
    vec![
        (
            mm(1024, 1024, 1024, DType::F32),
            ca_mm_25d(1024, 1024, 1024, 4, DType::F32),
        ),
        (
            mm(512, 512, 512, DType::F32),
            ca_mm_blockrec(512, 3, DType::F32),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::DepKind;

    #[test]
    fn mm_shape() {
        let r = mm(8192, 8192, 8192, DType::F32);
        assert_eq!(r.rank(), 3);
        assert_eq!(r.total_macs(), 8192u64.pow(3));
    }

    #[test]
    fn conv_deps_include_kernel_reuse() {
        let r = conv2d(64, 64, 4, 4, DType::I8);
        let deps = r.dependences();
        // K[p,q] reused along h and w
        assert!(deps
            .iter()
            .any(|d| d.array == "K" && d.vector == vec![1, 0, 0, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "K" && d.vector == vec![0, 1, 0, 0]));
        // Y accumulated along p and q
        assert!(deps
            .iter()
            .any(|d| d.array == "Y" && d.kind == DepKind::Flow && d.vector == vec![0, 0, 1, 0]));
    }

    #[test]
    fn fir_deps() {
        let r = fir(1024, 15, DType::F32);
        let deps = r.dependences();
        // h reused along n; y accumulated along t
        assert!(deps
            .iter()
            .any(|d| d.array == "h" && d.kind == DepKind::Read && d.vector == vec![1, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "y" && d.kind == DepKind::Flow && d.vector == vec![0, 1]));
    }

    #[test]
    fn fft_requires_complex_pow2() {
        let r = fft2d(8192, 8192, DType::CF32);
        // 2 passes × 8192 rows × 13 stages × 4096 butterflies
        assert_eq!(r.total_macs(), 2 * 8192 * 13 * 4096);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fft_rejects_non_pow2() {
        fft2d(100, 100, DType::CF32);
    }

    #[test]
    fn table2_has_14_rows() {
        assert_eq!(table2_benchmarks().len(), 14);
    }

    #[test]
    fn dwconv_group_loop_is_dependence_free() {
        let r = dw_conv2d(16, 64, 64, 3, 3, DType::F32);
        let deps = r.dependences();
        assert!(deps.iter().all(|d| d.vector[0] == 0), "{deps:?}");
        // K reused along h and w only
        assert!(deps
            .iter()
            .any(|d| d.array == "K" && d.vector == vec![0, 1, 0, 0, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "K" && d.vector == vec![0, 0, 1, 0, 0]));
        // Y accumulated along p and q
        assert!(deps
            .iter()
            .any(|d| d.array == "Y" && d.kind == DepKind::Flow && d.vector == vec![0, 0, 0, 1, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "Y" && d.kind == DepKind::Flow && d.vector == vec![0, 0, 0, 0, 1]));
    }

    #[test]
    fn trsv_has_fir_shaped_dependences_and_no_l_reuse() {
        let r = trsv(1024, DType::F32);
        let deps = r.dependences();
        assert!(!deps.iter().any(|d| d.array == "L"), "L must have no reuse");
        assert!(deps
            .iter()
            .any(|d| d.array == "x" && d.kind == DepKind::Read && d.vector == vec![1, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "y" && d.kind == DepKind::Flow && d.vector == vec![0, 1]));
    }

    #[test]
    fn stencil_carried_vectors_are_the_four_neighbours() {
        let r = stencil2d_chain(4, 256, 256, DType::F32);
        let deps = r.dependences();
        for v in [
            vec![1i64, 0, 0], // centre (from the Accumulate reuse)
            vec![1, 1, 0],
            vec![1, -1, 0],
            vec![1, 0, 1],
            vec![1, 0, -1],
        ] {
            assert!(
                deps.iter().any(|d| d.array == "A" && d.kind == DepKind::Flow && d.vector == v),
                "missing stencil dep {v:?} in {deps:?}"
            );
        }
        // 5 MACs per point in the TOPS accounting
        assert_eq!(r.total_macs(), 4 * 256 * 256 * 5);
    }

    #[test]
    #[should_panic(expected = "at least one sweep")]
    fn stencil_rejects_zero_stages() {
        stencil2d_chain(0, 64, 64, DType::F32);
    }

    #[test]
    fn ca_variants_replicate_without_changing_work() {
        let std = mm(1024, 1024, 1024, DType::F32);
        let ca = ca_mm_25d(1024, 1024, 1024, 4, DType::F32);
        // same computation, different mapping: work and accesses match
        assert_eq!(ca.total_macs(), std.total_macs());
        assert_eq!(ca.accesses.len(), std.accesses.len());
        assert_eq!(ca.replicate, 4);
        // distinct cache keys — replication is a semantic mapping choice
        assert_ne!(ca.canonical_u64(), std.canonical_u64());

        let br = ca_mm_blockrec(512, 3, DType::F32);
        assert_eq!(br.replicate, 8);
        assert_eq!(br.total_macs(), 512u64.pow(3));
        assert_ne!(br.canonical_u64(), mm(512, 512, 512, DType::F32).canonical_u64());
    }

    #[test]
    #[should_panic(expected = "at least two replicas")]
    fn ca_mm_rejects_degenerate_replication() {
        ca_mm_25d(64, 64, 64, 1, DType::F32);
    }

    #[test]
    #[should_panic(expected = "divide across replicas")]
    fn ca_mm_rejects_indivisible_reduction() {
        ca_mm_25d(64, 64, 63, 4, DType::F32);
    }

    #[test]
    fn seidel_has_the_reverse_sweep_dependence() {
        let r = seidel2d(2, 64, 64, DType::F32);
        let deps = r.dependences();
        // the same-sweep reverse term plus the previous-sweep stencil
        for v in [
            vec![0i64, -1, 0],
            vec![1, -1, 0],
            vec![1, 0, 1],
            vec![1, 0, -1],
            vec![1, 0, 0], // centre, from the Accumulate reuse along t
        ] {
            assert!(
                deps.iter().any(|d| d.array == "A" && d.kind == DepKind::Flow && d.vector == v),
                "missing seidel dep {v:?} in {deps:?}"
            );
        }
        assert_eq!(r.total_macs(), 2 * 64 * 64 * 5);
        // the declared order is NOT a legal sequential schedule — that is
        // the point: only the wavefront skew realises this recurrence.
        assert!(!crate::polyhedral::legality::is_legal_order(&deps));
    }

    #[test]
    fn ca_pairs_match_shapes() {
        for (std, ca) in ca_pairs() {
            assert_eq!(std.replicate, 1);
            assert!(ca.replicate > 1);
            assert_eq!(std.total_macs(), ca.total_macs());
            assert_eq!(std.dtype, ca.dtype);
        }
    }

    #[test]
    fn catalog_covers_every_constructor_once() {
        let names: Vec<String> = catalog_small().into_iter().map(|r| r.name).collect();
        for prefix in [
            "mm_",
            "conv2d_",
            "fir_",
            "fft2d_",
            "dwconv2d_",
            "trsv_",
            "stencil2d_",
            "ca_mm_25d_",
            "ca_mm_blockrec_",
            "seidel2d_",
        ] {
            assert_eq!(
                names.iter().filter(|n| n.starts_with(prefix)).count(),
                1,
                "catalog must hold exactly one {prefix} workload: {names:?}"
            );
        }
    }
}
