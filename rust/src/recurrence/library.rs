//! The paper's benchmark recurrences (Table II) as [`UniformRecurrence`]s.
//!
//! ```
//! use widesa::{library, DType};
//!
//! let rec = library::mm(8, 8, 8, DType::F32);
//! assert_eq!(rec.rank(), 3);
//! assert_eq!(rec.total_macs(), 512);
//! // MACs count 2 ops (mul + add) in the paper's TOPS convention.
//! assert_eq!(rec.total_ops(), 1024.0);
//! ```

use crate::polyhedral::affine::AffineMap;
use crate::polyhedral::domain::{IterationDomain, LoopDim};
use crate::recurrence::dtype::DType;
use crate::recurrence::spec::{Access, AccessKind, UniformRecurrence};

/// Matrix multiplication `C[i,j] += A[i,k] · B[k,j]` over `[n, m, k]`.
pub fn mm(n: u64, m: u64, k: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![
        LoopDim::new("i", n),
        LoopDim::new("j", m),
        LoopDim::new("k", k),
    ]);
    UniformRecurrence {
        name: format!("mm_{n}x{m}x{k}_{dtype}"),
        domain,
        accesses: vec![
            Access::new("A", AccessKind::Read, AffineMap::select(&[0, 2], &[0, 0], 3)),
            Access::new("B", AccessKind::Read, AffineMap::select(&[2, 1], &[0, 0], 3)),
            Access::new(
                "C",
                AccessKind::Accumulate,
                AffineMap::select(&[0, 1], &[0, 0], 3),
            ),
        ],
        dtype,
        macs_per_iter: 1,
    }
}

/// 2D convolution `Y[h,w] += X[h+p, w+q] · K[p,q]` over `[h, w, p, q]`
/// (the paper's 10240×10240 image with a p×q kernel).
pub fn conv2d(h: u64, w: u64, p: u64, q: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![
        LoopDim::new("h", h),
        LoopDim::new("w", w),
        LoopDim::new("p", p),
        LoopDim::new("q", q),
    ]);
    UniformRecurrence {
        name: format!("conv2d_{h}x{w}_{p}x{q}_{dtype}"),
        domain,
        accesses: vec![
            // X[h+p, w+q]: linear part selects (h,w) with +p/+q halo terms;
            // modelled with unit coefficients on both loops of each dim.
            Access::new(
                "X",
                AccessKind::Read,
                AffineMap::new(vec![
                    crate::polyhedral::affine::AffineExpr::new(vec![1, 0, 1, 0], 0),
                    crate::polyhedral::affine::AffineExpr::new(vec![0, 1, 0, 1], 0),
                ]),
            ),
            Access::new(
                "K",
                AccessKind::Read,
                AffineMap::select(&[2, 3], &[0, 0], 4),
            ),
            Access::new(
                "Y",
                AccessKind::Accumulate,
                AffineMap::select(&[0, 1], &[0, 0], 4),
            ),
        ],
        dtype,
        macs_per_iter: 1,
    }
}

/// FIR filter `y[n] += h[t] · x[n+t]` over `[n, taps]`.
pub fn fir(n: u64, taps: u64, dtype: DType) -> UniformRecurrence {
    let domain = IterationDomain::new(vec![LoopDim::new("n", n), LoopDim::new("t", taps)]);
    UniformRecurrence {
        name: format!("fir_{n}x{taps}_{dtype}"),
        domain,
        accesses: vec![
            Access::new(
                "x",
                AccessKind::Read,
                AffineMap::new(vec![crate::polyhedral::affine::AffineExpr::new(
                    vec![1, 1],
                    0,
                )]),
            ),
            Access::new("h", AccessKind::Read, AffineMap::select(&[1], &[0], 2)),
            Access::new("y", AccessKind::Accumulate, AffineMap::select(&[0], &[0], 2)),
        ],
        dtype,
        macs_per_iter: 1,
    }
}

/// 2D FFT over an `rows × cols` grid, decomposed as batched radix-2
/// stages: iteration space `[pass, row, stage, butterfly]` where pass 0
/// does row FFTs and pass 1 column FFTs (after transpose). Each butterfly
/// is one complex MAC (twiddle multiply) plus adds.
pub fn fft2d(rows: u64, cols: u64, dtype: DType) -> UniformRecurrence {
    assert!(cols.is_power_of_two(), "FFT size must be a power of two");
    assert!(dtype.is_complex(), "FFT operates on complex data");
    let stages = cols.trailing_zeros() as u64;
    let domain = IterationDomain::new(vec![
        LoopDim::new("pass", 2),
        LoopDim::new("row", rows),
        LoopDim::new("stage", stages),
        LoopDim::new("bfly", cols / 2),
    ]);
    UniformRecurrence {
        name: format!("fft2d_{rows}x{cols}_{dtype}"),
        domain,
        accesses: vec![
            // the working vector is read and rewritten every stage: an
            // accumulate-like carried dependence along `stage` (and along
            // `pass` at the macro level)
            Access::new(
                "X",
                AccessKind::Accumulate,
                AffineMap::select(&[1, 3], &[0, 0], 4),
            ),
            Access::new("W", AccessKind::Read, AffineMap::select(&[3], &[0], 4)),
        ],
        dtype,
        macs_per_iter: 1,
    }
}

/// Table II problem instances, in paper order.
pub fn table2_benchmarks() -> Vec<UniformRecurrence> {
    vec![
        mm(8192, 8192, 8192, DType::F32),
        mm(10240, 10240, 10240, DType::I8),
        mm(9600, 9600, 9600, DType::I16),
        mm(8192, 8192, 8192, DType::I32),
        conv2d(10240, 10240, 4, 4, DType::F32),
        conv2d(10240, 10240, 8, 8, DType::I8),
        conv2d(10240, 10240, 4, 4, DType::I16),
        conv2d(10240, 10240, 4, 4, DType::I32),
        fft2d(8192, 8192, DType::CF32),
        fft2d(8192, 8192, DType::CI16),
        fir(1048576, 15, DType::F32),
        fir(1048576, 15, DType::I8),
        fir(1048576, 15, DType::I16),
        fir(1048576, 15, DType::CF32),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::DepKind;

    #[test]
    fn mm_shape() {
        let r = mm(8192, 8192, 8192, DType::F32);
        assert_eq!(r.rank(), 3);
        assert_eq!(r.total_macs(), 8192u64.pow(3));
    }

    #[test]
    fn conv_deps_include_kernel_reuse() {
        let r = conv2d(64, 64, 4, 4, DType::I8);
        let deps = r.dependences();
        // K[p,q] reused along h and w
        assert!(deps
            .iter()
            .any(|d| d.array == "K" && d.vector == vec![1, 0, 0, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "K" && d.vector == vec![0, 1, 0, 0]));
        // Y accumulated along p and q
        assert!(deps
            .iter()
            .any(|d| d.array == "Y" && d.kind == DepKind::Flow && d.vector == vec![0, 0, 1, 0]));
    }

    #[test]
    fn fir_deps() {
        let r = fir(1024, 15, DType::F32);
        let deps = r.dependences();
        // h reused along n; y accumulated along t
        assert!(deps
            .iter()
            .any(|d| d.array == "h" && d.kind == DepKind::Read && d.vector == vec![1, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "y" && d.kind == DepKind::Flow && d.vector == vec![0, 1]));
    }

    #[test]
    fn fft_requires_complex_pow2() {
        let r = fft2d(8192, 8192, DType::CF32);
        // 2 passes × 8192 rows × 13 stages × 4096 butterflies
        assert_eq!(r.total_macs(), 2 * 8192 * 13 * 4096);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fft_rejects_non_pow2() {
        fft2d(100, 100, DType::CF32);
    }

    #[test]
    fn table2_has_14_rows() {
        assert_eq!(table2_benchmarks().len(), 14);
    }
}
