//! Uniform-recurrence specification: loop nest + typed affine accesses.
//!
//! This is the framework's input language (the role the C++ source plays
//! in the paper's Figure 5): a named statement in a rectangular loop nest
//! whose array accesses all have unit-coefficient affine maps, so every
//! dependence is a constant vector (Karp–Miller–Winograd uniformity).

use crate::polyhedral::affine::AffineMap;
use crate::polyhedral::dependence::{reuse_directions, DepKind, Dependence};
use crate::polyhedral::domain::IterationDomain;
use crate::polyhedral::schedule::LoopNest;
use crate::recurrence::dtype::DType;
use crate::util::hash::Fnv64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// Read-only input array.
    Read,
    /// Read-modify-write accumulation (flow + output dependence source).
    Accumulate,
    /// Pure output.
    Write,
}

/// One array access of the statement.
#[derive(Debug, Clone)]
pub struct Access {
    pub array: String,
    pub kind: AccessKind,
    pub map: AffineMap,
}

impl Access {
    pub fn new(array: impl Into<String>, kind: AccessKind, map: AffineMap) -> Self {
        Self {
            array: array.into(),
            kind,
            map,
        }
    }
}

/// A uniform recurrence: `for dims { S: accesses }` with `macs_per_iter`
/// MAC operations per innermost iteration point.
///
/// Dependence extraction is exact for this program class — every
/// dependence is a constant vector:
///
/// ```
/// use widesa::{library, DType};
/// use widesa::polyhedral::dependence::DepKind;
///
/// let rec = library::mm(64, 64, 64, DType::F32);
/// let deps = rec.dependences();
/// // A[i,k] is reused along j; the C accumulation is carried along k.
/// assert!(deps.iter().any(|d| d.array == "A"
///     && d.kind == DepKind::Read && d.vector == vec![0, 1, 0]));
/// assert!(deps.iter().any(|d| d.array == "C"
///     && d.kind == DepKind::Flow && d.vector == vec![0, 0, 1]));
/// ```
#[derive(Debug, Clone)]
pub struct UniformRecurrence {
    pub name: String,
    pub domain: IterationDomain,
    pub accesses: Vec<Access>,
    pub dtype: DType,
    /// MACs per iteration point (1 for MM/Conv/FIR; FFT butterflies carry
    /// one complex MAC + adds; a 5-point stencil sweep carries 5).
    pub macs_per_iter: u64,
    /// Explicitly carried uniform dependences, appended verbatim to the
    /// access-derived set by [`UniformRecurrence::dependences`].
    ///
    /// Access reuse (the null space of a selection map) can only express
    /// dependences whose vector is a positive unit direction. Stencil
    /// chains need more: the value read at `A[t-1, i±1, j±1]` induces the
    /// constant vectors `(1, ∓1, 0)` / `(1, 0, ∓1)`, which no
    /// unit-coefficient access map produces. Such recurrences state those
    /// vectors here — the classic Karp–Miller–Winograd presentation of a
    /// URE *is* its dependence-vector set, so this is the input language
    /// catching up with the paper's program class, not an escape hatch.
    /// Empty for every purely access-derived recurrence (all of Table II).
    pub carried: Vec<Dependence>,
    /// Replication factor of the communication-avoiding summand axis
    /// (the "c" of 2.5D matrix multiply): the computation is split into
    /// this many replicas that each produce a partial result, reduced on
    /// chip across the replication axis. `1` (the default for every
    /// standard-form recurrence) means no replication.
    ///
    /// The replication axis is *not* a loop of the iteration domain — it
    /// is neither space, time, nor tile. The mapper assigns it to array
    /// rows, `graph::builder` realises it as a broadcast-reduction mover
    /// shape, and `mapping::cost` prices the partial-sum reduction
    /// traffic it buys the PLIO savings with. See `docs/CA_VARIANTS.md`.
    pub replicate: u64,
}

impl UniformRecurrence {
    pub fn rank(&self) -> usize {
        self.domain.rank()
    }

    /// Total MAC count of the computation.
    pub fn total_macs(&self) -> u64 {
        self.domain.cardinality().saturating_mul(self.macs_per_iter)
    }

    /// Total arithmetic ops (the TOPS numerator, paper convention).
    pub fn total_ops(&self) -> f64 {
        self.total_macs() as f64 * self.dtype.ops_per_mac() as f64
    }

    /// Extract the uniform dependences:
    /// * each `Read` access contributes its reuse directions as read deps,
    /// * each `Accumulate` access contributes reuse directions as flow
    ///   deps (the carried partial sums) and the same directions as
    ///   output deps (last write wins),
    /// * `Write` accesses with reuse contribute output deps,
    /// * the explicitly [`carried`](UniformRecurrence::carried) vectors
    ///   (stencil neighbour reads) are appended verbatim.
    pub fn dependences(&self) -> Vec<Dependence> {
        let rank = self.rank();
        let mut out = Vec::new();
        for acc in &self.accesses {
            for dir in reuse_directions(&acc.map, rank) {
                match acc.kind {
                    AccessKind::Read => {
                        out.push(Dependence::new(acc.array.clone(), DepKind::Read, dir))
                    }
                    AccessKind::Accumulate => {
                        out.push(Dependence::new(
                            acc.array.clone(),
                            DepKind::Flow,
                            dir.clone(),
                        ));
                        out.push(Dependence::new(acc.array.clone(), DepKind::Output, dir));
                    }
                    AccessKind::Write => {
                        out.push(Dependence::new(acc.array.clone(), DepKind::Output, dir))
                    }
                }
            }
        }
        out.extend(self.carried.iter().cloned());
        out
    }

    /// Build the transformable loop nest (domain + dependences).
    pub fn loop_nest(&self) -> LoopNest {
        LoopNest::new(self.domain.clone(), self.dependences())
    }

    /// Bytes of one element of each distinct array, for bandwidth math.
    pub fn element_bytes(&self) -> u64 {
        self.dtype.bytes()
    }

    /// Stable canonical 64-bit fingerprint of the recurrence: the name,
    /// every loop dimension (name + extent), every access (array, kind,
    /// full affine map), the dtype, `macs_per_iter`, and — only when
    /// present — the explicitly carried dependence vectors.
    ///
    /// Two `UniformRecurrence` values hash equal iff they describe the
    /// same computation, and the value is reproducible across processes
    /// and machines (FNV-1a, no randomized hasher state) — this is the
    /// recurrence half of the serve layer's design-cache key and the
    /// memoization key for [`crate::recurrence::tiling::demarcate_cached`].
    ///
    /// **Key-stability contract:** the `carried` block is folded in only
    /// when non-empty, and the `replicate` factor only when > 1, so every
    /// pre-existing (access-derived, standard-form) recurrence keeps the
    /// exact key it had before either field existed — serve caches and
    /// persisted keys for the Table II workloads must never shift when
    /// the input language grows (asserted against a frozen re-computation
    /// of the original layout in `tests/proptest_invariants.rs`).
    pub fn canonical_u64(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_str(&self.name);
        h.write_usize(self.rank());
        for d in &self.domain.dims {
            h.write_str(&d.name);
            h.write_u64(d.extent);
        }
        h.write_usize(self.accesses.len());
        for acc in &self.accesses {
            h.write_str(&acc.array);
            h.write_u8(match acc.kind {
                AccessKind::Read => 0,
                AccessKind::Accumulate => 1,
                AccessKind::Write => 2,
            });
            h.write_usize(acc.map.exprs.len());
            for e in &acc.map.exprs {
                h.write_usize(e.coeffs.len());
                for &c in &e.coeffs {
                    h.write_i64(c);
                }
                h.write_i64(e.constant);
            }
        }
        h.write_str(self.dtype.name());
        h.write_u64(self.macs_per_iter);
        if !self.carried.is_empty() {
            h.write_usize(self.carried.len());
            for d in &self.carried {
                h.write_str(&d.array);
                h.write_u8(match d.kind {
                    DepKind::Read => 0,
                    DepKind::Flow => 1,
                    DepKind::Output => 2,
                });
                h.write_usize(d.vector.len());
                for &c in &d.vector {
                    h.write_i64(c);
                }
            }
        }
        if self.replicate > 1 {
            h.write_str("rep");
            h.write_u64(self.replicate);
        }
        h.finish()
    }

    /// Footprint in bytes of array `name` (product of its extent along
    /// each referenced dim — exact for selection maps).
    pub fn array_footprint(&self, name: &str) -> Option<u64> {
        let acc = self.accesses.iter().find(|a| a.array == name)?;
        let mut elems: u64 = 1;
        for e in &acc.map.exprs {
            // extent along this output dim = extent of referenced loop
            // plus |offset| halo (for shifted stencil accesses).
            let mut dim_extent: u64 = 1;
            for (d, &c) in e.coeffs.iter().enumerate() {
                if c != 0 {
                    dim_extent = dim_extent
                        .saturating_mul(self.domain.dims[d].extent.saturating_mul(c.unsigned_abs()));
                }
            }
            elems = elems.saturating_mul(dim_extent + e.constant.unsigned_abs());
        }
        Some(elems.saturating_mul(self.dtype.bytes()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::domain::LoopDim;

    fn mm() -> UniformRecurrence {
        let domain = IterationDomain::new(vec![
            LoopDim::new("i", 8),
            LoopDim::new("j", 8),
            LoopDim::new("k", 8),
        ]);
        UniformRecurrence {
            name: "mm".into(),
            domain,
            accesses: vec![
                Access::new("A", AccessKind::Read, AffineMap::select(&[0, 2], &[0, 0], 3)),
                Access::new("B", AccessKind::Read, AffineMap::select(&[2, 1], &[0, 0], 3)),
                Access::new(
                    "C",
                    AccessKind::Accumulate,
                    AffineMap::select(&[0, 1], &[0, 0], 3),
                ),
            ],
            dtype: DType::F32,
            macs_per_iter: 1,
            carried: vec![],
            replicate: 1,
        }
    }

    #[test]
    fn mm_dependences() {
        let deps = mm().dependences();
        // A read along j, B read along i, C flow+output along k.
        assert!(deps
            .iter()
            .any(|d| d.array == "A" && d.kind == DepKind::Read && d.vector == vec![0, 1, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "B" && d.kind == DepKind::Read && d.vector == vec![1, 0, 0]));
        assert!(deps
            .iter()
            .any(|d| d.array == "C" && d.kind == DepKind::Flow && d.vector == vec![0, 0, 1]));
        assert!(deps
            .iter()
            .any(|d| d.array == "C" && d.kind == DepKind::Output && d.vector == vec![0, 0, 1]));
        assert_eq!(deps.len(), 4);
    }

    #[test]
    fn mm_total_ops() {
        let r = mm();
        assert_eq!(r.total_macs(), 512);
        assert_eq!(r.total_ops(), 1024.0); // 2 ops per MAC
    }

    #[test]
    fn footprints() {
        let r = mm();
        // A is 8×8 f32 = 256 B
        assert_eq!(r.array_footprint("A"), Some(256));
        assert_eq!(r.array_footprint("Z"), None);
    }

    #[test]
    fn loop_nest_carries_deps() {
        let nest = mm().loop_nest();
        assert_eq!(nest.rank(), 3);
        assert_eq!(nest.deps.len(), 4);
    }

    #[test]
    fn canonical_key_is_stable_and_discriminating() {
        let a = mm();
        let b = mm();
        assert_eq!(a.canonical_u64(), b.canonical_u64());

        // any semantic difference changes the key
        let mut bigger = mm();
        bigger.domain.dims[2].extent = 16;
        assert_ne!(a.canonical_u64(), bigger.canonical_u64());

        let mut renamed = mm();
        renamed.name = "mm_other".into();
        assert_ne!(a.canonical_u64(), renamed.canonical_u64());

        let mut retyped = mm();
        retyped.dtype = DType::I8;
        assert_ne!(a.canonical_u64(), retyped.canonical_u64());

        let mut rekind = mm();
        rekind.accesses[2].kind = AccessKind::Write;
        assert_ne!(a.canonical_u64(), rekind.canonical_u64());
    }

    #[test]
    fn carried_deps_enter_dependences_and_key() {
        let base = mm();
        let mut stencil = mm();
        stencil
            .carried
            .push(Dependence::new("C", DepKind::Flow, vec![1, -1, 0]));
        // appended verbatim to the access-derived set
        let deps = stencil.dependences();
        assert_eq!(deps.len(), base.dependences().len() + 1);
        assert!(deps
            .iter()
            .any(|d| d.kind == DepKind::Flow && d.vector == vec![1, -1, 0]));
        // a carried vector is a semantic difference → the key moves
        assert_ne!(base.canonical_u64(), stencil.canonical_u64());
        // and differing carried sets hash apart
        let mut other = mm();
        other
            .carried
            .push(Dependence::new("C", DepKind::Flow, vec![1, 1, 0]));
        assert_ne!(stencil.canonical_u64(), other.canonical_u64());
    }

    #[test]
    fn replicate_enters_key_only_when_above_one() {
        // replicate == 1 is the standard form: bit-identical key to the
        // pre-field layout (the key-stability contract).
        let base = mm();
        let mut explicit_one = mm();
        explicit_one.replicate = 1;
        assert_eq!(base.canonical_u64(), explicit_one.canonical_u64());

        // a real replication factor is a semantic difference → key moves,
        // and distinct factors hash apart.
        let mut rep4 = mm();
        rep4.replicate = 4;
        assert_ne!(base.canonical_u64(), rep4.canonical_u64());
        let mut rep8 = mm();
        rep8.replicate = 8;
        assert_ne!(rep4.canonical_u64(), rep8.canonical_u64());
    }
}
