//! The paper's congestion model (§III-C-2).
//!
//! With PLIOs in row 0, a stream between PLIO `p` (column `p_col`) and
//! AIE `x` (column `x_col`) crosses every column boundary between them
//! horizontally. `Cong_i^west` counts streams crossing boundary `i`
//! westward (and symmetrically eastward):
//!
//! ```text
//! Cong_i^west = Σ_{p,x} W_i[p][x],
//! W_i[p][x] = 1 if (p_col < i and x_col > i and (x,p) ∈ Edges)
//!          or  (p_col > i and x_col < i and (p,x) ∈ Edges)
//! ```
//!
//! (Westward traffic at boundary `i` flows from higher to lower columns.)
//!
//! Hot-path note: the per-pair stream deduplication ([`PlioPairSet`])
//! and the broadcast trunk extents ([`BcastExtents`]) are dense
//! structures keyed by PLIO ordinal / `NodeId` — no hashing on the
//! compile path. Both are shared with the router so the two sides can
//! never disagree on pair identity or trunk shape.

use crate::graph::builder::MappedGraph;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use crate::util::bitset::DenseBitSet;
use std::collections::HashMap;

/// Congestion per column boundary (index i = boundary between col i and
/// i+1, matching the paper's summation bounds).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CongestionProfile {
    pub west: Vec<u32>,
    pub east: Vec<u32>,
}

impl CongestionProfile {
    pub fn max_west(&self) -> u32 {
        self.west.iter().copied().max().unwrap_or(0)
    }

    pub fn max_east(&self) -> u32 {
        self.east.iter().copied().max().unwrap_or(0)
    }

    pub fn within(&self, rc_west: u32, rc_east: u32) -> bool {
        self.max_west() <= rc_west && self.max_east() <= rc_east
    }
}

/// Broadcast multicast trunks: per source port, the column extent
/// `[lo, hi]` its horizontal trunk must span — one crossing per boundary
/// regardless of fan-out. Dense by `NodeId`; the single accumulation
/// helper shared by the congestion model and the router
/// ([`crate::place_route::router::route_all`]), which used to duplicate
/// this logic with separate `HashMap`s.
#[derive(Debug, Clone)]
pub struct BcastExtents {
    ext: Vec<Option<(u32, u32)>>,
}

impl BcastExtents {
    pub fn new(num_nodes: usize) -> Self {
        Self {
            ext: vec![None; num_nodes],
        }
    }

    /// Widen port `p`'s trunk to reach `col`.
    pub fn note(&mut self, p: NodeId, col: u32) {
        match &mut self.ext[p] {
            Some((lo, hi)) => {
                *lo = (*lo).min(col);
                *hi = (*hi).max(col);
            }
            slot @ None => *slot = Some((col, col)),
        }
    }

    /// All `(port, (lo, hi))` extents, in ascending port order.
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, (u32, u32))> + '_ {
        self.ext
            .iter()
            .enumerate()
            .filter_map(|(p, e)| e.map(|e| (p, e)))
    }
}

/// Per-(PLIO, node) stream deduplication — the one structure behind both
/// the congestion model's W_i pair dedup and the router's
/// packet-switched-sibling dedup, so the two key schemes cannot drift.
///
/// Keys are `plio_ordinal × direction × partner node` over a dense
/// bitset: O(P·N) bits for P PLIO ports and N nodes, not O(N²). PLIO
/// ordinals are assigned by node *index* (edge endpoints index `nodes`),
/// so graphs whose ids drifted from their indices degrade gracefully. A
/// pair with no PLIO endpoint (not producible by the builder) falls back
/// to an exact hash set rather than panicking or double-counting.
pub struct PlioPairSet {
    /// PLIO ordinal by node index; `u32::MAX` = not a PLIO.
    ord: Vec<u32>,
    seen: DenseBitSet,
    /// Exact fallback for pairs with no PLIO endpoint (normally empty).
    other: std::collections::HashSet<(NodeId, NodeId)>,
    nn: usize,
}

impl PlioPairSet {
    pub fn new(g: &MappedGraph) -> Self {
        let nn = g.nodes.len();
        let mut ord = vec![u32::MAX; nn];
        let mut n_plio = 0usize;
        for (i, n) in g.nodes.iter().enumerate() {
            if n.is_plio() {
                ord[i] = n_plio as u32;
                n_plio += 1;
            }
        }
        Self {
            ord,
            seen: DenseBitSet::new(2 * n_plio * nn),
            other: std::collections::HashSet::new(),
            nn,
        }
    }

    /// Insert an already-normalised `(plio, partner)` pair (the
    /// congestion model's W_i identity, direction-blind). Returns true
    /// when newly inserted.
    pub fn insert(&mut self, plio: NodeId, partner: NodeId) -> bool {
        if self.ord[plio] == u32::MAX {
            return self.other.insert((plio, partner));
        }
        self.seen
            .insert(2 * self.ord[plio] as usize * self.nn + partner)
    }

    /// Insert a directed `(src, dst)` pair (the router's route identity:
    /// which endpoint is the PLIO encodes the direction). Returns true
    /// when newly inserted.
    pub fn insert_directed(&mut self, src: NodeId, dst: NodeId) -> bool {
        if self.ord[src] != u32::MAX {
            self.seen.insert(2 * self.ord[src] as usize * self.nn + dst)
        } else if self.ord[dst] != u32::MAX {
            self.seen
                .insert((2 * self.ord[dst] as usize + 1) * self.nn + src)
        } else {
            self.other.insert((src, dst))
        }
    }
}

/// Compute congestion for a PLIO column assignment. `plio_cols` maps each
/// PLIO node to its column; AIE columns come from the placement. Streams
/// are deduplicated per (plio, aie) pair as in the paper's W_i.
pub fn congestion(
    g: &MappedGraph,
    placement: &Placement,
    plio_cols: &HashMap<NodeId, u32>,
    num_cols: u32,
) -> CongestionProfile {
    // Size boundaries to the widest column actually used (guards against
    // callers passing a narrower nominal width).
    let max_col = placement
        .max_col()
        .into_iter()
        .chain(plio_cols.values().copied())
        .max()
        .unwrap_or(0)
        .max(num_cols.saturating_sub(1));
    let nb = max_col as usize;
    let mut west = vec![0u32; nb];
    let mut east = vec![0u32; nb];
    let nn = g.nodes.len();
    let mut seen = PlioPairSet::new(g);
    let mut bcast = BcastExtents::new(nn);
    for e in &g.edges {
        let (p, x) = if g.nodes[e.src].is_plio() && g.nodes[e.dst].is_aie() {
            (e.src, e.dst)
        } else if g.nodes[e.dst].is_plio() && g.nodes[e.src].is_aie() {
            (e.dst, e.src)
        } else {
            continue;
        };
        let (Some(&pc), Some(xc)) = (plio_cols.get(&p), placement.col(x)) else {
            continue;
        };
        if e.kind == crate::graph::edge::EdgeKind::Broadcast {
            bcast.note(p, xc);
            continue;
        }
        if !seen.insert(p, x) {
            continue;
        }
        if pc == xc {
            continue; // pure vertical climb
        }
        let (lo, hi) = (pc.min(xc), pc.max(xc));
        // Eastward if data moves to a higher column. Input (p → x):
        // eastward iff x_col > p_col. Output (x → p): eastward iff
        // p_col > x_col. Both reduce to "towards the higher column" of
        // the actual direction of flow.
        let flow_east = if g.nodes[e.src].id == p {
            xc > pc
        } else {
            pc > xc
        };
        for b in lo..hi {
            if flow_east {
                east[b as usize] += 1;
            } else {
                west[b as usize] += 1;
            }
        }
    }
    for (p, (lo, hi)) in bcast.iter() {
        let pc = plio_cols[&p];
        // trunk spans [min(lo, pc), max(hi, pc)]: eastward part from pc
        // to hi, westward part from pc down to lo
        for b in pc.min(hi)..hi.max(pc) {
            if b >= pc {
                east[b as usize] += 1;
            }
        }
        for b in lo.min(pc)..pc.max(lo) {
            if b < pc {
                west[b as usize] += 1;
            }
        }
    }
    CongestionProfile { west, east }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::Coord;
    use crate::arch::plio::PlioDir;
    use crate::graph::edge::{Edge, EdgeKind};
    use crate::graph::node::{Node, NodeKind};
    use crate::polyhedral::dependence::DepKind;

    /// Tiny hand-built graph: one input PLIO feeding two AIEs, one output.
    fn toy() -> (MappedGraph, Placement) {
        let mut g = MappedGraph::default();
        g.nodes = vec![
            Node {
                id: 0,
                kind: NodeKind::Plio { dir: PlioDir::In },
                name: "in".into(),
            },
            Node {
                id: 1,
                kind: NodeKind::Aie {
                    virt: Coord::new(0, 0),
                },
                name: "k_r0_0_0".into(),
            },
            Node {
                id: 2,
                kind: NodeKind::Aie {
                    virt: Coord::new(0, 3),
                },
                name: "k_r0_0_3".into(),
            },
            Node {
                id: 3,
                kind: NodeKind::Plio { dir: PlioDir::Out },
                name: "out".into(),
            },
        ];
        g.edges = vec![
            Edge::new(0, 1, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(0, 2, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(2, 3, EdgeKind::Stream, "C", DepKind::Output, 1.0),
        ];
        let mut p = Placement::default();
        p.insert(1, Coord::new(2, 0));
        p.insert(2, Coord::new(2, 3));
        (g, p)
    }

    #[test]
    fn eastward_input_counts_boundaries() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 0u32); // input PLIO at col 0
        cols.insert(3usize, 5u32); // output PLIO at col 5
        let prof = congestion(&g, &pl, &cols, 8);
        // in→AIE@3 crosses boundaries 0,1,2 eastward
        assert_eq!(&prof.east[0..3], &[1, 1, 1]);
        // AIE@3→out@5 crosses boundaries 3,4 eastward
        assert_eq!(&prof.east[3..5], &[1, 1]);
        assert_eq!(prof.max_west(), 0);
    }

    #[test]
    fn westward_output() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 3u32); // input at col 3: vertical for AIE@3
        cols.insert(3usize, 1u32); // output west of AIE@3
        let prof = congestion(&g, &pl, &cols, 8);
        // in@3 → AIE@0 crosses 0,1,2 westward; AIE@3 → out@1 crosses 1,2 westward
        assert_eq!(prof.west, vec![1, 2, 2, 0, 0, 0, 0]);
        assert_eq!(prof.max_east(), 0);
    }

    #[test]
    fn same_column_is_free() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 0u32);
        cols.insert(3usize, 3u32);
        let prof = congestion(&g, &pl, &cols, 8);
        // in@0→AIE@0 vertical; out@3→AIE@3 vertical; only in@0→AIE@3 crosses
        assert_eq!(prof.east, vec![1, 1, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn within_budget_check() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 0u32);
        cols.insert(3usize, 5u32);
        let prof = congestion(&g, &pl, &cols, 8);
        assert!(prof.within(6, 6));
        assert!(!prof.within(6, 0));
    }

    #[test]
    fn bcast_extents_accumulate() {
        let mut b = BcastExtents::new(4);
        b.note(1, 5);
        b.note(1, 2);
        b.note(1, 9);
        b.note(3, 4);
        let v: Vec<_> = b.iter().collect();
        assert_eq!(v, vec![(1, (2, 9)), (3, (4, 4))]);
    }

    #[test]
    fn plio_pair_set_dedups_like_a_hash_set() {
        let (g, _) = toy(); // PLIOs at indices 0 and 3, AIEs at 1 and 2
        let mut s = PlioPairSet::new(&g);
        assert!(s.insert(0, 1));
        assert!(!s.insert(0, 1)); // duplicate pair
        assert!(s.insert(0, 2)); // same port, other AIE
        assert!(s.insert(3, 2)); // other port, same AIE
        // directed: (plio→aie) and (aie→plio) are distinct route keys
        let mut d = PlioPairSet::new(&g);
        assert!(d.insert_directed(0, 1));
        assert!(d.insert_directed(1, 0));
        assert!(!d.insert_directed(0, 1));
        // pairs with no PLIO endpoint fall back gracefully
        assert!(d.insert_directed(1, 2));
        assert!(!d.insert_directed(1, 2));
    }
}
