//! The paper's congestion model (§III-C-2).
//!
//! With PLIOs in row 0, a stream between PLIO `p` (column `p_col`) and
//! AIE `x` (column `x_col`) crosses every column boundary between them
//! horizontally. `Cong_i^west` counts streams crossing boundary `i`
//! westward (and symmetrically eastward):
//!
//! ```text
//! Cong_i^west = Σ_{p,x} W_i[p][x],
//! W_i[p][x] = 1 if (p_col < i and x_col > i and (x,p) ∈ Edges)
//!          or  (p_col > i and x_col < i and (p,x) ∈ Edges)
//! ```
//!
//! (Westward traffic at boundary `i` flows from higher to lower columns.)

use crate::graph::builder::MappedGraph;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use std::collections::HashMap;

/// Congestion per column boundary (index i = boundary between col i and
/// i+1, matching the paper's summation bounds).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CongestionProfile {
    pub west: Vec<u32>,
    pub east: Vec<u32>,
}

impl CongestionProfile {
    pub fn max_west(&self) -> u32 {
        self.west.iter().copied().max().unwrap_or(0)
    }

    pub fn max_east(&self) -> u32 {
        self.east.iter().copied().max().unwrap_or(0)
    }

    pub fn within(&self, rc_west: u32, rc_east: u32) -> bool {
        self.max_west() <= rc_west && self.max_east() <= rc_east
    }
}

/// Compute congestion for a PLIO column assignment. `plio_cols` maps each
/// PLIO node to its column; AIE columns come from the placement. Streams
/// are deduplicated per (plio, aie) pair as in the paper's W_i.
pub fn congestion(
    g: &MappedGraph,
    placement: &Placement,
    plio_cols: &HashMap<NodeId, u32>,
    num_cols: u32,
) -> CongestionProfile {
    // Size boundaries to the widest column actually used (guards against
    // callers passing a narrower nominal width).
    let max_col = placement
        .coords
        .values()
        .map(|c| c.col)
        .chain(plio_cols.values().copied())
        .max()
        .unwrap_or(0)
        .max(num_cols.saturating_sub(1));
    let nb = max_col as usize;
    let mut west = vec![0u32; nb];
    let mut east = vec![0u32; nb];
    let mut seen = std::collections::HashSet::new();
    // Broadcast multicast trunks: one horizontal crossing per boundary
    // regardless of fan-out — collect extents per port.
    let mut bcast_extent: HashMap<NodeId, (u32, u32)> = HashMap::new();
    for e in &g.edges {
        let (p, x) = if g.nodes[e.src].is_plio() && g.nodes[e.dst].is_aie() {
            (e.src, e.dst)
        } else if g.nodes[e.dst].is_plio() && g.nodes[e.src].is_aie() {
            (e.dst, e.src)
        } else {
            continue;
        };
        let (Some(&pc), Some(xc)) = (plio_cols.get(&p), placement.col(x)) else {
            continue;
        };
        if e.kind == crate::graph::edge::EdgeKind::Broadcast {
            let ext = bcast_extent.entry(p).or_insert((xc, xc));
            ext.0 = ext.0.min(xc);
            ext.1 = ext.1.max(xc);
            continue;
        }
        if !seen.insert((p, x)) {
            continue;
        }
        if pc == xc {
            continue; // pure vertical climb
        }
        let (lo, hi) = (pc.min(xc), pc.max(xc));
        // Eastward if data moves to a higher column. Input (p → x):
        // eastward iff x_col > p_col. Output (x → p): eastward iff
        // p_col > x_col. Both reduce to "towards the higher column" of
        // the actual direction of flow.
        let flow_east = if g.nodes[e.src].id == p {
            xc > pc
        } else {
            pc > xc
        };
        for b in lo..hi {
            if flow_east {
                east[b as usize] += 1;
            } else {
                west[b as usize] += 1;
            }
        }
    }
    for (p, (lo, hi)) in bcast_extent {
        let pc = plio_cols[&p];
        // trunk spans [min(lo, pc), max(hi, pc)]: eastward part from pc
        // to hi, westward part from pc down to lo
        for b in pc.min(hi)..hi.max(pc) {
            if b >= pc {
                east[b as usize] += 1;
            }
        }
        for b in lo.min(pc)..pc.max(lo) {
            if b < pc {
                west[b as usize] += 1;
            }
        }
    }
    CongestionProfile { west, east }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::Coord;
    use crate::arch::plio::PlioDir;
    use crate::graph::edge::{Edge, EdgeKind};
    use crate::graph::node::{Node, NodeKind};
    use crate::polyhedral::dependence::DepKind;

    /// Tiny hand-built graph: one input PLIO feeding two AIEs, one output.
    fn toy() -> (MappedGraph, Placement) {
        let mut g = MappedGraph::default();
        g.nodes = vec![
            Node {
                id: 0,
                kind: NodeKind::Plio { dir: PlioDir::In },
                name: "in".into(),
            },
            Node {
                id: 1,
                kind: NodeKind::Aie {
                    virt: Coord::new(0, 0),
                },
                name: "k_r0_0_0".into(),
            },
            Node {
                id: 2,
                kind: NodeKind::Aie {
                    virt: Coord::new(0, 3),
                },
                name: "k_r0_0_3".into(),
            },
            Node {
                id: 3,
                kind: NodeKind::Plio { dir: PlioDir::Out },
                name: "out".into(),
            },
        ];
        g.edges = vec![
            Edge::new(0, 1, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(0, 2, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(2, 3, EdgeKind::Stream, "C", DepKind::Output, 1.0),
        ];
        let mut p = Placement::default();
        p.coords.insert(1, Coord::new(2, 0));
        p.coords.insert(2, Coord::new(2, 3));
        (g, p)
    }

    #[test]
    fn eastward_input_counts_boundaries() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 0u32); // input PLIO at col 0
        cols.insert(3usize, 5u32); // output PLIO at col 5
        let prof = congestion(&g, &pl, &cols, 8);
        // in→AIE@3 crosses boundaries 0,1,2 eastward
        assert_eq!(&prof.east[0..3], &[1, 1, 1]);
        // AIE@3→out@5 crosses boundaries 3,4 eastward
        assert_eq!(&prof.east[3..5], &[1, 1]);
        assert_eq!(prof.max_west(), 0);
    }

    #[test]
    fn westward_output() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 3u32); // input at col 3: vertical for AIE@3
        cols.insert(3usize, 1u32); // output west of AIE@3
        let prof = congestion(&g, &pl, &cols, 8);
        // in@3 → AIE@0 crosses 0,1,2 westward; AIE@3 → out@1 crosses 1,2 westward
        assert_eq!(prof.west, vec![1, 2, 2, 0, 0, 0, 0]);
        assert_eq!(prof.max_east(), 0);
    }

    #[test]
    fn same_column_is_free() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 0u32);
        cols.insert(3usize, 3u32);
        let prof = congestion(&g, &pl, &cols, 8);
        // in@0→AIE@0 vertical; out@3→AIE@3 vertical; only in@0→AIE@3 crosses
        assert_eq!(prof.east, vec![1, 1, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn within_budget_check() {
        let (g, pl) = toy();
        let mut cols = HashMap::new();
        cols.insert(0usize, 0u32);
        cols.insert(3usize, 5u32);
        let prof = congestion(&g, &pl, &cols, 8);
        assert!(prof.within(6, 6));
        assert!(!prof.within(6, 0));
    }
}
