//! Routing-aware PLIO assignment (paper §III-C-2, Algorithm 1).
//!
//! After placement, every PLIO node needs an interface column. Routing on
//! the mesh makes this a satisfiability problem: horizontal crossings per
//! column boundary must stay within the NoC's channel budget
//! (`Cong_i^{west/east} ≤ RC`). [`congestion`] computes the paper's
//! congestion sums, [`assignment`] implements the greedy median heuristic
//! of Algorithm 1, and [`sat`] checks feasibility (and provides an
//! exhaustive fallback for small instances, used to validate the greedy).
//!
//! Paper map: [`assignment::assign`] ↔ Algorithm 1 (find-median /
//! find-nearest / remove loop, most-constrained port first);
//! [`congestion::congestion`] ↔ the `W_i[p][x]` summation of §III-C-2;
//! [`sat::check`] ↔ the satisfiability formulation the paper reduces
//! assignment to, with [`sat::exhaustive_assign`] as the ground-truth
//! solver the property tests compare the greedy against.

pub mod assignment;
pub mod congestion;
pub mod sat;

pub use assignment::{assign, PlioAssignment};
pub use congestion::{congestion, CongestionProfile};
pub use sat::{check, exhaustive_assign};
