//! Satisfiability checking for PLIO assignments.
//!
//! The paper formulates PLIO assignment as a satisfiability problem over
//! the congestion constraints; [`check`] verifies an assignment, and
//! [`exhaustive_assign`] finds a feasible assignment by backtracking —
//! exponential, so only usable on small instances, where it serves as
//! ground truth for the greedy (property tests compare the two).

use super::congestion::congestion;
use crate::arch::plio::{PlioDir, PlioSpec};
use crate::graph::builder::MappedGraph;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use std::collections::HashMap;

/// Verify `columns` against capacity and congestion bounds.
pub fn check(
    g: &MappedGraph,
    placement: &Placement,
    columns: &HashMap<NodeId, u32>,
    spec: &PlioSpec,
    rc_west: u32,
    rc_east: u32,
) -> bool {
    // per-column, per-direction capacity
    let mut used: HashMap<(u32, PlioDir), u32> = HashMap::new();
    for n in g.plio_nodes() {
        let Some(&col) = columns.get(&n.id) else {
            return false;
        };
        if !spec.columns.contains(&col) {
            return false;
        }
        let dir = n.plio_dir().unwrap();
        let u = used.entry((col, dir)).or_default();
        *u += 1;
        if *u > spec.channels_per_column {
            return false;
        }
    }
    let num_cols = spec.columns.iter().copied().max().unwrap_or(0) + 1;
    congestion(g, placement, columns, num_cols).within(rc_west, rc_east)
}

/// Backtracking search for a feasible assignment (small instances only).
pub fn exhaustive_assign(
    g: &MappedGraph,
    placement: &Placement,
    spec: &PlioSpec,
    rc_west: u32,
    rc_east: u32,
) -> Option<HashMap<NodeId, u32>> {
    let ports: Vec<NodeId> = g.plio_nodes().map(|n| n.id).collect();
    let mut columns = HashMap::new();
    fn bt(
        idx: usize,
        ports: &[NodeId],
        g: &MappedGraph,
        placement: &Placement,
        spec: &PlioSpec,
        rc_west: u32,
        rc_east: u32,
        columns: &mut HashMap<NodeId, u32>,
    ) -> bool {
        if idx == ports.len() {
            return check(g, placement, columns, spec, rc_west, rc_east);
        }
        for &col in &spec.columns {
            columns.insert(ports[idx], col);
            // prune: partial assignment must not already violate capacity
            let dir = g.nodes[ports[idx]].plio_dir().unwrap();
            let cap_ok = columns
                .iter()
                .filter(|(id, c)| {
                    g.nodes[**id].plio_dir() == Some(dir) && **c == col
                })
                .count()
                <= spec.channels_per_column as usize;
            if cap_ok
                && bt(
                    idx + 1,
                    ports,
                    g,
                    placement,
                    spec,
                    rc_west,
                    rc_east,
                    columns,
                )
            {
                return true;
            }
            columns.remove(&ports[idx]);
        }
        false
    }
    if bt(
        0,
        &ports,
        g,
        placement,
        spec,
        rc_west,
        rc_east,
        &mut columns,
    ) {
        Some(columns)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::{AieArray, Coord};
    use crate::graph::edge::{Edge, EdgeKind};
    use crate::graph::node::{Node, NodeKind};
    use crate::plio::assignment::assign;
    use crate::polyhedral::dependence::DepKind;

    /// 2×2 systolic toy with 2 in + 2 out PLIOs on a 4-column array.
    fn toy() -> (MappedGraph, Placement, PlioSpec) {
        let mut g = MappedGraph {
            replica: (2, 2),
            replicas: 1,
            ..Default::default()
        };
        for (i, (r, c)) in [(0u32, 0u32), (0, 1), (1, 0), (1, 1)].iter().enumerate() {
            g.nodes.push(Node {
                id: i,
                kind: NodeKind::Aie {
                    virt: Coord::new(*r, *c),
                },
                name: format!("k_r0_{r}_{c}"),
            });
        }
        for (id, dir, name) in [
            (4usize, crate::arch::plio::PlioDir::In, "in0"),
            (5, crate::arch::plio::PlioDir::In, "in1"),
            (6, crate::arch::plio::PlioDir::Out, "out0"),
            (7, crate::arch::plio::PlioDir::Out, "out1"),
        ] {
            g.nodes.push(Node {
                id,
                kind: NodeKind::Plio { dir },
                name: name.into(),
            });
        }
        g.edges = vec![
            Edge::new(4, 0, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(5, 2, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(1, 6, EdgeKind::Stream, "C", DepKind::Output, 1.0),
            Edge::new(3, 7, EdgeKind::Stream, "C", DepKind::Output, 1.0),
        ];
        let mut p = Placement::default();
        p.coords.insert(0, Coord::new(0, 1));
        p.coords.insert(1, Coord::new(0, 2));
        p.coords.insert(2, Coord::new(1, 1));
        p.coords.insert(3, Coord::new(1, 2));
        let spec = PlioSpec {
            in_channels: 4,
            out_channels: 4,
            columns: vec![0, 1, 2, 3],
            channels_per_column: 1,
            ..PlioSpec::default()
        };
        (g, p, spec)
    }

    #[test]
    fn exhaustive_finds_feasible_toy() {
        let (g, p, spec) = toy();
        let cols = exhaustive_assign(&g, &p, &spec, 2, 2).expect("feasible");
        assert!(check(&g, &p, &cols, &spec, 2, 2));
    }

    #[test]
    fn greedy_matches_exhaustive_feasibility() {
        let (g, p, spec) = toy();
        let greedy = assign(&g, &p, &spec, 2, 2);
        let exact = exhaustive_assign(&g, &p, &spec, 2, 2);
        assert_eq!(greedy.feasible, exact.is_some());
        if greedy.feasible {
            assert!(check(&g, &p, &greedy.columns, &spec, 2, 2));
        }
    }

    #[test]
    fn infeasible_when_rc_zero_and_columns_misaligned() {
        let (g, p, mut spec) = toy();
        // only one column available: every stream must cross boundaries,
        // rc = 0 forbids all crossings
        spec.columns = vec![0];
        spec.channels_per_column = 4;
        assert!(exhaustive_assign(&g, &p, &spec, 0, 0).is_none());
        let greedy = assign(&g, &p, &spec, 0, 0);
        assert!(!greedy.feasible);
    }

    #[test]
    fn check_rejects_overfull_columns() {
        let (g, p, spec) = toy();
        let mut cols = HashMap::new();
        for n in g.plio_nodes() {
            cols.insert(n.id, 0u32); // all on column 0; capacity 1/dir
        }
        assert!(!check(&g, &p, &cols, &spec, 10, 10));
    }

    #[test]
    fn toy_array_sanity() {
        let (g, p, _) = toy();
        assert!(p.is_valid(&AieArray::default()));
        assert_eq!(g.num_aies(), 4);
    }
}
