//! Satisfiability checking for PLIO assignments.
//!
//! The paper formulates PLIO assignment as a satisfiability problem over
//! the congestion constraints; [`check`] verifies an assignment, and
//! [`exhaustive_assign`] finds a feasible assignment by backtracking —
//! exponential, so only usable on small instances, where it serves as
//! ground truth for the greedy (property tests compare the two).

use super::congestion::congestion;
use crate::arch::plio::{PlioDir, PlioSpec};
use crate::graph::builder::MappedGraph;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use std::collections::HashMap;

/// Verify `columns` against capacity and congestion bounds.
pub fn check(
    g: &MappedGraph,
    placement: &Placement,
    columns: &HashMap<NodeId, u32>,
    spec: &PlioSpec,
    rc_west: u32,
    rc_east: u32,
) -> bool {
    // per-column, per-direction capacity: a flat tally, two lanes per
    // column (in / out are distinct hardware channels)
    let num_cols = spec.columns.iter().copied().max().unwrap_or(0) + 1;
    let mut used = vec![0u32; 2 * num_cols as usize];
    for n in g.plio_nodes() {
        let Some(&col) = columns.get(&n.id) else {
            return false;
        };
        if !spec.columns.contains(&col) {
            return false;
        }
        // a non-PLIO node in the port set constrains no channel — skip
        // it rather than panic (unreachable from `plio_nodes`, but the
        // port set invariant is worth asserting in debug builds)
        let Some(dir) = n.plio_dir() else {
            debug_assert!(false, "non-PLIO node {} in the PLIO port set", n.id);
            continue;
        };
        let lane = match dir {
            PlioDir::In => 0,
            PlioDir::Out => 1,
        };
        let u = &mut used[2 * col as usize + lane];
        *u += 1;
        if *u > spec.channels_per_column {
            return false;
        }
    }
    congestion(g, placement, columns, num_cols).within(rc_west, rc_east)
}

/// Backtracking search for a feasible assignment (small instances only).
///
/// Each port carries its direction from the moment the port set is built
/// — the search never re-derives it by indexing `g.nodes`, so a graph
/// whose node ids drifted from their indices (the historical vector for
/// non-PLIO nodes leaking into the port set) degrades gracefully instead
/// of panicking.
pub fn exhaustive_assign(
    g: &MappedGraph,
    placement: &Placement,
    spec: &PlioSpec,
    rc_west: u32,
    rc_east: u32,
) -> Option<HashMap<NodeId, u32>> {
    let ports: Vec<(NodeId, PlioDir)> = g
        .plio_nodes()
        .filter_map(|n| match n.plio_dir() {
            Some(dir) => Some((n.id, dir)),
            None => {
                debug_assert!(false, "non-PLIO node {} in the PLIO port set", n.id);
                None
            }
        })
        .collect();
    let mut columns = HashMap::new();
    fn bt(
        idx: usize,
        ports: &[(NodeId, PlioDir)],
        g: &MappedGraph,
        placement: &Placement,
        spec: &PlioSpec,
        rc_west: u32,
        rc_east: u32,
        columns: &mut HashMap<NodeId, u32>,
    ) -> bool {
        if idx == ports.len() {
            return check(g, placement, columns, spec, rc_west, rc_east);
        }
        let (id, dir) = ports[idx];
        for &col in &spec.columns {
            columns.insert(id, col);
            // prune: partial assignment must not already violate capacity
            // (only ports[..=idx] are assigned at this point)
            let cap_ok = ports[..=idx]
                .iter()
                .filter(|(pid, pdir)| *pdir == dir && columns.get(pid) == Some(&col))
                .count()
                <= spec.channels_per_column as usize;
            if cap_ok
                && bt(
                    idx + 1,
                    ports,
                    g,
                    placement,
                    spec,
                    rc_west,
                    rc_east,
                    columns,
                )
            {
                return true;
            }
            columns.remove(&id);
        }
        false
    }
    if bt(
        0,
        &ports,
        g,
        placement,
        spec,
        rc_west,
        rc_east,
        &mut columns,
    ) {
        Some(columns)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::{AieArray, Coord};
    use crate::graph::edge::{Edge, EdgeKind};
    use crate::graph::node::{Node, NodeKind};
    use crate::plio::assignment::assign;
    use crate::polyhedral::dependence::DepKind;

    /// 2×2 systolic toy with 2 in + 2 out PLIOs on a 4-column array.
    fn toy() -> (MappedGraph, Placement, PlioSpec) {
        let mut g = MappedGraph {
            replica: (2, 2),
            replicas: 1,
            ..Default::default()
        };
        for (i, (r, c)) in [(0u32, 0u32), (0, 1), (1, 0), (1, 1)].iter().enumerate() {
            g.nodes.push(Node {
                id: i,
                kind: NodeKind::Aie {
                    virt: Coord::new(*r, *c),
                },
                name: format!("k_r0_{r}_{c}"),
            });
        }
        for (id, dir, name) in [
            (4usize, crate::arch::plio::PlioDir::In, "in0"),
            (5, crate::arch::plio::PlioDir::In, "in1"),
            (6, crate::arch::plio::PlioDir::Out, "out0"),
            (7, crate::arch::plio::PlioDir::Out, "out1"),
        ] {
            g.nodes.push(Node {
                id,
                kind: NodeKind::Plio { dir },
                name: name.into(),
            });
        }
        g.edges = vec![
            Edge::new(4, 0, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(5, 2, EdgeKind::Stream, "A", DepKind::Read, 1.0),
            Edge::new(1, 6, EdgeKind::Stream, "C", DepKind::Output, 1.0),
            Edge::new(3, 7, EdgeKind::Stream, "C", DepKind::Output, 1.0),
        ];
        let mut p = Placement::default();
        p.insert(0, Coord::new(0, 1));
        p.insert(1, Coord::new(0, 2));
        p.insert(2, Coord::new(1, 1));
        p.insert(3, Coord::new(1, 2));
        let spec = PlioSpec {
            in_channels: 4,
            out_channels: 4,
            columns: vec![0, 1, 2, 3],
            channels_per_column: 1,
            ..PlioSpec::default()
        };
        (g, p, spec)
    }

    #[test]
    fn exhaustive_finds_feasible_toy() {
        let (g, p, spec) = toy();
        let cols = exhaustive_assign(&g, &p, &spec, 2, 2).expect("feasible");
        assert!(check(&g, &p, &cols, &spec, 2, 2));
    }

    #[test]
    fn greedy_matches_exhaustive_feasibility() {
        let (g, p, spec) = toy();
        let greedy = assign(&g, &p, &spec, 2, 2);
        let exact = exhaustive_assign(&g, &p, &spec, 2, 2);
        assert_eq!(greedy.feasible, exact.is_some());
        if greedy.feasible {
            assert!(check(&g, &p, &greedy.columns, &spec, 2, 2));
        }
    }

    #[test]
    fn infeasible_when_rc_zero_and_columns_misaligned() {
        let (g, p, mut spec) = toy();
        // only one column available: every stream must cross boundaries,
        // rc = 0 forbids all crossings
        spec.columns = vec![0];
        spec.channels_per_column = 4;
        assert!(exhaustive_assign(&g, &p, &spec, 0, 0).is_none());
        let greedy = assign(&g, &p, &spec, 0, 0);
        assert!(!greedy.feasible);
    }

    #[test]
    fn check_rejects_overfull_columns() {
        let (g, p, spec) = toy();
        let mut cols = HashMap::new();
        for n in g.plio_nodes() {
            cols.insert(n.id, 0u32); // all on column 0; capacity 1/dir
        }
        assert!(!check(&g, &p, &cols, &spec, 10, 10));
    }

    #[test]
    fn stale_node_ids_do_not_panic_the_port_set() {
        // Regression: a hand-built graph whose PLIO node id drifted from
        // its index — the leak vector that used to surface a non-PLIO
        // node in the port set and panic `plio_dir().unwrap()` when the
        // search re-derived directions by indexing `g.nodes`. The search
        // must terminate gracefully and stay consistent with its own
        // checker; the greedy must not panic either.
        let (mut g, p, spec) = toy();
        g.nodes[4].id = 0; // "in0" now claims the id of an AIE node
        if let Some(cols) = exhaustive_assign(&g, &p, &spec, 2, 2) {
            assert!(check(&g, &p, &cols, &spec, 2, 2));
        }
        let greedy = assign(&g, &p, &spec, 2, 2);
        // no panic is the contract; feasibility is whatever the corrupt
        // topology implies
        let _ = greedy.feasible;
    }

    #[test]
    fn toy_array_sanity() {
        let (g, p, _) = toy();
        assert!(p.is_valid(&AieArray::default()));
        assert_eq!(g.num_aies(), 4);
    }
}
