//! Algorithm 1: routing-aware PLIO assignment.
//!
//! For each PLIO port, collect the columns of its connected AIE cores,
//! take the median, and claim the nearest still-available interface-
//! column slot. The median balances west/east crossings around the port
//! — the greedy that "generates an optimal placement for the PLIO ports,
//! ensuring successful routing on the NoC".

use super::congestion::{congestion, CongestionProfile};
use crate::arch::plio::{PlioDir, PlioSpec};
use crate::graph::builder::MappedGraph;
use crate::graph::node::NodeId;
use crate::place_route::placement::Placement;
use std::collections::HashMap;

/// Result: a column per PLIO node plus the final congestion profile.
#[derive(Debug, Clone)]
pub struct PlioAssignment {
    pub columns: HashMap<NodeId, u32>,
    pub congestion: CongestionProfile,
    /// Whether the congestion satisfies the routing-resource bounds.
    pub feasible: bool,
}

/// Per-column slot availability (each direction budgeted separately).
/// The occupancy tally is a flat vector indexed by column — the interface
/// row is a fixed, small strip, so there is nothing to hash.
struct Slots {
    capacity: u32,
    used: Vec<u32>,
    columns: Vec<u32>,
}

impl Slots {
    fn new(spec: &PlioSpec) -> Self {
        let width = spec.columns.iter().map(|&c| c as usize + 1).max().unwrap_or(0);
        Self {
            capacity: spec.channels_per_column,
            used: vec![0; width],
            columns: spec.columns.clone(),
        }
    }

    /// Nearest column to `want` with a free slot (Algorithm 1's
    /// find_nearest + remove).
    fn claim_nearest(&mut self, want: u32) -> Option<u32> {
        let mut best: Option<(u32, u32)> = None; // (distance, col)
        for &col in &self.columns {
            if self.used[col as usize] >= self.capacity {
                continue;
            }
            let d = col.abs_diff(want);
            if best.map_or(true, |(bd, bc)| d < bd || (d == bd && col < bc)) {
                best = Some((d, col));
            }
        }
        let (_, col) = best?;
        self.used[col as usize] += 1;
        Some(col)
    }
}

/// Run Algorithm 1 over all PLIO nodes of the graph. Ports are processed
/// in descending connectivity (most-constrained first), inputs and
/// outputs drawing from separate slot pools (in/out channels are distinct
/// hardware).
pub fn assign(
    g: &MappedGraph,
    placement: &Placement,
    spec: &PlioSpec,
    rc_west: u32,
    rc_east: u32,
) -> PlioAssignment {
    let mut in_slots = Slots::new(spec);
    let mut out_slots = Slots::new(spec);

    // (node, connected AIE columns) per PLIO, most-connected first.
    let mut ports: Vec<(NodeId, PlioDir, Vec<u32>)> = g
        .plio_nodes()
        .filter_map(|n| {
            // skip (don't panic on) anything that is not actually a PLIO
            // port — same port-set invariant as `plio::sat`
            let dir = n.plio_dir()?;
            let mut cols: Vec<u32> = g
                .plio_neighbours(n.id)
                .into_iter()
                .filter_map(|a| placement.col(a))
                .collect();
            cols.sort_unstable();
            Some((n.id, dir, cols))
        })
        .collect();
    ports.sort_by(|a, b| b.2.len().cmp(&a.2.len()).then(a.0.cmp(&b.0)));

    let mut columns = HashMap::new();
    for (id, dir, cols) in ports {
        // median of connected AIE columns (Algorithm 1 lines 3–11)
        let want = if cols.is_empty() {
            spec.columns.first().copied().unwrap_or(0)
        } else {
            cols[cols.len() / 2]
        };
        let slots = match dir {
            PlioDir::In => &mut in_slots,
            PlioDir::Out => &mut out_slots,
        };
        if let Some(col) = slots.claim_nearest(want) {
            columns.insert(id, col);
        }
    }

    let num_cols = spec.columns.iter().copied().max().unwrap_or(0) + 1;
    let prof = congestion(g, placement, &columns, num_cols);
    let feasible =
        columns.len() == g.plio_nodes().count() && prof.within(rc_west, rc_east);
    PlioAssignment {
        columns,
        congestion: prof,
        feasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::array::AieArray;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::graph::packet::merge_ports;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::place_route::placement::place;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn assigned(
        rec: crate::recurrence::spec::UniformRecurrence,
        cap: u64,
    ) -> (MappedGraph, PlioAssignment) {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        let pl = place(&g, &AieArray::default()).unwrap();
        let a = assign(&g, &pl, &board.plio, board.array.rc_west, board.array.rc_east);
        (g, a)
    }

    #[test]
    fn mm_assignment_feasible_at_full_array() {
        let (g, a) = assigned(library::mm(8192, 8192, 8192, DType::F32), 400);
        assert_eq!(a.columns.len(), g.plio_nodes().count());
        assert!(
            a.feasible,
            "W {} E {} over budget",
            a.congestion.max_west(),
            a.congestion.max_east()
        );
    }

    #[test]
    fn conv_assignment_feasible() {
        let (_, a) = assigned(library::conv2d(10240, 10240, 8, 8, DType::I8), 400);
        assert!(a.feasible);
    }

    #[test]
    fn fir_assignment_feasible() {
        let (_, a) = assigned(library::fir(1048576, 15, DType::F32), 256);
        assert!(a.feasible);
    }

    #[test]
    fn slots_respect_per_column_capacity() {
        let (_, a) = assigned(library::mm(8192, 8192, 8192, DType::I8), 400);
        let mut per_col: HashMap<u32, u32> = HashMap::new();
        for &c in a.columns.values() {
            *per_col.entry(c).or_default() += 1;
        }
        // 2 per direction per column → ≤ 4 total
        for (col, n) in per_col {
            assert!(n <= 4, "column {col} hosts {n} ports");
        }
    }

    #[test]
    fn median_placement_beats_leftmost() {
        // Compare Algorithm 1 congestion against a naive leftmost packing.
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(8192, 8192, 8192, DType::F32), &board, &cons).unwrap();
        let model = CostModel::new(board.clone());
        let (g, _) = merge_ports(&build(&cand, &model), model.channel_bw());
        let pl = place(&g, &AieArray::default()).unwrap();
        let smart = assign(&g, &pl, &board.plio, 6, 6);

        // Naive: every port to the leftmost available column slot.
        let mut naive_cols = HashMap::new();
        let mut used: HashMap<u32, u32> = HashMap::new();
        for n in g.plio_nodes() {
            let col = (0..50)
                .find(|c| used.get(c).copied().unwrap_or(0) < 4)
                .unwrap();
            *used.entry(col).or_default() += 1;
            naive_cols.insert(n.id, col);
        }
        let naive = congestion(&g, &pl, &naive_cols, 50);
        let smart_max = smart.congestion.max_west().max(smart.congestion.max_east());
        let naive_max = naive.max_west().max(naive.max_east());
        assert!(
            smart_max < naive_max,
            "Algorithm 1 ({smart_max}) should beat leftmost ({naive_max})"
        );
    }
}
