//! Packet-switch merging and broadcast (paper Figure 4).
//!
//! PLIO ports are scarce (78 per direction); the builder emits one port
//! per logical stream, and this pass merges low-rate streams onto shared
//! ports via packet switching: streams whose combined sustained rate fits
//! within a port's usable bandwidth share a `packet_group`, and the
//! merged graph keeps one PLIO node per group.
//!
//! [`predict_ports`] is the *incremental* counterpart: it computes the
//! [`MergeStats`] this pass would realise for a candidate directly from
//! the candidate's space-time transform and mover shape — bit-identical
//! to [`merge_ports_with_budget`] on the built graph, but without
//! materializing any graph. The DSE ranks every candidate with it
//! (see [`crate::mapping::cost::PortModel`]), which is what closes the
//! analytic-vs-exact port gap the paper's §IV routing-aware assignment
//! depends on.

use super::builder::{stream_rates, MappedGraph, PortRates};
use super::edge::EdgeKind;
use super::node::{NodeId, NodeKind};
use crate::arch::plio::PlioDir;
use crate::mapping::candidate::MappingCandidate;
use crate::mapping::cost::CostModel;

/// Usable fraction of a port's bandwidth when packet-switched (header +
/// arbitration overhead).
pub const PACKET_UTIL: f64 = 0.8;
/// Hardware fan-in limit per port (packet-switch IDs; two chained stages).
pub const MAX_FANIN: usize = 8;

/// Merge result statistics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergeStats {
    pub in_ports_before: usize,
    pub in_ports_after: usize,
    pub out_ports_before: usize,
    pub out_ports_after: usize,
}

/// Merge PLIO ports of one direction. `port_bw` is the effective channel
/// bandwidth (mover-limited). Returns the new graph and stats.
pub fn merge_ports(g: &MappedGraph, port_bw: f64) -> (MappedGraph, MergeStats) {
    merge_ports_with_budget(g, port_bw, 78, 78)
}

/// As [`merge_ports`], but force the result under per-direction channel
/// budgets: when rate-based packing needs more ports than exist, fan-in
/// is raised (up to [`MAX_FANIN`]) and the oversubscribed streams simply
/// run slower — exactly the PLIO-bound regime the cost model prices.
pub fn merge_ports_with_budget(
    g: &MappedGraph,
    port_bw: f64,
    in_budget: usize,
    out_budget: usize,
) -> (MappedGraph, MergeStats) {
    let mut out = g.clone();
    let stats_before = (
        g.plio_count(PlioDir::In),
        g.plio_count(PlioDir::Out),
    );

    // One pass over the edges builds everything the packing needs:
    // per-node non-broadcast rate and the (col, row) locality key of the
    // first AIE neighbour (§Perf: the previous per-port O(E) rescans made
    // this the framework's hottest path).
    let mut rate_of = vec![0f64; out.nodes.len()];
    let mut loc_of = vec![(u32::MAX, u32::MAX); out.nodes.len()];
    for e in &out.edges {
        let (plio, aie) = if out.nodes[e.src].is_plio() && out.nodes[e.dst].is_aie() {
            (e.src, e.dst)
        } else if out.nodes[e.dst].is_plio() && out.nodes[e.src].is_aie() {
            (e.dst, e.src)
        } else {
            continue;
        };
        if e.kind != EdgeKind::Broadcast {
            rate_of[plio] += e.rate;
        }
        if let Some(c) = out.nodes[aie].virt() {
            let key = (c.col, c.row);
            if key < loc_of[plio] {
                loc_of[plio] = key;
            }
        }
    }

    for dir in [PlioDir::In, PlioDir::Out] {
        let budget = match dir {
            PlioDir::In => in_budget,
            PlioDir::Out => out_budget,
        };
        // (plio node, total rate) pairs, skipping broadcasts (they
        // already occupy a single port).
        let ports: Vec<(NodeId, f64)> = out
            .nodes
            .iter()
            .filter(|n| n.plio_dir() == Some(dir))
            .map(|n| (n.id, rate_of[n.id]))
            .filter(|(_, r)| *r > 0.0)
            .collect();

        // Locality-first packing: sort ports by the (column, row) of their
        // connected AIEs so consecutive streams share a column, then
        // first-fit into ports of capacity port_bw × PACKET_UTIL with
        // ≤ MAX_FANIN members. Same-column grouping is what keeps the
        // Algorithm-1 congestion low: a port placed at its members'
        // column routes almost fully vertically.
        let mut sorted = ports.clone();
        sorted.sort_by_key(|(id, _)| loc_of[*id]);
        let cap = port_bw * PACKET_UTIL;
        // Minimum fan-in forced by the channel budget (streams must fit
        // even if that oversubscribes port bandwidth — PLIO-bound regime).
        let forced_fanin = sorted.len().div_ceil(budget.max(1)).clamp(1, MAX_FANIN);
        let mut bins: Vec<(f64, Vec<NodeId>)> = Vec::new();
        for (id, rate) in sorted {
            // only try the most recent bin (keeps groups contiguous in
            // column order)
            let fits = bins.last().is_some_and(|(used, members)| {
                members.len() < MAX_FANIN
                    && (members.len() < forced_fanin || *used + rate <= cap)
            });
            if fits {
                let (used, members) = bins.last_mut().unwrap();
                *used += rate;
                members.push(id);
            } else {
                bins.push((rate, vec![id]));
            }
        }

        // Rewire: members of a bin redirect their edges to the bin head;
        // merged nodes become orphans (dropped below). Single pass over
        // the edges via a redirect table (was O(bins × members × E)).
        let mut redirect: Vec<Option<(NodeId, u32)>> = vec![None; out.nodes.len()];
        for (gid, (_, members)) in bins.iter().enumerate() {
            let head = members[0];
            for &m in members {
                redirect[m] = Some((head, gid as u32));
            }
        }
        for e in out.edges.iter_mut() {
            if let Some((head, gid)) = redirect[e.src] {
                e.src = head;
                e.packet_group = Some(gid);
            }
            if let Some((head, gid)) = redirect[e.dst] {
                e.dst = head;
                e.packet_group = Some(gid);
            }
        }
    }

    // Drop orphaned PLIO nodes and reindex.
    let used: std::collections::HashSet<NodeId> = out
        .edges
        .iter()
        .flat_map(|e| [e.src, e.dst])
        .collect();
    let mut remap = vec![usize::MAX; out.nodes.len()];
    let mut nodes = Vec::new();
    for n in &out.nodes {
        let keep = match n.kind {
            NodeKind::Aie { .. } => true,
            NodeKind::Plio { .. } => used.contains(&n.id),
        };
        if keep {
            remap[n.id] = nodes.len();
            let mut n2 = n.clone();
            n2.id = nodes.len();
            nodes.push(n2);
        }
    }
    for e in out.edges.iter_mut() {
        e.src = remap[e.src];
        e.dst = remap[e.dst];
    }
    out.nodes = nodes;

    let stats = MergeStats {
        in_ports_before: stats_before.0,
        out_ports_before: stats_before.1,
        in_ports_after: out.plio_count(PlioDir::In),
        out_ports_after: out.plio_count(PlioDir::Out),
    };
    (out, stats)
}

/// Predict the exact [`MergeStats`] that [`merge_ports_with_budget`]
/// produces for `cand`'s built graph, **without materializing the graph**
/// — the cheap incremental port count the DSE ranks candidates with.
///
/// The prediction replays the packing loop over a synthesized port
/// sequence in the builder's locality-sort order, using the same
/// per-stream rates ([`stream_rates`]) the builder stamps on edges, so
/// the result is bit-identical to merging the real graph (validated on
/// every candidate of all 14 Table II recurrences — see
/// `tests/divergence_corpus.rs`). Cost is O(ports) with no allocation
/// beyond one small rate vector for the mixed-rate MM input side.
pub fn predict_ports(
    cand: &MappingCandidate,
    model: &CostModel,
    port_bw: f64,
    in_budget: usize,
    out_budget: usize,
) -> MergeStats {
    let (r, c) = cand.replica_shape();
    let f = cand.threading.factor.max(1) as usize;
    let active = cand.partition.active_aies() as usize;
    let cap = port_bw * PACKET_UTIL;
    match stream_rates(cand, model) {
        PortRates::Systolic { a, b, c: c_rate } => {
            let (r, c) = (r as usize, c as usize);
            // Input side mixes two rate classes (A row feeds, B column
            // feeds), so replay the packing over the exact sorted
            // sequence. Locality keys are (col, row) of the fed core:
            // A_i feeds (i, 0) → key (0, i); B_j feeds (0, j) → key
            // (j, 0). Sorted stably, with node order breaking ties:
            //   key (0,0): A_0, B_0 of each replica in replica order,
            //   keys (0,i) i≥1: A_i per replica,
            //   keys (j,0) j≥1: B_j per replica.
            let n_in = (r + c) * f;
            let mut rates = Vec::with_capacity(n_in);
            for _ in 0..f {
                rates.push(a);
                rates.push(b);
            }
            for _ in 1..r {
                for _ in 0..f {
                    rates.push(a);
                }
            }
            for _ in 1..c {
                for _ in 0..f {
                    rates.push(b);
                }
            }
            let in_after = pack_count(&rates, forced_fanin(n_in, in_budget), cap);
            // Output side: one C drain per core, all at one rate — the
            // bin count is order-independent.
            let n_out = active * f;
            let out_after = equal_rate_bins(n_out, c_rate, forced_fanin(n_out, out_budget), cap);
            MergeStats {
                in_ports_before: n_in,
                in_ports_after: in_after,
                out_ports_before: n_out,
                out_ports_after: out_after,
            }
        }
        PortRates::BroadcastReduce { b, c: c_rate } => {
            // Input side: R B-row feeds per threading replica, all at one
            // rate (the zero-rate A broadcast per replica is skipped by
            // the merge's rate>0 filter and survives untouched). Output
            // side: one reduced C drain per column, all at one rate.
            let (r, c) = (r as usize, c as usize);
            let n_in = r * f;
            let n_out = c * f;
            let in_after = equal_rate_bins(n_in, b, forced_fanin(n_in, in_budget), cap) + f;
            let out_after = equal_rate_bins(n_out, c_rate, forced_fanin(n_out, out_budget), cap);
            MergeStats {
                in_ports_before: n_in + f,
                in_ports_after: in_after,
                out_ports_before: n_out,
                out_ports_after: out_after,
            }
        }
        PortRates::Private { rate } => {
            // One private in + out stream per core at one rate; the
            // zero-rate broadcast port per replica is never merged and
            // survives into the merged graph's input count.
            let n = active * f;
            let bcast = if active > 0 { f } else { 0 };
            let in_after = equal_rate_bins(n, rate, forced_fanin(n, in_budget), cap) + bcast;
            let out_after = equal_rate_bins(n, rate, forced_fanin(n, out_budget), cap);
            MergeStats {
                in_ports_before: n + bcast,
                in_ports_after: in_after,
                out_ports_before: n,
                out_ports_after: out_after,
            }
        }
    }
}

/// Minimum fan-in forced by the channel budget — the same expression
/// [`merge_ports_with_budget`] applies to its sorted port list.
fn forced_fanin(len: usize, budget: usize) -> usize {
    len.div_ceil(budget.max(1)).clamp(1, MAX_FANIN)
}

/// Replay the merge's first-fit packing over a pre-sorted rate sequence,
/// returning the bin (= merged port) count. Float accumulation order is
/// identical to the merge loop's, so the counts cannot drift.
fn pack_count(sorted: &[f64], forced_fanin: usize, cap: f64) -> usize {
    let mut bins = 0usize;
    let mut used = 0f64;
    let mut members = 0usize;
    for &rate in sorted {
        let fits =
            bins > 0 && members < MAX_FANIN && (members < forced_fanin || used + rate <= cap);
        if fits {
            used += rate;
            members += 1;
        } else {
            bins += 1;
            used = rate;
            members = 1;
        }
    }
    bins
}

/// Bin count when every stream has the same rate: each bin fills
/// identically, so simulating one bin's fill (≤ [`MAX_FANIN`] additions,
/// same accumulation as the merge loop) gives the uniform bin size.
fn equal_rate_bins(n: usize, rate: f64, forced_fanin: usize, cap: f64) -> usize {
    if n == 0 {
        return 0;
    }
    let mut used = rate;
    let mut members = 1usize;
    while members < MAX_FANIN && (members < forced_fanin || used + rate <= cap) {
        used += rate;
        members += 1;
    }
    n.div_ceil(members)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn merged(rec: crate::recurrence::spec::UniformRecurrence, cap: u64) -> (MappedGraph, MergeStats) {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board);
        let g = build(&cand, &model);
        merge_ports(&g, model.channel_bw())
    }

    #[test]
    fn mm_c_drains_merge_under_budget() {
        let (g, stats) = merged(library::mm(8192, 8192, 8192, DType::F32), 400);
        assert_eq!(stats.out_ports_before, 400);
        assert!(
            stats.out_ports_after <= 78,
            "C drains must fit the PLIO budget: {}",
            stats.out_ports_after
        );
        assert!(g.plio_count(PlioDir::Out) == stats.out_ports_after);
    }

    #[test]
    fn conv_private_streams_merge() {
        let (_, stats) = merged(library::conv2d(10240, 10240, 8, 8, DType::I8), 400);
        assert!(stats.in_ports_after < stats.in_ports_before);
        assert!(
            stats.in_ports_after <= 78,
            "in ports {} over budget",
            stats.in_ports_after
        );
        assert!(stats.out_ports_after <= 78);
    }

    #[test]
    fn merge_preserves_aie_count_and_edges() {
        let (g0, _) = {
            let board = BoardConfig::vck5000();
            let cons = DseConstraints {
                max_aies: Some(256),
                ..Default::default()
            };
            let (cand, _) = explore(&library::fir(1048576, 15, DType::F32), &board, &cons).unwrap();
            let model = CostModel::new(board);
            let g = build(&cand, &model);
            let n_aie = g.num_aies();
            let n_edges = g.edges.len();
            let (gm, st) = merge_ports(&g, model.channel_bw());
            assert_eq!(gm.num_aies(), n_aie);
            assert_eq!(gm.edges.len(), n_edges);
            (gm, st)
        };
        // all edge endpoints valid after reindexing
        for e in &g0.edges {
            assert!(e.src < g0.nodes.len());
            assert!(e.dst < g0.nodes.len());
            assert_eq!(g0.nodes[e.src].id, e.src);
        }
    }

    #[test]
    fn predictor_matches_merge_on_representative_designs() {
        // bit-identical predictor vs real merge across workload families
        // and budgets (the full Table II sweep lives in
        // tests/divergence_corpus.rs)
        let board = BoardConfig::vck5000();
        for (rec, cap) in [
            (library::mm(8192, 8192, 8192, DType::F32), 400u64),
            (library::mm(2048, 2048, 2048, DType::I8), 400),
            (library::conv2d(10240, 10240, 8, 8, DType::I8), 400),
            (library::fir(1048576, 15, DType::F32), 256),
            (library::fft2d(8192, 8192, DType::CF32), 320),
            (library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32), 400),
            (library::ca_mm_blockrec(512, 3, DType::F32), 400),
        ] {
            let cons = DseConstraints {
                max_aies: Some(cap),
                ..Default::default()
            };
            let (cand, _) = explore(&rec, &board, &cons).unwrap();
            let model = CostModel::new(board.clone());
            let g = build(&cand, &model);
            for (in_b, out_b) in [(78usize, 78usize), (16, 16), (4, 4)] {
                let (_, stats) = merge_ports_with_budget(&g, model.channel_bw(), in_b, out_b);
                let predicted = predict_ports(&cand, &model, model.channel_bw(), in_b, out_b);
                assert_eq!(
                    predicted, stats,
                    "{} budget {}x{}: predicted != merged",
                    rec.name, in_b, out_b
                );
            }
        }
    }

    #[test]
    fn predictor_is_exact_for_every_candidate_shape() {
        // sweep *all* DSE candidates of a small MM — this covers 1D
        // serpentine folds (possibly with a partial last row) and
        // threading replicas > 1, where the replica-interleaved sort
        // order is hardest to get right
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let model = CostModel::new(board.clone());
        for rec in [
            library::mm(512, 512, 512, DType::F32),
            library::conv2d(1024, 1024, 4, 4, DType::I16),
            library::ca_mm_25d(512, 512, 512, 4, DType::F32),
        ] {
            for (cand, _) in crate::mapping::dse::explore_all(&rec, &board, &cons) {
                let g = build(&cand, &model);
                let (_, stats) = merge_ports_with_budget(&g, model.channel_bw(), 78, 78);
                let predicted = predict_ports(&cand, &model, model.channel_bw(), 78, 78);
                assert_eq!(predicted, stats, "{}", cand.summary());
            }
        }
    }

    #[test]
    fn fanin_limit_respected() {
        let (g, _) = merged(library::conv2d(10240, 10240, 4, 4, DType::I16), 400);
        use std::collections::HashMap;
        let mut fanin: HashMap<usize, usize> = HashMap::new();
        for e in &g.edges {
            if g.nodes[e.src].is_plio() && e.kind != EdgeKind::Broadcast {
                *fanin.entry(e.src).or_default() += 1;
            }
        }
        for (p, n) in fanin {
            assert!(n <= MAX_FANIN, "port {p} fanin {n}");
        }
    }
}
