//! Packet-switch merging and broadcast (paper Figure 4).
//!
//! PLIO ports are scarce (78 per direction); the builder emits one port
//! per logical stream, and this pass merges low-rate streams onto shared
//! ports via packet switching: streams whose combined sustained rate fits
//! within a port's usable bandwidth share a `packet_group`, and the
//! merged graph keeps one PLIO node per group.

use super::builder::MappedGraph;
use super::edge::EdgeKind;
use super::node::{NodeId, NodeKind};
use crate::arch::plio::PlioDir;

/// Usable fraction of a port's bandwidth when packet-switched (header +
/// arbitration overhead).
pub const PACKET_UTIL: f64 = 0.8;
/// Hardware fan-in limit per port (packet-switch IDs; two chained stages).
pub const MAX_FANIN: usize = 8;

/// Merge result statistics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergeStats {
    pub in_ports_before: usize,
    pub in_ports_after: usize,
    pub out_ports_before: usize,
    pub out_ports_after: usize,
}

/// Merge PLIO ports of one direction. `port_bw` is the effective channel
/// bandwidth (mover-limited). Returns the new graph and stats.
pub fn merge_ports(g: &MappedGraph, port_bw: f64) -> (MappedGraph, MergeStats) {
    merge_ports_with_budget(g, port_bw, 78, 78)
}

/// As [`merge_ports`], but force the result under per-direction channel
/// budgets: when rate-based packing needs more ports than exist, fan-in
/// is raised (up to [`MAX_FANIN`]) and the oversubscribed streams simply
/// run slower — exactly the PLIO-bound regime the cost model prices.
pub fn merge_ports_with_budget(
    g: &MappedGraph,
    port_bw: f64,
    in_budget: usize,
    out_budget: usize,
) -> (MappedGraph, MergeStats) {
    let mut out = g.clone();
    let stats_before = (
        g.plio_count(PlioDir::In),
        g.plio_count(PlioDir::Out),
    );

    // One pass over the edges builds everything the packing needs:
    // per-node non-broadcast rate and the (col, row) locality key of the
    // first AIE neighbour (§Perf: the previous per-port O(E) rescans made
    // this the framework's hottest path).
    let mut rate_of = vec![0f64; out.nodes.len()];
    let mut loc_of = vec![(u32::MAX, u32::MAX); out.nodes.len()];
    for e in &out.edges {
        let (plio, aie) = if out.nodes[e.src].is_plio() && out.nodes[e.dst].is_aie() {
            (e.src, e.dst)
        } else if out.nodes[e.dst].is_plio() && out.nodes[e.src].is_aie() {
            (e.dst, e.src)
        } else {
            continue;
        };
        if e.kind != EdgeKind::Broadcast {
            rate_of[plio] += e.rate;
        }
        if let Some(c) = out.nodes[aie].virt() {
            let key = (c.col, c.row);
            if key < loc_of[plio] {
                loc_of[plio] = key;
            }
        }
    }

    for dir in [PlioDir::In, PlioDir::Out] {
        let budget = match dir {
            PlioDir::In => in_budget,
            PlioDir::Out => out_budget,
        };
        // (plio node, total rate) pairs, skipping broadcasts (they
        // already occupy a single port).
        let ports: Vec<(NodeId, f64)> = out
            .nodes
            .iter()
            .filter(|n| n.plio_dir() == Some(dir))
            .map(|n| (n.id, rate_of[n.id]))
            .filter(|(_, r)| *r > 0.0)
            .collect();

        // Locality-first packing: sort ports by the (column, row) of their
        // connected AIEs so consecutive streams share a column, then
        // first-fit into ports of capacity port_bw × PACKET_UTIL with
        // ≤ MAX_FANIN members. Same-column grouping is what keeps the
        // Algorithm-1 congestion low: a port placed at its members'
        // column routes almost fully vertically.
        let mut sorted = ports.clone();
        sorted.sort_by_key(|(id, _)| loc_of[*id]);
        let cap = port_bw * PACKET_UTIL;
        // Minimum fan-in forced by the channel budget (streams must fit
        // even if that oversubscribes port bandwidth — PLIO-bound regime).
        let forced_fanin = sorted.len().div_ceil(budget.max(1)).clamp(1, MAX_FANIN);
        let mut bins: Vec<(f64, Vec<NodeId>)> = Vec::new();
        for (id, rate) in sorted {
            // only try the most recent bin (keeps groups contiguous in
            // column order)
            let fits = bins.last().is_some_and(|(used, members)| {
                members.len() < MAX_FANIN
                    && (members.len() < forced_fanin || *used + rate <= cap)
            });
            if fits {
                let (used, members) = bins.last_mut().unwrap();
                *used += rate;
                members.push(id);
            } else {
                bins.push((rate, vec![id]));
            }
        }

        // Rewire: members of a bin redirect their edges to the bin head;
        // merged nodes become orphans (dropped below). Single pass over
        // the edges via a redirect table (was O(bins × members × E)).
        let mut redirect: Vec<Option<(NodeId, u32)>> = vec![None; out.nodes.len()];
        for (gid, (_, members)) in bins.iter().enumerate() {
            let head = members[0];
            for &m in members {
                redirect[m] = Some((head, gid as u32));
            }
        }
        for e in out.edges.iter_mut() {
            if let Some((head, gid)) = redirect[e.src] {
                e.src = head;
                e.packet_group = Some(gid);
            }
            if let Some((head, gid)) = redirect[e.dst] {
                e.dst = head;
                e.packet_group = Some(gid);
            }
        }
    }

    // Drop orphaned PLIO nodes and reindex.
    let used: std::collections::HashSet<NodeId> = out
        .edges
        .iter()
        .flat_map(|e| [e.src, e.dst])
        .collect();
    let mut remap = vec![usize::MAX; out.nodes.len()];
    let mut nodes = Vec::new();
    for n in &out.nodes {
        let keep = match n.kind {
            NodeKind::Aie { .. } => true,
            NodeKind::Plio { .. } => used.contains(&n.id),
        };
        if keep {
            remap[n.id] = nodes.len();
            let mut n2 = n.clone();
            n2.id = nodes.len();
            nodes.push(n2);
        }
    }
    for e in out.edges.iter_mut() {
        e.src = remap[e.src];
        e.dst = remap[e.dst];
    }
    out.nodes = nodes;

    let stats = MergeStats {
        in_ports_before: stats_before.0,
        out_ports_before: stats_before.1,
        in_ports_after: out.plio_count(PlioDir::In),
        out_ports_after: out.plio_count(PlioDir::Out),
    };
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::graph::builder::build;
    use crate::mapping::cost::CostModel;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn merged(rec: crate::recurrence::spec::UniformRecurrence, cap: u64) -> (MappedGraph, MergeStats) {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board);
        let g = build(&cand, &model);
        merge_ports(&g, model.channel_bw())
    }

    #[test]
    fn mm_c_drains_merge_under_budget() {
        let (g, stats) = merged(library::mm(8192, 8192, 8192, DType::F32), 400);
        assert_eq!(stats.out_ports_before, 400);
        assert!(
            stats.out_ports_after <= 78,
            "C drains must fit the PLIO budget: {}",
            stats.out_ports_after
        );
        assert!(g.plio_count(PlioDir::Out) == stats.out_ports_after);
    }

    #[test]
    fn conv_private_streams_merge() {
        let (_, stats) = merged(library::conv2d(10240, 10240, 8, 8, DType::I8), 400);
        assert!(stats.in_ports_after < stats.in_ports_before);
        assert!(
            stats.in_ports_after <= 78,
            "in ports {} over budget",
            stats.in_ports_after
        );
        assert!(stats.out_ports_after <= 78);
    }

    #[test]
    fn merge_preserves_aie_count_and_edges() {
        let (g0, _) = {
            let board = BoardConfig::vck5000();
            let cons = DseConstraints {
                max_aies: Some(256),
                ..Default::default()
            };
            let (cand, _) = explore(&library::fir(1048576, 15, DType::F32), &board, &cons).unwrap();
            let model = CostModel::new(board);
            let g = build(&cand, &model);
            let n_aie = g.num_aies();
            let n_edges = g.edges.len();
            let (gm, st) = merge_ports(&g, model.channel_bw());
            assert_eq!(gm.num_aies(), n_aie);
            assert_eq!(gm.edges.len(), n_edges);
            (gm, st)
        };
        // all edge endpoints valid after reindexing
        for e in &g0.edges {
            assert!(e.src < g0.nodes.len());
            assert!(e.dst < g0.nodes.len());
            assert_eq!(g0.nodes[e.src].id, e.src);
        }
    }

    #[test]
    fn fanin_limit_respected() {
        let (g, _) = merged(library::conv2d(10240, 10240, 4, 4, DType::I16), 400);
        use std::collections::HashMap;
        let mut fanin: HashMap<usize, usize> = HashMap::new();
        for e in &g.edges {
            if g.nodes[e.src].is_plio() && e.kind != EdgeKind::Broadcast {
                *fanin.entry(e.src).or_default() += 1;
            }
        }
        for (p, n) in fanin {
            assert!(n <= MAX_FANIN, "port {p} fanin {n}");
        }
    }
}
