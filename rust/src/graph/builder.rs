//! Build the mapped graph for one round of a mapping candidate.
//!
//! Follows §III-C-1: iterate the space coordinates, create an AIE node
//! per coordinate, derive inter-core edges from the dependences' space
//! projections (constant, non-zero distance ⇒ neighbour edge through the
//! shared buffer), and attach PLIO ports for boundary inputs, outputs and
//! zero-distance (broadcast) inputs. Flow dependences are realised as
//! inputs (AIEs keep no state between graph iterations). Packet-switch
//! merging ([`super::packet`]) brings port counts under the budget.

use super::edge::{Edge, EdgeKind};
use super::node::{Node, NodeId, NodeKind};
use crate::arch::array::Coord;
use crate::arch::plio::PlioDir;
use crate::mapping::candidate::{Kind, MappingCandidate};
use crate::mapping::cost::CostModel;
use crate::polyhedral::dependence::DepKind;

/// The mapped graph: nodes, edges and the replica grid layout.
///
/// **Dense-index invariant:** node ids are contiguous indices into
/// `nodes` (`nodes[i].id == i`) — `MappedGraph::add_node` hands out
/// `nodes.len()` and nothing may renumber afterwards. The whole P&R hot
/// path (the annealer's flat coordinate/incidence arrays, the congestion
/// model's pair bitset, [`crate::place_route::placement::Placement`],
/// codegen's kernel-index table) indexes vectors by `NodeId` on the
/// strength of this; check with [`MappedGraph::node_ids_are_dense`]
/// when constructing graphs by hand.
#[derive(Debug, Clone, Default)]
pub struct MappedGraph {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    /// Rows × cols of one replica.
    pub replica: (u32, u32),
    /// Number of threading replicas.
    pub replicas: u32,
}

impl MappedGraph {
    pub fn aie_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.is_aie())
    }

    pub fn plio_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.is_plio())
    }

    pub fn plio_count(&self, dir: PlioDir) -> usize {
        self.plio_nodes().filter(|n| n.plio_dir() == Some(dir)).count()
    }

    pub fn num_aies(&self) -> usize {
        self.aie_nodes().count()
    }

    /// AIE nodes adjacent (by an edge) to a given PLIO node.
    pub fn plio_neighbours(&self, plio: NodeId) -> Vec<NodeId> {
        self.edges
            .iter()
            .filter_map(|e| {
                if e.src == plio {
                    Some(e.dst)
                } else if e.dst == plio {
                    Some(e.src)
                } else {
                    None
                }
            })
            .filter(|&n| self.nodes[n].is_aie())
            .collect()
    }

    /// Every node id equals its index — the dense-index invariant the
    /// P&R hot path relies on (true for every builder-produced graph;
    /// hand-built test graphs can drift and should assert this).
    pub fn node_ids_are_dense(&self) -> bool {
        self.nodes.iter().enumerate().all(|(i, n)| n.id == i)
    }

    fn add_node(&mut self, kind: NodeKind, name: String) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, kind, name });
        id
    }
}

/// Per-stream sustained PLIO rates of `cand`'s mapped graph — exactly the
/// rates [`build`] stamps on its stream edges, computed without the graph.
/// Shared with [`crate::graph::packet::predict_ports`] so the incremental
/// port predictor can never diverge from the built graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PortRates {
    /// Systolic MM: row feeds (`a`), column feeds (`b`), per-core drains
    /// (`c`).
    Systolic { a: f64, b: f64, c: f64 },
    /// Private-stream families (Conv2d / FIR / FFT): one input and one
    /// output stream per core at the same sustained rate, plus one
    /// zero-rate broadcast input per replica.
    Private { rate: f64 },
    /// Communication-avoiding replicated-summand MM: one `A` broadcast
    /// per threading replica, `B` slab feeds at rate `b` (one per
    /// replication row, propagating east), and the partial-`C` reduction
    /// chain down the replication axis draining one stream per column at
    /// rate `c`.
    BroadcastReduce { b: f64, c: f64 },
}

/// Derive the per-stream rates for `cand` from the cost model's step time
/// (the mover shape and kernel-level calibration both enter through
/// `model`).
pub fn stream_rates(cand: &MappingCandidate, model: &CostModel) -> PortRates {
    let core = &model.board.array.core;
    let eff = crate::mapping::cost::issue_efficiency(cand.kind, cand.rec.dtype)
        * cand.latency.efficiency(core);
    let step_s = cand.scope.core_macs.max(1) as f64
        / (core.macs_per_cycle(cand.rec.dtype) as f64 * core.freq_hz * eff);
    let b = cand.rec.dtype.bytes();
    let t = &cand.scope.core_factors;
    match cand.kind {
        Kind::Mm => {
            let a_rate = (t[0] * t[2] * b) as f64 / step_s;
            let b_rate = (t[2] * t[1] * b) as f64 / step_s;
            let steps = cand.time_steps_per_round().max(1);
            let c_rate = (t[0] * t[1] * b) as f64 / (step_s * steps as f64);
            PortRates::Systolic {
                a: a_rate,
                b: b_rate,
                c: c_rate,
            }
        }
        Kind::CaMm => {
            // B[k-slab, j-tile] streams along each replication row (same
            // tile-per-step cadence as MM's feeds); the reduced C column
            // drains once per round like MM's per-core C, but only from
            // the bottom replication row.
            let b_rate = (t[2] * t[1] * b) as f64 / step_s;
            let steps = cand.time_steps_per_round().max(1);
            let c_rate = (t[0] * t[1] * b) as f64 / (step_s * steps as f64);
            PortRates::BroadcastReduce {
                b: b_rate,
                c: c_rate,
            }
        }
        Kind::Conv2d | Kind::Fir | Kind::Fft2d | Kind::DwConv2d | Kind::Trsv | Kind::Stencil => {
            let unique_in = match cand.kind {
                Kind::Conv2d => t[0] * t[1] * b,
                Kind::Fir => t[0] * b,
                // per-group spatial tile (halo via DMA, kernels broadcast)
                Kind::DwConv2d => t[0] * t[1] * t[2] * b,
                // the L tile dominates; x rides along
                Kind::Trsv => (t[0] * t[1] + t[1]) * b,
                // grid tile per sweep (±1 halo via DMA)
                Kind::Stencil => t[1] * t[2] * b,
                _ => {
                    let cols = cand.rec.domain.dims[3].extent * 2;
                    cols * b
                }
            };
            PortRates::Private {
                rate: unique_in as f64 / step_s,
            }
        }
    }
}

/// Build the mapped graph for `cand` (one round of the physical array,
/// all threading replicas included).
pub fn build(cand: &MappingCandidate, model: &CostModel) -> MappedGraph {
    let (r, c) = cand.replica_shape();
    let f = cand.threading.factor.max(1) as u32;
    let mut g = MappedGraph {
        replica: (r as u32, c as u32),
        replicas: f,
        ..Default::default()
    };

    // Per-step stream rates shared with the port predictor.
    let rates = stream_rates(cand, model);

    // 1D partitions fold serpentine into (r, c) but may not fill the last
    // row: build exactly `active` cores per replica. CA designs replicate
    // the partitioned chain across rows — every slot of the (replicate ×
    // active) block holds a core.
    let active = match cand.kind {
        Kind::CaMm => r * c,
        _ => cand.partition.active_aies(),
    };
    for rep in 0..f {
        // AIE nodes of this replica (usize::MAX = absent slot).
        let mut ids = vec![vec![usize::MAX; c as usize]; r as usize];
        let mut built = 0u64;
        'rows: for i in 0..r as u32 {
            for j in 0..c as u32 {
                if built == active {
                    break 'rows;
                }
                let id = g.add_node(
                    NodeKind::Aie {
                        virt: Coord::new(i, j),
                    },
                    format!("k_r{rep}_{i}_{j}"),
                );
                ids[i as usize][j as usize] = id;
                built += 1;
            }
        }

        match cand.kind {
            Kind::Mm => {
                let PortRates::Systolic {
                    a: a_rate,
                    b: b_rate,
                    c: c_rate,
                } = rates
                else {
                    unreachable!("MM candidates have systolic rates");
                };
                // The serpentine fold fills row-major, so a partially
                // filled box (1D spaces whose extent is not a multiple of
                // the column count) leaves absent slots only as a suffix
                // of the last row: column 0 of every row and all of row 0
                // always hold cores, and chain walks stop at the first
                // absent slot.
                // A flows east along rows; enters at column 0.
                for i in 0..r as usize {
                    if ids[i][0] == usize::MAX {
                        continue;
                    }
                    let p = g.add_node(
                        NodeKind::Plio { dir: PlioDir::In },
                        format!("A_in_r{rep}_{i}"),
                    );
                    g.edges
                        .push(Edge::new(p, ids[i][0], EdgeKind::Stream, "A", DepKind::Read, a_rate));
                    for j in 0..c as usize - 1 {
                        if ids[i][j + 1] == usize::MAX {
                            break;
                        }
                        g.edges.push(Edge::new(
                            ids[i][j],
                            ids[i][j + 1],
                            EdgeKind::SharedBuffer,
                            "A",
                            DepKind::Read,
                            a_rate,
                        ));
                    }
                }
                // B flows south along columns; enters at row 0.
                for j in 0..c as usize {
                    if ids[0][j] == usize::MAX {
                        continue;
                    }
                    let p = g.add_node(
                        NodeKind::Plio { dir: PlioDir::In },
                        format!("B_in_r{rep}_{j}"),
                    );
                    g.edges
                        .push(Edge::new(p, ids[0][j], EdgeKind::Stream, "B", DepKind::Read, b_rate));
                    for i in 0..r as usize - 1 {
                        if ids[i + 1][j] == usize::MAX {
                            break;
                        }
                        g.edges.push(Edge::new(
                            ids[i][j],
                            ids[i + 1][j],
                            EdgeKind::SharedBuffer,
                            "B",
                            DepKind::Read,
                            b_rate,
                        ));
                    }
                }
                // C drains per core (flow dep is carried in-core along k;
                // the output dependence terminates at a PLIO port).
                for i in 0..r as usize {
                    for j in 0..c as usize {
                        if ids[i][j] == usize::MAX {
                            continue;
                        }
                        let p = g.add_node(
                            NodeKind::Plio { dir: PlioDir::Out },
                            format!("C_out_r{rep}_{i}_{j}"),
                        );
                        g.edges.push(Edge::new(
                            ids[i][j],
                            p,
                            EdgeKind::Stream,
                            "C",
                            DepKind::Output,
                            c_rate,
                        ));
                    }
                }
            }
            Kind::CaMm => {
                let PortRates::BroadcastReduce {
                    b: b_rate,
                    c: c_rate,
                } = rates
                else {
                    unreachable!("CA candidates have broadcast-reduce rates");
                };
                // The replicated block is always full (active = r × c), so
                // no absent-slot checks are needed here.
                //
                // A k-slabs broadcast to the whole block: every core in
                // replication row i works the same A[*, k-slab i] panel,
                // and one port time-multiplexes the R slabs. Broadcast
                // edges carry the usual negligible sustained rate — the
                // real A bandwidth is priced by the cost model's traffic
                // accounting, and zero-rate ports survive packet merging
                // untouched, which keeps the port predictor exact.
                let bc = g.add_node(
                    NodeKind::Plio { dir: PlioDir::In },
                    format!("A_bcast_r{rep}"),
                );
                for i in 0..r as usize {
                    for j in 0..c as usize {
                        g.edges.push(Edge::new(
                            bc,
                            ids[i][j],
                            EdgeKind::Broadcast,
                            "A",
                            DepKind::Read,
                            1e3, // negligible sustained rate
                        ));
                    }
                }
                // B slab rows: edge-fed at column 0, propagating east —
                // MM's systolic feed, one per replication row.
                for i in 0..r as usize {
                    let p = g.add_node(
                        NodeKind::Plio { dir: PlioDir::In },
                        format!("B_in_r{rep}_{i}"),
                    );
                    g.edges
                        .push(Edge::new(p, ids[i][0], EdgeKind::Stream, "B", DepKind::Read, b_rate));
                    for j in 0..c as usize - 1 {
                        g.edges.push(Edge::new(
                            ids[i][j],
                            ids[i][j + 1],
                            EdgeKind::SharedBuffer,
                            "B",
                            DepKind::Read,
                            b_rate,
                        ));
                    }
                }
                // Partial-sum reduction down the replication axis: each
                // column's partials flow south through shared buffers and
                // only the bottom row drains to PLIO — this is the mover
                // shape that collapses MM's per-core C drains to one port
                // per column.
                for j in 0..c as usize {
                    for i in 0..r as usize - 1 {
                        g.edges.push(Edge::new(
                            ids[i][j],
                            ids[i + 1][j],
                            EdgeKind::SharedBuffer,
                            "C",
                            DepKind::Flow,
                            c_rate,
                        ));
                    }
                    let p = g.add_node(
                        NodeKind::Plio { dir: PlioDir::Out },
                        format!("C_out_r{rep}_{j}"),
                    );
                    g.edges.push(Edge::new(
                        ids[r as usize - 1][j],
                        p,
                        EdgeKind::Stream,
                        "C",
                        DepKind::Output,
                        c_rate,
                    ));
                }
            }
            Kind::Conv2d | Kind::Fir | Kind::Fft2d | Kind::DwConv2d | Kind::Trsv
            | Kind::Stencil => {
                // Private in/out per core + one broadcast input (weights /
                // taps / twiddles / stencil coefficients / rhs vector).
                let (in_name, out_name, bc_name) = match cand.kind {
                    Kind::Conv2d => ("X", "Y", "K"),
                    Kind::Fir => ("x", "y", "h"),
                    Kind::DwConv2d => ("X", "Y", "K"),
                    Kind::Trsv => ("L", "x", "b"),
                    Kind::Stencil => ("A", "A_next", "coef"),
                    _ => ("row", "row_out", "W"),
                };
                let PortRates::Private { rate } = rates else {
                    unreachable!("private-stream candidates have private rates");
                };
                let bc = g.add_node(
                    NodeKind::Plio { dir: PlioDir::In },
                    format!("{bc_name}_bcast_r{rep}"),
                );
                for i in 0..r as usize {
                    for j in 0..c as usize {
                        if ids[i][j] == usize::MAX {
                            continue;
                        }
                        let pin = g.add_node(
                            NodeKind::Plio { dir: PlioDir::In },
                            format!("{in_name}_in_r{rep}_{i}_{j}"),
                        );
                        let pout = g.add_node(
                            NodeKind::Plio { dir: PlioDir::Out },
                            format!("{out_name}_out_r{rep}_{i}_{j}"),
                        );
                        g.edges.push(Edge::new(
                            pin,
                            ids[i][j],
                            EdgeKind::Stream,
                            in_name,
                            DepKind::Read,
                            rate,
                        ));
                        g.edges.push(Edge::new(
                            ids[i][j],
                            pout,
                            EdgeKind::Stream,
                            out_name,
                            DepKind::Output,
                            rate,
                        ));
                        g.edges.push(Edge::new(
                            bc,
                            ids[i][j],
                            EdgeKind::Broadcast,
                            bc_name,
                            DepKind::Read,
                            1e3, // negligible sustained rate
                        ));
                    }
                }
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn build_for(rec: crate::recurrence::spec::UniformRecurrence, cap: u64) -> MappedGraph {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        build(&cand, &CostModel::new(board))
    }

    #[test]
    fn mm_graph_shape() {
        let g = build_for(library::mm(8192, 8192, 8192, DType::F32), 400);
        assert_eq!(g.num_aies(), 400);
        // A row feeds + B col feeds in; C out per core
        assert_eq!(g.plio_count(PlioDir::In), 8 + 50);
        assert_eq!(g.plio_count(PlioDir::Out), 400);
        // systolic shared-buffer edges: A: 8×49, B: 7×50
        let shared = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer)
            .count();
        assert_eq!(shared, 8 * 49 + 7 * 50);
    }

    #[test]
    fn conv_graph_has_private_streams_and_broadcast() {
        let g = build_for(library::conv2d(10240, 10240, 4, 4, DType::F32), 400);
        let aies = g.num_aies();
        assert_eq!(g.plio_count(PlioDir::In), aies + 1); // + broadcast
        assert_eq!(g.plio_count(PlioDir::Out), aies);
        let bcast = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::Broadcast)
            .count();
        assert_eq!(bcast, aies);
    }

    #[test]
    fn ca_graph_is_broadcast_reduce_shaped() {
        let g = build_for(library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32), 400);
        assert!(g.node_ids_are_dense());
        let f = g.replicas as usize;
        let (r, c) = (g.replica.0 as usize, g.replica.1 as usize);
        assert_eq!(r, 4, "replication occupies the rows");
        assert!(c >= 2, "the chain spans at least two columns");
        // every slot of the replicated block holds a core
        assert_eq!(g.num_aies(), f * r * c);
        // in: one A broadcast + R B-row feeds per threading replica
        assert_eq!(g.plio_count(PlioDir::In), f * (1 + r));
        // out: one reduced C drain per column per threading replica —
        // not per core, that is the whole point of the reduction chain
        assert_eq!(g.plio_count(PlioDir::Out), f * c);
        let bcast = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::Broadcast)
            .count();
        assert_eq!(bcast, f * r * c);
        // reduction edges: (r - 1) per column; B propagation: (c - 1) per row
        let reduce = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer && e.array == "C")
            .count();
        assert_eq!(reduce, f * (r - 1) * c);
        let b_prop = g
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::SharedBuffer && e.array == "B")
            .count();
        assert_eq!(b_prop, f * r * (c - 1));
        for e in &g.edges {
            assert!(e.rate > 0.0);
        }
    }

    #[test]
    fn plio_neighbours_reported() {
        let g = build_for(library::mm(1024, 1024, 1024, DType::F32), 400);
        for p in g.plio_nodes() {
            let nb = g.plio_neighbours(p.id);
            assert!(!nb.is_empty(), "PLIO {} disconnected", p.name);
        }
    }

    #[test]
    fn edge_rates_positive() {
        let g = build_for(library::fir(1048576, 15, DType::F32), 256);
        for e in &g.edges {
            assert!(e.rate > 0.0);
        }
    }

    #[test]
    fn new_families_build_private_stream_graphs() {
        for rec in [
            library::dw_conv2d(64, 256, 256, 3, 3, DType::F32),
            library::trsv(8192, DType::F32),
            library::stencil2d_chain(2, 1024, 1024, DType::F32),
        ] {
            let name = rec.name.clone();
            let g = build_for(rec, 400);
            let aies = g.num_aies();
            assert!(aies > 0, "{name}");
            // per-core private in/out + one broadcast per replica
            assert_eq!(g.plio_count(PlioDir::In), aies + g.replicas as usize, "{name}");
            assert_eq!(g.plio_count(PlioDir::Out), aies, "{name}");
            assert!(g.node_ids_are_dense(), "{name}");
            for e in &g.edges {
                assert!(e.rate > 0.0, "{name}");
            }
        }
    }
}
