//! Mapped-graph construction (paper §III-C-1, Figure 4).
//!
//! Turns an abstract [`crate::mapping::MappingCandidate`] into the
//! concrete dataflow graph the AIE compiler consumes: one node per AIE
//! kernel instance and per PLIO port, edges for every stream, with
//! packet-switch merging and broadcast applied so the PLIO budget holds.

pub mod builder;
pub mod edge;
pub mod node;
pub mod packet;

pub use builder::{build, MappedGraph};
pub use edge::{Edge, EdgeKind};
pub use node::{Node, NodeId, NodeKind};
