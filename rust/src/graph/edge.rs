//! Edges of the mapped graph: streams between kernels and ports.

use super::node::NodeId;
use crate::polyhedral::dependence::DepKind;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Neighbour-to-neighbour transfer via shared buffer (AIE DMA).
    SharedBuffer,
    /// Stream over the NoC (PLIO↔AIE or packet-switched).
    Stream,
    /// Broadcast stream (one source fanning out to many).
    Broadcast,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub kind: EdgeKind,
    /// Which array's data this stream carries.
    pub array: String,
    /// The dependence class that created the edge.
    pub dep: DepKind,
    /// Sustained bytes per second this edge must carry.
    pub rate: f64,
    /// Packet-switch group: edges sharing a group share one PLIO port.
    pub packet_group: Option<u32>,
}

impl Edge {
    pub fn new(
        src: NodeId,
        dst: NodeId,
        kind: EdgeKind,
        array: impl Into<String>,
        dep: DepKind,
        rate: f64,
    ) -> Self {
        Self {
            src,
            dst,
            kind,
            array: array.into(),
            dep,
            rate,
            packet_group: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_construction() {
        let e = Edge::new(0, 1, EdgeKind::Stream, "A", DepKind::Read, 1e9);
        assert_eq!(e.src, 0);
        assert_eq!(e.packet_group, None);
        assert_eq!(e.kind, EdgeKind::Stream);
    }
}
