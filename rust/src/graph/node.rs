//! Nodes of the mapped graph: AIE kernel instances and PLIO ports.

use crate::arch::array::Coord;
use crate::arch::plio::PlioDir;

pub type NodeId = usize;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeKind {
    /// An AIE kernel instance at a virtual systolic coordinate.
    Aie {
        /// Virtual (row, col) in the systolic space (one round's worth).
        virt: Coord,
    },
    /// A PLIO port endpoint (column assigned later by Algorithm 1).
    Plio { dir: PlioDir },
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Node {
    pub id: NodeId,
    pub kind: NodeKind,
    /// Human-readable instance name (stable across codegen).
    pub name: String,
}

impl Node {
    pub fn is_aie(&self) -> bool {
        matches!(self.kind, NodeKind::Aie { .. })
    }

    pub fn is_plio(&self) -> bool {
        matches!(self.kind, NodeKind::Plio { .. })
    }

    pub fn virt(&self) -> Option<Coord> {
        match self.kind {
            NodeKind::Aie { virt } => Some(virt),
            _ => None,
        }
    }

    pub fn plio_dir(&self) -> Option<PlioDir> {
        match self.kind {
            NodeKind::Plio { dir } => Some(dir),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_kind_queries() {
        let a = Node {
            id: 0,
            kind: NodeKind::Aie {
                virt: Coord::new(1, 2),
            },
            name: "k_1_2".into(),
        };
        let p = Node {
            id: 1,
            kind: NodeKind::Plio { dir: PlioDir::In },
            name: "pi0".into(),
        };
        assert!(a.is_aie() && !a.is_plio());
        assert_eq!(a.virt(), Some(Coord::new(1, 2)));
        assert!(p.is_plio());
        assert_eq!(p.plio_dir(), Some(PlioDir::In));
        assert_eq!(a.plio_dir(), None);
    }
}
