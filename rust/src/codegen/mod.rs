//! Heterogeneous-backend code generation (paper Figure 5): the AIE
//! kernel C++ ([`aie_kernel`]), the ADF graph with location constraints
//! ([`adf_graph`]), the PL DMA-mover HLS C++ ([`pl_dma`]) and the host
//! XRT program ([`host`]). The output is the source bundle the real
//! toolchain (aiecompiler + v++ + g++) would consume; on this testbed
//! its structure is validated by tests and its *behaviour* is what the
//! functional executor replays through the AOT kernels.

pub mod adf_graph;
pub mod aie_kernel;
pub mod host;
pub mod pl_dma;

use crate::graph::builder::MappedGraph;
use crate::mapping::MappingCandidate;
use crate::place_route::compiler::CompileOutcome;

/// The generated source bundle.
#[derive(Debug, Clone, Default)]
pub struct CodeBundle {
    pub aie_kernel: String,
    pub adf_graph: String,
    pub pl_dma: String,
    pub host: String,
    pub constraints_json: String,
}

impl CodeBundle {
    /// Write the bundle into a directory (one file per backend).
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("kernel.cc"), &self.aie_kernel)?;
        std::fs::write(dir.join("graph.cpp"), &self.adf_graph)?;
        std::fs::write(dir.join("dma_mover.cpp"), &self.pl_dma)?;
        std::fs::write(dir.join("host.cpp"), &self.host)?;
        std::fs::write(dir.join("constraints.json"), &self.constraints_json)?;
        Ok(())
    }
}

/// Generate all backends for a compiled design.
pub fn generate(
    cand: &MappingCandidate,
    graph: &MappedGraph,
    compile: &CompileOutcome,
) -> CodeBundle {
    CodeBundle {
        aie_kernel: aie_kernel::generate(cand),
        adf_graph: adf_graph::generate(cand, graph, compile),
        pl_dma: pl_dma::generate(cand, graph),
        host: host::generate(cand),
        constraints_json: compile
            .constraints
            .as_ref()
            .map(|c| c.to_json())
            .unwrap_or_default(),
    }
}
