//! The WideSA systolic mapping engine (paper §III).
//!
//! Pipeline: [`spacetime`] enumerates legal space-time transformations of
//! the graph-level loop nest (§III-B-1); [`partition`] tiles the space
//! loops onto the physical array shape (§III-B-2); [`latency`] applies
//! latency hiding to cover the MAC pipeline (§III-B-3); [`threading`]
//! unrolls parallelizable time loops across spare AIEs (§III-B-4);
//! [`cost`] scores each [`candidate::MappingCandidate`] with the analytic
//! performance model; [`dse`] runs the whole enumeration and picks the
//! best legal mapping under the board's resource budgets.
//!
//! Paper map:
//!
//! | module        | paper                                             |
//! |---------------|---------------------------------------------------|
//! | [`spacetime`] | §III-B-1 space-time transformation                |
//! | [`partition`] | §III-B-2 array partition                          |
//! | [`latency`]   | §III-B-3 latency hiding                           |
//! | [`threading`] | §III-B-4 multiple threading                       |
//! | [`cost`]      | analytic model behind Table III / Figure 6        |
//! | [`dse`]       | the "optimal schedule" search of §II-B / §III-B   |

pub mod candidate;
pub mod cost;
pub mod dse;
pub mod latency;
pub mod partition;
pub mod spacetime;
pub mod threading;

pub use candidate::MappingCandidate;
pub use cost::{CostModel, Estimate, PerfBound, PerfEstimate, PortModel};
pub use dse::{explore, DseConstraints, Objective};
pub use spacetime::SpaceTimeChoice;
