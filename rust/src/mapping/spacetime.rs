//! Space-time transformation (paper §III-B-1).
//!
//! Candidate space loops are the loops of the outermost permutable band
//! whose dependence distances are at most one (a systolic array can only
//! realise neighbour transfers). The mapper enumerates all 1- and
//! 2-element subsets of the candidate pool (the AIE array is physically
//! 2D), permutes the chosen loops outermost, marks the rest as time
//! loops, and keeps only schedules that remain legal.
//!
//! Legality is the two-clause check of
//! [`crate::polyhedral::legality::is_legal_mapping`]: the classic
//! sequential-order clause (everything Table II needs) plus the
//! neighbour-transfer clause that admits the negative spatial offsets of
//! stencil chains. When even that fails — a transfer that regresses in
//! time — the enumerator falls back to a **wavefront skew** of the
//! outermost time loop by the space loops ([`Transform::Skew`], recorded
//! in [`SpaceTimeChoice::skews`]) before giving up on the choice.

use crate::polyhedral::legality::is_legal_mapping;
use crate::polyhedral::schedule::{LoopNest, LoopRole};
use crate::polyhedral::transform::Transform;

/// One space-time choice: which graph-nest loops become space loops.
#[derive(Debug, Clone, PartialEq)]
pub struct SpaceTimeChoice {
    /// Indices (into the *original* graph nest) of the space loops,
    /// ordered (array-row dim first, array-column dim second).
    pub space: Vec<usize>,
    /// Wavefront skews that legalised this choice, applied *after* the
    /// space permutation: `(target, source, factor)` positions in the
    /// permuted nest (`target` is always the outermost time loop,
    /// `source` a space loop). Empty for permute-only choices — every
    /// Table II workload — so summaries and cache behaviour of the
    /// existing corpus are untouched.
    pub skews: Vec<(usize, usize, i64)>,
    /// The transformed nest: space loops outermost, roles assigned,
    /// skews (if any) already applied.
    pub nest: LoopNest,
}

impl SpaceTimeChoice {
    pub fn dims(&self) -> usize {
        self.space.len()
    }

    /// Did legalising this choice require a wavefront skew?
    pub fn is_skewed(&self) -> bool {
        !self.skews.is_empty()
    }
}

/// Loops eligible as space loops: |dependence distance| ≤ 1 on that loop
/// for every dependence (paper: "loops in the outermost loop band with
/// dependence distances no greater than one").
pub fn candidate_space_loops(nest: &LoopNest, graph_loops: &[usize]) -> Vec<usize> {
    graph_loops
        .iter()
        .copied()
        .filter(|&d| nest.max_dep_distance(d) <= 1 && nest.domain.dims[d].extent > 1)
        .collect()
}

/// Enumerate all 1D and 2D space-loop selections that yield a legal
/// sequential order after permuting space outermost. `graph_loops` are
/// the loops in graph scope (kernel-scope loops stay innermost).
pub fn enumerate(nest: &LoopNest, graph_loops: &[usize]) -> Vec<SpaceTimeChoice> {
    let cands = candidate_space_loops(nest, graph_loops);
    let mut out = Vec::new();
    // 2D selections (ordered pairs — row/col assignment matters for the
    // rectangular array) and 1D selections.
    for &a in &cands {
        for &b in &cands {
            if a != b {
                if let Some(c) = build_choice(nest, graph_loops, &[a, b]) {
                    out.push(c);
                }
            }
        }
        if let Some(c) = build_choice(nest, graph_loops, &[a]) {
            out.push(c);
        }
    }
    out
}

fn build_choice(
    nest: &LoopNest,
    graph_loops: &[usize],
    space: &[usize],
) -> Option<SpaceTimeChoice> {
    // New order: space loops, then remaining graph loops (original
    // relative order), then kernel-scope loops.
    let rank = nest.rank();
    let mut order: Vec<usize> = space.to_vec();
    for &g in graph_loops {
        if !space.contains(&g) {
            order.push(g);
        }
    }
    for d in 0..rank {
        if !order.contains(&d) {
            order.push(d);
        }
    }
    let mut permuted = Transform::Permute(order.clone()).apply(nest);
    // Assign roles.
    for (new_pos, &old) in order.iter().enumerate() {
        permuted.roles[new_pos] = if space.contains(&old) {
            LoopRole::Space
        } else if permuted.roles[new_pos] == LoopRole::Kernel {
            LoopRole::Kernel
        } else {
            LoopRole::Time
        };
    }
    // Legality: sequential order (clause 1 — how chained designs are
    // realised) or neighbour transfer with advancing time (clause 2 —
    // stencil halos). See `is_legal_mapping`.
    if is_legal_mapping(&permuted.deps, space.len()) {
        return Some(SpaceTimeChoice {
            space: space.to_vec(),
            skews: vec![],
            nest: permuted,
        });
    }
    legalise_by_skewing(permuted, space)
}

/// Wavefront fallback: skew the outermost time loop by the space loops so
/// transfers that regress in time advance instead (the classic systolic
/// schedule `t' = t + Σ ±s`). Candidate factor sets are tried smallest
/// first and validated by re-running the full legality check — a skew
/// that fixes one dependence but breaks another is rejected wholesale.
/// Returns `None` when no unit-factor wavefront legalises the choice.
fn legalise_by_skewing(permuted: LoopNest, space: &[usize]) -> Option<SpaceTimeChoice> {
    let n_space = space.len();
    let lead = n_space; // position of the outermost time loop
    if n_space == 0 || lead >= permuted.rank() {
        return None;
    }
    let mut plans: Vec<Vec<(usize, usize, i64)>> = Vec::new();
    for s in 0..n_space {
        for f in [1i64, -1] {
            plans.push(vec![(lead, s, f)]);
        }
    }
    if n_space == 2 {
        for f0 in [1i64, -1] {
            for f1 in [1i64, -1] {
                plans.push(vec![(lead, 0, f0), (lead, 1, f1)]);
            }
        }
    }
    for plan in plans {
        let mut nest = permuted.clone();
        for &(target, source, factor) in &plan {
            nest = Transform::Skew {
                target,
                source,
                factor,
            }
            .apply(&nest);
        }
        if is_legal_mapping(&nest.deps, n_space) {
            return Some(SpaceTimeChoice {
                space: space.to_vec(),
                skews: plan,
                nest,
            });
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;
    use crate::recurrence::tiling::demarcate;

    fn mm_graph() -> (LoopNest, Vec<usize>) {
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let scope = demarcate(&rec);
        let loops = scope.graph_loops();
        (scope.graph_nest, loops)
    }

    #[test]
    fn mm_candidates_are_all_graph_loops() {
        let (nest, loops) = mm_graph();
        let cands = candidate_space_loops(&nest, &loops);
        // All three MM tile loops have |d| ≤ 1
        assert_eq!(cands.len(), loops.len());
    }

    #[test]
    fn mm_enumeration_includes_ij_choice() {
        let (nest, loops) = mm_graph();
        let choices = enumerate(&nest, &loops);
        assert!(!choices.is_empty());
        // the canonical (i, j) spatial choice must be present
        assert!(choices.iter().any(|c| c.space.len() == 2));
        // every choice's space loops are marked Space and outermost
        for c in &choices {
            for s in 0..c.space.len() {
                assert_eq!(c.nest.roles[s], LoopRole::Space);
            }
        }
    }

    #[test]
    fn enumeration_counts_1d_and_2d() {
        let (nest, loops) = mm_graph();
        let choices = enumerate(&nest, &loops);
        let n = candidate_space_loops(&nest, &loops).len();
        // ordered pairs + singletons, all legal for MM
        assert_eq!(choices.len(), n * (n - 1) + n);
    }

    #[test]
    fn fir_has_limited_space_choices() {
        let rec = library::fir(1048576, 15, DType::F32);
        let scope = demarcate(&rec);
        let loops = scope.graph_loops();
        let choices = enumerate(&scope.graph_nest, &loops);
        // FIR's tap loop tile usually has extent 1 after demarcation
        // (taps=15 fits in-core), so space choices are over n only.
        assert!(!choices.is_empty());
        for c in &choices {
            assert!(c.dims() <= 2);
        }
    }

    #[test]
    fn stencil_chain_enumerates_via_neighbour_realisation() {
        // The stencil's (1, ±1, 0) / (1, 0, ±1) deps are lex-negative
        // with a grid loop permuted outermost — the old sequential-order
        // check alone would yield an empty choice set. The neighbour
        // clause must admit them, without any skew.
        let rec = library::stencil2d_chain(2, 1024, 1024, DType::F32);
        let scope = demarcate(&rec);
        let loops = scope.graph_loops();
        let choices = enumerate(&scope.graph_nest, &loops);
        assert!(!choices.is_empty(), "stencil must have space-time choices");
        // the 2D grid choice (it, jt) is present and permute-only
        let grid_2d = choices
            .iter()
            .find(|c| c.space == vec![loops[1], loops[2]])
            .expect("(i, j) grid choice must be legal");
        assert!(!grid_2d.is_skewed());
        // and it genuinely relies on the neighbour clause: the permuted
        // dep set is NOT sequentially legal
        assert!(!crate::polyhedral::legality::is_legal_order(&grid_2d.nest.deps));
        assert!(grid_2d
            .nest
            .deps
            .iter()
            .any(|d| d.vector.iter().any(|&c| c < 0)));
    }

    // NOTE: the synthetic wavefront-skew test that lived here moved to
    // tests/integration_workloads.rs (`seidel_is_only_mappable_via_the_
    // skew_fallback`): the Gauss–Seidel sweep chain carries the same
    // time-regressing (0, −1, 0) dependence as a *library* workload, so
    // the fallback is now pinned by a recurrence the DSE actually maps
    // end to end instead of a hand-built nest.

    #[test]
    fn extent1_loops_are_not_space_candidates() {
        let (mut nest, loops) = mm_graph();
        // force one loop to extent 1
        nest.domain.dims[loops[0]].extent = 1;
        let cands = candidate_space_loops(&nest, &loops);
        assert!(!cands.contains(&loops[0]));
    }
}
