//! Design-space exploration: enumerate space-time choices × partitions ×
//! threading factors, score each with the cost model, return the best
//! legal candidate (the "optimal schedule" search of §II-B / §III-B).

use crate::arch::vck5000::BoardConfig;
use crate::mapping::candidate::{Kind, MappingCandidate};
use crate::mapping::cost::{CostModel, PerfEstimate};
use crate::mapping::latency;
use crate::mapping::partition::partition;
use crate::mapping::spacetime;
use crate::mapping::threading;
use crate::recurrence::spec::UniformRecurrence;
use crate::recurrence::tiling::demarcate;

/// Resource constraints for a DSE run (Figure 6 sweeps these).
#[derive(Debug, Clone, Default)]
pub struct DseConstraints {
    /// Cap on AIEs used (None = whole array).
    pub max_aies: Option<u64>,
    /// Disable latency hiding (ablation).
    pub no_latency_hiding: bool,
    /// Disable multiple threading (ablation).
    pub no_threading: bool,
}

/// Explore and return the best candidate with its estimate.
pub fn explore(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) -> Option<(MappingCandidate, PerfEstimate)> {
    explore_all(rec, board, cons).into_iter().next()
}

/// All evaluated candidates, best first.
pub fn explore_all(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) -> Vec<(MappingCandidate, PerfEstimate)> {
    let scope = demarcate(rec);
    let graph_loops = scope.graph_loops();
    let choices = spacetime::enumerate(&scope.graph_nest, &graph_loops);
    let model = CostModel::new(board.clone());
    let budget = cons
        .max_aies
        .unwrap_or(board.array.num_cores() as u64)
        .min(board.array.num_cores() as u64);

    let mut results: Vec<(MappingCandidate, PerfEstimate)> = Vec::new();
    for choice in choices {
        let part = partition(&choice.nest, &choice.space, &board.array, Some(budget));
        let spare = budget / part.active_aies().max(1);
        // Latency hiding plans over the kernel-scope loops of the
        // recurrence's core nest.
        let kernel_nest = rec.loop_nest();
        let lat = if cons.no_latency_hiding {
            latency::LatencyHiding {
                factors: vec![],
                chains: 1,
            }
        } else {
            latency::plan(&kernel_nest, &board.array.core)
        };
        let thr = if cons.no_threading {
            threading::Threading::none()
        } else {
            threading::plan(&choice.nest, spare)
        };
        let cand = MappingCandidate {
            rec: rec.clone(),
            kind: Kind::of(rec),
            scope: scope.clone(),
            choice,
            partition: part,
            latency: lat,
            threading: thr,
        };
        if cand.aies_used() > budget {
            continue;
        }
        let est = model.estimate(&cand);
        results.push((cand, est));
    }
    results.sort_by(|a, b| b.1.tops.partial_cmp(&a.1.tops).unwrap());
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn mm_dse_finds_2d_mapping() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let (cand, est) = explore(&rec, &board, &DseConstraints::default()).unwrap();
        assert_eq!(cand.choice.dims(), 2, "MM should map to a 2D array");
        assert!(est.tops > 1.0);
        assert!(cand.aies_used() <= 400);
    }

    #[test]
    fn dse_respects_aie_budget() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        for budget in [50, 100, 200, 400] {
            let cons = DseConstraints {
                max_aies: Some(budget),
                ..Default::default()
            };
            let (cand, _) = explore(&rec, &board, &cons).unwrap();
            assert!(cand.aies_used() <= budget, "budget {budget}");
        }
    }

    #[test]
    fn throughput_monotone_in_aie_budget() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let mut last = 0.0;
        for budget in [50, 100, 200, 400] {
            let cons = DseConstraints {
                max_aies: Some(budget),
                ..Default::default()
            };
            let (_, est) = explore(&rec, &board, &cons).unwrap();
            assert!(
                est.tops >= last * 0.95,
                "throughput dropped at budget {budget}: {} < {last}",
                est.tops
            );
            last = est.tops;
        }
    }

    #[test]
    fn latency_hiding_ablation_hurts() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let (_, with) = explore(&rec, &board, &DseConstraints::default()).unwrap();
        let (_, without) = explore(
            &rec,
            &board,
            &DseConstraints {
                no_latency_hiding: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            with.tops > without.tops * 1.5,
            "latency hiding should matter: {} vs {}",
            with.tops,
            without.tops
        );
    }

    #[test]
    fn all_candidates_ranked() {
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let board = BoardConfig::vck5000();
        let all = explore_all(&rec, &board, &DseConstraints::default());
        assert!(all.len() >= 3);
        for w in all.windows(2) {
            assert!(w[0].1.tops >= w[1].1.tops);
        }
    }
}
