//! Design-space exploration: enumerate space-time choices × partitions ×
//! threading factors, score each with the cost model, return the best
//! legal candidate (the "optimal schedule" search of §II-B / §III-B).
//!
//! The search is decomposed so callers can shard it: [`plan`] does the
//! per-recurrence setup once (memoized demarcation, space-time
//! enumeration, the loop-invariant latency-hiding plan), [`score_choice`]
//! evaluates one candidate — a pure function of its inputs — and
//! [`rank_by`] merges scored candidates in the canonical order of the
//! run's [`Objective`] (throughput, TOPS/W efficiency, or the
//! [`rank_pareto`] non-dominated frontier). Both [`explore_all`]
//! (serial) and [`explore_all_parallel`] (scoped-thread sharding) are
//! thin drivers over those three, as is the serve layer's worker-pool
//! variant — all produce bit-identical rankings.
//!
//! Candidates are ranked on **exact merged-PLIO port counts** (the
//! incremental predictor behind [`PortModel::Exact`], the
//! [`scoring_model`] default), so the winner is priced exactly as packet
//! merging and place & route will see it; set
//! [`DseConstraints::analytic_ranking`] to A/B against the legacy
//! analytic approximation.

use crate::arch::vck5000::BoardConfig;
use crate::mapping::candidate::{Kind, MappingCandidate};
use crate::mapping::cost::{CostModel, Estimate, PortModel};
use crate::mapping::latency::{self, LatencyHiding};
use crate::mapping::partition::partition;
use crate::mapping::spacetime::{self, SpaceTimeChoice};
use crate::mapping::threading;
use crate::obs::metrics::{self, Counter};
use crate::obs::trace::{self, Span, TraceCtx};
use crate::recurrence::spec::UniformRecurrence;
use crate::recurrence::tiling::{demarcate_cached, KernelScope};
use crate::util::hash::Fnv64;
use std::sync::{Arc, OnceLock};

/// Global-registry counters for DSE volume (`dse.plans`,
/// `dse.candidates_scored`, `dse.candidates_over_budget`): handles are
/// resolved once and cached, so the per-candidate cost is one relaxed
/// `fetch_add`. Counters don't perturb results — scoring stays pure and
/// bit-identical across the serial/scoped/pooled drivers.
struct DseCounters {
    plans: Arc<Counter>,
    scored: Arc<Counter>,
    over_budget: Arc<Counter>,
    over_power: Arc<Counter>,
    frontier: Arc<Counter>,
}

fn counters() -> &'static DseCounters {
    static C: OnceLock<DseCounters> = OnceLock::new();
    C.get_or_init(|| {
        let r = metrics::global();
        DseCounters {
            plans: r.counter("dse.plans"),
            scored: r.counter("dse.candidates_scored"),
            over_budget: r.counter("dse.candidates_over_budget"),
            over_power: r.counter("dse.candidates_over_power"),
            frontier: r.counter("dse.frontier_size"),
        }
    })
}

/// What the DSE optimizes for when ordering scored candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// On-chip TOPS, descending — the paper's Table III ordering and the
    /// historical single-metric ranking. The default: rankings (and
    /// serve cache keys) are unchanged from before power existed.
    #[default]
    Throughput,
    /// TOPS/W, descending (Table IV's metric).
    Efficiency,
    /// Non-dominated (tops, tops_per_watt) frontier first, dominated
    /// candidates after — see [`rank_pareto`].
    Pareto,
}

impl Objective {
    /// Stable wire/fingerprint discriminant (never reorder).
    pub fn discriminant(self) -> u8 {
        match self {
            Objective::Throughput => 0,
            Objective::Efficiency => 1,
            Objective::Pareto => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Objective::Throughput => "throughput",
            Objective::Efficiency => "efficiency",
            Objective::Pareto => "pareto",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "throughput" => Some(Objective::Throughput),
            "efficiency" => Some(Objective::Efficiency),
            "pareto" => Some(Objective::Pareto),
            _ => None,
        }
    }
}

/// Resource constraints for a DSE run (Figure 6 sweeps these).
#[derive(Debug, Clone, Default)]
pub struct DseConstraints {
    /// Cap on AIEs used (None = whole array).
    pub max_aies: Option<u64>,
    /// Disable latency hiding (ablation).
    pub no_latency_hiding: bool,
    /// Disable multiple threading (ablation).
    pub no_threading: bool,
    /// Rank with the legacy analytic port approximation instead of the
    /// exact merged-port predictor (A/B comparison — see
    /// [`PortModel`]).
    pub analytic_ranking: bool,
    /// Drop candidates whose estimated board draw exceeds this cap (W).
    pub max_power_w: Option<f64>,
    /// Ranking objective (throughput / efficiency / Pareto).
    pub objective: Objective,
}

impl DseConstraints {
    /// Fold the constraints into a stable fingerprint (serve cache key).
    ///
    /// Backward compatible by construction: fields at their defaults
    /// write **no bytes**, so `DseConstraints::default()` hashes exactly
    /// as it did before `max_power_w`/`objective` existed and schema-1
    /// `serve::persist` snapshots keep warm-starting (guarded by
    /// `tests/cache_compat.rs`). New fields append tag bytes (2, 3)
    /// disjoint from the legacy `max_aies` tags (0, 1).
    pub fn fingerprint(&self, h: &mut Fnv64) {
        match self.max_aies {
            Some(v) => {
                h.write_u8(1);
                h.write_u64(v);
            }
            None => h.write_u8(0),
        }
        h.write_bool(self.no_latency_hiding);
        h.write_bool(self.no_threading);
        h.write_bool(self.analytic_ranking);
        if let Some(w) = self.max_power_w {
            h.write_u8(2);
            h.write_u64(w.to_bits());
        }
        if self.objective != Objective::Throughput {
            h.write_u8(3);
            h.write_u8(self.objective.discriminant());
        }
    }
}

/// The cost model a DSE run scores with: exact merged-port pricing by
/// default, the legacy analytic packing when
/// [`DseConstraints::analytic_ranking`] is set. Every exploration driver
/// (serial, scoped-thread, serve-pool) builds its model here, so the
/// ranking port model cannot silently diverge between them.
pub fn scoring_model(board: &BoardConfig, cons: &DseConstraints) -> CostModel {
    let model = CostModel::new(board.clone());
    if cons.analytic_ranking {
        model.with_port_model(PortModel::Analytic)
    } else {
        model
    }
}

/// Scored candidates in ranking order (what every `explore_all` variant
/// returns and [`crate::WideSa::compile_ranked`] consumes).
pub type Ranked = Vec<(MappingCandidate, Estimate)>;

/// The loop-invariant part of one DSE run: everything [`score_choice`]
/// needs besides the choice itself. `Clone` so the serve layer can cache
/// plans across requests (near-key requests share the enumeration).
#[derive(Debug, Clone)]
pub struct DsePlan {
    pub scope: KernelScope,
    /// Latency-hiding plan (identical for every candidate of a run: it
    /// depends only on the kernel nest and the core, not the choice).
    pub latency: LatencyHiding,
    /// Effective AIE budget after clamping to the physical array.
    pub budget: u64,
    /// Space-time choices to score, in canonical enumeration order.
    pub choices: Vec<SpaceTimeChoice>,
}

/// Per-recurrence setup: memoized demarcation, space-time enumeration and
/// the shared latency plan.
pub fn plan(rec: &UniformRecurrence, board: &BoardConfig, cons: &DseConstraints) -> DsePlan {
    let _span = Span::begin("dse.plan", "dse");
    counters().plans.inc();
    let scope = demarcate_cached(rec);
    let graph_loops = scope.graph_loops();
    let choices = spacetime::enumerate(&scope.graph_nest, &graph_loops);
    let budget = cons
        .max_aies
        .unwrap_or(board.array.num_cores() as u64)
        .min(board.array.num_cores() as u64);
    // Latency hiding plans over the kernel-scope loops of the
    // recurrence's core nest.
    let latency = if cons.no_latency_hiding {
        LatencyHiding {
            factors: vec![],
            chains: 1,
        }
    } else {
        latency::plan(&rec.loop_nest(), &board.array.core)
    };
    DsePlan {
        scope,
        latency,
        budget,
        choices,
    }
}

/// Score one space-time choice: partition, thread, estimate. Pure —
/// shardable across threads with no ordering concerns. Returns `None`
/// when the candidate exceeds the AIE budget.
pub fn score_choice(
    rec: &UniformRecurrence,
    model: &CostModel,
    cons: &DseConstraints,
    plan: &DsePlan,
    choice: SpaceTimeChoice,
) -> Option<(MappingCandidate, Estimate)> {
    let board = &model.board;
    let repl = rec.replicate.max(1);
    if repl > 1 {
        // The replication axis occupies array rows: each of the `repl`
        // summand replicas instantiates the partitioned chain on its own
        // row band, so CA designs map the remaining space 1D (the chain
        // spans columns) and the replication factor must fit the rows.
        if choice.dims() != 1 || repl > board.array.rows as u64 {
            return None;
        }
    }
    // Per-replica AIE budget: replication multiplies the footprint, and
    // a CA chain cannot exceed one physical row.
    let part_budget = if repl > 1 {
        (plan.budget / repl).min(board.array.cols as u64).max(1)
    } else {
        plan.budget
    };
    let part = partition(&choice.nest, &choice.space, &board.array, Some(part_budget));
    let spare = plan.budget / (part.active_aies().max(1) * repl);
    let thr = if cons.no_threading {
        threading::Threading::none()
    } else {
        threading::plan(&choice.nest, spare)
    };
    let cand = MappingCandidate {
        rec: rec.clone(),
        kind: Kind::of(rec),
        scope: plan.scope.clone(),
        choice,
        partition: part,
        latency: plan.latency.clone(),
        threading: thr,
    };
    if cand.aies_used() > plan.budget {
        counters().over_budget.inc();
        return None;
    }
    counters().scored.inc();
    let est = model.estimate(&cand);
    if let Some(cap) = cons.max_power_w {
        if est.power.watts > cap {
            counters().over_power.inc();
            return None;
        }
    }
    Some((cand, est))
}

/// Canonical throughput ranking: TOPS-descending, ties broken by
/// enumeration order (stable sort) — the historical merge step, and what
/// [`Objective::Throughput`] (the default) selects.
pub fn rank(mut results: Ranked) -> Ranked {
    let _span = Span::begin("dse.rank", "dse");
    results.sort_by(|a, b| b.1.perf.tops.partial_cmp(&a.1.perf.tops).unwrap());
    results
}

/// Deterministic non-dominated sort over `(tops, tops_per_watt)`.
///
/// The frontier (candidates no other candidate beats on both throughput
/// and efficiency) comes first, TOPS-descending; dominated candidates
/// follow, also TOPS-descending. Both halves keep the existing
/// total-order tie-break — a stable sort over the canonical enumeration
/// order — so serial, scoped-thread and serve-pooled exploration return
/// bit-identical rankings, and frontier *membership* is independent of
/// input order. Reports the frontier size on the `dse.frontier_size`
/// counter and runs under `dse.rank` with sort/frontier child spans.
pub fn rank_pareto(mut results: Ranked) -> Ranked {
    let _span = Span::begin("dse.rank", "dse");
    {
        let _sort = Span::begin("dse.rank.sort", "dse");
        results.sort_by(|a, b| b.1.perf.tops.partial_cmp(&a.1.perf.tops).unwrap());
    }
    let _frontier_span = Span::begin("dse.rank.frontier", "dse");
    let n = results.len();
    let mut on_frontier = vec![false; n];
    // One sweep over equal-TOPS groups: with TOPS descending, a candidate
    // is dominated iff some strictly-higher-TOPS candidate has >= its
    // TOPS/W, or a same-TOPS candidate has strictly more TOPS/W. Exact
    // (tops, tops_per_watt) duplicates dominate neither way and all stay
    // on the frontier.
    let mut best_tpw_above = f64::NEG_INFINITY;
    let mut i = 0;
    while i < n {
        let mut j = i;
        let tops = results[i].1.perf.tops;
        let mut group_max = f64::NEG_INFINITY;
        while j < n && results[j].1.perf.tops == tops {
            group_max = group_max.max(results[j].1.power.tops_per_watt);
            j += 1;
        }
        for (k, flag) in on_frontier.iter_mut().enumerate().take(j).skip(i) {
            let tpw = results[k].1.power.tops_per_watt;
            *flag = tpw > best_tpw_above && tpw >= group_max;
        }
        best_tpw_above = best_tpw_above.max(group_max);
        i = j;
    }
    let frontier_size = on_frontier.iter().filter(|f| **f).count();
    counters().frontier.add(frontier_size as u64);
    // Stable partition: frontier first, dominated after, both keeping
    // the TOPS-descending + enumeration-order sequence.
    let mut frontier = Vec::with_capacity(frontier_size);
    let mut dominated = Vec::with_capacity(n - frontier_size);
    for (flag, item) in on_frontier.into_iter().zip(results) {
        if flag {
            frontier.push(item);
        } else {
            dominated.push(item);
        }
    }
    frontier.extend(dominated);
    frontier
}

/// Order scored candidates under the run's objective — the one merge
/// step all three exploration drivers (serial, scoped-thread,
/// serve-pooled) share, so the objective semantics cannot diverge
/// between them.
pub fn rank_by(results: Ranked, objective: Objective) -> Ranked {
    match objective {
        Objective::Throughput => rank(results),
        Objective::Efficiency => {
            let _span = Span::begin("dse.rank", "dse");
            let mut results = results;
            results.sort_by(|a, b| {
                b.1.power
                    .tops_per_watt
                    .partial_cmp(&a.1.power.tops_per_watt)
                    .unwrap()
            });
            results
        }
        Objective::Pareto => rank_pareto(results),
    }
}

/// How many leading candidates of a ranking sit on the Pareto frontier
/// (the frontier summary the framework publishes). Under
/// [`Objective::Pareto`] the frontier is exactly the ranking's prefix;
/// for other objectives this recomputes membership without reordering.
pub fn frontier_size(results: &Ranked) -> usize {
    let refs: Vec<(f64, f64)> = results
        .iter()
        .map(|(_, e)| (e.perf.tops, e.power.tops_per_watt))
        .collect();
    refs.iter()
        .filter(|(tops, tpw)| {
            !refs.iter().any(|(t2, w2)| {
                t2 >= tops && w2 >= tpw && (t2 > tops || w2 > tpw)
            })
        })
        .count()
}

/// Which form [`select_form`] crowned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Form {
    Standard,
    Ca,
}

impl Form {
    pub fn as_str(self) -> &'static str {
        match self {
            Form::Standard => "standard",
            Form::Ca => "ca",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "standard" => Some(Form::Standard),
            "ca" => Some(Form::Ca),
            _ => None,
        }
    }
}

/// Outcome of a standard-vs-CA form selection (see [`select_form`]).
#[derive(Debug, Clone)]
pub struct FormSelection {
    pub standard: (MappingCandidate, Estimate),
    pub ca: (MappingCandidate, Estimate),
    /// Do the standard winner's merged port counts fit the board's
    /// channel budget in both directions?
    pub standard_fits: bool,
    pub selected: Form,
}

/// Choose between a recurrence's standard form and its
/// communication-avoiding variant.
///
/// The CA form pays on-chip partial-sum reduction to collapse the
/// standard form's per-core drains, so it is only worth considering when
/// the standard form is PLIO-bound in the *strict* sense: packet merging
/// cannot bring its winner's ports under the board's channel budget even
/// at maximum fan-in — the merged design is unroutable as built (the
/// cost model prices it charitably by time-sharing channels, but the
/// ports do not exist). The rule is therefore a feasibility gate, not a
/// performance race: `Form::Ca` iff the standard winner's predicted
/// merged ports exceed the budget in either direction. The predicate is
/// [`crate::graph::packet::predict_ports`], which the testkit law
/// `ca_selected_iff_port_bound` re-verifies against the real merge on
/// the built graph.
pub fn select_form(
    std_rec: &UniformRecurrence,
    ca_rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) -> Option<FormSelection> {
    let standard = explore(std_rec, board, cons)?;
    let ca = explore(ca_rec, board, cons)?;
    let model = scoring_model(board, cons);
    let stats = crate::graph::packet::predict_ports(
        &standard.0,
        &model,
        model.channel_bw(),
        board.plio.in_channels as usize,
        board.plio.out_channels as usize,
    );
    let standard_fits = stats.in_ports_after <= board.plio.in_channels as usize
        && stats.out_ports_after <= board.plio.out_channels as usize;
    let selected = if standard_fits { Form::Standard } else { Form::Ca };
    Some(FormSelection {
        standard,
        ca,
        standard_fits,
        selected,
    })
}

/// Explore and return the best candidate with its estimate.
pub fn explore(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) -> Option<(MappingCandidate, Estimate)> {
    explore_all(rec, board, cons).into_iter().next()
}

/// Score `choices` serially against a prepared plan and rank them — the
/// one serial scoring body every exploration variant shares (so a future
/// change to the scoring path cannot silently diverge between the
/// serial, scoped-thread and worker-pool drivers).
pub fn score_serial(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
    plan: &DsePlan,
    choices: Vec<SpaceTimeChoice>,
) -> Ranked {
    let model = scoring_model(board, cons);
    let score_span = Span::begin("dse.score", "dse");
    let results = choices
        .into_iter()
        .filter_map(|choice| score_choice(rec, &model, cons, plan, choice))
        .collect();
    drop(score_span); // close before rank so dse.rank is a sibling
    rank_by(results, cons.objective)
}

/// All evaluated candidates, best first (serial reference path).
pub fn explore_all(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
) -> Ranked {
    let _dse = Span::begin("dse", "dse");
    let mut p = plan(rec, board, cons);
    let choices = std::mem::take(&mut p.choices);
    score_serial(rec, board, cons, &p, choices)
}

/// As [`explore_all`], with candidate scoring sharded over `threads`
/// scoped threads.
///
/// Deterministic by construction: results land in a slot vector indexed
/// by enumeration position, then go through the same stable [`rank`] as
/// the serial path — the returned ranking (including every tie-break) is
/// bit-identical to [`explore_all`]'s, regardless of thread count or
/// scheduling.
pub fn explore_all_parallel(
    rec: &UniformRecurrence,
    board: &BoardConfig,
    cons: &DseConstraints,
    threads: usize,
) -> Ranked {
    if threads <= 1 {
        return explore_all(rec, board, cons);
    }
    let _dse = Span::begin("dse", "dse");
    let mut p = plan(rec, board, cons);
    let choices = std::mem::take(&mut p.choices);
    if choices.len() <= 1 {
        return score_serial(rec, board, cons, &p, choices);
    }
    let model = scoring_model(board, cons);
    let indexed: Vec<(usize, SpaceTimeChoice)> = choices.into_iter().enumerate().collect();
    let chunk = indexed.len().div_ceil(threads);
    let mut slots: Vec<Option<(MappingCandidate, Estimate)>> = Vec::new();
    slots.resize_with(indexed.len(), || None);
    // propagate the request's trace ID into the scoring shards so their
    // dse.score spans correlate with the caller's trace
    let trace_id = trace::current_trace();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for shard in indexed.chunks(chunk) {
            let (p, model) = (&p, &model);
            handles.push(s.spawn(move || {
                let _ctx = TraceCtx::set(trace_id);
                let _span = Span::begin("dse.score", "dse");
                shard
                    .iter()
                    .map(|(i, choice)| (*i, score_choice(rec, model, cons, p, choice.clone())))
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            for (i, scored) in h.join().expect("DSE scoring shard panicked") {
                slots[i] = scored;
            }
        }
    });
    rank_by(slots.into_iter().flatten().collect(), cons.objective)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn mm_dse_finds_2d_mapping() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let (cand, est) = explore(&rec, &board, &DseConstraints::default()).unwrap();
        assert_eq!(cand.choice.dims(), 2, "MM should map to a 2D array");
        assert!(est.perf.tops > 1.0);
        assert!(cand.aies_used() <= 400);
    }

    #[test]
    fn dse_respects_aie_budget() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        for budget in [50, 100, 200, 400] {
            let cons = DseConstraints {
                max_aies: Some(budget),
                ..Default::default()
            };
            let (cand, _) = explore(&rec, &board, &cons).unwrap();
            assert!(cand.aies_used() <= budget, "budget {budget}");
        }
    }

    #[test]
    fn throughput_monotone_in_aie_budget() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let mut last = 0.0;
        for budget in [50, 100, 200, 400] {
            let cons = DseConstraints {
                max_aies: Some(budget),
                ..Default::default()
            };
            let (_, est) = explore(&rec, &board, &cons).unwrap();
            assert!(
                est.perf.tops >= last * 0.95,
                "throughput dropped at budget {budget}: {} < {last}",
                est.perf.tops
            );
            last = est.perf.tops;
        }
    }

    #[test]
    fn latency_hiding_ablation_hurts() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let (_, with) = explore(&rec, &board, &DseConstraints::default()).unwrap();
        let (_, without) = explore(
            &rec,
            &board,
            &DseConstraints {
                no_latency_hiding: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            with.perf.tops > without.perf.tops * 1.5,
            "latency hiding should matter: {} vs {}",
            with.perf.tops,
            without.perf.tops
        );
    }

    #[test]
    fn all_candidates_ranked() {
        let rec = library::mm(1024, 1024, 1024, DType::F32);
        let board = BoardConfig::vck5000();
        let all = explore_all(&rec, &board, &DseConstraints::default());
        assert!(all.len() >= 3);
        for w in all.windows(2) {
            assert!(w[0].1.perf.tops >= w[1].1.perf.tops);
        }
    }

    #[test]
    fn parallel_ranking_is_bit_identical_to_serial() {
        let rec = library::mm(2048, 2048, 2048, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints::default();
        let serial = explore_all(&rec, &board, &cons);
        for threads in [2, 3, 8, 64] {
            let par = explore_all_parallel(&rec, &board, &cons, threads);
            assert_eq!(serial.len(), par.len(), "{threads} threads");
            for (s, p) in serial.iter().zip(&par) {
                assert_eq!(s.0.summary(), p.0.summary(), "{threads} threads");
                assert_eq!(s.1.perf.tops.to_bits(), p.1.perf.tops.to_bits());
                assert_eq!(
                    s.1.power.tops_per_watt.to_bits(),
                    p.1.power.tops_per_watt.to_bits()
                );
            }
        }
    }

    #[test]
    fn pareto_frontier_is_non_dominated_and_leads_the_ranking() {
        let rec = library::mm(2048, 2048, 2048, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            objective: Objective::Pareto,
            ..Default::default()
        };
        let ranked = explore_all(&rec, &board, &cons);
        assert!(ranked.len() >= 3);
        let k = frontier_size(&ranked);
        assert!((1..=ranked.len()).contains(&k));
        // The first k entries are exactly the frontier: nothing in the
        // full set dominates any of them, and every later entry is
        // dominated by someone.
        for (i, (_, e)) in ranked.iter().enumerate() {
            let dominated = ranked.iter().any(|(_, o)| {
                o.perf.tops >= e.perf.tops
                    && o.power.tops_per_watt >= e.power.tops_per_watt
                    && (o.perf.tops > e.perf.tops
                        || o.power.tops_per_watt > e.power.tops_per_watt)
            });
            assert_eq!(dominated, i >= k, "entry {i} of frontier size {k}");
        }
        // Frontier half and dominated half are each TOPS-descending.
        for w in ranked[..k].windows(2) {
            assert!(w[0].1.perf.tops >= w[1].1.perf.tops);
        }
        for w in ranked[k..].windows(2) {
            assert!(w[0].1.perf.tops >= w[1].1.perf.tops);
        }
    }

    #[test]
    fn throughput_objective_matches_legacy_rank_exactly() {
        // Acceptance bar: under the default objective the ranking (and
        // so the selected design) is byte-identical to the historical
        // single-metric `rank`.
        let rec = library::mm(2048, 2048, 2048, DType::F32);
        let board = BoardConfig::vck5000();
        let legacy = rank(explore_all(&rec, &board, &DseConstraints::default()));
        let via_by = explore_all(&rec, &board, &DseConstraints::default());
        assert_eq!(legacy.len(), via_by.len());
        for (l, r) in legacy.iter().zip(&via_by) {
            assert_eq!(l.0.summary(), r.0.summary());
            assert_eq!(l.1.perf.tops.to_bits(), r.1.perf.tops.to_bits());
        }
    }

    #[test]
    fn efficiency_objective_orders_by_tops_per_watt() {
        let rec = library::mm(2048, 2048, 2048, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            objective: Objective::Efficiency,
            ..Default::default()
        };
        let ranked = explore_all(&rec, &board, &cons);
        assert!(ranked.len() >= 3);
        for w in ranked.windows(2) {
            assert!(w[0].1.power.tops_per_watt >= w[1].1.power.tops_per_watt);
        }
    }

    #[test]
    fn power_cap_filters_candidates() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let open = explore_all(&rec, &board, &DseConstraints::default());
        let peak = open
            .iter()
            .map(|(_, e)| e.power.watts)
            .fold(f64::NEG_INFINITY, f64::max);
        let floor = open
            .iter()
            .map(|(_, e)| e.power.watts)
            .fold(f64::INFINITY, f64::min);
        assert!(peak > floor, "need a power spread to test the cap");
        let cap = (peak + floor) / 2.0;
        let capped = explore_all(
            &rec,
            &board,
            &DseConstraints {
                max_power_w: Some(cap),
                ..Default::default()
            },
        );
        assert!(!capped.is_empty());
        assert!(capped.len() < open.len(), "cap {cap} W must drop candidates");
        for (_, e) in &capped {
            assert!(e.power.watts <= cap);
        }
        // An unreachable cap empties the search instead of panicking.
        let none = explore_all(
            &rec,
            &board,
            &DseConstraints {
                max_power_w: Some(1.0),
                ..Default::default()
            },
        );
        assert!(none.is_empty());
    }

    #[test]
    fn ca_candidates_are_row_replicated_1d_chains() {
        let rec = library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32);
        let board = BoardConfig::vck5000();
        let all = explore_all(&rec, &board, &DseConstraints::default());
        assert!(!all.is_empty(), "CA variant must map on the full board");
        for (cand, _) in &all {
            // every CA candidate is a 1D chain replicated across rows
            assert_eq!(cand.choice.dims(), 1, "{}", cand.summary());
            let (r, c) = cand.replica_shape();
            assert_eq!(r, 4);
            assert!(c <= board.array.cols as u64);
            assert_eq!(cand.aies_used(), r * c * cand.threading.factor);
            assert!(cand.aies_used() <= 400, "{}", cand.summary());
        }
        // a replication factor beyond the physical rows is unmappable
        let too_tall = library::ca_mm_25d(1024, 1024, 1024, 16, DType::F32);
        assert!(explore_all(&too_tall, &board, &DseConstraints::default()).is_empty());
    }

    #[test]
    fn ca_form_selected_only_when_standard_is_port_bound() {
        // The acceptance pair of the CA arm: on the default 78-channel
        // board the standard form's merged ports fit and it stays
        // crowned; on an 8-channel board the standard winner's drains
        // cannot merge under the budget and the CA form takes over.
        for (std_rec, ca_rec) in library::ca_pairs() {
            let cons = DseConstraints::default();
            let full = select_form(&std_rec, &ca_rec, &BoardConfig::vck5000(), &cons)
                .expect("both forms map on the full board");
            assert!(full.standard_fits, "{}", std_rec.name);
            assert_eq!(full.selected, Form::Standard, "{}", std_rec.name);

            let starved = BoardConfig::vck5000().with_plio_budget(8);
            let tight = select_form(&std_rec, &ca_rec, &starved, &cons)
                .expect("both forms map on the starved board");
            assert!(!tight.standard_fits, "{}", std_rec.name);
            assert_eq!(tight.selected, Form::Ca, "{}", std_rec.name);
            // the crowned CA design really is a replicated chain
            assert!(tight.ca.0.replication() >= 2);
            assert_eq!(tight.ca.0.choice.dims(), 1);
        }
    }

    #[test]
    fn constraint_fingerprint_discriminates() {
        let mut base = Fnv64::new();
        DseConstraints::default().fingerprint(&mut base);
        let mut capped = Fnv64::new();
        DseConstraints {
            max_aies: Some(64),
            ..Default::default()
        }
        .fingerprint(&mut capped);
        let mut ablated = Fnv64::new();
        DseConstraints {
            no_threading: true,
            ..Default::default()
        }
        .fingerprint(&mut ablated);
        let mut analytic = Fnv64::new();
        DseConstraints {
            analytic_ranking: true,
            ..Default::default()
        }
        .fingerprint(&mut analytic);
        let mut powered = Fnv64::new();
        DseConstraints {
            max_power_w: Some(40.0),
            ..Default::default()
        }
        .fingerprint(&mut powered);
        let mut pareto = Fnv64::new();
        DseConstraints {
            objective: Objective::Pareto,
            ..Default::default()
        }
        .fingerprint(&mut pareto);
        let mut efficiency = Fnv64::new();
        DseConstraints {
            objective: Objective::Efficiency,
            ..Default::default()
        }
        .fingerprint(&mut efficiency);
        assert_ne!(base.finish(), capped.finish());
        assert_ne!(base.finish(), ablated.finish());
        assert_ne!(capped.finish(), ablated.finish());
        assert_ne!(base.finish(), analytic.finish());
        assert_ne!(ablated.finish(), analytic.finish());
        assert_ne!(base.finish(), powered.finish());
        assert_ne!(base.finish(), pareto.finish());
        assert_ne!(pareto.finish(), efficiency.finish());
        assert_ne!(powered.finish(), pareto.finish());
    }
}
