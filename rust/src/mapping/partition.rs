//! Array partition (paper §III-B-2).
//!
//! The virtual systolic array produced by the space-time transformation
//! can exceed the physical 8×50 grid; partitioning tiles the space loops
//! so one *round* of the physical array covers an (R × C) block of the
//! virtual array, and the outer tile loops become sequential rounds.

use crate::arch::array::AieArray;
use crate::polyhedral::schedule::LoopNest;
use crate::util::math::ceil_div;

/// How the virtual space maps onto the physical array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayPartition {
    /// Virtual extents of the (up to two) space loops.
    pub virt: Vec<u64>,
    /// Physical extents used per round (rows, cols for 2D; len for 1D).
    pub phys: Vec<u64>,
    /// Sequential rounds needed to cover the virtual array.
    pub rounds: u64,
}

impl ArrayPartition {
    /// AIEs active per round from the space mapping alone (before
    /// multiple threading).
    pub fn active_aies(&self) -> u64 {
        self.phys.iter().product()
    }

    /// Total virtual tiles to cover.
    pub fn total_tiles(&self) -> u64 {
        self.virt.iter().product()
    }

    /// Utilisation over the linearised round schedule (the DMA movers
    /// stream virtual tiles through the array as a work queue, so only
    /// the final partial round wastes cores): ≈ 1 for large problems.
    pub fn edge_efficiency(&self) -> f64 {
        let total = self.total_tiles().max(1);
        total as f64 / (self.rounds * self.active_aies()).max(1) as f64
    }
}

/// Partition the space loops of `nest` onto `array`, optionally capping
/// the number of AIEs used (Figure 6 sweeps). The first space loop maps
/// to array rows, the second to columns; a 1D space maps to a serpentine
/// over the whole budget.
pub fn partition(
    nest: &LoopNest,
    space: &[usize],
    array: &AieArray,
    max_aies: Option<u64>,
) -> ArrayPartition {
    let budget = max_aies
        .unwrap_or(array.num_cores() as u64)
        .min(array.num_cores() as u64)
        .max(1);
    // Positions: after the space-time permutation the space loops are
    // outermost, i.e. nest dims 0..space.len().
    let virt: Vec<u64> = (0..space.len())
        .map(|s| nest.domain.dims[s].extent)
        .collect();
    match virt.len() {
        1 => {
            let len = virt[0].min(budget);
            ArrayPartition {
                rounds: ceil_div(virt[0], len),
                virt,
                phys: vec![len],
            }
        }
        2 => {
            // Choose (r, c) ≤ (rows, cols) maximising used AIEs under the
            // budget. Rounds are *linearised*: the DMA movers stream
            // virtual (i, j) tiles through the array as a work queue, so
            // the only waste is the final partial round.
            let total: u64 = virt.iter().product();
            let mut best: Option<(u64, u64, f64)> = None;
            for r in 1..=array.rows as u64 {
                for c in 1..=array.cols as u64 {
                    if r * c > budget {
                        continue;
                    }
                    let r_eff = virt[0].min(r);
                    let c_eff = virt[1].min(c);
                    let used = r_eff * c_eff;
                    let rounds = ceil_div(total, used);
                    let cover = total as f64 / (rounds * used) as f64;
                    let score = used as f64 * (0.5 + 0.5 * cover);
                    if best.map_or(true, |(_, _, s)| score > s) {
                        best = Some((r_eff, c_eff, score));
                    }
                }
            }
            let (r, c, _) = best.expect("non-empty array");
            ArrayPartition {
                rounds: ceil_div(total, r * c),
                virt,
                phys: vec![r, c],
            }
        }
        n => panic!("unsupported space rank {n}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::{DepKind, Dependence};
    use crate::polyhedral::domain::{IterationDomain, LoopDim};

    fn nest2d(vi: u64, vj: u64) -> LoopNest {
        LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("it", vi), LoopDim::new("jt", vj)]),
            vec![Dependence::new("A", DepKind::Read, vec![0, 1])],
        )
    }

    #[test]
    fn full_array_partition_mm_like() {
        // 256×256 virtual tiles on 8×50: phys should be the whole array
        let nest = nest2d(256, 256);
        let p = partition(&nest, &[0, 1], &AieArray::default(), None);
        assert_eq!(p.phys, vec![8, 50]);
        // linearised work-queue rounds: ceil(256·256 / 400)
        assert_eq!(p.rounds, (256u64 * 256).div_ceil(400));
        assert_eq!(p.active_aies(), 400);
        assert!(p.edge_efficiency() > 0.99);
    }

    #[test]
    fn budget_cap_respected() {
        let nest = nest2d(256, 256);
        let p = partition(&nest, &[0, 1], &AieArray::default(), Some(100));
        assert!(p.active_aies() <= 100);
        assert!(p.active_aies() >= 90, "should use most of the budget: {p:?}");
    }

    #[test]
    fn small_virtual_array_uses_fewer_cores() {
        let nest = nest2d(4, 10);
        let p = partition(&nest, &[0, 1], &AieArray::default(), None);
        assert_eq!(p.phys, vec![4, 10]);
        assert_eq!(p.rounds, 1);
        assert!((p.edge_efficiency() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partition_1d() {
        let nest = LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("nt", 4096)]),
            vec![],
        );
        let p = partition(&nest, &[0], &AieArray::default(), Some(256));
        assert_eq!(p.phys, vec![256]);
        assert_eq!(p.rounds, 16);
    }

    #[test]
    fn edge_efficiency_penalises_ragged_cover() {
        let nest = nest2d(9, 50); // 9 rows over 8-phys rows → 2 ragged rounds
        let p = partition(&nest, &[0, 1], &AieArray::default(), None);
        assert!(p.edge_efficiency() < 1.0);
        assert!(p.edge_efficiency() > 0.5);
    }
}
