//! A complete mapping candidate: every decision the WideSA mapper makes
//! for one design point, bundled for costing, graph building and codegen.

use crate::mapping::latency::LatencyHiding;
use crate::mapping::partition::ArrayPartition;
use crate::mapping::spacetime::SpaceTimeChoice;
use crate::mapping::threading::Threading;
use crate::recurrence::spec::UniformRecurrence;
use crate::recurrence::tiling::KernelScope;

/// Workload families the kernel-level mapper specialises for (the
/// microkernel issue-efficiency calibration keys on this).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    Mm,
    Conv2d,
    Fir,
    Fft2d,
    /// Depthwise / grouped 2D convolution: one independent filter per
    /// channel group ([`crate::recurrence::library::dw_conv2d`]).
    DwConv2d,
    /// Triangular solve (forward substitution) over the rectangular hull
    /// ([`crate::recurrence::library::trsv`]).
    Trsv,
    /// 2D stencil chain: pipelined Jacobi/advection sweeps
    /// ([`crate::recurrence::library::stencil2d_chain`]).
    Stencil,
    /// Communication-avoiding replicated-summand matrix multiply
    /// (2.5D / block-recursive forms): `replicate` row-replicas each
    /// compute a `k`-slab of partials, reduced on chip across the
    /// replication axis ([`crate::recurrence::library::ca_mm_25d`],
    /// [`crate::recurrence::library::ca_mm_blockrec`]).
    CaMm,
}

impl Kind {
    pub fn of(rec: &UniformRecurrence) -> Self {
        let n = rec.name.as_str();
        if n.starts_with("ca_mm") {
            Kind::CaMm
        } else if n.starts_with("mm") {
            Kind::Mm
        } else if n.starts_with("seidel2d") {
            // Gauss–Seidel sweeps share the stencil microkernel: same
            // 5-term relaxation body, different sweep dependences.
            Kind::Stencil
        } else if n.starts_with("dwconv2d") {
            Kind::DwConv2d
        } else if n.starts_with("conv2d") {
            Kind::Conv2d
        } else if n.starts_with("fir") {
            Kind::Fir
        } else if n.starts_with("fft2d") {
            Kind::Fft2d
        } else if n.starts_with("trsv") {
            Kind::Trsv
        } else if n.starts_with("stencil2d") {
            Kind::Stencil
        } else {
            // default to the most generic systolic family
            Kind::Mm
        }
    }
}

#[derive(Debug, Clone)]
pub struct MappingCandidate {
    pub rec: UniformRecurrence,
    pub kind: Kind,
    pub scope: KernelScope,
    pub choice: SpaceTimeChoice,
    pub partition: ArrayPartition,
    pub latency: LatencyHiding,
    pub threading: Threading,
}

impl MappingCandidate {
    /// Replication factor of the summand axis (1 for standard forms).
    pub fn replication(&self) -> u64 {
        self.rec.replicate.max(1)
    }

    /// AIE cores the design occupies. The replication axis multiplies
    /// in: each of the `replicate` summand replicas instantiates the
    /// partitioned chain on its own array rows.
    pub fn aies_used(&self) -> u64 {
        self.partition.active_aies() * self.threading.factor * self.replication()
    }

    /// Physical array shape used per replica (rows, cols).
    ///
    /// For CA designs the shape is the whole replicated block: the
    /// replication axis occupies rows, the partitioned 1D chain spans
    /// columns — the geometry `graph::builder`'s broadcast-reduction
    /// mover shape realises.
    pub fn replica_shape(&self) -> (u64, u64) {
        if self.replication() > 1 {
            return (self.replication(), self.partition.active_aies().max(1));
        }
        match self.partition.phys.as_slice() {
            [r, c] => (*r, *c),
            [len] => {
                // serpentine over rows of 50
                let cols = (*len).min(50);
                let rows = len.div_ceil(cols);
                (rows, cols)
            }
            _ => (1, 1),
        }
    }

    /// Sequential rounds of the physical array (space folding ×
    /// threading handled separately).
    pub fn rounds(&self) -> u64 {
        self.partition.rounds
    }

    /// Time steps within one round: product of Time-role loop extents in
    /// the space-time nest, with the threaded loop divided by its factor.
    pub fn time_steps_per_round(&self) -> u64 {
        use crate::polyhedral::schedule::LoopRole;
        let mut steps = 1u64;
        for d in self.choice.nest.loops_with_role(LoopRole::Time) {
            let mut e = self.choice.nest.domain.dims[d].extent;
            if self.threading.dim == Some(d) {
                e = e.div_ceil(self.threading.factor);
            }
            steps = steps.saturating_mul(e);
        }
        // The replication axis splits the reduction across replicas:
        // each of the R row-replicas walks 1/R of the summand extent
        // (work conservation: R replicas × steps/R × core MACs = total).
        steps.div_ceil(self.replication())
    }

    /// Is the design *edge-fed* — inputs enter at the array boundary and
    /// propagate core-to-core systolically (MM's A/B feeds) — rather than
    /// landing a private stream on every core? Edge-fed designs pay a
    /// pipeline fill of one array diameter before their first result; the
    /// private-stream families start computing as soon as the first tile
    /// lands. Must agree with the graph shape
    /// [`crate::graph::builder::stream_rates`] assigns.
    pub fn edge_fed(&self) -> bool {
        // CA MM keeps MM's edge feeding for B (row-edge inject, eastward
        // systolic propagation) and adds the column reduction — both are
        // boundary-fed pipelines, so the fill model applies unchanged.
        matches!(self.kind, Kind::Mm | Kind::CaMm)
    }

    /// Systolic pipeline-fill steps before the first round's value
    /// completes: the array diameter for edge-fed designs, zero for
    /// private-stream designs. This is the **one** fill model — both the
    /// analytic cost model ([`crate::mapping::cost::CostModel::estimate`])
    /// and the simulator ([`crate::sim::engine::simulate`]) price fill
    /// through this method, so the ≤15 % sim/analytic agreement holds by
    /// construction for every workload family instead of being an MM
    /// special case.
    pub fn fill_steps(&self) -> u64 {
        if self.edge_fed() {
            let (r, c) = self.replica_shape();
            r + c
        } else {
            0
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let (r, c) = self.replica_shape();
        let skew = if self.choice.is_skewed() {
            format!(" skew{:?}", self.choice.skews)
        } else {
            String::new()
        };
        format!(
            "{}: space {:?}{skew} → {}×{} phys ×{} threads = {} AIEs, {} rounds × {} steps, core tile {:?} ({} B)",
            self.rec.name,
            self.choice.space,
            r,
            c,
            self.threading.factor,
            self.aies_used(),
            self.rounds(),
            self.time_steps_per_round(),
            self.scope.core_factors,
            self.scope.core_bytes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn kind_inference() {
        assert_eq!(
            Kind::of(&library::mm(64, 64, 64, DType::F32)),
            Kind::Mm
        );
        assert_eq!(
            Kind::of(&library::conv2d(64, 64, 4, 4, DType::I8)),
            Kind::Conv2d
        );
        assert_eq!(Kind::of(&library::fir(1024, 15, DType::F32)), Kind::Fir);
        assert_eq!(
            Kind::of(&library::fft2d(64, 64, DType::CF32)),
            Kind::Fft2d
        );
        // the dwconv2d prefix must not be swallowed by the conv2d arm
        assert_eq!(
            Kind::of(&library::dw_conv2d(8, 64, 64, 3, 3, DType::F32)),
            Kind::DwConv2d
        );
        assert_eq!(Kind::of(&library::trsv(256, DType::F32)), Kind::Trsv);
        assert_eq!(
            Kind::of(&library::stencil2d_chain(2, 64, 64, DType::F32)),
            Kind::Stencil
        );
        // the ca_mm prefix must not fall through to the mm arm
        assert_eq!(
            Kind::of(&library::ca_mm_25d(64, 64, 64, 4, DType::F32)),
            Kind::CaMm
        );
        assert_eq!(
            Kind::of(&library::ca_mm_blockrec(64, 2, DType::F32)),
            Kind::CaMm
        );
        // seidel shares the stencil microkernel family
        assert_eq!(
            Kind::of(&library::seidel2d(2, 64, 64, DType::F32)),
            Kind::Stencil
        );
    }
}
