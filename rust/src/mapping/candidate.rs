//! A complete mapping candidate: every decision the WideSA mapper makes
//! for one design point, bundled for costing, graph building and codegen.

use crate::mapping::latency::LatencyHiding;
use crate::mapping::partition::ArrayPartition;
use crate::mapping::spacetime::SpaceTimeChoice;
use crate::mapping::threading::Threading;
use crate::recurrence::spec::UniformRecurrence;
use crate::recurrence::tiling::KernelScope;

/// Workload families the kernel-level mapper specialises for (the
/// microkernel issue-efficiency calibration keys on this).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kind {
    Mm,
    Conv2d,
    Fir,
    Fft2d,
}

impl Kind {
    pub fn of(rec: &UniformRecurrence) -> Self {
        let n = rec.name.as_str();
        if n.starts_with("mm") {
            Kind::Mm
        } else if n.starts_with("conv2d") {
            Kind::Conv2d
        } else if n.starts_with("fir") {
            Kind::Fir
        } else if n.starts_with("fft2d") {
            Kind::Fft2d
        } else {
            // default to the most generic systolic family
            Kind::Mm
        }
    }
}

#[derive(Debug, Clone)]
pub struct MappingCandidate {
    pub rec: UniformRecurrence,
    pub kind: Kind,
    pub scope: KernelScope,
    pub choice: SpaceTimeChoice,
    pub partition: ArrayPartition,
    pub latency: LatencyHiding,
    pub threading: Threading,
}

impl MappingCandidate {
    /// AIE cores the design occupies.
    pub fn aies_used(&self) -> u64 {
        self.partition.active_aies() * self.threading.factor
    }

    /// Physical array shape used per replica (rows, cols).
    pub fn replica_shape(&self) -> (u64, u64) {
        match self.partition.phys.as_slice() {
            [r, c] => (*r, *c),
            [len] => {
                // serpentine over rows of 50
                let cols = (*len).min(50);
                let rows = len.div_ceil(cols);
                (rows, cols)
            }
            _ => (1, 1),
        }
    }

    /// Sequential rounds of the physical array (space folding ×
    /// threading handled separately).
    pub fn rounds(&self) -> u64 {
        self.partition.rounds
    }

    /// Time steps within one round: product of Time-role loop extents in
    /// the space-time nest, with the threaded loop divided by its factor.
    pub fn time_steps_per_round(&self) -> u64 {
        use crate::polyhedral::schedule::LoopRole;
        let mut steps = 1u64;
        for d in self.choice.nest.loops_with_role(LoopRole::Time) {
            let mut e = self.choice.nest.domain.dims[d].extent;
            if self.threading.dim == Some(d) {
                e = e.div_ceil(self.threading.factor);
            }
            steps = steps.saturating_mul(e);
        }
        steps
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let (r, c) = self.replica_shape();
        format!(
            "{}: space {:?} → {}×{} phys ×{} threads = {} AIEs, {} rounds × {} steps, core tile {:?} ({} B)",
            self.rec.name,
            self.choice.space,
            r,
            c,
            self.threading.factor,
            self.aies_used(),
            self.rounds(),
            self.time_steps_per_round(),
            self.scope.core_factors,
            self.scope.core_bytes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn kind_inference() {
        assert_eq!(
            Kind::of(&library::mm(64, 64, 64, DType::F32)),
            Kind::Mm
        );
        assert_eq!(
            Kind::of(&library::conv2d(64, 64, 4, 4, DType::I8)),
            Kind::Conv2d
        );
        assert_eq!(Kind::of(&library::fir(1024, 15, DType::F32)), Kind::Fir);
        assert_eq!(
            Kind::of(&library::fft2d(64, 64, DType::CF32)),
            Kind::Fft2d
        );
    }
}
