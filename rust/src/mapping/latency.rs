//! Latency hiding (paper §III-B-3).
//!
//! Accumulation statements carry a loop dependence through the MAC
//! pipeline: with a single accumulation chain the core stalls
//! `mac_pipeline_depth` cycles per vector MAC. The transform identifies
//! parallel loops (no carried dependence), strip-mines them, and sinks
//! the point loops innermost so the kernel interleaves `chains`
//! independent accumulators — exactly the paper's tiling of (i, j) by
//! (N2, M2) with point loops permuted innermost.

use crate::arch::aie::AieCore;
use crate::polyhedral::schedule::{LoopNest, LoopRole};

/// Chosen latency-hiding factors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHiding {
    /// (loop index in the *kernel-scope* nest, strip factor) pairs.
    pub factors: Vec<(usize, u64)>,
    /// Independent accumulation chains the kernel interleaves.
    pub chains: u64,
}

impl LatencyHiding {
    /// Pipeline efficiency achieved on `core`.
    pub fn efficiency(&self, core: &AieCore) -> f64 {
        core.accumulation_efficiency(self.chains)
    }
}

/// Loops (by index) eligible for latency hiding inside the kernel scope:
/// parallel w.r.t. every *flow* dependence (read reuse does not stall the
/// accumulator). A flow dependence constrains the loop that **carries**
/// it — the first non-zero component in loop order — not inner loops the
/// same vector merely touches: a stencil halo `(1, −1, 0)` is carried by
/// the sweep loop `t`, so within one sweep the grid loops stay parallel
/// and can still interleave accumulation chains. (For unit-vector flow
/// deps — every Table II workload — both readings coincide.)
pub fn parallel_kernel_loops(nest: &LoopNest) -> Vec<usize> {
    use crate::polyhedral::dependence::DepKind;
    (0..nest.rank())
        .filter(|&d| {
            nest.domain.dims[d].extent > 1
                && nest
                    .deps
                    .iter()
                    .filter(|dep| dep.kind == DepKind::Flow)
                    .all(|dep| dep.vector.iter().position(|&c| c != 0) != Some(d))
        })
        .collect()
}

/// Pick strip factors so the product of point extents covers the MAC
/// pipeline depth (more chains than depth wastes accumulator registers).
pub fn plan(nest: &LoopNest, core: &AieCore) -> LatencyHiding {
    let depth = core.mac_pipeline_depth.max(1);
    let mut chains = 1u64;
    let mut factors = Vec::new();
    for d in parallel_kernel_loops(nest) {
        if chains >= depth {
            break;
        }
        let want = depth / chains;
        let f = want.min(nest.domain.dims[d].extent).min(core.acc_registers);
        if f > 1 {
            factors.push((d, f));
            chains *= f;
        }
    }
    LatencyHiding { factors, chains }
}

/// Apply the plan: strip-mine each chosen loop and sink the point loop
/// innermost with the Latency role.
pub fn apply(nest: &LoopNest, plan: &LatencyHiding) -> LoopNest {
    use crate::polyhedral::transform::tile_and_sink;
    let mut out = nest.clone();
    // Indices shift as we tile: process in descending index order.
    let mut fs = plan.factors.clone();
    fs.sort_by(|a, b| b.0.cmp(&a.0));
    for (d, f) in fs {
        out = tile_and_sink(&out, d, f, LoopRole::Latency);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::{DepKind, Dependence};
    use crate::polyhedral::domain::{IterationDomain, LoopDim};

    fn mm_kernel_nest() -> LoopNest {
        // core-scope MM loops (i2, j2, k2) with the accumulation carried
        // along k2 only.
        LoopNest::new(
            IterationDomain::new(vec![
                LoopDim::new("i2", 32),
                LoopDim::new("j2", 32),
                LoopDim::new("k2", 32),
            ]),
            vec![
                Dependence::new("A", DepKind::Read, vec![0, 1, 0]),
                Dependence::new("C", DepKind::Flow, vec![0, 0, 1]),
            ],
        )
    }

    #[test]
    fn parallel_loops_exclude_reduction() {
        let nest = mm_kernel_nest();
        let par = parallel_kernel_loops(&nest);
        assert_eq!(par, vec![0, 1]); // i2, j2 parallel; k2 carries flow
    }

    #[test]
    fn plan_covers_pipeline_depth() {
        let nest = mm_kernel_nest();
        let core = AieCore::default();
        let p = plan(&nest, &core);
        assert!(p.chains >= core.mac_pipeline_depth.min(4));
        assert!((p.efficiency(&core) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn apply_sinks_point_loops() {
        let nest = mm_kernel_nest();
        let core = AieCore::default();
        let p = plan(&nest, &core);
        let out = apply(&nest, &p);
        assert_eq!(out.rank(), nest.rank() + p.factors.len());
        // innermost loops have the Latency role
        for extra in 0..p.factors.len() {
            assert_eq!(out.roles[out.rank() - 1 - extra], LoopRole::Latency);
        }
        assert_eq!(out.cardinality(), nest.cardinality());
    }

    #[test]
    fn stencil_halo_deps_constrain_only_their_carrying_loop() {
        // (1, -1, 0): carried by t; the grid loops remain parallel and
        // can interleave accumulation chains within one sweep
        let nest = LoopNest::new(
            IterationDomain::new(vec![
                LoopDim::new("t", 4),
                LoopDim::new("i", 32),
                LoopDim::new("j", 32),
            ]),
            vec![
                Dependence::new("A", DepKind::Flow, vec![1, 0, 0]),
                Dependence::new("A", DepKind::Flow, vec![1, -1, 0]),
                Dependence::new("A", DepKind::Flow, vec![1, 0, 1]),
            ],
        );
        assert_eq!(parallel_kernel_loops(&nest), vec![1, 2]);
    }

    #[test]
    fn no_parallel_loops_means_single_chain() {
        // pure chain recurrence: only a carried loop
        let nest = LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("t", 64)]),
            vec![Dependence::new("s", DepKind::Flow, vec![1])],
        );
        let core = AieCore::default();
        let p = plan(&nest, &core);
        assert_eq!(p.chains, 1);
        assert!((p.efficiency(&core) - 0.25).abs() < 1e-9);
    }
}
