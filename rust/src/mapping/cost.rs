//! Analytic performance model: scores a [`MappingCandidate`] on a board.
//!
//! The model separates two levels, mirroring the paper's demarcation:
//!
//! * **Kernel level** — the issue efficiency of the generated AIE
//!   microkernel (fraction of cycles the vector MAC unit fires). This is
//!   a *calibrated* quantity: we do not simulate VLIW scheduling, we take
//!   the sustained efficiencies that published AIE kernels achieve per
//!   workload family and dtype (sources: this paper's Table III per-AIE
//!   throughputs, CHARM, and the XVDPU report; see DESIGN.md §1). The
//!   latency-hiding plan modulates it: without enough independent
//!   accumulation chains the MAC pipeline drains (§III-B-3).
//!
//! * **System level** — everything WideSA actually decides: how many AIEs
//!   work, how rounds/steps are scheduled, what crosses PLIOs after
//!   packet-switch/broadcast merging, what the PL buffer can cache
//!   (k-segmentation drains when it cannot), and what DRAM must supply.
//!
//! Two throughputs are reported:
//!
//! * [`PerfEstimate::tops`] — **on-chip** throughput (array + PLIO + PL
//!   buffer), the quantity the paper's Table III reports: inputs are
//!   staged by the PL movers and DRAM prefetch overlaps steady-state
//!   execution.
//! * [`PerfEstimate::tops_e2e`] — cold-DRAM end-to-end throughput (adds
//!   the Table I PL-DRAM bound), which we report alongside for honesty.
//!
//! PLIO channels are rate-limited by the PL-side DMA mover, not the
//! AIE-side interface: a `mover_bits`-wide HLS mover at the PL clock.
//! WideSA's DMA module constructor widens movers to 512 bit for
//! bandwidth-hungry designs (the Table III operating points); the
//! conservative 128-bit mover is what the Figure 6 sweeps exercise.
//!
//! **One port model.** By default ([`PortModel::Exact`]) the PLIO port
//! counts entering the estimate are the *exact* packet-merge results,
//! computed incrementally per candidate by
//! [`crate::graph::packet::predict_ports`] — so the DSE ranking, the
//! simulator and the framework's post-merge re-pricing all agree on one
//! port model. The legacy analytic packing survives behind
//! [`PortModel::Analytic`] for A/B comparison.

use crate::arch::power::{design_activity, PowerEstimate, PowerModel};
use crate::arch::vck5000::BoardConfig;
use crate::mapping::candidate::{Kind, MappingCandidate};
use crate::recurrence::dtype::DType;

/// Which resource binds the design (on-chip classification).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PerfBound {
    Compute,
    PlioIn,
    PlioOut,
    Dram,
}

impl std::fmt::Display for PerfBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PerfBound::Compute => write!(f, "compute"),
            PerfBound::PlioIn => write!(f, "plio-in"),
            PerfBound::PlioOut => write!(f, "plio-out"),
            PerfBound::Dram => write!(f, "dram"),
        }
    }
}

/// Full performance estimate for one candidate.
#[derive(Debug, Clone)]
pub struct PerfEstimate {
    /// On-chip throughput (paper Table III semantics).
    pub tops: f64,
    /// Cold-DRAM end-to-end throughput.
    pub tops_e2e: f64,
    pub seconds: f64,
    pub aies: u64,
    pub tops_per_aie: f64,
    pub bound: PerfBound,
    /// Total time components (seconds).
    pub compute_s: f64,
    pub plio_in_s: f64,
    pub plio_out_s: f64,
    pub dram_s: f64,
    /// PLIO ports the design needs after packet-switch/broadcast merging.
    pub plio_in_ports: u32,
    pub plio_out_ports: u32,
    /// DRAM bytes moved (end-to-end).
    pub dram_bytes: u64,
    /// Average MAC occupancy of active AIEs (for the power model).
    pub occupancy: f64,
}

/// The multi-metric design estimate every consumer sees: throughput and
/// power priced together, from one candidate, under one port model and
/// one power model. `perf` is the Table III half; `power` is the
/// Table IV half, derived from the same activity (`perf.aies`, merged
/// PLIO ports, mover DSPs, DRAM GB/s, `perf.occupancy`) — so the two
/// can never describe different designs.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub perf: PerfEstimate,
    pub power: PowerEstimate,
}

/// Mutation seam for `make mutation-smoke`: `WIDESA_MUTATE=cost-peak`
/// halves every sustained issue efficiency. A vacuous ranking/throughput
/// test suite would keep passing under that perturbation; the smoke
/// target asserts the Table III tolerances and framework throughput
/// gates actually fail. Read once (the DSE calls this in a hot loop).
fn mutation_scale() -> f64 {
    static SCALE: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *SCALE.get_or_init(|| match std::env::var("WIDESA_MUTATE").as_deref() {
        Ok("cost-peak") => 0.5,
        _ => 1.0,
    })
}

/// Mutation seam for `make mutation-smoke`: `WIDESA_MUTATE=blocking-reuse`
/// makes [`CostModel::blocked_mm_dram_bytes`] mis-count panel reuse — the
/// streamed operand's reload factor collapses to 1, as if every panel
/// order got perfect reuse for free. Under that lie the host-blocking
/// planner picks a traffic-pessimal order; the planner guard test
/// (`blocking_planner_prices_true_reuse`) is asserted to flip. Read once
/// (the planner prices hundreds of candidates per plan).
pub(crate) fn blocking_reuse_mutated() -> bool {
    static CACHE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        matches!(std::env::var("WIDESA_MUTATE").as_deref(), Ok("blocking-reuse"))
    })
}

/// Mutation seam for `make mutation-smoke`: `WIDESA_MUTATE=ca-reduce`
/// makes the CA traffic pricer *forget* the partial-sum reduction bytes —
/// as if reducing `replicate` partial C tiles down the replication axis
/// were free. Under that lie the communication-avoiding form looks
/// strictly cheaper than it is; the guard test
/// (`ca_pricer_charges_partial_sum_reduction`) is asserted to flip. Read
/// once (the DSE prices every CA candidate through this).
fn ca_reduce_scale() -> f64 {
    static SCALE: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *SCALE.get_or_init(|| match std::env::var("WIDESA_MUTATE").as_deref() {
        Ok("ca-reduce") => 0.0,
        _ => 1.0,
    })
}

/// Sustained issue efficiency of the generated AIE microkernel
/// (kernel-level calibration — see module docs). Values assume latency
/// hiding has filled the accumulation pipeline; [`CostModel::estimate`]
/// multiplies by the latency plan's efficiency.
pub fn issue_efficiency(kind: Kind, dtype: DType) -> f64 {
    let base = match (kind, dtype) {
        (Kind::Mm, DType::F32) => 0.52,
        (Kind::Mm, DType::I8) => 0.254,
        (Kind::Mm, DType::I16) => 0.253,
        (Kind::Mm, DType::I32) => 0.49,
        (Kind::Mm, DType::CF32) => 0.40,
        (Kind::Mm, DType::CI16) => 0.30,
        // CA MM replicas run the MM microkernel with an extra partial-sum
        // accumulate per k-slab boundary — a hair under the dense MM
        // sustained rates.
        (Kind::CaMm, DType::F32) => 0.50,
        (Kind::CaMm, DType::I8) => 0.244,
        (Kind::CaMm, DType::I16) => 0.243,
        (Kind::CaMm, DType::I32) => 0.47,
        (Kind::CaMm, _) => 0.36,
        (Kind::Conv2d, DType::F32) => 0.5625,
        (Kind::Conv2d, DType::I8) => 0.2814,
        (Kind::Conv2d, DType::I16) => 0.3234,
        (Kind::Conv2d, DType::I32) => 0.56,
        (Kind::Conv2d, _) => 0.40,
        (Kind::Fir, DType::F32) => 0.5703,
        (Kind::Fir, DType::I8) => 0.4797,
        (Kind::Fir, DType::I16) => 0.4624,
        (Kind::Fir, DType::CF32) => 0.5645,
        (Kind::Fir, _) => 0.45,
        (Kind::Fft2d, DType::CF32) => 0.1719,
        (Kind::Fft2d, DType::CI16) => 0.1496,
        (Kind::Fft2d, _) => 0.15,
        // Depthwise conv sustains slightly below dense conv: the same MAC
        // pattern but less register-level reuse per loaded operand
        // (per-group kernels; cf. the XVDPU depthwise path).
        (Kind::DwConv2d, DType::F32) => 0.54,
        (Kind::DwConv2d, DType::I8) => 0.27,
        (Kind::DwConv2d, DType::I16) => 0.31,
        (Kind::DwConv2d, DType::I32) => 0.53,
        (Kind::DwConv2d, _) => 0.38,
        // Triangular solve: MM-shaped MACs interrupted by the per-row
        // divide and short accumulation runs near the diagonal.
        (Kind::Trsv, DType::F32) => 0.41,
        (Kind::Trsv, DType::I32) => 0.39,
        (Kind::Trsv, _) => 0.33,
        // Stencil sweeps: 5 short MACs per point with neighbour loads —
        // below conv, above FFT (cf. Brown's Versal advection study,
        // arXiv:2301.13016, which sustains ~half of dense-conv issue).
        (Kind::Stencil, DType::F32) => 0.47,
        (Kind::Stencil, DType::I16) => 0.33,
        (Kind::Stencil, DType::I32) => 0.45,
        (Kind::Stencil, _) => 0.30,
    };
    base * mutation_scale()
}

/// Packet-switch aggregation limits: one switch stage merges 4 packet
/// streams; low-rate private streams may chain two stages.
pub const MAX_PACKET_FANIN_EDGE: u64 = 4;
pub const MAX_PACKET_FANIN_PRIVATE: u64 = 8;

/// Which PLIO port-count model [`CostModel::estimate`] prices designs
/// with — the **one-port-model invariant** knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PortModel {
    /// Exact packet-merge counts from the incremental predictor
    /// ([`crate::graph::packet::predict_ports`]), bit-identical to what
    /// [`crate::graph::packet::merge_ports_with_budget`] realises on the
    /// built graph. The default: the DSE ranking, the simulator and the
    /// framework's published estimates all price one consistent port
    /// model, so the ranking can never crown a design whose merged ports
    /// blow the budget while a cheaper-ported rival existed.
    #[default]
    Exact,
    /// The legacy analytic stream-class packing — the pre-unification
    /// ranking, kept for A/B comparison
    /// ([`crate::mapping::dse::DseConstraints::analytic_ranking`]).
    Analytic,
}

#[derive(Debug, Clone)]
pub struct CostModel {
    pub board: BoardConfig,
    /// PL-side DMA mover datapath width in bits (the DMA module
    /// constructor's choice): 512 for tuned designs, 128 conservative.
    pub mover_bits: u64,
    /// Port-count model [`CostModel::estimate`] prices with.
    pub ports: PortModel,
    /// Power model every estimate is priced with — the **one-power-model
    /// invariant**: the DSE ranking, the simulator and the framework's
    /// published estimates all derive watts from this same model.
    pub power: PowerModel,
}

/// Price a perf estimate through a power model. This is *the* activity
/// derivation (shared by [`CostModel::estimate`], `sim::engine`, the
/// energy eval tables, and `serve::persist`'s snapshot-load recompute):
/// active AIEs, total merged PLIO channels, Table IV mover DSPs for the
/// dtype, achieved DRAM GB/s, and the estimate's own occupancy.
pub fn price_power(model: &PowerModel, dtype: DType, perf: &PerfEstimate) -> PowerEstimate {
    let act = design_activity(
        dtype,
        perf.aies,
        perf.plio_in_ports + perf.plio_out_ports,
        perf.dram_bytes,
        perf.seconds,
        perf.occupancy,
    );
    model.estimate(perf.tops, perf.seconds, &act)
}

impl CostModel {
    pub fn new(board: BoardConfig) -> Self {
        Self {
            board,
            mover_bits: 512,
            ports: PortModel::default(),
            power: PowerModel::default(),
        }
    }

    pub fn with_mover_bits(mut self, bits: u64) -> Self {
        self.mover_bits = bits;
        self
    }

    pub fn with_port_model(mut self, ports: PortModel) -> Self {
        self.ports = ports;
        self
    }

    pub fn with_power_model(mut self, power: PowerModel) -> Self {
        self.power = power;
        self
    }

    /// Effective per-channel PLIO bandwidth: AIE-side stream rate capped
    /// by the PL-side mover.
    pub fn channel_bw(&self) -> f64 {
        let aie_side = self.board.plio.channel_bandwidth();
        let pl_side = self.mover_bits as f64 / 8.0 * self.board.pl.freq_hz;
        aie_side.min(pl_side)
    }

    /// Score a candidate under the configured [`PortModel`].
    ///
    /// With [`PortModel::Exact`] (the default) the PLIO port counts come
    /// from the incremental packet-merge predictor — the same counts
    /// port merging will realise on the built graph — so no mapped graph
    /// is needed and the estimate still agrees with what place & route
    /// sees. [`PortModel::Analytic`] keeps the legacy stream-class
    /// packing for A/B comparison.
    pub fn estimate(&self, cand: &MappingCandidate) -> Estimate {
        match self.ports {
            PortModel::Exact => {
                let stats = crate::graph::packet::predict_ports(
                    cand,
                    self,
                    self.channel_bw(),
                    self.board.plio.in_channels as usize,
                    self.board.plio.out_channels as usize,
                );
                self.estimate_impl(
                    cand,
                    Some((stats.in_ports_after as u64, stats.out_ports_after as u64)),
                )
            }
            PortModel::Analytic => self.estimate_impl(cand, None),
        }
    }

    /// The legacy analytic port-packing estimate, regardless of the
    /// configured [`PortModel`].
    pub fn estimate_analytic(&self, cand: &MappingCandidate) -> Estimate {
        self.estimate_impl(cand, None)
    }

    /// Score a candidate with *exact* merged PLIO port counts — the
    /// numbers [`crate::graph::packet::merge_ports_with_budget`] actually
    /// produced for the built graph — instead of the analytic packing
    /// approximation. This is what the framework reports once a design
    /// has been through port merging, so the published estimate agrees
    /// with what place & route actually sees. Counts are clamped to the
    /// board's channel budget exactly like the analytic path.
    pub fn estimate_with_ports(
        &self,
        cand: &MappingCandidate,
        in_ports: u64,
        out_ports: u64,
    ) -> Estimate {
        self.estimate_impl(cand, Some((in_ports, out_ports)))
    }

    fn estimate_impl(
        &self,
        cand: &MappingCandidate,
        exact_ports: Option<(u64, u64)>,
    ) -> Estimate {
        let core = &self.board.array.core;
        let dtype = cand.rec.dtype;
        let eff = issue_efficiency(cand.kind, dtype) * cand.latency.efficiency(core);
        let aies = cand.aies_used().max(1);
        let mac_rate_core = core.macs_per_cycle(dtype) as f64 * core.freq_hz * eff;

        // ---- total compute time ------------------------------------------
        let rounds = cand.rounds().max(1);
        let steps = cand.time_steps_per_round().max(1);
        let macs_per_step_core = cand.scope.core_macs.max(1);
        let step_compute_s = macs_per_step_core as f64 / mac_rate_core;
        let compute_total_s = rounds as f64 * steps as f64 * step_compute_s;

        // ---- PLIO traffic (totals) ----------------------------------------
        let traffic = self.traffic(cand, rounds, steps);
        let bw = self.channel_bw();

        // Port counts: exact merged counts when the caller has a built
        // graph, else stream classes packed analytically by rate.
        let (in_ports_needed, out_ports_needed) = match exact_ports {
            Some((i, o)) => (i, o),
            None => {
                let pack = |streams: u64, bytes_per_stream: f64, max_fanin: u64| -> u64 {
                    if streams == 0 {
                        return 0;
                    }
                    let rate = bytes_per_stream / compute_total_s.max(1e-12);
                    let fanin = ((bw * 0.8 / rate.max(1.0)) as u64).clamp(1, max_fanin);
                    streams.div_ceil(fanin)
                };
                let inp = pack(
                    traffic.edge_in_streams,
                    traffic.edge_in_bytes_per_stream,
                    MAX_PACKET_FANIN_EDGE,
                ) + pack(
                    traffic.private_in_streams,
                    traffic.private_in_bytes_per_stream,
                    MAX_PACKET_FANIN_PRIVATE,
                ) + traffic.broadcast_ports;
                let outp = pack(
                    traffic.private_out_streams,
                    traffic.private_out_bytes_per_stream,
                    MAX_PACKET_FANIN_PRIVATE,
                );
                (inp, outp)
            }
        };

        let in_ports = in_ports_needed.min(self.board.plio.in_channels as u64).max(1);
        let out_ports = out_ports_needed
            .min(self.board.plio.out_channels as u64)
            .max(1);

        let plio_in_s = traffic.in_bytes_total / (in_ports as f64 * bw);
        let plio_out_s = traffic.out_bytes_total / (out_ports as f64 * bw);

        // ---- on-chip execution (double-buffered overlap) -------------------
        // Systolic pipeline fill (array diameter × step) is paid once and
        // only by edge-fed systolic designs; private-stream designs start
        // computing as soon as their first tile lands. The simulator
        // prices fill through the same `fill_steps()` method, so the two
        // models cannot disagree on it.
        let fill_s = cand.fill_steps() as f64 * step_compute_s;
        let exec_s = compute_total_s.max(plio_in_s).max(plio_out_s) + fill_s;

        // ---- DRAM (end-to-end) ---------------------------------------------
        let dram_bytes = self.dram_traffic(cand);
        let dram_s = dram_bytes as f64 / self.board.pl.dram_bandwidth();
        let e2e_s = exec_s.max(dram_s);

        let ops = cand.rec.total_ops();
        let tops = ops / exec_s / 1e12;
        let tops_e2e = ops / e2e_s / 1e12;

        let bound = if compute_total_s >= plio_in_s.max(plio_out_s) {
            PerfBound::Compute
        } else if plio_in_s >= plio_out_s {
            PerfBound::PlioIn
        } else {
            PerfBound::PlioOut
        };

        let perf = PerfEstimate {
            tops,
            tops_e2e,
            seconds: exec_s,
            aies,
            tops_per_aie: tops / aies as f64,
            bound,
            compute_s: compute_total_s,
            plio_in_s,
            plio_out_s,
            dram_s,
            plio_in_ports: in_ports as u32,
            plio_out_ports: out_ports as u32,
            dram_bytes,
            occupancy: (compute_total_s / exec_s).min(1.0),
        };
        let power = price_power(&self.power, dtype, &perf);
        Estimate { perf, power }
    }

    /// Total PLIO traffic decomposition by workload family.
    ///
    /// Halo/window overlaps between neighbouring cores travel through the
    /// shared-buffer DMA links (Table I's 15.6 TB/s), not PLIOs, so
    /// private streams carry only each core's *unique* bytes.
    fn traffic(&self, cand: &MappingCandidate, rounds: u64, steps: u64) -> Traffic {
        let (r, c) = cand.replica_shape();
        let f = cand.threading.factor.max(1);
        // actual cores (1D partitions fold serpentine and may not fill
        // the last row of the (r, c) bounding box)
        let active = cand.partition.active_aies() * f;
        let b = cand.rec.dtype.bytes();
        let t = &cand.scope.core_factors;
        let total_steps = (rounds * steps) as f64;
        match cand.kind {
            Kind::Mm => {
                let (n0, m0, k0) = (t[0], t[1], t[2]);
                let a_tile = n0 * k0 * b;
                let b_tile = k0 * m0 * b;
                let c_tile = n0 * m0 * b;
                // k-segmentation: if the PL buffer cannot hold the A/B
                // panels of a round, partial C tiles drain and reload
                // once per extra segment (the Figure 6 buffer mechanism).
                let k_ext = cand.rec.domain.dims[2].extent;
                let panel = (r * t[0] + c * t[1]) * k_ext * b;
                let segments = panel.div_ceil(self.board.pl.buffer_bytes().max(1)).max(1);
                let c_redrain = (segments - 1) as f64 * (r * c) as f64 * c_tile as f64 * rounds as f64;

                let edge_streams = (r + c) * f;
                let in_total =
                    total_steps * (r * a_tile + c * b_tile) as f64 * f as f64 + c_redrain;
                let out_total = (rounds * r * c * c_tile * f) as f64 + c_redrain;
                Traffic {
                    edge_in_streams: edge_streams,
                    edge_in_bytes_per_stream: in_total / edge_streams.max(1) as f64,
                    private_in_streams: 0,
                    private_in_bytes_per_stream: 0.0,
                    broadcast_ports: 0,
                    private_out_streams: r * c * f,
                    private_out_bytes_per_stream: out_total / (r * c * f).max(1) as f64,
                    in_bytes_total: in_total,
                    out_bytes_total: out_total,
                }
            }
            Kind::CaMm => {
                // Replicated-summand MM: `rr` row-replicas each walk a
                // k-slab. B is edge-fed per replication row; one
                // broadcast port carries the rows' A slabs (one copy
                // serves the whole chain — the communication saving over
                // the standard form's per-column feeds). Partial C tiles
                // reduce on chip down the replication axis; the
                // reduction bytes are charged to the output side — the
                // bottom-row cores absorb (rr − 1) partial tiles per
                // column before the merged drain leaves the array.
                let (rr, cc) = cand.replica_shape();
                let (n0, m0, k0) = (t[0], t[1], t[2]);
                let a_tile = n0 * k0 * b;
                let b_tile = k0 * m0 * b;
                let c_tile = n0 * m0 * b;
                let in_total = total_steps * (rr * (a_tile + b_tile)) as f64 * f as f64;
                let drain = (rounds * cc * c_tile * f) as f64;
                let reduce =
                    (rounds * cc * (rr - 1) * c_tile * f) as f64 * ca_reduce_scale();
                let out_total = drain + reduce;
                Traffic {
                    edge_in_streams: rr * f,
                    edge_in_bytes_per_stream: in_total / (rr * f).max(1) as f64,
                    private_in_streams: 0,
                    private_in_bytes_per_stream: 0.0,
                    broadcast_ports: f,
                    private_out_streams: cc * f,
                    private_out_bytes_per_stream: out_total / (cc * f).max(1) as f64,
                    in_bytes_total: in_total,
                    out_bytes_total: out_total,
                }
            }
            Kind::Conv2d => {
                // Unique input bytes = output tile bytes (halo via DMA).
                let (h0, w0, _, _) = (t[0], t[1], t[2], t[3]);
                let x_tile = h0 * w0 * b;
                let y_tile = h0 * w0 * b;
                let cores = active;
                let in_total = total_steps * (cores * x_tile) as f64;
                let out_total = total_steps * (cores * y_tile) as f64;
                Traffic::private(cores, in_total, out_total, 1)
            }
            Kind::Fir => {
                let n0 = t[0];
                let x_chunk = n0 * b; // unique bytes (window overlap via DMA)
                let y_chunk = n0 * b;
                let cores = active;
                let in_total = total_steps * (cores * x_chunk) as f64;
                let out_total = total_steps * (cores * y_chunk) as f64;
                Traffic::private(cores, in_total, out_total, 1)
            }
            Kind::Fft2d => {
                // Each row enters and leaves once per pass.
                let dims = &cand.rec.domain.dims;
                let rows = dims[1].extent;
                let cols = dims[3].extent * 2;
                let passes = dims[0].extent;
                let cores = active;
                let total = (passes * rows * cols * b) as f64;
                Traffic::private(cores, total, total, 1)
            }
            Kind::DwConv2d => {
                // Per-core unique input bytes equal the output tile (the
                // spatial halo travels over the shared-buffer DMA links,
                // as for dense conv); per-group kernels ride the
                // broadcast port.
                let tile = t[0] * t[1] * t[2] * b;
                let cores = active;
                let in_total = total_steps * (cores * tile) as f64;
                let out_total = total_steps * (cores * tile) as f64;
                Traffic::private(cores, in_total, out_total, 1)
            }
            Kind::Trsv => {
                // L has no reuse: every matrix element crosses a PLIO
                // exactly once, so the L byte stream dominates and the
                // workload is PLIO-bound at any interesting array size.
                // x values ride along with each tile; row results drain
                // once per round.
                //
                // The solve's concurrency is bounded by its wavefront:
                // x(j) transitively depends on x(j−1), so at any instant
                // only one block-column of the triangle is computable —
                // at most `V_i` row-blocks, shrinking to 1 as the solve
                // descends (average V_i/2). A design that instantiates
                // more concurrent tiles than that wavefront stalls its
                // streams proportionally. 1D chains (the Kung–Leiserson
                // linear-array family) sit near the bound; 2D hull
                // mappings instantiate the whole rectangle and idle
                // hardest — which is why the DSE ranks a 1D array first
                // (see docs/WORKLOADS.md).
                let l_tile = t[0] * t[1] * b;
                let x_tile = t[1] * b;
                let y_tile = t[0] * b;
                let cores = active;
                let v_i = cand.rec.domain.dims[0].extent / t[0].max(1);
                let wavefront = (v_i as f64 / 2.0).max(1.0);
                let stall = (cores as f64 / wavefront).max(1.0);
                let in_total = total_steps * (cores * (l_tile + x_tile)) as f64 * stall;
                let out_total = (rounds * cores * y_tile) as f64 * stall;
                Traffic::private(cores, in_total, out_total, 1)
            }
            Kind::Stencil => {
                // One sweep per graph step: each core loads its grid tile
                // (the ±1 halo travels over the shared-buffer DMA links)
                // and stores the updated tile; the 5 coefficients ride
                // the broadcast port. t is never core-tiled (see
                // `tiling_preserves_order`), so core factors are
                // [1, i0, j0].
                let tile = t[1] * t[2] * b;
                let cores = active;
                let in_total = total_steps * (cores * tile) as f64;
                let out_total = total_steps * (cores * tile) as f64;
                Traffic::private(cores, in_total, out_total, 1)
            }
        }
    }

    /// Total DRAM traffic for the end-to-end number.
    fn dram_traffic(&self, cand: &MappingCandidate) -> u64 {
        let b = cand.rec.dtype.bytes();
        let buf = self.board.pl.buffer_bytes();
        let t = &cand.scope.core_factors;
        let dims = &cand.rec.domain.dims;
        match cand.kind {
            Kind::Mm => {
                let (n, m, k) = (dims[0].extent, dims[1].extent, dims[2].extent);
                let (r, c) = cand.replica_shape();
                let n_tile = r * t[0];
                let m_tile = c * t[1];
                let a_panel = n_tile * k * b;
                let b_panel = m_tile * k * b;
                let reload_a = if a_panel <= buf / 2 {
                    1
                } else {
                    m.div_ceil(m_tile).max(1)
                };
                let reload_b = if b_panel <= buf / 2 {
                    1
                } else {
                    n.div_ceil(n_tile).max(1)
                };
                let thread_out = cand.threading.factor.max(1);
                n * k * b * reload_a + m * k * b * reload_b + (1 + thread_out) * n * m * b
            }
            Kind::CaMm => {
                // Every k-slab of A and B is read once (the on-chip
                // broadcast gives the chain full A reuse; partial sums
                // reduce on chip and never round-trip DRAM). C is written
                // once plus one pass per threading-replica recombination.
                let (n, m, k) = (dims[0].extent, dims[1].extent, dims[2].extent);
                let thread_out = cand.threading.factor.max(1);
                n * k * b + m * k * b + (1 + thread_out) * n * m * b
            }
            Kind::Conv2d => {
                let (h, w, p, q) = (dims[0].extent, dims[1].extent, dims[2].extent, dims[3].extent);
                (h + p - 1) * (w + q - 1) * b + p * q * b + h * w * b
            }
            Kind::Fir => {
                let (n, taps) = (dims[0].extent, dims[1].extent);
                (n + taps - 1) * b + taps * b + n * b
            }
            Kind::Fft2d => {
                let (rows, bfly) = (dims[1].extent, dims[3].extent);
                let cols = bfly * 2;
                6 * rows * cols * b // 2 passes r/w + transpose r/w
            }
            Kind::DwConv2d => {
                let (g, h, w, p, q) = (
                    dims[0].extent,
                    dims[1].extent,
                    dims[2].extent,
                    dims[3].extent,
                    dims[4].extent,
                );
                g * ((h + p - 1) * (w + q - 1) + p * q + h * w) * b
            }
            Kind::Trsv => {
                // the real triangular footprint: the hull's strictly
                // upper half never moves; b in, x out
                let n = dims[0].extent;
                n * (n + 1) / 2 * b + 2 * n * b
            }
            Kind::Stencil => {
                // grid in + grid out; intermediate sweeps stay on-chip
                // (the PL buffer ping-pongs the chain)
                let (n, m) = (dims[1].extent, dims[2].extent);
                2 * n * m * b + 5 * b
            }
        }
    }

    /// DRAM bytes a GotoBLAS2-style host-blocked MM replay moves under one
    /// blocking choice — **the** pricing formula the host-blocking planner
    /// ([`crate::coordinator::blocking`]) minimizes over, kept here next
    /// to [`Self::dram_traffic`] so the DSE and the planner price DRAM
    /// with one model (same `buffer_bytes()/2` residency convention, same
    /// reload-factor accounting as the `Kind::Mm` arm above).
    ///
    /// Dimensions are the *padded* problem (tile multiples); `eb` is the
    /// element width. One operand panel (`kc × span` of B when
    /// `b_resident`, else of A) stays resident in the PL buffer across
    /// the inner loop, so it is read once; the other operand streams and
    /// re-reads once per panel step of the resident operand's free
    /// dimension. C is written once per k-segment and re-read on every
    /// re-entry (`2·segs − 1` transfers of n×m).
    ///
    /// Internally u128 (an absurd shape like 1e9³ would overflow u64
    /// mid-sum), saturating to `u64::MAX` on return — the planner's
    /// feasibility cap rejects such shapes before any driver runs them.
    pub fn blocked_mm_dram_bytes(
        &self,
        n: u64,
        m: u64,
        k: u64,
        eb: u64,
        kc: u64,
        span: u64,
        b_resident: bool,
    ) -> u64 {
        let (n, m, k, eb) = (n as u128, m as u128, k as u128, eb as u128);
        let segs = (k.div_ceil(kc.max(1) as u128)).max(1);
        let (resident_once, streamed_total, mut reload) = if b_resident {
            // B panels resident: A streams, re-read per jc panel of m.
            (k * m * eb, n * k * eb, m.div_ceil(span.max(1) as u128).max(1))
        } else {
            // A panels resident: B streams, re-read per ic panel of n.
            (n * k * eb, k * m * eb, n.div_ceil(span.max(1) as u128).max(1))
        };
        if blocking_reuse_mutated() {
            reload = 1; // seam: pretend the streamed operand never re-reads
        }
        let c_rw = n * m * eb * (2 * segs - 1);
        let total = resident_once + streamed_total * reload + c_rw;
        u64::try_from(total).unwrap_or(u64::MAX)
    }
}

struct Traffic {
    edge_in_streams: u64,
    edge_in_bytes_per_stream: f64,
    private_in_streams: u64,
    private_in_bytes_per_stream: f64,
    broadcast_ports: u64,
    private_out_streams: u64,
    private_out_bytes_per_stream: f64,
    in_bytes_total: f64,
    out_bytes_total: f64,
}

impl Traffic {
    fn private(cores: u64, in_total: f64, out_total: f64, broadcast_ports: u64) -> Self {
        Traffic {
            edge_in_streams: 0,
            edge_in_bytes_per_stream: 0.0,
            private_in_streams: cores,
            private_in_bytes_per_stream: in_total / cores.max(1) as f64,
            broadcast_ports,
            private_out_streams: cores,
            private_out_bytes_per_stream: out_total / cores.max(1) as f64,
            in_bytes_total: in_total,
            out_bytes_total: out_total,
        }
    }
}

impl MappingCandidate {
    /// Rounds multiplier hook (threaded reductions recombine on the PL at
    /// negligible cost in this model).
    pub fn threadable_rounds_scale(&self) -> u64 {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn estimate_best(
        rec: crate::recurrence::spec::UniformRecurrence,
        max_aies: Option<u64>,
    ) -> Estimate {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies,
            ..Default::default()
        };
        let (cand, est) = explore(&rec, &board, &cons).expect("mapping found");
        assert!(cand.aies_used() > 0);
        est
    }

    #[test]
    fn mm_f32_lands_near_paper() {
        let est = estimate_best(library::mm(8192, 8192, 8192, DType::F32), Some(400));
        assert!(
            (est.perf.tops - 4.15).abs() < 0.6,
            "MM f32 TOPS {} vs paper 4.15",
            est.perf.tops
        );
        assert_eq!(est.perf.aies, 400);
        assert_eq!(est.perf.bound, PerfBound::Compute);
    }

    #[test]
    fn mm_i8_lands_near_paper() {
        let est = estimate_best(library::mm(10240, 10240, 10240, DType::I8), Some(400));
        assert!(
            (est.perf.tops - 32.49).abs() < 4.0,
            "MM i8 TOPS {} vs paper 32.49",
            est.perf.tops
        );
    }

    #[test]
    fn conv_i8_lands_near_paper() {
        let est = estimate_best(library::conv2d(10240, 10240, 8, 8, DType::I8), Some(400));
        assert!(
            (est.perf.tops - 36.02).abs() < 5.0,
            "Conv i8 TOPS {} vs paper 36.02",
            est.perf.tops
        );
    }

    #[test]
    fn fir_f32_lands_near_paper() {
        let est = estimate_best(library::fir(1048576, 15, DType::F32), Some(256));
        assert!(
            (est.perf.tops - 2.92).abs() < 0.6,
            "FIR f32 TOPS {} vs paper 2.92",
            est.perf.tops
        );
    }

    #[test]
    fn fft_cf32_lands_near_paper() {
        let est = estimate_best(library::fft2d(8192, 8192, DType::CF32), Some(320));
        assert!(
            (est.perf.tops - 1.10).abs() < 0.35,
            "FFT cf32 TOPS {} vs paper 1.10",
            est.perf.tops
        );
    }

    #[test]
    fn e2e_never_exceeds_onchip() {
        for rec in [
            library::mm(8192, 8192, 8192, DType::F32),
            library::conv2d(10240, 10240, 4, 4, DType::F32),
            library::fir(1048576, 15, DType::F32),
            library::fft2d(8192, 8192, DType::CF32),
        ] {
            let est = estimate_best(rec, Some(400));
            assert!(est.perf.tops_e2e <= est.perf.tops * (1.0 + 1e-9));
        }
    }

    #[test]
    fn every_estimate_carries_consistent_power() {
        // The power half is derived from the perf half by the shared
        // `price_power` recipe — identical by construction, above the
        // static rail, and energy = watts × seconds.
        let model = CostModel::new(BoardConfig::vck5000());
        for rec in [
            library::mm(8192, 8192, 8192, DType::F32),
            library::trsv(8192, DType::F32),
        ] {
            let cons = DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            };
            let (cand, est) = explore(&rec, &BoardConfig::vck5000(), &cons).unwrap();
            let repriced = price_power(&model.power, cand.rec.dtype, &est.perf);
            assert_eq!(est.power.watts.to_bits(), repriced.watts.to_bits());
            assert_eq!(est.power.tops_per_watt.to_bits(), repriced.tops_per_watt.to_bits());
            assert!(est.power.watts > model.power.static_w);
            assert!(
                (est.power.energy_j - est.power.watts * est.perf.seconds).abs() < 1e-9,
                "energy_j must be watts × seconds"
            );
            assert!(est.power.tops_per_watt > 0.0);
        }
    }

    #[test]
    fn conservative_movers_shift_bound_to_plio() {
        // Figure 6 mechanism: 128-bit movers + few PLIO ports turn the
        // int8 MM design memory-bound at the full array.
        let rec = library::mm(10240, 10240, 10240, DType::I8);
        let board = BoardConfig::vck5000().with_plio_budget(8);
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board).with_mover_bits(128);
        let est = model.estimate(&cand);
        assert_ne!(est.perf.bound, PerfBound::Compute, "8 ports × 128-bit movers must bind");
        // And the same design with the full 78 ports is compute-bound.
        let model78 = CostModel::new(BoardConfig::vck5000()).with_mover_bits(512);
        let est78 = model78.estimate(&cand);
        assert_eq!(est78.perf.bound, PerfBound::Compute);
        assert!(est78.perf.tops > est.perf.tops);
    }

    #[test]
    fn small_buffer_adds_redrain_traffic() {
        let rec = library::mm(8192, 8192, 8192, DType::I8);
        let board_small = BoardConfig::vck5000().with_pl_buffer_bytes(1 << 20);
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &BoardConfig::vck5000(), &cons).unwrap();
        let small = CostModel::new(board_small).with_mover_bits(128).estimate(&cand);
        let big = CostModel::new(BoardConfig::vck5000())
            .with_mover_bits(128)
            .estimate(&cand);
        assert!(
            small.perf.plio_in_s > big.perf.plio_in_s,
            "segment drains must add PLIO traffic: {} vs {}",
            small.perf.plio_in_s,
            big.perf.plio_in_s
        );
        assert!(small.perf.tops <= big.perf.tops);
    }

    #[test]
    fn exact_port_estimate_tracks_merged_counts() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board);
        let analytic = model.estimate(&cand).perf;
        // Feeding the analytic path's own port counts back reproduces it.
        let echo = model
            .estimate_with_ports(
                &cand,
                analytic.plio_in_ports as u64,
                analytic.plio_out_ports as u64,
            )
            .perf;
        assert_eq!(echo.plio_in_ports, analytic.plio_in_ports);
        assert_eq!(echo.plio_out_ports, analytic.plio_out_ports);
        assert_eq!(echo.tops.to_bits(), analytic.tops.to_bits());
        // Halving the ports cannot shrink PLIO time, and over-budget
        // requests clamp to the board's channels.
        let halved = model
            .estimate_with_ports(
                &cand,
                (analytic.plio_in_ports as u64 / 2).max(1),
                (analytic.plio_out_ports as u64 / 2).max(1),
            )
            .perf;
        assert!(halved.plio_in_s >= analytic.plio_in_s);
        assert!(halved.plio_out_s >= analytic.plio_out_s);
        let clamped = model.estimate_with_ports(&cand, 10_000, 10_000).perf;
        assert!(clamped.plio_in_ports <= 78);
        assert!(clamped.plio_out_ports <= 78);
    }

    #[test]
    fn exact_is_default_and_flag_restores_analytic() {
        let rec = library::mm(8192, 8192, 8192, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board);
        assert_eq!(model.ports, PortModel::Exact);
        // the default estimate prices the predictor's merged counts
        let exact = model.estimate(&cand).perf;
        let stats = crate::graph::packet::predict_ports(
            &cand,
            &model,
            model.channel_bw(),
            78,
            78,
        );
        assert_eq!(exact.plio_in_ports as usize, stats.in_ports_after.clamp(1, 78));
        assert_eq!(exact.plio_out_ports as usize, stats.out_ports_after.clamp(1, 78));
        // the A/B flag reproduces the legacy analytic path bit-for-bit
        let flagged = model.clone().with_port_model(PortModel::Analytic).estimate(&cand).perf;
        let legacy = model.estimate_analytic(&cand).perf;
        assert_eq!(flagged.tops.to_bits(), legacy.tops.to_bits());
        assert_eq!(flagged.plio_in_ports, legacy.plio_in_ports);
        assert_eq!(flagged.plio_out_ports, legacy.plio_out_ports);
    }

    #[test]
    fn ports_within_board_limits() {
        for rec in [
            library::mm(8192, 8192, 8192, DType::F32),
            library::conv2d(10240, 10240, 4, 4, DType::F32),
            library::fir(1048576, 15, DType::I16),
            library::dw_conv2d(64, 256, 256, 3, 3, DType::F32),
            library::trsv(8192, DType::F32),
            library::stencil2d_chain(2, 1024, 1024, DType::F32),
            library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32),
            library::ca_mm_blockrec(512, 3, DType::F32),
            library::seidel2d(2, 64, 64, DType::F32),
        ] {
            let est = estimate_best(rec, Some(400));
            assert!(est.perf.plio_in_ports <= 78);
            assert!(est.perf.plio_out_ports <= 78);
        }
    }

    #[test]
    fn ca_pricer_charges_partial_sum_reduction() {
        // The CA output side must charge the on-chip reduction on top of
        // the merged drain — forgetting it is exactly the
        // WIDESA_MUTATE=ca-reduce lie `make mutation-smoke` injects, and
        // this is the guard asserted to flip under it.
        let rec = library::ca_mm_25d(1024, 1024, 1024, 4, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board);
        let rounds = cand.rounds().max(1);
        let steps = cand.time_steps_per_round().max(1);
        let tr = model.traffic(&cand, rounds, steps);
        let (rr, cc) = cand.replica_shape();
        let f = cand.threading.factor.max(1);
        let t = &cand.scope.core_factors;
        let c_tile = t[0] * t[1] * cand.rec.dtype.bytes();
        let drain = (rounds * cc * c_tile * f) as f64;
        let reduce = (rounds * cc * (rr - 1) * c_tile * f) as f64;
        assert!(rr >= 2 && reduce > 0.0);
        assert!(
            tr.out_bytes_total >= drain + reduce * 0.999,
            "CA out bytes {} must include the {} reduction bytes over the {} drain",
            tr.out_bytes_total,
            reduce,
            drain
        );
    }

    #[test]
    fn ca_estimates_are_positive_and_consistent() {
        for (_, ca) in library::ca_pairs() {
            let est = estimate_best(ca, Some(400));
            assert!(est.perf.tops > 0.0);
            assert!(est.perf.tops_e2e <= est.perf.tops * (1.0 + 1e-9));
            assert!(est.perf.dram_bytes > 0);
        }
    }

    #[test]
    fn new_families_have_positive_estimates() {
        for rec in [
            library::dw_conv2d(64, 256, 256, 3, 3, DType::F32),
            library::trsv(8192, DType::F32),
            library::stencil2d_chain(2, 1024, 1024, DType::F32),
        ] {
            let est = estimate_best(rec, Some(400));
            assert!(est.perf.tops > 0.0);
            assert!(est.perf.tops_e2e <= est.perf.tops * (1.0 + 1e-9));
            assert!(est.perf.dram_bytes > 0);
        }
    }

    #[test]
    fn trsv_wavefront_bound_crowns_the_1d_linear_array() {
        // the solve's block-column wavefront caps usable concurrency, so
        // the ranking must put the classic Kung–Leiserson 1D array (the
        // accumulation loop j spatial, rows streaming through time) above
        // every hull mapping that instantiates more tiles than the wave
        let rec = library::trsv(8192, DType::F32);
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let all = crate::mapping::dse::explore_all(&rec, &board, &cons);
        assert!(all.len() >= 3, "hull candidates missing");
        let winner = &all[0].0;
        assert_eq!(winner.choice.dims(), 1, "{}", winner.summary());
        // L streams are the bound: the design is PLIO-in limited
        assert_eq!(all[0].1.perf.bound, PerfBound::PlioIn, "{}", winner.summary());
        // every 2D hull mapping ranks strictly below the linear array
        for (cand, est) in &all[1..] {
            if cand.choice.dims() == 2 {
                assert!(
                    est.perf.tops < all[0].1.perf.tops,
                    "2D hull {} must trail the 1D array",
                    cand.summary()
                );
            }
        }
    }
}

