//! Multiple threading (paper §III-B-4).
//!
//! AIE cores execute concurrently, so a *parallelizable* time loop (one
//! whose iterations exchange no values — e.g. the reduction loop k in MM
//! split into partial sums recombined afterwards) can be strip-mined and
//! its point loop unrolled across replicas of the whole systolic array:
//! the same kernel program with different indexing, multiplying the
//! active-AIE count without new programs to write.

use crate::polyhedral::dependence::DepKind;
use crate::polyhedral::schedule::{LoopNest, LoopRole};

/// A multiple-threading decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Threading {
    /// Time-loop index being threaded (in the space-time nest), if any.
    pub dim: Option<usize>,
    /// Replication factor (1 = no threading).
    pub factor: u64,
    /// Whether the threaded loop is a reduction (partial results must be
    /// recombined — adds one reduction pass per round).
    pub is_reduction: bool,
}

impl Threading {
    pub fn none() -> Self {
        Self {
            dim: None,
            factor: 1,
            is_reduction: false,
        }
    }
}

/// Time loops eligible for threading: every dependence with a non-zero
/// component on the loop is a Flow/Output *reduction* dependence (partial
/// sums can be recombined associatively) or none at all — and the chain
/// must be confined to the loop itself plus kernel-scope point loops (the
/// intra-tile half of the same strip-mined chain). A carried dependence
/// that also moves along another graph loop — a stencil halo like
/// `(1, ±1, 0)` — is *not* an associative reduction: splitting its loop
/// across replicas would compute sweeps against stale neighbours, so
/// such loops are excluded.
pub fn threadable_time_loops(nest: &LoopNest) -> Vec<(usize, bool)> {
    nest.loops_with_role(LoopRole::Time)
        .into_iter()
        .filter_map(|d| {
            if nest.domain.dims[d].extent <= 1 {
                return None;
            }
            let carried: Vec<_> = nest
                .deps
                .iter()
                .filter(|dep| dep.vector[d] != 0)
                .collect();
            if carried.is_empty() {
                Some((d, false))
            } else if carried.iter().all(|dep| {
                matches!(dep.kind, DepKind::Flow | DepKind::Output)
                    && dep.vector.iter().enumerate().all(|(o, &c)| {
                        o == d || c == 0 || nest.roles[o] == LoopRole::Kernel
                    })
            }) {
                // pure reduction chain: threadable with a recombine pass
                Some((d, true))
            } else {
                None
            }
        })
        .collect()
}

/// Pick the threading factor that fills `spare` replicas of the array
/// (factor divides the loop extent where possible).
pub fn plan(nest: &LoopNest, spare_replicas: u64) -> Threading {
    if spare_replicas <= 1 {
        return Threading::none();
    }
    let mut best = Threading::none();
    for (dim, is_reduction) in threadable_time_loops(nest) {
        let extent = nest.domain.dims[dim].extent;
        // largest divisor of extent ≤ spare_replicas (fall back to cap)
        let mut f = spare_replicas.min(extent);
        while f > 1 && extent % f != 0 {
            f -= 1;
        }
        if f > best.factor {
            best = Threading {
                dim: Some(dim),
                factor: f,
                is_reduction,
            };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::dependence::Dependence;
    use crate::polyhedral::domain::{IterationDomain, LoopDim};

    fn spacetime_mm() -> LoopNest {
        // (i, j) space; k time carrying the C reduction
        let mut nest = LoopNest::new(
            IterationDomain::new(vec![
                LoopDim::new("it", 8),
                LoopDim::new("jt", 50),
                LoopDim::new("kt", 256),
            ]),
            vec![
                Dependence::new("A", DepKind::Read, vec![0, 1, 0]),
                Dependence::new("C", DepKind::Flow, vec![0, 0, 1]),
                Dependence::new("C", DepKind::Output, vec![0, 0, 1]),
            ],
        );
        nest.roles = vec![LoopRole::Space, LoopRole::Space, LoopRole::Time];
        nest
    }

    #[test]
    fn k_is_threadable_as_reduction() {
        let nest = spacetime_mm();
        let t = threadable_time_loops(&nest);
        assert_eq!(t, vec![(2, true)]);
    }

    #[test]
    fn read_carried_time_loop_not_threadable() {
        let mut nest = spacetime_mm();
        nest.deps
            .push(Dependence::new("A", DepKind::Read, vec![0, 0, 1]));
        // now k also carries a read dep — still threadable? Read deps are
        // reuse only, but our conservative rule requires all carried deps
        // to be Flow/Output. The added Read blocks threading.
        assert!(threadable_time_loops(&nest).is_empty());
    }

    #[test]
    fn stencil_sweep_loop_is_not_a_reduction() {
        // a t-carried dep that also moves along a non-kernel loop (the
        // stencil halo (1, -1, 0)) must block threading of t: sweeps are
        // sequential, not an associative reduction
        let mut nest = LoopNest::new(
            IterationDomain::new(vec![
                LoopDim::new("t", 8),
                LoopDim::new("it", 16),
                LoopDim::new("jt", 16),
            ]),
            vec![
                Dependence::new("A", DepKind::Flow, vec![1, 0, 0]),
                Dependence::new("A", DepKind::Flow, vec![1, -1, 0]),
            ],
        );
        nest.roles = vec![LoopRole::Time, LoopRole::Space, LoopRole::Space];
        assert!(threadable_time_loops(&nest).is_empty());
        // …while an intra-tile (kernel-role) spill of the same chain is
        // still a pure reduction (the MM k-tile shape)
        let mut mm = LoopNest::new(
            IterationDomain::new(vec![LoopDim::new("kt", 64), LoopDim::new("kp", 4)]),
            vec![Dependence::new("C", DepKind::Flow, vec![1, -3])],
        );
        mm.roles = vec![LoopRole::Time, LoopRole::Kernel];
        assert_eq!(threadable_time_loops(&mm), vec![(0, true)]);
    }

    #[test]
    fn plan_picks_divisor_factor() {
        let nest = spacetime_mm();
        let t = plan(&nest, 4);
        assert_eq!(t.dim, Some(2));
        assert_eq!(t.factor, 4); // 256 % 4 == 0
        assert!(t.is_reduction);
    }

    #[test]
    fn plan_respects_non_divisor_budget() {
        let nest = spacetime_mm();
        let t = plan(&nest, 3);
        assert!(t.factor <= 3 && 256 % t.factor == 0);
    }

    #[test]
    fn no_spare_means_no_threading() {
        let nest = spacetime_mm();
        assert_eq!(plan(&nest, 1), Threading::none());
    }
}
