//! Round-level discrete-event simulation of a mapped design.
//!
//! The schedule a WideSA design executes is a stream of rounds; each
//! round loads its input tiles through the assigned PLIO ports, computes
//! on the array, and drains outputs. The movers double-buffer: round
//! `i+1`'s load overlaps round `i`'s compute, and drains overlap the next
//! round's compute. This engine walks that timeline event by event with
//! per-phase durations derived from the same first-principles quantities
//! the analytic model uses — but *composed* temporally rather than
//! bounded, so pipeline bubbles (cold start, prefetch misses, drain
//! backpressure) appear naturally.
//!
//! Phase durations come from `model.estimate(..)`, so the simulator
//! prices PLIO time with whatever port model the [`CostModel`] is
//! configured with — by default the **exact merged port counts**
//! ([`crate::mapping::cost::PortModel::Exact`]), the same counts the DSE
//! ranked with and packet merging realises. The sim/analytic agreement
//! tests therefore check one consistent port model end to end.
//!
//! ## Model assumptions (what is calibrated, what is coarse)
//!
//! * **Calibrated** — per-step compute time (kernel-level
//!   [`issue_efficiency`] × the latency-hiding plan, fitted to published
//!   per-AIE throughputs), PLIO phase totals (exact merged port counts ×
//!   the mover-limited channel bandwidth), and the systolic **fill**:
//!   both this engine and the analytic model price fill through the one
//!   [`MappingCandidate::fill_steps`] method (array diameter for
//!   edge-fed designs, zero for private-stream designs), so the two can
//!   never disagree on it — for any workload family, not just MM.
//! * **Coarse** — drain backpressure is a single in-flight drain slot
//!   (no per-port queue model); DRAM prefetch issues in round-sized
//!   granules against a flat-bandwidth [`Prefetcher`] (no bank or page
//!   structure); and intra-round overlap is approximated by slicing
//!   rounds to ≥32 pipeline stages rather than per-tile events. These
//!   are the knobs the ROADMAP's "sim accuracy calibration" item tracks:
//!   tightening any of them against per-round traces should shrink the
//!   ≤15 % sim/analytic tolerance, not move the analytic side.

use crate::mapping::candidate::MappingCandidate;
use crate::mapping::cost::{issue_efficiency, CostModel, PerfBound};
use crate::sim::memory::Prefetcher;
use crate::sim::metrics::SimReport;
use crate::sim::trace::{stall_fraction, RoundTrace};

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Simulate cold-DRAM end-to-end (true) or on-chip staging (false).
    pub cold_dram: bool,
    /// Keep the full per-round trace (memory ∝ rounds).
    pub keep_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            cold_dram: false,
            keep_trace: false,
        }
    }
}

/// Simulate `cand` under `model`.
pub fn simulate(cand: &MappingCandidate, model: &CostModel, cfg: &SimConfig) -> (SimReport, Vec<RoundTrace>) {
    let core = &model.board.array.core;
    let dtype = cand.rec.dtype;
    let eff = issue_efficiency(cand.kind, dtype) * cand.latency.efficiency(core);
    let mac_rate_core = core.macs_per_cycle(dtype) as f64 * core.freq_hz * eff;

    let sched_rounds = cand.rounds().max(1);
    let steps = cand.time_steps_per_round().max(1);
    let step_s = cand.scope.core_macs.max(1) as f64 / mac_rate_core;

    // Streaming designs overlap load/compute *within* a round (cores
    // start as soon as their first tile lands); model that by slicing
    // rounds so the pipeline has at least 32 stages of granularity.
    let slice = (32u64.div_ceil(sched_rounds)).max(1);
    let rounds = sched_rounds * slice;
    let compute_round_s = steps as f64 * step_s / slice as f64;

    // Phase durations shared with the analytic model: per-round PLIO
    // in/out times at the assigned port counts.
    let est = model.estimate(cand).perf;
    let in_round_s = est.plio_in_s / rounds as f64;
    let out_round_s = est.plio_out_s / rounds as f64;
    let in_bytes_round = est.dram_bytes as f64 / rounds as f64; // prefetch granularity

    let mut prefetch = if cfg.cold_dram {
        Prefetcher::new(model.board.pl.dram_bandwidth())
    } else {
        Prefetcher::onchip()
    };

    // Systolic fill before the first round's compute completes its value
    // — the shared fill model (see `MappingCandidate::fill_steps`), so
    // simulator and analytic estimate agree on fill for every family.
    let fill_s = cand.fill_steps() as f64 * step_s;

    let mut trace: Vec<RoundTrace> = Vec::with_capacity(if cfg.keep_trace {
        rounds.min(1 << 20) as usize
    } else {
        0
    });

    // Double-buffered timeline: the mover can load round i+1 while the
    // array computes round i; one load and one drain in flight at a time.
    let mut mover_free = 0.0f64; // input mover availability
    let mut array_free = fill_s; // array availability
    let mut drain_free = 0.0f64; // output mover availability
    let mut end = 0.0f64;
    let mut first_load_start = f64::INFINITY;

    for round in 0..rounds {
        let ready = prefetch.fetch(mover_free, in_bytes_round);
        let load_start = mover_free.max(ready - in_round_s.max(0.0)).max(0.0);
        let load_start = load_start.max(if ready > load_start + in_round_s {
            ready - in_round_s
        } else {
            load_start
        });
        let load_end = load_start.max(ready - in_round_s).max(load_start) + in_round_s;
        let load_end = load_end.max(ready);
        mover_free = load_end;

        let compute_start = load_end.max(array_free);
        let compute_end = compute_start + compute_round_s;
        array_free = compute_end;

        let drain_start = compute_end.max(drain_free);
        let drain_end = drain_start + out_round_s;
        drain_free = drain_end;
        end = drain_end;

        first_load_start = first_load_start.min(load_start);
        if cfg.keep_trace {
            trace.push(RoundTrace {
                round,
                load_start,
                load_end,
                compute_start,
                compute_end,
                drain_end,
            });
        }
    }

    let seconds = end;
    let ops = cand.rec.total_ops();
    let tops = ops / seconds / 1e12;
    let aies = cand.aies_used().max(1);
    let stall = if cfg.keep_trace {
        stall_fraction(&trace)
    } else {
        (1.0 - (rounds as f64 * compute_round_s) / seconds).max(0.0)
    };
    let bound = if cfg.cold_dram && est.dram_s > est.compute_s.max(est.plio_in_s) {
        PerfBound::Dram
    } else {
        est.bound
    };

    // Occupancy-consistent power from the same shared model the cost
    // estimate priced with (the one-power-model invariant): identical
    // activity derivation, but at the simulator's own wall time and
    // occupancy (1 − stall) rather than the analytic ones.
    let power = model.power.estimate(
        tops,
        seconds,
        &crate::arch::power::design_activity(
            dtype,
            aies,
            est.plio_in_ports + est.plio_out_ports,
            est.dram_bytes,
            seconds,
            (1.0 - stall).clamp(0.0, 1.0),
        ),
    );

    (
        SimReport {
            seconds,
            cycles: (seconds * core.freq_hz) as u64,
            tops,
            aies,
            tops_per_aie: tops / aies as f64,
            stall_fraction: stall,
            bound,
            rounds,
            watts: power.watts,
            tops_per_watt: power.tops_per_watt,
        },
        trace,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;
    use crate::mapping::dse::{explore, DseConstraints};
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    fn sim_for(
        rec: crate::recurrence::spec::UniformRecurrence,
        cap: u64,
        cold: bool,
    ) -> (SimReport, crate::mapping::cost::Estimate) {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(cap),
            ..Default::default()
        };
        let (cand, est) = explore(&rec, &board, &cons).unwrap();
        let model = CostModel::new(board);
        let (rep, _) = simulate(
            &cand,
            &model,
            &SimConfig {
                cold_dram: cold,
                keep_trace: false,
            },
        );
        (rep, est)
    }

    #[test]
    fn sim_agrees_with_analytic_mm() {
        let (rep, est) = sim_for(library::mm(8192, 8192, 8192, DType::F32), 400, false);
        let rel = (rep.tops - est.perf.tops).abs() / est.perf.tops;
        assert!(rel < 0.15, "sim {} vs analytic {}", rep.tops, est.perf.tops);
    }

    #[test]
    fn sim_agrees_with_analytic_conv() {
        let (rep, est) = sim_for(library::conv2d(10240, 10240, 8, 8, DType::I8), 400, false);
        let rel = (rep.tops - est.perf.tops).abs() / est.perf.tops;
        assert!(rel < 0.15, "sim {} vs analytic {}", rep.tops, est.perf.tops);
    }

    #[test]
    fn sim_power_tracks_the_shared_model() {
        // One power model end to end: the sim's watts come from the same
        // coefficients as the analytic estimate, differing only through
        // occupancy and wall time — so they must land within the same
        // ballpark (well inside 25 % for a compute-bound design), and the
        // efficiency must divide out exactly.
        let (rep, est) = sim_for(library::mm(8192, 8192, 8192, DType::F32), 400, false);
        assert!(rep.watts > 0.0);
        let rel = (rep.watts - est.power.watts).abs() / est.power.watts;
        assert!(
            rel < 0.25,
            "sim power {} W vs analytic {} W (rel {rel:.3})",
            rep.watts,
            est.power.watts
        );
        assert!((rep.tops_per_watt - rep.tops / rep.watts).abs() < 1e-12);
        assert!(rep.summary().contains("TOPS/W"));
    }

    #[test]
    fn sim_agrees_with_analytic_on_the_new_families() {
        // the ≤15 % agreement extends past the Table II corpus: the fill
        // and phase durations come from the same shared methods for the
        // depthwise-conv, triangular-solve and stencil-chain families
        for (rec, cap) in [
            (library::dw_conv2d(64, 256, 256, 3, 3, DType::F32), 400u64),
            (library::trsv(8192, DType::F32), 400),
            (library::stencil2d_chain(2, 1024, 1024, DType::F32), 400),
        ] {
            let name = rec.name.clone();
            let (rep, est) = sim_for(rec, cap, false);
            let rel = (rep.tops - est.perf.tops).abs() / est.perf.tops;
            assert!(
                rel < 0.15,
                "{name}: sim {} vs analytic {} (rel {rel:.3})",
                rep.tops,
                est.perf.tops
            );
        }
    }

    #[test]
    fn sim_agrees_with_analytic_on_ca_variants() {
        // the CA broadcast-reduction designs go through the same shared
        // fill/phase methods, so the ≤15 % agreement covers them too
        for (_, ca) in library::ca_pairs() {
            let name = ca.name.clone();
            let (rep, est) = sim_for(ca, 400, false);
            let rel = (rep.tops - est.perf.tops).abs() / est.perf.tops;
            assert!(
                rel < 0.15,
                "{name}: sim {} vs analytic {} (rel {rel:.3})",
                rep.tops,
                est.perf.tops
            );
        }
    }

    #[test]
    fn sim_tracks_the_ranked_port_model_when_plio_bound() {
        // a PLIO-starved design: the exact merged counts (not the
        // analytic approximation) must be what the simulator's phase
        // durations are built from, so sim agrees with the exact estimate
        // of the *same* model instance
        let board = BoardConfig::vck5000().with_plio_budget(8);
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) = explore(&library::mm(10240, 10240, 10240, DType::I8), &board, &cons).unwrap();
        let model = CostModel::new(board).with_mover_bits(128);
        let est = model.estimate(&cand).perf;
        let (rep, _) = simulate(&cand, &model, &SimConfig::default());
        let rel = (rep.tops - est.tops).abs() / est.tops;
        assert!(
            rel < 0.15,
            "sim {} vs exact-port estimate {} (rel {rel:.3})",
            rep.tops,
            est.tops
        );
    }

    #[test]
    fn cold_dram_is_slower_or_equal() {
        let (warm, _) = sim_for(library::mm(4096, 4096, 4096, DType::F32), 400, false);
        let (cold, _) = sim_for(library::mm(4096, 4096, 4096, DType::F32), 400, true);
        assert!(cold.tops <= warm.tops * 1.001);
    }

    #[test]
    fn trace_is_monotone_and_pipelined() {
        let board = BoardConfig::vck5000();
        let cons = DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        };
        let (cand, _) =
            explore(&library::mm(4096, 4096, 4096, DType::F32), &board, &cons).unwrap();
        let model = CostModel::new(board);
        let (_, trace) = simulate(
            &cand,
            &model,
            &SimConfig {
                cold_dram: false,
                keep_trace: true,
            },
        );
        assert!(!trace.is_empty());
        for w in trace.windows(2) {
            // rounds retire in order
            assert!(w[1].compute_end >= w[0].compute_end);
            // double buffering: next load may start before previous
            // compute ends
            assert!(w[1].load_start <= w[0].compute_end + 1e-9);
        }
        for t in &trace {
            assert!(t.load_end >= t.load_start);
            assert!(t.compute_start >= t.load_end - 1e-12);
            assert!(t.drain_end >= t.compute_end);
        }
    }

    #[test]
    fn stall_fraction_small_when_compute_bound() {
        let (rep, est) = sim_for(library::mm(8192, 8192, 8192, DType::I8), 400, false);
        assert_eq!(est.perf.bound, crate::mapping::cost::PerfBound::Compute);
        assert!(rep.stall_fraction < 0.2, "stall {}", rep.stall_fraction);
    }
}
