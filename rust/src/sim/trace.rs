//! Per-round execution trace.

/// Timestamps (seconds) of one round's phases.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundTrace {
    pub round: u64,
    pub load_start: f64,
    pub load_end: f64,
    pub compute_start: f64,
    pub compute_end: f64,
    pub drain_end: f64,
}

impl RoundTrace {
    /// Was this round's compute stalled waiting for input?
    pub fn input_stalled(&self) -> bool {
        self.compute_start > self.load_end + 1e-15 || self.load_end > self.load_start
    }

    pub fn compute_s(&self) -> f64 {
        self.compute_end - self.compute_start
    }
}

/// Aggregate stall statistics over a trace.
pub fn stall_fraction(trace: &[RoundTrace]) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    let total: f64 = trace.last().unwrap().drain_end - trace.first().unwrap().load_start;
    let compute: f64 = trace.iter().map(RoundTrace::compute_s).sum();
    (1.0 - compute / total.max(1e-15)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stall_fraction_zero_when_fully_busy() {
        let trace = vec![
            RoundTrace {
                round: 0,
                load_start: 0.0,
                load_end: 0.0,
                compute_start: 0.0,
                compute_end: 1.0,
                drain_end: 1.0,
            },
            RoundTrace {
                round: 1,
                load_start: 0.5,
                load_end: 1.0,
                compute_start: 1.0,
                compute_end: 2.0,
                drain_end: 2.0,
            },
        ];
        assert!(stall_fraction(&trace) < 1e-12);
    }

    #[test]
    fn stall_fraction_half_when_half_idle() {
        let trace = vec![RoundTrace {
            round: 0,
            load_start: 0.0,
            load_end: 1.0,
            compute_start: 1.0,
            compute_end: 2.0,
            drain_end: 2.0,
        }];
        assert!((stall_fraction(&trace) - 0.5).abs() < 1e-12);
    }
}
