//! Simulation end metrics.

use crate::mapping::cost::PerfBound;

#[derive(Debug, Clone)]
pub struct SimReport {
    /// Wall time of the simulated execution (seconds).
    pub seconds: f64,
    /// Equivalent AIE cycles (at the core clock).
    pub cycles: u64,
    pub tops: f64,
    pub aies: u64,
    pub tops_per_aie: f64,
    /// Fraction of wall time cores spent stalled on input/drain.
    pub stall_fraction: f64,
    pub bound: PerfBound,
    pub rounds: u64,
    /// Board draw priced from the *same* power model the cost estimate
    /// used (the one-power-model invariant), at the simulator's own
    /// occupancy (1 − stall) and wall time.
    pub watts: f64,
    pub tops_per_watt: f64,
}

impl SimReport {
    pub fn summary(&self) -> String {
        format!(
            "{:.4} TOPS on {} AIEs ({:.4} TOPS/AIE), {:.3} ms, stall {:.1}%, bound {}, {:.1} W ({:.4} TOPS/W)",
            self.tops,
            self.aies,
            self.tops_per_aie,
            self.seconds * 1e3,
            self.stall_fraction * 100.0,
            self.bound,
            self.watts,
            self.tops_per_watt
        )
    }
}
