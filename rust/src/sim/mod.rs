//! Cycle-approximate simulator of a mapped design on the ACAP model.
//!
//! Where [`crate::mapping::cost`] computes closed-form bounds, this
//! module *executes* the round schedule: per-round load / compute / drain
//! phases flow through a double-buffered timeline with per-port PLIO
//! contention and a DRAM prefetcher ([`memory`]), producing a trace
//! ([`trace`]) and end metrics ([`metrics`]). Agreement between the two
//! (tests assert ≤15 % divergence) is the evidence the closed forms used
//! by the evaluation harness are right; divergence appears exactly when
//! pipelining effects matter (short runs, cold starts).
//!
//! The simulated quantities mirror the paper's measurement setup: on-chip
//! throughput with PL-staged inputs is what Table III reports, and the
//! cold-DRAM mode adds the Table I PL-DRAM bound for honest end-to-end
//! numbers. [`engine::simulate`] walks the double-buffered round
//! timeline; [`metrics::SimReport`] carries TOPS / stall fraction /
//! binding resource.

pub mod engine;
pub mod memory;
pub mod metrics;
pub mod trace;

pub use engine::{simulate, SimConfig};
pub use metrics::SimReport;
pub use trace::RoundTrace;
