//! DRAM prefetcher model: streams each round's working set into the PL
//! buffer ahead of the movers. Load phases block on prefetch when the
//! round's bytes have not arrived yet (the end-to-end mode); in on-chip
//! mode the prefetcher is infinitely fast (data staged before launch).

#[derive(Debug, Clone)]
pub struct Prefetcher {
    /// DRAM bandwidth (bytes/s); f64::INFINITY = on-chip mode.
    pub bandwidth: f64,
    /// Time the prefetcher finishes the bytes requested so far.
    ready_at: f64,
}

impl Prefetcher {
    pub fn new(bandwidth: f64) -> Self {
        Self {
            bandwidth,
            ready_at: 0.0,
        }
    }

    pub fn onchip() -> Self {
        Self::new(f64::INFINITY)
    }

    /// Request `bytes` for a round; returns the earliest time the round's
    /// input is fully resident given the request is issued at `now`.
    pub fn fetch(&mut self, now: f64, bytes: f64) -> f64 {
        if !self.bandwidth.is_finite() {
            return now;
        }
        let start = self.ready_at.max(now - 1.0); // prefetch ahead ≤ 1 s window
        self.ready_at = start.max(0.0) + bytes / self.bandwidth;
        self.ready_at.max(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onchip_never_blocks() {
        let mut p = Prefetcher::onchip();
        assert_eq!(p.fetch(5.0, 1e12), 5.0);
    }

    #[test]
    fn dram_serialises_requests() {
        let mut p = Prefetcher::new(100.0);
        let t1 = p.fetch(0.0, 100.0); // 1 s of traffic
        let t2 = p.fetch(0.0, 100.0); // queued behind
        assert!(t1 >= 1.0);
        assert!(t2 >= 2.0);
    }

    #[test]
    fn idle_prefetcher_catches_up() {
        let mut p = Prefetcher::new(100.0);
        let t1 = p.fetch(10.0, 100.0);
        // issued at t=10 with ≤1 s of lookahead credit
        assert!(t1 <= 10.5, "t1 = {t1}");
    }
}
