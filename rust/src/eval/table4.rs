//! Table IV: MM performance + energy efficiency, PL-only (AutoSA) vs
//! WideSA (E2).

use crate::arch::power::widesa_mover_dsps;
use crate::baselines::autosa_pl;
use crate::coordinator::framework::{WideSa, WideSaConfig};
use crate::mapping::dse::DseConstraints;
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::util::table::TextTable;

#[derive(Debug, Clone)]
pub struct Row {
    pub dtype: DType,
    pub pl_dsps: u32,
    pub pl_tops: f64,
    pub pl_power_w: f64,
    pub ws_dsps: u32,
    pub ws_aies: u64,
    pub ws_tops: f64,
    pub ws_power_w: f64,
    pub norm_tops_per_watt: f64,
    pub paper_norm: f64,
}

/// Paper's normalised TOPS/W column.
pub fn paper_norm(dtype: DType) -> f64 {
    match dtype {
        DType::F32 => 2.25,
        DType::I8 => 1.94,
        DType::I16 => 1.29,
        DType::I32 => 2.25,
        _ => 1.0,
    }
}

pub fn run() -> (Vec<Row>, String) {
    let mut rows = Vec::new();
    for dtype in [DType::F32, DType::I8, DType::I16, DType::I32] {
        let pl = autosa_pl::design(dtype);
        let n = match dtype {
            DType::I8 => 10240,
            DType::I16 => 9600,
            _ => 8192,
        };
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(n, n, n, dtype)).expect("mapping");
        // The design's own power estimate: every estimate is priced
        // through the shared model now, so Table IV consumes it instead
        // of rebuilding an activity profile by hand.
        let ws_power = d.estimate.power.watts;
        let norm = d.estimate.power.tops_per_watt / (pl.tops / pl.power_w);
        rows.push(Row {
            dtype,
            pl_dsps: pl.dsps,
            pl_tops: pl.tops,
            pl_power_w: pl.power_w,
            ws_dsps: widesa_mover_dsps(dtype),
            ws_aies: d.estimate.perf.aies,
            ws_tops: d.estimate.perf.tops,
            ws_power_w: ws_power,
            norm_tops_per_watt: norm,
            paper_norm: paper_norm(dtype),
        });
    }
    let rendered = render(&rows);
    (rows, rendered)
}

pub fn render(rows: &[Row]) -> String {
    let mut t = TextTable::new("Table IV — MM: PL-only (AutoSA) vs WideSA");
    t.header(&[
        "Dtype", "PL DSPs", "PL TOPS", "PL W", "PL TOPS/W", "| WS DSPs", "WS #AIEs", "WS TOPS",
        "WS W", "WS TOPS/W", "Norm(ours)", "Norm(paper)",
    ]);
    for r in rows {
        t.row(vec![
            r.dtype.to_string(),
            r.pl_dsps.to_string(),
            format!("{:.2}", r.pl_tops),
            format!("{:.1}", r.pl_power_w),
            format!("{:.3}", r.pl_tops / r.pl_power_w),
            r.ws_dsps.to_string(),
            r.ws_aies.to_string(),
            format!("{:.2}", r.ws_tops),
            format!("{:.1}", r.ws_power_w),
            format!("{:.3}", r.ws_tops / r.ws_power_w),
            format!("{:.2}x", r.norm_tops_per_watt),
            format!("{:.2}x", r.paper_norm),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_efficiency_ratios_reproduce() {
        let (rows, _) = run();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(
                r.norm_tops_per_watt > 1.0,
                "{}: WideSA must beat PL-only on TOPS/W",
                r.dtype
            );
            let rel = (r.norm_tops_per_watt - r.paper_norm).abs() / r.paper_norm;
            assert!(
                rel < 0.30,
                "{}: norm {:.2} vs paper {:.2}",
                r.dtype,
                r.norm_tops_per_watt,
                r.paper_norm
            );
        }
    }

    #[test]
    fn widesa_power_near_55w() {
        let (rows, _) = run();
        for r in &rows {
            assert!(
                (r.ws_power_w - 55.0).abs() < 6.0,
                "{}: {} W",
                r.dtype,
                r.ws_power_w
            );
        }
    }
}
