//! Large-N scalability table (`widesa scalability`): how the host-level
//! blocking planner carries one compiled graph-tile artifact past its
//! single-staging ceiling. Each row is an N×N×N f32 MM: the plan the
//! planner picked (tile, loop order, panel geometry), its predicted DRAM
//! traffic and DRAM-bound time from the shared
//! [`crate::mapping::cost::CostModel`], and — for the sizes the table
//! actually replays — the *measured* host traffic from walking the plan
//! on the [`crate::coordinator::exec::NullArray`] host-path backend
//! (driver bookkeeping only, no kernel math) plus a functional GF/s
//! point from the real stub runtime at the smallest size. Measured and
//! predicted bytes agree exactly by construction; `make blocking-smoke`
//! gates the same invariant at N = 2048.

use crate::arch::vck5000::BoardConfig;
use crate::coordinator::blocking::{plan_mm, BlockingPlan};
use crate::coordinator::exec::{run_mm, NullArray};
use crate::mapping::cost::CostModel;
use crate::runtime::client::Runtime;
use crate::util::rng::XorShift64;
use crate::util::table::TextTable;

/// Problem sizes the table sweeps. The 256-tile artifact stages at most
/// one padded operand panel at a time, so everything from 512 up
/// exercises multi-round blocking; the top sizes are planner-only rows
/// (operands would not fit a test runner's memory budget).
pub const SWEEP_N: [u64; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// Largest N the table actually replays on the NullArray host path.
pub const MEASURE_CEILING: u64 = 2048;

/// One evaluated scalability row.
#[derive(Debug, Clone)]
pub struct Row {
    pub n: u64,
    pub plan: BlockingPlan,
    /// Measured host DRAM bytes from the NullArray replay; `None` for
    /// planner-only rows past [`MEASURE_CEILING`].
    pub measured_bytes: Option<u64>,
    /// Blocked-replay wall seconds on the NullArray host path.
    pub replay_s: Option<f64>,
    /// Functional GF/s on the real stub runtime (smallest size only —
    /// the stub does the actual f32 tile math).
    pub stub_gflops: Option<f64>,
}

/// Replay an n³ MM on the NullArray host path and report
/// (measured bytes, wall seconds).
fn replay_null(n: usize) -> (u64, f64) {
    let mut rng = XorShift64::new(0x5CA1E);
    let mut a = vec![0f32; n * n];
    let mut b = vec![0f32; n * n];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let (_, stats) = run_mm(&mut NullArray, &a, &b, n, n, n).expect("planned replay");
    (stats.dram_bytes, stats.seconds)
}

/// Functional GF/s through the real stub runtime at size n³.
fn stub_gflops(n: usize) -> Option<f64> {
    let mut rt = Runtime::new().ok()?;
    let mut rng = XorShift64::new(0x6F10);
    let mut a = vec![0f32; n * n];
    let mut b = vec![0f32; n * n];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let (_, stats) = run_mm(&mut rt, &a, &b, n, n, n).ok()?;
    Some(2.0 * (n as f64).powi(3) / stats.seconds / 1e9)
}

/// Sweep [`SWEEP_N`] and tabulate plan + replay evidence.
pub fn run() -> (Vec<Row>, String) {
    let model = CostModel::new(BoardConfig::vck5000());
    let mut rows = Vec::new();
    let mut table =
        TextTable::new("Host-blocking scalability — N×N×N f32 MM on one graph-tile artifact");
    table.header(&[
        "N", "tile", "order", "kc", "span", "mc", "rounds", "pred MB", "DRAM s", "meas MB",
        "GF/s",
    ]);
    for n in SWEEP_N {
        let plan = plan_mm(&model, n, n, n)
            .unwrap_or_else(|e| panic!("sweep size {n} must be plannable: {e}"));
        let (measured_bytes, replay_s) = if n <= MEASURE_CEILING {
            let (bytes, secs) = replay_null(n as usize);
            (Some(bytes), Some(secs))
        } else {
            (None, None)
        };
        let gfs = if n == SWEEP_N[0] { stub_gflops(n as usize) } else { None };
        let row = Row {
            n,
            plan: plan.clone(),
            measured_bytes,
            replay_s,
            stub_gflops: gfs,
        };
        table.row(vec![
            n.to_string(),
            plan.tile.to_string(),
            plan.order.to_string(),
            plan.kc.to_string(),
            plan.span.to_string(),
            plan.mc.to_string(),
            plan.rounds.to_string(),
            format!("{:.1}", plan.predicted_dram_bytes as f64 / 1e6),
            format!("{:.4}", plan.predicted_dram_s),
            row.measured_bytes
                .map_or_else(|| "-".to_string(), |b| format!("{:.1}", b as f64 / 1e6)),
            row.stub_gflops
                .map_or_else(|| "-".to_string(), |g| format!("{g:.2}")),
        ]);
        rows.push(row);
    }
    (rows, table.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalability_sweep_covers_and_reconciles() {
        let (rows, rendered) = run();
        assert_eq!(rows.len(), SWEEP_N.len());
        for (row, n) in rows.iter().zip(SWEEP_N) {
            assert_eq!(row.n, n);
            assert_eq!(row.plan.n, n);
            assert!(row.plan.predicted_dram_bytes > 0, "N={n}");
            // measured replays reconcile with the model exactly
            if let Some(bytes) = row.measured_bytes {
                assert_eq!(bytes, row.plan.predicted_dram_bytes, "N={n}");
            } else {
                assert!(n > MEASURE_CEILING, "N={n} should have been measured");
            }
        }
        // traffic grows with the problem: the sweep actually scales
        for w in rows.windows(2) {
            assert!(
                w[1].plan.predicted_dram_bytes > w[0].plan.predicted_dram_bytes,
                "DRAM traffic must grow monotonically over the sweep"
            );
        }
        assert!(
            rows[0].stub_gflops.is_none() || rows[0].stub_gflops.unwrap() > 0.0,
            "stub GF/s point must be positive when available"
        );
        assert!(rendered.contains("Host-blocking scalability"));
    }
}
