//! Workload-coverage table (`widesa workloads`, `make workloads-smoke`):
//! every library constructor — the Table II four plus the expanded
//! catalog families — through the full framework at a small size, with
//! the mapping shape the DSE selected, the resources it uses, and the
//! sim-vs-analytic agreement. This is the scenario-diversity ledger the
//! `docs/WORKLOADS.md` cookbook references: a new workload is "open" once
//! it shows up here with a compiling design and an agreement within the
//! simulator's ±15 % tolerance.

use crate::coordinator::framework::{WideSa, WideSaConfig};
use crate::mapping::cost::PerfBound;
use crate::mapping::dse::DseConstraints;
use crate::recurrence::library;
use crate::util::table::{fmt3, TextTable};

/// One evaluated catalog row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    /// `"2D serpentine"`, `"1D"` or `"skewed"` — the selected space-time
    /// transform shape.
    pub mapping: &'static str,
    pub aies: u64,
    pub tops: f64,
    pub sim_tops: f64,
    /// |sim − analytic| / analytic.
    pub sim_rel_err: f64,
    pub bound: PerfBound,
    pub pnr_success: bool,
    pub in_ports: usize,
    pub out_ports: usize,
}

/// Compile every [`library::catalog_small`] workload and tabulate it.
pub fn run() -> (Vec<Row>, String) {
    let mut rows = Vec::new();
    let mut table = TextTable::new("Workload coverage — expanded catalog (small sizes, 400-AIE budget)");
    table.header(&[
        "workload", "mapping", "AIEs", "TOPS", "sim", "Δ%", "bound", "P&R", "in", "out",
    ]);
    for rec in library::catalog_small() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws
            .compile(&rec)
            .unwrap_or_else(|e| panic!("{}: no legal mapping: {e}", rec.name));
        let mapping = if d.candidate.choice.is_skewed() {
            "skewed"
        } else if d.candidate.choice.dims() == 1 {
            "1D"
        } else {
            "2D serpentine"
        };
        let rel = (d.sim.tops - d.estimate.perf.tops).abs() / d.estimate.perf.tops;
        let row = Row {
            name: d.candidate.rec.name.clone(),
            mapping,
            aies: d.candidate.aies_used(),
            tops: d.estimate.perf.tops,
            sim_tops: d.sim.tops,
            sim_rel_err: rel,
            bound: d.estimate.perf.bound,
            pnr_success: d.compile.success,
            in_ports: d.merge_stats.in_ports_after,
            out_ports: d.merge_stats.out_ports_after,
        };
        table.row(vec![
            row.name.clone(),
            row.mapping.to_string(),
            row.aies.to_string(),
            fmt3(row.tops),
            fmt3(row.sim_tops),
            format!("{:.1}", row.sim_rel_err * 100.0),
            row.bound.to_string(),
            if row.pnr_success { "ok" } else { "FAIL" }.to_string(),
            row.in_ports.to_string(),
            row.out_ports.to_string(),
        ]);
        rows.push(row);
    }
    (rows, table.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coverage_table_spans_the_catalog_and_agrees_with_sim() {
        let (rows, rendered) = run();
        assert_eq!(rows.len(), library::catalog_small().len());
        for row in &rows {
            assert!(row.pnr_success, "{} failed P&R", row.name);
            assert!(
                row.sim_rel_err < 0.15,
                "{}: sim diverges {:.1}% from the analytic estimate",
                row.name,
                row.sim_rel_err * 100.0
            );
            assert!(row.in_ports <= 78 && row.out_ports <= 78, "{}", row.name);
        }
        // the catalog exercises more than the 2D-serpentine arm
        assert!(
            rows.iter().any(|r| r.mapping != "2D serpentine"),
            "every workload mapped 2D serpentine:\n{rendered}"
        );
        assert!(rendered.contains("Workload coverage"));
    }
}
