//! Energy table (`widesa energy`, `make energy-smoke`): Table IV's
//! TOPS-vs-W tradeoff generalized across the workload catalog.
//!
//! Every row compiles under [`Objective::Pareto`] and prints the
//! design's shared-model power estimate (`watts`, TOPS/W, J per pass),
//! its normalised TOPS/W against the AutoSA PL-only baseline at the same
//! dtype ([`autosa_pl`], the paper's Table IV comparison), and the
//! Pareto-frontier summary of the ranking it was selected from. The
//! corpus is [`library::catalog_small`] (one instance of every family)
//! plus the four Table IV MM operating points — eleven workloads total,
//! so the fp32 MM 8192³ row reproduces the paper's 2.25× normalised
//! TOPS/W headline while the rest show how the tradeoff looks for
//! families the paper never priced.
//!
//! Calibration knobs and regeneration snippets live in `docs/ENERGY.md`.

use crate::baselines::autosa_pl;
use crate::coordinator::framework::{WideSa, WideSaConfig};
use crate::eval::table4;
use crate::mapping::dse::{DseConstraints, Objective};
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::recurrence::spec::UniformRecurrence;
use crate::util::table::TextTable;

/// One energy-table row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub dtype: DType,
    pub aies: u64,
    pub tops: f64,
    pub watts: f64,
    pub tops_per_watt: f64,
    /// Energy of one full pass at the analytic wall time (J).
    pub energy_j: f64,
    /// AutoSA PL-only TOPS/W at the same dtype (the Table IV baseline).
    pub pl_tops_per_watt: f64,
    /// (WideSA TOPS/W) / (PL-only TOPS/W) — Table IV's normalised column.
    pub norm_vs_pl: f64,
    /// Pareto-optimal candidates in this design's ranking.
    pub frontier: usize,
    /// Total ranked candidates.
    pub candidates: usize,
}

/// The eleven-workload energy corpus: every catalog family at its small
/// size plus the four Table IV MM operating points.
pub fn corpus() -> Vec<UniformRecurrence> {
    let mut v = library::catalog_small();
    v.push(library::mm(8192, 8192, 8192, DType::F32));
    v.push(library::mm(10240, 10240, 10240, DType::I8));
    v.push(library::mm(9600, 9600, 9600, DType::I16));
    v.push(library::mm(8192, 8192, 8192, DType::I32));
    v
}

pub fn run() -> (Vec<Row>, String) {
    let mut rows = Vec::new();
    for rec in corpus() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                objective: Objective::Pareto,
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws
            .compile(&rec)
            .unwrap_or_else(|e| panic!("{}: no legal mapping: {e}", rec.name));
        let pl = autosa_pl::design(rec.dtype);
        let p = &d.estimate.power;
        rows.push(Row {
            name: d.candidate.rec.name.clone(),
            dtype: rec.dtype,
            aies: d.estimate.perf.aies,
            tops: d.estimate.perf.tops,
            watts: p.watts,
            tops_per_watt: p.tops_per_watt,
            energy_j: p.energy_j,
            pl_tops_per_watt: pl.tops_per_watt,
            norm_vs_pl: p.tops_per_watt / pl.tops_per_watt,
            frontier: d.frontier.frontier,
            candidates: d.frontier.candidates,
        });
    }
    let rendered = render(&rows);
    (rows, rendered)
}

pub fn render(rows: &[Row]) -> String {
    let mut t = TextTable::new("Energy — TOPS vs W across the catalog (vs AutoSA PL-only)");
    t.header(&[
        "Workload", "Dtype", "AIEs", "TOPS", "W", "TOPS/W", "J/pass", "PL TOPS/W", "Norm",
        "Pareto",
    ]);
    for r in rows {
        t.row(vec![
            r.name.clone(),
            r.dtype.to_string(),
            r.aies.to_string(),
            format!("{:.3}", r.tops),
            format!("{:.1}", r.watts),
            format!("{:.4}", r.tops_per_watt),
            format!("{:.2}", r.energy_j),
            format!("{:.4}", r.pl_tops_per_watt),
            format!("{:.2}x", r.norm_vs_pl),
            format!("{}/{}", r.frontier, r.candidates),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_mm_reproduces_table4_normalised_ratio() {
        // The 8192³ fp32 MM row is exactly Table IV's fp32 operating
        // point: its normalised TOPS/W must land within the same
        // power-model tolerance the Table IV test enforces.
        let (rows, rendered) = run();
        assert_eq!(rows.len(), 11, "the energy corpus is eleven workloads");
        let fp32 = rows
            .iter()
            .find(|r| r.name.starts_with("mm_8192x8192x8192") && r.dtype == DType::F32)
            .expect("fp32 MM row present");
        let paper = table4::paper_norm(DType::F32);
        let rel = (fp32.norm_vs_pl - paper).abs() / paper;
        assert!(
            rel < 0.30,
            "fp32 norm {:.2} vs paper {paper:.2} (rel {rel:.3})",
            fp32.norm_vs_pl
        );
        assert!(rendered.contains("TOPS/W"));
    }

    #[test]
    fn every_row_carries_consistent_power_and_frontier() {
        let (rows, _) = run();
        for r in &rows {
            assert!(r.watts > 13.0, "{}: below static floor", r.name);
            assert!(
                (r.tops_per_watt - r.tops / r.watts).abs() < 1e-9,
                "{}: TOPS/W inconsistent",
                r.name
            );
            assert!(r.energy_j > 0.0, "{}", r.name);
            assert!(
                (1..=r.candidates).contains(&r.frontier),
                "{}: frontier {}/{}",
                r.name,
                r.frontier,
                r.candidates
            );
        }
    }
}
