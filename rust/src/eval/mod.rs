//! Evaluation harness: regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §5 index). Each module returns both the
//! structured rows (for tests and benches) and a rendered text table
//! whose rows mirror what the paper prints.

pub mod ablations;
pub mod ca;
pub mod energy;
pub mod figure6;
pub mod pnr_ablation;
pub mod scalability;
pub mod table1;
pub mod table3;
pub mod table4;
pub mod workloads;

/// Experiment index (mirrors the paper's evaluation section):
/// E1 = [`table3`], E2 = [`table4`], E3 = [`figure6`], E4 = [`table1`],
/// E5 = [`pnr_ablation`], E7 = [`ablations`]; [`workloads`] is the
/// repo's own workload-coverage table over the expanded catalog and
/// [`energy`] its Table IV-style TOPS-vs-W tradeoff across the same
/// catalog; [`scalability`] sweeps N×N×N MM past the single-artifact
/// staging ceiling under the host-level blocking planner; [`ca`] sweeps
/// standard-vs-communication-avoiding form selection across PLIO channel
/// budgets (docs/CA_VARIANTS.md). Each `run()`
/// returns the structured rows plus a rendered text table; the `widesa`
/// CLI prints them (`widesa table3`, `widesa workloads`,
/// `widesa scalability`, ...).
pub use ablations::run as run_ablations;
pub use ca::run as run_ca;
pub use energy::run as run_energy;
pub use figure6::run as run_figure6;
pub use pnr_ablation::run as run_pnr_ablation;
pub use scalability::run as run_scalability;
pub use table1::run as run_table1;
pub use table3::run as run_table3;
pub use table4::run as run_table4;
pub use workloads::run as run_workloads;

/// Paper-vs-ours comparison cell.
#[derive(Debug, Clone, Copy)]
pub struct Compared {
    pub paper: f64,
    pub ours: f64,
}

impl Compared {
    pub fn rel_err(&self) -> f64 {
        if self.paper == 0.0 {
            0.0
        } else {
            (self.ours - self.paper).abs() / self.paper
        }
    }
}
