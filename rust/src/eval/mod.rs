//! Evaluation harness: regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §5 index). Each module returns both the
//! structured rows (for tests and benches) and a rendered text table
//! whose rows mirror what the paper prints.

pub mod ablations;
pub mod figure6;
pub mod pnr_ablation;
pub mod table1;
pub mod table3;
pub mod table4;

/// Paper-vs-ours comparison cell.
#[derive(Debug, Clone, Copy)]
pub struct Compared {
    pub paper: f64,
    pub ours: f64,
}

impl Compared {
    pub fn rel_err(&self) -> f64 {
        if self.paper == 0.0 {
            0.0
        } else {
            (self.ours - self.paper).abs() / self.paper
        }
    }
}
