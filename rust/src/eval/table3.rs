//! Table III: throughput + AIE efficiency across the four benchmarks and
//! all data types (E1) — baseline vs WideSA (ours) vs WideSA (paper).

use crate::baselines::table3_baseline;
use crate::coordinator::framework::{WideSa, WideSaConfig};
use crate::mapping::candidate::Kind;
use crate::mapping::dse::DseConstraints;
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::recurrence::spec::UniformRecurrence;
use crate::util::table::TextTable;

/// One evaluated row.
#[derive(Debug, Clone)]
pub struct Row {
    pub bench: &'static str,
    pub dtype: DType,
    pub baseline_name: Option<&'static str>,
    pub baseline_aies: Option<u32>,
    pub baseline_tops: Option<f64>,
    pub widesa_aies: u64,
    pub widesa_tops: f64,
    pub widesa_tops_e2e: f64,
    pub paper_widesa_aies: u32,
    pub paper_widesa_tops: f64,
}

/// The paper's WideSA rows (Table III) — reproduction targets.
pub fn paper_rows() -> Vec<(&'static str, DType, u32, f64)> {
    vec![
        ("MM", DType::F32, 400, 4.15),
        ("MM", DType::I8, 400, 32.49),
        ("MM", DType::I16, 400, 8.10),
        ("MM", DType::I32, 400, 3.92),
        ("2D-Conv", DType::F32, 400, 4.50),
        ("2D-Conv", DType::I8, 400, 36.02),
        ("2D-Conv", DType::I16, 400, 10.35),
        ("2D-Conv", DType::I32, 400, 4.48),
        ("2D-FFT", DType::CF32, 320, 1.10),
        ("2D-FFT", DType::CI16, 320, 3.83),
        ("FIR", DType::F32, 256, 2.92),
        ("FIR", DType::I8, 256, 39.3),
        ("FIR", DType::I16, 256, 9.47),
        ("FIR", DType::CF32, 256, 2.89),
    ]
}

fn benchmark(bench: &str, dtype: DType) -> UniformRecurrence {
    match bench {
        "MM" => {
            let n = match dtype {
                DType::I8 => 10240,
                DType::I16 => 9600,
                _ => 8192,
            };
            library::mm(n, n, n, dtype)
        }
        "2D-Conv" => {
            let k = if dtype == DType::I8 { 8 } else { 4 };
            library::conv2d(10240, 10240, k, k, dtype)
        }
        "2D-FFT" => library::fft2d(8192, 8192, dtype),
        "FIR" => library::fir(1048576, 15, dtype),
        _ => unreachable!(),
    }
}

/// Evaluate all 14 rows at the paper's operating points.
pub fn run() -> (Vec<Row>, String) {
    let mut rows = Vec::new();
    for (bench, dtype, paper_aies, paper_tops) in paper_rows() {
        let rec = benchmark(bench, dtype);
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(paper_aies as u64),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&rec).expect("mapping");
        let base = table3_baseline(Kind::of(&rec), dtype);
        rows.push(Row {
            bench,
            dtype,
            baseline_name: base.as_ref().map(|b| b.name),
            baseline_aies: base.as_ref().map(|b| b.aies),
            baseline_tops: base.as_ref().map(|b| b.tops),
            widesa_aies: d.estimate.perf.aies,
            widesa_tops: d.estimate.perf.tops,
            widesa_tops_e2e: d.estimate.perf.tops_e2e,
            paper_widesa_aies: paper_aies,
            paper_widesa_tops: paper_tops,
        });
    }
    let rendered = render(&rows);
    (rows, rendered)
}

pub fn render(rows: &[Row]) -> String {
    let mut t = TextTable::new(
        "Table III — Throughput and AIE Efficiency (baseline / WideSA-ours / WideSA-paper)",
    );
    t.header(&[
        "Bench", "Dtype", "Baseline", "#AIEs", "TOPS", "TOPS/AIE", "| ours #AIEs", "ours TOPS",
        "ours TOPS/AIE", "ours e2e", "| paper TOPS", "Δ%",
    ]);
    for r in rows {
        let delta = 100.0 * (r.widesa_tops - r.paper_widesa_tops) / r.paper_widesa_tops;
        t.row(vec![
            r.bench.to_string(),
            r.dtype.to_string(),
            r.baseline_name.unwrap_or("-").to_string(),
            r.baseline_aies.map_or("-".into(), |v| v.to_string()),
            r.baseline_tops.map_or("-".into(), |v| format!("{v:.2}")),
            r.baseline_tops
                .zip(r.baseline_aies)
                .map_or("-".into(), |(t, a)| format!("{:.3}", t / a as f64)),
            r.widesa_aies.to_string(),
            format!("{:.2}", r.widesa_tops),
            format!("{:.4}", r.widesa_tops / r.widesa_aies as f64),
            format!("{:.2}", r.widesa_tops_e2e),
            format!("{:.2}", r.paper_widesa_tops),
            format!("{delta:+.1}"),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_reproduce_within_15_percent() {
        let (rows, _) = run();
        assert_eq!(rows.len(), 14);
        for r in &rows {
            let rel = (r.widesa_tops - r.paper_widesa_tops).abs() / r.paper_widesa_tops;
            assert!(
                rel < 0.15,
                "{} {}: ours {:.2} vs paper {:.2}",
                r.bench,
                r.dtype,
                r.widesa_tops,
                r.paper_widesa_tops
            );
        }
    }

    #[test]
    fn widesa_beats_every_baseline() {
        let (rows, _) = run();
        for r in &rows {
            if let Some(b) = r.baseline_tops {
                assert!(
                    r.widesa_tops > b,
                    "{} {}: WideSA {:.2} ≤ baseline {:.2}",
                    r.bench,
                    r.dtype,
                    r.widesa_tops,
                    b
                );
            }
        }
    }

    #[test]
    fn mm_f32_speedup_near_1_11x() {
        let (rows, _) = run();
        let r = rows
            .iter()
            .find(|r| r.bench == "MM" && r.dtype == DType::F32)
            .unwrap();
        let speedup = r.widesa_tops / r.baseline_tops.unwrap();
        assert!(
            (speedup - 1.11).abs() < 0.08,
            "abstract claims 1.11×, got {speedup:.3}"
        );
    }
}

