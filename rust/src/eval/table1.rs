//! Table I: data-communication bandwidth profile (E4).

use crate::arch::bandwidth::BandwidthProfile;
use crate::arch::vck5000::BoardConfig;
use crate::util::table::TextTable;

pub const PAPER_ROWS: [(&str, f64); 5] = [
    ("AIE DMA", 15.6),
    ("AIE NoC Stream", 1.95),
    ("PLIO-PL", 1.52),
    ("GMIO-DRAM", 0.125),
    ("PL-DRAM", 0.100),
];

pub fn run() -> (BandwidthProfile, String) {
    let profile = BandwidthProfile::profile(&BoardConfig::vck5000());
    let mut t = TextTable::new("Table I — Data Communication Bandwidth (paper vs ours)");
    t.header(&["Method", "Freq", "Bitwidth", "Channels", "Paper TB/s", "Ours TB/s"]);
    for (name, paper) in PAPER_ROWS {
        let m = profile.get(name).unwrap();
        t.row(vec![
            name.to_string(),
            format!("{:.2} GHz", m.freq_ghz),
            if m.bits > 0 {
                format!("{} bits", m.bits)
            } else {
                "-".into()
            },
            m.channels.to_string(),
            format!("{paper:.3}"),
            format!("{:.3}", m.total_tbs),
        ]);
    }
    let rendered = t.render();
    (profile, rendered)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_within_tolerance() {
        let (profile, table) = run();
        for (name, paper) in PAPER_ROWS {
            let ours = profile.get(name).unwrap().total_tbs;
            assert!(
                (ours - paper).abs() / paper < 0.12,
                "{name}: {ours} vs {paper}"
            );
        }
        assert_eq!(table.lines().count(), 3 + 5);
    }
}
