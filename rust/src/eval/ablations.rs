//! E7 (ours): ablations of WideSA's four mapping techniques (§III-B) —
//! what each transformation contributes to the headline numbers.
//!
//! * no latency hiding → single accumulation chain, MAC pipeline drains;
//! * no multiple threading → spare AIEs idle when space loops are small;
//! * no packet-switch merging → port demand explodes past the budget;
//! * conservative movers → the Figure 6 PLIO-bound regime.

use crate::arch::vck5000::BoardConfig;
use crate::coordinator::framework::{WideSa, WideSaConfig};
use crate::graph::builder::build;
use crate::mapping::cost::CostModel;
use crate::mapping::dse::{explore, DseConstraints};
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::recurrence::spec::UniformRecurrence;
use crate::util::table::TextTable;

#[derive(Debug, Clone)]
pub struct Row {
    pub bench: String,
    pub full_tops: f64,
    pub no_latency_tops: f64,
    pub no_threading_tops: f64,
    pub ports_unmerged: usize,
    pub ports_merged: usize,
    pub narrow_mover_tops: f64,
}

fn compile_tops(rec: &UniformRecurrence, cap: u64, cons: DseConstraints) -> f64 {
    let board = BoardConfig::vck5000();
    explore(rec, &board, &DseConstraints { max_aies: Some(cap), ..cons })
        .map(|(_, est)| est.perf.tops)
        .unwrap_or(0.0)
}

pub fn run() -> (Vec<Row>, String) {
    let benches: Vec<(UniformRecurrence, u64)> = vec![
        (library::mm(8192, 8192, 8192, DType::F32), 400),
        (library::mm(10240, 10240, 10240, DType::I8), 400),
        (library::conv2d(10240, 10240, 8, 8, DType::I8), 400),
        (library::fir(1048576, 15, DType::F32), 256),
    ];
    let mut rows = Vec::new();
    for (rec, cap) in benches {
        let full = compile_tops(&rec, cap, DseConstraints::default());
        let no_lat = compile_tops(
            &rec,
            cap,
            DseConstraints {
                no_latency_hiding: true,
                ..Default::default()
            },
        );
        let no_thr = compile_tops(
            &rec,
            cap,
            DseConstraints {
                no_threading: true,
                ..Default::default()
            },
        );
        // port demand before/after packet merging
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(cap),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&rec).expect("mapping");
        // narrow (128-bit) movers
        let board = BoardConfig::vck5000();
        let model = CostModel::new(board.clone()).with_mover_bits(128);
        let narrow = model.estimate(&d.candidate).perf.tops;
        let raw = build(&d.candidate, &CostModel::new(board));
        rows.push(Row {
            bench: rec.name.clone(),
            full_tops: full,
            no_latency_tops: no_lat,
            no_threading_tops: no_thr,
            ports_unmerged: raw.plio_nodes().count(),
            ports_merged: d.merge_stats.in_ports_after + d.merge_stats.out_ports_after,
            narrow_mover_tops: narrow,
        });
    }
    let mut t = TextTable::new("E7 — technique ablations (TOPS unless noted)");
    t.header(&[
        "Bench", "full", "no latency-hiding", "no threading", "ports raw→merged",
        "128-bit movers",
    ]);
    for r in &rows {
        t.row(vec![
            r.bench.clone(),
            format!("{:.2}", r.full_tops),
            format!("{:.2}", r.no_latency_tops),
            format!("{:.2}", r.no_threading_tops),
            format!("{}→{}", r.ports_unmerged, r.ports_merged),
            format!("{:.2}", r.narrow_mover_tops),
        ]);
    }
    (rows.clone(), t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_hiding_is_worth_4x_on_mm() {
        let (rows, _) = run();
        let mm = &rows[0];
        // pipeline depth 4 ⇒ ~4× loss without interleaved accumulators
        let ratio = mm.full_tops / mm.no_latency_tops.max(1e-9);
        assert!(
            (ratio - 4.0).abs() < 1.0,
            "latency hiding ratio {ratio:.2} (expect ≈4)"
        );
    }

    #[test]
    fn packet_merge_fits_budget_everywhere() {
        let (rows, _) = run();
        for r in &rows {
            assert!(r.ports_unmerged >= r.ports_merged);
            assert!(r.ports_merged <= 156, "{}: {}", r.bench, r.ports_merged);
        }
    }

    #[test]
    fn narrow_movers_never_faster() {
        let (rows, _) = run();
        for r in &rows {
            assert!(
                r.narrow_mover_tops <= r.full_tops * 1.001,
                "{}: narrow {} vs full {}",
                r.bench,
                r.narrow_mover_tops,
                r.full_tops
            );
        }
    }
}
