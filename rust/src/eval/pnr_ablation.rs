//! E5: constrained vs unconstrained place-and-route — the experiment
//! behind §II-A-2's motivation ("finding a legal solution efficiently
//! becomes challenging for the solvers") and §III-C's claim that systolic
//! constraints fix it.

use crate::arch::vck5000::BoardConfig;
use crate::coordinator::framework::{WideSa, WideSaConfig};
use crate::graph::builder::MappedGraph;
use crate::mapping::dse::DseConstraints;
use crate::place_route::compiler::{compile, compile_unconstrained};
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::util::table::TextTable;

pub const SIZES: [u64; 5] = [16, 64, 128, 256, 400];
pub const ANNEAL_BUDGET: u64 = 2_000_000;

#[derive(Debug, Clone)]
pub struct Row {
    pub aies: u64,
    pub constrained_ok: bool,
    pub constrained_s: f64,
    /// Peak routed channel occupancy of the constrained flow (`None` if
    /// it failed before routing — the typed replacement for the old
    /// `u32::MAX` sentinel, which a table could aggregate by accident).
    pub constrained_congestion: Option<u32>,
    pub unconstrained_ok: bool,
    pub unconstrained_s: f64,
    pub unconstrained_iters: u64,
}

fn graph_at(aies: u64) -> (MappedGraph, BoardConfig) {
    let ws = WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies: Some(aies),
            ..Default::default()
        },
        ..Default::default()
    });
    let d = ws
        .compile(&library::mm(8192, 8192, 8192, DType::F32))
        .expect("mapping");
    (d.graph, BoardConfig::vck5000())
}

pub fn run() -> (Vec<Row>, String) {
    let mut rows = Vec::new();
    for &aies in &SIZES {
        let (g, board) = graph_at(aies);
        let c = compile(&g, &board);
        let u = compile_unconstrained(&g, &board, 11, ANNEAL_BUDGET);
        rows.push(Row {
            aies,
            constrained_ok: c.success,
            constrained_s: c.wall_s,
            constrained_congestion: c.max_congestion,
            unconstrained_ok: u.success,
            unconstrained_s: u.wall_s,
            unconstrained_iters: u.iterations,
        });
    }
    let mut t = TextTable::new("E5 — Place & route: WideSA constraints vs unconstrained (anneal stand-in)");
    t.header(&[
        "#AIEs", "constrained ok", "time (s)", "cong", "unconstrained ok", "time (s)", "iters",
    ]);
    for r in &rows {
        t.row(vec![
            r.aies.to_string(),
            r.constrained_ok.to_string(),
            format!("{:.4}", r.constrained_s),
            r.constrained_congestion
                .map_or_else(|| "-".to_string(), |c| c.to_string()),
            r.unconstrained_ok.to_string(),
            format!("{:.3}", r.unconstrained_s),
            r.unconstrained_iters.to_string(),
        ]);
    }
    (rows, t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constrained_always_succeeds_and_is_fast() {
        let (rows, _) = run();
        for r in &rows {
            assert!(r.constrained_ok, "{} AIEs", r.aies);
            assert!(r.constrained_s < 2.0, "{} AIEs took {}s", r.aies, r.constrained_s);
            // a successful flow always routed, so congestion is measured
            assert!(r.constrained_congestion.is_some(), "{} AIEs", r.aies);
        }
    }

    #[test]
    fn unconstrained_degrades_with_scale() {
        let (rows, _) = run();
        // the smallest design anneals to legality; the largest must fail
        // (or at minimum cost vastly more iterations) — the paper's
        // compile-difficulty claim
        let small = rows.first().unwrap();
        let large = rows.last().unwrap();
        assert!(
            small.unconstrained_ok,
            "16-AIE design should anneal to legality"
        );
        assert!(
            !large.unconstrained_ok || large.unconstrained_iters > 10 * small.unconstrained_iters,
            "unconstrained P&R should struggle at 400 AIEs"
        );
    }
}
