//! Figure 6: throughput + per-AIE efficiency of MM while sweeping
//! #AIEs, #PLIOs and PL buffer sizes (E3).
//!
//! The sweeps run with the conservative 128-bit movers (the default DMA
//! constructor output the paper's scalability study exercises — DESIGN.md
//! §1); the Table III operating points use the widened 512-bit movers.

use crate::arch::vck5000::BoardConfig;
use crate::mapping::cost::CostModel;
use crate::mapping::dse::{explore, DseConstraints};
use crate::recurrence::dtype::DType;
use crate::recurrence::library;
use crate::util::table::TextTable;

pub const AIE_SWEEP: [u64; 8] = [50, 100, 150, 200, 250, 300, 350, 400];
pub const PLIO_SWEEP: [u32; 4] = [4, 8, 13, 26];
pub const BUFFER_SWEEP_MB: [u64; 3] = [1, 4, 21];

#[derive(Debug, Clone)]
pub struct Point {
    pub aies: u64,
    pub plios: u32,
    pub buffer_mb: u64,
    pub tops: f64,
    pub tops_per_aie: f64,
    pub bound: String,
}

/// Sweep #AIEs × #PLIOs at the full 21 MB buffer (Figure 6 left/middle).
pub fn sweep_aies_plios() -> Vec<Point> {
    let mut out = Vec::new();
    for &plios in &PLIO_SWEEP {
        for &aies in &AIE_SWEEP {
            out.push(eval_point(aies, plios, 21));
        }
    }
    out
}

/// Sweep PL buffer sizes at 400 AIEs / 13 PLIOs (Figure 6 right).
pub fn sweep_buffers() -> Vec<Point> {
    BUFFER_SWEEP_MB
        .iter()
        .map(|&mb| eval_point(400, 13, mb))
        .collect()
}

fn eval_point(aies: u64, plios: u32, buffer_mb: u64) -> Point {
    let board = BoardConfig::vck5000()
        .with_plio_budget(plios)
        .with_pl_buffer_bytes(buffer_mb << 20);
    let rec = library::mm(8192, 8192, 8192, DType::F32);
    let cons = DseConstraints {
        max_aies: Some(aies),
        ..Default::default()
    };
    let (cand, _) = explore(&rec, &board, &cons).expect("mapping");
    // conservative movers for the scalability study
    let model = CostModel::new(board).with_mover_bits(128);
    let est = model.estimate(&cand).perf;
    Point {
        aies: est.aies,
        plios,
        buffer_mb,
        tops: est.tops,
        tops_per_aie: est.tops_per_aie,
        bound: est.bound.to_string(),
    }
}

pub fn run() -> (Vec<Point>, Vec<Point>, String) {
    let ap = sweep_aies_plios();
    let bp = sweep_buffers();
    let mut s = String::new();
    let mut t = TextTable::new("Figure 6a/6b — MM fp32 throughput vs #AIEs at PLIO budgets (128-bit movers)");
    t.header(&["#PLIOs", "#AIEs", "TOPS", "TOPS/AIE", "bound"]);
    for p in &ap {
        t.row(vec![
            p.plios.to_string(),
            p.aies.to_string(),
            format!("{:.3}", p.tops),
            format!("{:.5}", p.tops_per_aie),
            p.bound.clone(),
        ]);
    }
    s.push_str(&t.render());
    let mut t2 = TextTable::new("Figure 6c — MM fp32 vs PL buffer size (400 AIEs, 13 PLIOs)");
    t2.header(&["Buffer MB", "TOPS", "TOPS/AIE", "bound"]);
    for p in &bp {
        t2.row(vec![
            p.buffer_mb.to_string(),
            format!("{:.3}", p.tops),
            format!("{:.5}", p.tops_per_aie),
            p.bound.clone(),
        ]);
    }
    s.push_str(&t2.render());
    (ap, bp, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_increases_with_aies() {
        let pts = sweep_aies_plios();
        // at the largest PLIO budget, TOPS must rise monotonically-ish
        let line: Vec<_> = pts.iter().filter(|p| p.plios == 26).collect();
        for w in line.windows(2) {
            assert!(
                w[1].tops >= w[0].tops * 0.98,
                "throughput dropped: {} → {}",
                w[0].tops,
                w[1].tops
            );
        }
    }

    #[test]
    fn efficiency_declines_past_knee_at_low_plio() {
        // the paper's observation: past ~200 AIEs the per-AIE efficiency
        // falls when PLIO-constrained
        let pts = sweep_aies_plios();
        let line: Vec<_> = pts.iter().filter(|p| p.plios == 4).collect();
        let eff_200 = line.iter().find(|p| p.aies >= 200).unwrap().tops_per_aie;
        let eff_400 = line.last().unwrap().tops_per_aie;
        assert!(
            eff_400 < eff_200 * 0.95,
            "no knee: eff@200={eff_200:.5} eff@400={eff_400:.5}"
        );
    }

    #[test]
    fn more_plios_never_hurt() {
        let pts = sweep_aies_plios();
        for &aies in &AIE_SWEEP {
            let series: Vec<_> = pts.iter().filter(|p| p.aies as u64 >= aies.saturating_sub(30) && p.aies <= aies).collect();
            let _ = series;
        }
        // direct pairing: same AIE budget, increasing PLIOs
        for i in 0..AIE_SWEEP.len() {
            let mut last = 0.0;
            for &plios in &PLIO_SWEEP {
                let p = pts
                    .iter()
                    .find(|p| p.plios == plios && AIE_SWEEP[i] >= p.aies && p.aies + 60 >= AIE_SWEEP[i])
                    .unwrap();
                assert!(p.tops >= last * 0.999, "PLIO increase hurt at {} AIEs", p.aies);
                last = p.tops;
            }
        }
    }

    #[test]
    fn bigger_buffer_never_hurts() {
        let pts = sweep_buffers();
        for w in pts.windows(2) {
            assert!(w[1].tops >= w[0].tops * 0.999);
        }
    }
}
