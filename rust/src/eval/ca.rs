//! Standard-vs-CA form selection table (`widesa ca`, `make ca-smoke`):
//! every [`library::ca_pairs`] recurrence through [`dse::select_form`] at
//! a sweep of PLIO channel budgets. The communication-avoiding variant
//! must be crowned exactly when the standard winner's merged port counts
//! exceed the board budget (the `ca_selected_iff_port_bound` law in
//! `tests/testkit/laws.rs`), so this table is the human-readable ledger
//! of where that boundary sits: on the full 78-channel VCK5000 the
//! standard form wins everywhere; on port-starved boards the broadcast-
//! reduction designs take over. See docs/CA_VARIANTS.md.

use crate::arch::vck5000::BoardConfig;
use crate::mapping::dse::{select_form, DseConstraints};
use crate::recurrence::library;
use crate::util::table::{fmt3, TextTable};

/// PLIO budgets the table sweeps (per direction): the real board, a
/// mid-range point, and the port-starved regime the CA arm exists for.
pub const CHANNEL_BUDGETS: [u32; 3] = [78, 16, 8];

/// One (workload, budget) selection row.
#[derive(Debug, Clone)]
pub struct Row {
    pub name: String,
    pub channels: u32,
    /// `"standard"` or `"ca"` — what [`select_form`] crowned.
    pub selected: &'static str,
    /// Did the standard winner's merged ports fit the budget?
    pub standard_fits: bool,
    pub std_tops: f64,
    pub ca_tops: f64,
    /// CA winner's replication factor (rows of the reduction chain).
    pub replication: u64,
    pub std_in_ports: u32,
    pub std_out_ports: u32,
}

/// Evaluate every CA pair at every budget and tabulate the selections.
pub fn run() -> (Vec<Row>, String) {
    let mut rows = Vec::new();
    let mut table =
        TextTable::new("Form selection — standard vs communication-avoiding across PLIO budgets");
    table.header(&[
        "workload", "chan", "selected", "std fits", "std TOPS", "CA TOPS", "repl", "std in",
        "std out",
    ]);
    let cons = DseConstraints {
        max_aies: Some(400),
        ..Default::default()
    };
    for (std_rec, ca_rec) in library::ca_pairs() {
        for &chan in &CHANNEL_BUDGETS {
            let board = BoardConfig::vck5000().with_plio_budget(chan);
            let sel = select_form(&std_rec, &ca_rec, &board, &cons)
                .unwrap_or_else(|| panic!("{}: no legal mapping for either form", std_rec.name));
            let row = Row {
                name: std_rec.name.clone(),
                channels: chan,
                selected: sel.selected.as_str(),
                standard_fits: sel.standard_fits,
                std_tops: sel.standard.1.perf.tops,
                ca_tops: sel.ca.1.perf.tops,
                replication: sel.ca.0.replication(),
                std_in_ports: sel.standard.1.perf.plio_in_ports,
                std_out_ports: sel.standard.1.perf.plio_out_ports,
            };
            table.row(vec![
                row.name.clone(),
                row.channels.to_string(),
                row.selected.to_string(),
                if row.standard_fits { "yes" } else { "no" }.to_string(),
                fmt3(row.std_tops),
                fmt3(row.ca_tops),
                row.replication.to_string(),
                row.std_in_ports.to_string(),
                row.std_out_ports.to_string(),
            ]);
            rows.push(row);
        }
    }
    (rows, table.render())
}

/// Render the rows as the `BENCH_ca.json` document (`widesa ca` writes
/// this at the repo root; the committed file is the seed schema).
pub fn bench_json(rows: &[Row]) -> String {
    let mut cells = Vec::new();
    for r in rows {
        cells.push(format!(
            "{{\"workload\": \"{}\", \"channels\": {}, \"selected\": \"{}\", \
             \"standard_fits\": {}, \"std_tops\": {:.4}, \"ca_tops\": {:.4}, \
             \"replication\": {}, \"std_in_ports\": {}, \"std_out_ports\": {}}}",
            r.name,
            r.channels,
            r.selected,
            r.standard_fits,
            r.std_tops,
            r.ca_tops,
            r.replication,
            r.std_in_ports,
            r.std_out_ports
        ));
    }
    format!(
        "{{\"bench\": \"ca\", \"budgets\": [78, 16, 8], \"rows\": [{}]}}",
        cells.join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_flips_exactly_at_the_port_boundary() {
        let (rows, rendered) = run();
        assert_eq!(rows.len(), library::ca_pairs().len() * CHANNEL_BUDGETS.len());
        for row in &rows {
            // the table IS the law: CA ⇔ the standard form is port-bound
            assert_eq!(
                row.selected == "ca",
                !row.standard_fits,
                "{} @ {} channels: selected {} but standard_fits={}",
                row.name,
                row.channels,
                row.selected,
                row.standard_fits
            );
            assert!(row.std_tops > 0.0 && row.ca_tops > 0.0, "{}", row.name);
            assert!(row.replication >= 2, "{}: CA winner not replicated", row.name);
        }
        // the full board keeps the standard form; the 8-channel board
        // must force every pair onto the CA arm
        assert!(rows
            .iter()
            .filter(|r| r.channels == 78)
            .all(|r| r.selected == "standard"));
        assert!(rows
            .iter()
            .filter(|r| r.channels == 8)
            .all(|r| r.selected == "ca"));
        assert!(rendered.contains("Form selection"));
    }

    #[test]
    fn bench_json_is_well_formed() {
        let (rows, _) = run();
        let doc = bench_json(&rows);
        let parsed = crate::util::json::parse(&doc).expect("BENCH_ca.json must parse");
        let rows_json = parsed.get("rows").and_then(crate::util::json::Json::as_arr);
        assert_eq!(rows_json.map(<[_]>::len), Some(rows.len()));
    }
}
