//! PLIO interface tiles: the PL↔AIE stream ports of Table I.
//!
//! PLIOs live in the interface row below AIE row 0. The VCK5000 exposes
//! 78 input and 78 output 128-bit channels at 1.25 GHz (Table I:
//! 1.52 TB/s aggregate). Interface tiles sit under a subset of columns;
//! each interface column terminates a bounded number of channels — the
//! resource Algorithm 1 allocates.



#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlioDir {
    /// PL → AIE (input to the array).
    In,
    /// AIE → PL (output from the array).
    Out,
}

#[derive(Debug, Clone)]
pub struct PlioSpec {
    /// Total input channels (PL → AIE).
    pub in_channels: u32,
    /// Total output channels (AIE → PL).
    pub out_channels: u32,
    /// Channel width in bits.
    pub bits: u64,
    /// Channel clock in Hz.
    pub freq_hz: f64,
    /// Columns that host an interface tile (ascending). On VC1902 every
    /// AIE column has an interface tile but only these carry PLIO
    /// streams to the PL fabric.
    pub columns: Vec<u32>,
    /// Max channels (per direction) terminating at one interface column.
    pub channels_per_column: u32,
}

impl Default for PlioSpec {
    fn default() -> Self {
        Self {
            in_channels: 78,
            out_channels: 78,
            bits: 128,
            freq_hz: 1.25e9,
            columns: (0..50).collect(),
            channels_per_column: 2,
        }
    }
}

impl PlioSpec {
    /// Aggregate bandwidth over both directions (bytes/s) — Table I's
    /// 1.52 TB/s row counts in + out channels together.
    pub fn total_bandwidth(&self) -> f64 {
        (self.in_channels + self.out_channels) as f64 * self.bits as f64 / 8.0 * self.freq_hz
    }

    /// Bandwidth of a single channel (bytes/s).
    pub fn channel_bandwidth(&self) -> f64 {
        self.bits as f64 / 8.0 * self.freq_hz
    }

    pub fn channels(&self, dir: PlioDir) -> u32 {
        match dir {
            PlioDir::In => self.in_channels,
            PlioDir::Out => self.out_channels,
        }
    }

    /// Total per-direction column capacity (sanity bound for Algorithm 1).
    pub fn column_capacity(&self) -> u32 {
        self.columns.len() as u32 * self.channels_per_column
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_plio_row() {
        let p = PlioSpec::default();
        // 156 channels × 16 B × 1.25 GHz = 3.12 TB/s? No: Table I counts
        // 78 channels: 78 × 16 B × 1.25 GHz = 1.56 TB/s ≈ the published
        // 1.52 TB/s. Our default exposes 78 per direction; the Table I
        // figure is the per-direction aggregate.
        let per_dir = p.in_channels as f64 * p.channel_bandwidth();
        assert!((per_dir / 1e12 - 1.56).abs() < 0.05);
    }

    #[test]
    fn channel_bandwidth() {
        let p = PlioSpec::default();
        assert!((p.channel_bandwidth() - 20e9).abs() < 1.0); // 16 B × 1.25 GHz
    }

    #[test]
    fn column_capacity_covers_channels() {
        let p = PlioSpec::default();
        assert!(p.column_capacity() >= p.in_channels);
        assert!(p.column_capacity() >= p.out_channels);
    }
}
