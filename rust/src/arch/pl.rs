//! Programmable-logic fabric model: DSP58s, on-chip buffer (BRAM + URAM)
//! and the PL-side DMA movers that stage data between DRAM and PLIOs.



#[derive(Debug, Clone)]
pub struct PlFabric {
    /// DSP58 slices available (VCK5000: 1968).
    pub dsp58: u32,
    /// Block RAM bits (967 × 36 Kb on VC1902).
    pub bram_bits: u64,
    /// UltraRAM bits (463 × 288 Kb).
    pub uram_bits: u64,
    /// PL clock for WideSA designs (paper: 250 MHz).
    pub freq_hz: f64,
    /// DRAM channels × per-channel bandwidth (Table I PL-DRAM: 0.1 TB/s).
    pub dram_channels: u32,
    pub dram_bw_per_channel: f64,
}

impl Default for PlFabric {
    fn default() -> Self {
        Self {
            dsp58: 1968,
            bram_bits: 967 * 36 * 1024,
            uram_bits: 463 * 288 * 1024,
            freq_hz: 250e6,
            dram_channels: 4,
            dram_bw_per_channel: 25e9,
        }
    }
}

impl PlFabric {
    /// Total on-chip buffer bytes usable for AIE staging (BRAM + URAM).
    pub fn buffer_bytes(&self) -> u64 {
        (self.bram_bits + self.uram_bits) / 8
    }

    /// Aggregate DRAM bandwidth (bytes/s) — Table I's PL-DRAM row.
    pub fn dram_bandwidth(&self) -> f64 {
        self.dram_channels as f64 * self.dram_bw_per_channel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck5000_resources() {
        let pl = PlFabric::default();
        assert_eq!(pl.dsp58, 1968);
        // ≈ 4.35 MB BRAM + 16.7 MB URAM ≈ 21 MB staging buffer
        let mb = pl.buffer_bytes() as f64 / 1e6;
        assert!(mb > 20.0 && mb < 22.0, "buffer {mb} MB");
    }

    #[test]
    fn dram_bandwidth_matches_table1() {
        let pl = PlFabric::default();
        assert!((pl.dram_bandwidth() / 1e12 - 0.1).abs() < 1e-9);
    }
}
