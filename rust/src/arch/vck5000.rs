//! Assembled board configurations.

use super::aie::AieCore;
use super::array::AieArray;
use super::pl::PlFabric;
use super::plio::PlioSpec;
use super::power::PowerModel;


/// A complete ACAP board model: the simulator's and mapper's one-stop
/// description of the hardware.
#[derive(Debug, Clone)]
pub struct BoardConfig {
    pub name: String,
    pub array: AieArray,
    pub plio: PlioSpec,
    pub pl: PlFabric,
    pub power: PowerModel,
}

impl Default for BoardConfig {
    fn default() -> Self {
        Self::vck5000()
    }
}

impl BoardConfig {
    /// The paper's evaluation board: VCK5000 (VC1902 silicon), PL at
    /// 250 MHz, AIE array at 1.25 GHz.
    pub fn vck5000() -> Self {
        Self {
            name: "VCK5000".into(),
            array: AieArray::default(),
            plio: PlioSpec::default(),
            pl: PlFabric::default(),
            power: PowerModel::default(),
        }
    }

    /// The Vitis-AI DPU operating point (2D-Conv int8 baseline): 256 AIEs
    /// at 1.33 GHz with the PL at 350 MHz.
    pub fn vck5000_dpu() -> Self {
        let mut b = Self::vck5000();
        b.name = "VCK5000-DPU".into();
        b.array.core = AieCore {
            freq_hz: 1.33e9,
            ..AieCore::default()
        };
        b.pl.freq_hz = 350e6;
        b
    }

    /// Restrict to a sub-array (scalability sweeps of Figure 6) — rows ×
    /// cols chosen to keep the array as square as the 8-row limit allows.
    pub fn with_aie_budget(mut self, aies: u32) -> Self {
        let rows = self.array.rows.min(((aies as f64).sqrt().ceil()) as u32).max(1);
        let cols = aies.div_ceil(rows).min(self.array.cols).max(1);
        self.array.rows = rows.min(8);
        self.array.cols = cols;
        self
    }

    /// Restrict PLIO channel counts (Figure 6 PLIO sweep).
    pub fn with_plio_budget(mut self, per_direction: u32) -> Self {
        self.plio.in_channels = per_direction;
        self.plio.out_channels = per_direction;
        self
    }

    /// Override the PL staging-buffer size (Figure 6 buffer sweep).
    pub fn with_pl_buffer_bytes(mut self, bytes: u64) -> Self {
        // express as BRAM-only budget for simplicity
        self.pl.bram_bits = bytes * 8;
        self.pl.uram_bits = 0;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck5000_defaults() {
        let b = BoardConfig::vck5000();
        assert_eq!(b.array.num_cores(), 400);
        assert_eq!(b.plio.in_channels, 78);
        assert_eq!(b.pl.dsp58, 1968);
    }

    #[test]
    fn dpu_operating_point() {
        let b = BoardConfig::vck5000_dpu();
        assert!((b.array.core.freq_hz - 1.33e9).abs() < 1.0);
        assert!((b.pl.freq_hz - 350e6).abs() < 1.0);
    }

    #[test]
    fn aie_budget_resize() {
        let b = BoardConfig::vck5000().with_aie_budget(100);
        assert!(b.array.num_cores() >= 100);
        assert!(b.array.rows <= 8);
        let b50 = BoardConfig::vck5000().with_aie_budget(50);
        assert!(b50.array.num_cores() >= 50);
    }

    #[test]
    fn plio_and_buffer_overrides() {
        let b = BoardConfig::vck5000().with_plio_budget(39);
        assert_eq!(b.plio.in_channels, 39);
        let b = BoardConfig::vck5000().with_pl_buffer_bytes(4 << 20);
        assert_eq!(b.pl.buffer_bytes(), 4 << 20);
    }
}
