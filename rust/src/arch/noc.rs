//! AIE-array mesh NoC stream model.
//!
//! Streams route on a mesh: vertical hops within a column, horizontal
//! hops along rows. PLIO-sourced traffic enters at row 0 of its assigned
//! column and climbs; traffic whose source and destination columns differ
//! crosses column boundaries horizontally — the congestion the paper's
//! `Cong_i^{west/east}` counts (§III-C-2).

use super::array::Coord;


/// A routed stream path as a sequence of coordinates (unit steps).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamRoute {
    pub hops: Vec<Coord>,
}

impl StreamRoute {
    /// Deterministic X-then-Y route (horizontal first along row 0 — where
    /// PLIO traffic actually travels — then vertical up the column).
    pub fn xy(from: Coord, to: Coord) -> Self {
        let mut hops = vec![from];
        let mut cur = from;
        while cur.col != to.col {
            cur.col = if to.col > cur.col { cur.col + 1 } else { cur.col - 1 };
            hops.push(cur);
        }
        while cur.row != to.row {
            cur.row = if to.row > cur.row { cur.row + 1 } else { cur.row - 1 };
            hops.push(cur);
        }
        Self { hops }
    }

    pub fn len(&self) -> usize {
        self.hops.len().saturating_sub(1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Column boundaries crossed horizontally, as (boundary_index,
    /// direction) pairs; boundary `i` sits between columns `i` and `i+1`.
    /// `true` = eastward crossing.
    pub fn horizontal_crossings(&self) -> Vec<(u32, bool)> {
        let mut out = Vec::new();
        for w in self.hops.windows(2) {
            let (a, b) = (w[0], w[1]);
            if b.col == a.col + 1 {
                out.push((a.col, true));
            } else if a.col == b.col + 1 {
                out.push((b.col, false));
            }
        }
        out
    }
}

/// Per-boundary horizontal channel occupancy for a set of routes.
#[derive(Debug, Clone, Default)]
pub struct ChannelOccupancy {
    /// east[i] = streams crossing boundary i eastward.
    pub east: Vec<u32>,
    /// west[i] = streams crossing boundary i westward.
    pub west: Vec<u32>,
}

impl ChannelOccupancy {
    pub fn new(cols: u32) -> Self {
        let n = cols.saturating_sub(1) as usize;
        Self {
            east: vec![0; n],
            west: vec![0; n],
        }
    }

    pub fn add_route(&mut self, route: &StreamRoute) {
        for (b, eastward) in route.horizontal_crossings() {
            let b = b as usize;
            if eastward {
                self.east[b] += 1;
            } else {
                self.west[b] += 1;
            }
        }
    }

    pub fn max_east(&self) -> u32 {
        self.east.iter().copied().max().unwrap_or(0)
    }

    pub fn max_west(&self) -> u32 {
        self.west.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xy_route_shape() {
        let r = StreamRoute::xy(Coord::new(0, 2), Coord::new(3, 5));
        assert_eq!(r.len(), 3 + 3);
        assert_eq!(*r.hops.first().unwrap(), Coord::new(0, 2));
        assert_eq!(*r.hops.last().unwrap(), Coord::new(3, 5));
        // horizontal first
        assert_eq!(r.hops[1], Coord::new(0, 3));
    }

    #[test]
    fn degenerate_route() {
        let r = StreamRoute::xy(Coord::new(2, 2), Coord::new(2, 2));
        assert!(r.is_empty());
        assert!(r.horizontal_crossings().is_empty());
    }

    #[test]
    fn crossings_eastward() {
        let r = StreamRoute::xy(Coord::new(0, 1), Coord::new(0, 4));
        assert_eq!(r.horizontal_crossings(), vec![(1, true), (2, true), (3, true)]);
    }

    #[test]
    fn crossings_westward() {
        let r = StreamRoute::xy(Coord::new(0, 4), Coord::new(0, 2));
        assert_eq!(r.horizontal_crossings(), vec![(3, false), (2, false)]);
    }

    #[test]
    fn occupancy_accumulates() {
        let mut occ = ChannelOccupancy::new(50);
        occ.add_route(&StreamRoute::xy(Coord::new(0, 0), Coord::new(0, 10)));
        occ.add_route(&StreamRoute::xy(Coord::new(0, 5), Coord::new(0, 15)));
        assert_eq!(occ.east[7], 2); // boundary 7 crossed by both
        assert_eq!(occ.east[2], 1);
        assert_eq!(occ.max_west(), 0);
        assert_eq!(occ.max_east(), 2);
    }
}
