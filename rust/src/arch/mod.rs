//! Versal ACAP architecture model (paper §II-A, Figure 1, Table I).
//!
//! Everything the simulator and the place-and-route substrate need to
//! know about the board: AIE core micro-architecture ([`aie`]), the 8×50
//! array and its shared-buffer connectivity ([`array`]), the mesh NoC
//! stream network ([`noc`]), PLIO interface tiles ([`plio`]), PL
//! resources ([`pl`]), the five data-transfer methods of Table I
//! ([`bandwidth`]), the power model behind Table IV ([`power`]) and the
//! assembled VCK5000 board configuration ([`vck5000`]).

pub mod aie;
pub mod array;
pub mod bandwidth;
pub mod noc;
pub mod pl;
pub mod plio;
pub mod power;
pub mod vck5000;

pub use aie::AieCore;
pub use array::AieArray;
pub use bandwidth::BandwidthProfile;
pub use pl::PlFabric;
pub use plio::PlioSpec;
pub use vck5000::BoardConfig;
