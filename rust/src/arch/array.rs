//! The AIE array: an 8-row × 50-column grid of cores with shared-buffer
//! neighbour links and per-row stream channels (paper §II-A, Figure 1).

use super::aie::AieCore;


/// Physical coordinates on the array: row 0 is adjacent to the PL
/// interface tiles (where PLIOs land).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub row: u32,
    pub col: u32,
}

impl Coord {
    pub fn new(row: u32, col: u32) -> Self {
        Self { row, col }
    }

    pub fn manhattan(self, other: Coord) -> u32 {
        self.row.abs_diff(other.row) + self.col.abs_diff(other.col)
    }
}

#[derive(Debug, Clone)]
pub struct AieArray {
    pub rows: u32,
    pub cols: u32,
    pub core: AieCore,
    /// Aggregate westward stream channels per column boundary, summed
    /// over all rows (the `RC_west` of the paper's satisfiability
    /// constraints): 6 channels per row × 8 rows.
    pub rc_west: u32,
    /// East direction channels (aggregate per boundary).
    pub rc_east: u32,
}

impl Default for AieArray {
    fn default() -> Self {
        Self {
            rows: 8,
            cols: 50,
            core: AieCore::default(),
            rc_west: 48,
            rc_east: 48,
        }
    }
}

impl AieArray {
    pub fn num_cores(&self) -> u32 {
        self.rows * self.cols
    }

    pub fn contains(&self, c: Coord) -> bool {
        c.row < self.rows && c.col < self.cols
    }

    /// Are two cores neighbours able to communicate through a shared
    /// buffer (N/S/E/W adjacency)?
    pub fn shares_buffer(&self, a: Coord, b: Coord) -> bool {
        self.contains(a) && self.contains(b) && a.manhattan(b) == 1
    }

    /// All in-bounds neighbours of a core.
    pub fn neighbours(&self, c: Coord) -> Vec<Coord> {
        let mut out = Vec::with_capacity(4);
        if c.row > 0 {
            out.push(Coord::new(c.row - 1, c.col));
        }
        if c.row + 1 < self.rows {
            out.push(Coord::new(c.row + 1, c.col));
        }
        if c.col > 0 {
            out.push(Coord::new(c.row, c.col - 1));
        }
        if c.col + 1 < self.cols {
            out.push(Coord::new(c.row, c.col + 1));
        }
        out
    }

    /// Iterate all coordinates row-major.
    pub fn coords(&self) -> impl Iterator<Item = Coord> + '_ {
        (0..self.rows).flat_map(move |r| (0..self.cols).map(move |c| Coord::new(r, c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck5000_has_400_cores() {
        assert_eq!(AieArray::default().num_cores(), 400);
    }

    #[test]
    fn adjacency() {
        let a = AieArray::default();
        assert!(a.shares_buffer(Coord::new(0, 0), Coord::new(0, 1)));
        assert!(a.shares_buffer(Coord::new(3, 7), Coord::new(4, 7)));
        assert!(!a.shares_buffer(Coord::new(0, 0), Coord::new(1, 1)));
        assert!(!a.shares_buffer(Coord::new(0, 0), Coord::new(0, 0)));
        // out of bounds
        assert!(!a.shares_buffer(Coord::new(7, 49), Coord::new(8, 49)));
    }

    #[test]
    fn neighbours_at_corner_and_interior() {
        let a = AieArray::default();
        assert_eq!(a.neighbours(Coord::new(0, 0)).len(), 2);
        assert_eq!(a.neighbours(Coord::new(3, 25)).len(), 4);
        assert_eq!(a.neighbours(Coord::new(7, 49)).len(), 2);
    }

    #[test]
    fn coords_cover_array() {
        let a = AieArray::default();
        let v: Vec<_> = a.coords().collect();
        assert_eq!(v.len(), 400);
        assert_eq!(v[0], Coord::new(0, 0));
        assert_eq!(v[399], Coord::new(7, 49));
    }

    #[test]
    fn manhattan_distance() {
        assert_eq!(Coord::new(0, 0).manhattan(Coord::new(3, 4)), 7);
        assert_eq!(Coord::new(5, 5).manhattan(Coord::new(5, 5)), 0);
    }
}
