//! The five data-transfer methods of the paper's Table I, profiled from
//! the architecture model — `widesa table1` regenerates the table.

use super::vck5000::BoardConfig;
use crate::util::table::TextTable;


#[derive(Debug, Clone)]
pub struct TransferMethod {
    pub name: &'static str,
    pub freq_ghz: f64,
    pub bits: u64,
    pub channels: u32,
    /// Aggregate bandwidth in TB/s.
    pub total_tbs: f64,
}

#[derive(Debug, Clone)]
pub struct BandwidthProfile {
    pub methods: Vec<TransferMethod>,
}

impl BandwidthProfile {
    /// Profile the board exactly as the paper's Table I reports it.
    pub fn profile(board: &BoardConfig) -> Self {
        let aie = &board.array.core;
        let ncores = board.array.num_cores();
        let tbs = |bw: f64| bw / 1e12;
        let methods = vec![
            TransferMethod {
                name: "AIE DMA",
                freq_ghz: aie.freq_hz / 1e9,
                bits: aie.dma_bits,
                channels: ncores,
                // one 256-bit DMA channel per core counted once (Table I
                // counts 400 channels): 400 × 32 B × 1.25 GHz ≈ 15.6 TB/s
                total_tbs: tbs(ncores as f64 * aie.dma_bits as f64 / 8.0 * aie.freq_hz),
            },
            TransferMethod {
                name: "AIE NoC Stream",
                freq_ghz: aie.freq_hz / 1e9,
                bits: aie.stream_bits,
                channels: ncores,
                total_tbs: tbs(aie.stream_bandwidth() * ncores as f64),
            },
            TransferMethod {
                name: "PLIO-PL",
                freq_ghz: board.plio.freq_hz / 1e9,
                bits: board.plio.bits,
                channels: board.plio.in_channels,
                total_tbs: tbs(board.plio.in_channels as f64 * board.plio.channel_bandwidth()),
            },
            TransferMethod {
                name: "GMIO-DRAM",
                // GMIO streams cross the NoC at the 1 GHz NoC clock even
                // though the AIE side runs 1.25 GHz — that is why the
                // paper's measured 0.125 TB/s sits under the nominal rate.
                freq_ghz: 1.0,
                bits: 64,
                channels: 16,
                total_tbs: tbs(16.0 * 8.0 * 1.0e9),
            },
            TransferMethod {
                name: "PL-DRAM",
                freq_ghz: board.pl.freq_hz / 1e9,
                bits: 0,
                channels: board.pl.dram_channels,
                total_tbs: tbs(board.pl.dram_bandwidth()),
            },
        ];
        Self { methods }
    }

    pub fn get(&self, name: &str) -> Option<&TransferMethod> {
        self.methods.iter().find(|m| m.name == name)
    }

    pub fn render_table(&self) -> String {
        let mut t = TextTable::new("Table I: Data Communication Bandwidth (reproduced)");
        t.header(&["Method", "Frequency", "Bitwidth", "Channels", "Total"]);
        for m in &self.methods {
            t.row(vec![
                m.name.to_string(),
                format!("{:.2} GHz", m.freq_ghz),
                if m.bits > 0 {
                    format!("{} bits", m.bits)
                } else {
                    "-".to_string()
                },
                m.channels.to_string(),
                format!("{:.3} TB/s", m.total_tbs),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> BandwidthProfile {
        BandwidthProfile::profile(&BoardConfig::vck5000())
    }

    #[test]
    fn dma_is_fastest_method() {
        let p = profile();
        let dma = p.get("AIE DMA").unwrap().total_tbs;
        for m in &p.methods {
            assert!(dma >= m.total_tbs, "{} beats DMA", m.name);
        }
    }

    #[test]
    fn matches_table1_within_tolerance() {
        let p = profile();
        // Paper: 15.6, 1.95, 1.52, 0.125, 0.100 TB/s
        let expect = [
            ("AIE DMA", 15.6, 0.5),
            ("AIE NoC Stream", 1.95, 0.2),
            ("PLIO-PL", 1.52, 0.1),
            ("GMIO-DRAM", 0.125, 0.01),
            ("PL-DRAM", 0.100, 0.01),
        ];
        for (name, want, tol) in expect {
            let got = p.get(name).unwrap().total_tbs;
            assert!(
                (got - want).abs() <= tol,
                "{name}: got {got} want {want}±{tol}"
            );
        }
    }

    #[test]
    fn dram_much_slower_than_onchip() {
        let p = profile();
        let dram = p.get("PL-DRAM").unwrap().total_tbs;
        let plio = p.get("PLIO-PL").unwrap().total_tbs;
        assert!(plio / dram > 10.0); // the data-locality motivation (§II-A)
    }

    #[test]
    fn render_has_five_rows() {
        let s = profile().render_table();
        assert_eq!(s.lines().filter(|l| l.contains("TB/s")).count(), 5);
    }
}
