//! Component-level power model behind Table IV.
//!
//! Calibrated at the paper's published endpoints (DESIGN.md §1): a
//! PL-only AutoSA design draws ≈19 W (static + DSP/BRAM dynamic) while a
//! full-array WideSA design draws ≈55 W (static + 400 AIEs + movers).
//! The model is linear in active components, which is what lets it
//! reproduce the paper's TOPS/W *ratios* without board telemetry.

use crate::recurrence::dtype::DType;


#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Board static power (always-on rails, W).
    pub static_w: f64,
    /// Per-active-AIE dynamic power at full MAC occupancy (W).
    pub aie_w: f64,
    /// Per-DSP58 dynamic power at the PL clock (W).
    pub dsp_w: f64,
    /// PL data-mover + BRAM/URAM overhead per PLIO channel in use (W).
    pub mover_w: f64,
    /// NoC + DRAM controller overhead per GB/s of DRAM traffic (W·s/GB).
    pub dram_w_per_gbs: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            static_w: 13.0 + static_w_mutation(),
            aie_w: 0.095,
            dsp_w: 0.0038,
            mover_w: 0.055,
            dram_w_per_gbs: 0.009,
        }
    }
}

/// Mutation seam for `make mutation-smoke`: `WIDESA_MUTATE=power-static`
/// inflates the static rail draw, which must flip the Table IV
/// calibration guards (`widesa_power_near_55w` here and in
/// `eval::table4`). Read once so every model in the process agrees.
fn static_w_mutation() -> f64 {
    static DELTA: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *DELTA.get_or_init(|| match std::env::var("WIDESA_MUTATE").as_deref() {
        Ok("power-static") => 7.0,
        _ => 0.0,
    })
}

/// Power-side half of a design estimate: absolute draw, efficiency, and
/// the energy of one full pass. Produced next to every `PerfEstimate` by
/// `mapping::cost::CostModel` (see `mapping::cost::Estimate`), always
/// through one shared `PowerModel` — the one-power-model invariant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerEstimate {
    /// Total board draw while the design runs (W).
    pub watts: f64,
    /// Energy efficiency (TOPS/W) at the estimate's throughput.
    pub tops_per_watt: f64,
    /// Energy of one full pass over the recurrence (J = W × s).
    pub energy_j: f64,
}

/// What a design activates, for power accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct ActivityProfile {
    pub aies: u32,
    pub dsps: u32,
    pub plio_channels: u32,
    pub dram_gbs: f64,
    /// Average MAC occupancy of active AIEs in [0, 1].
    pub aie_occupancy: f64,
}

impl PowerModel {
    pub fn total_w(&self, act: &ActivityProfile) -> f64 {
        self.static_w
            + act.aies as f64 * self.aie_w * act.aie_occupancy.clamp(0.0, 1.0).max(0.3)
            + act.dsps as f64 * self.dsp_w
            + act.plio_channels as f64 * self.mover_w
            + act.dram_gbs * self.dram_w_per_gbs
    }

    /// Energy efficiency in TOPS/W.
    pub fn tops_per_watt(&self, tops: f64, act: &ActivityProfile) -> f64 {
        tops / self.total_w(act)
    }

    /// Activity profile of a full-array WideSA design (helper for the
    /// evaluation harness).
    pub fn widesa_activity(aies: u32, plio_channels: u32, dsps: u32, dram_gbs: f64) -> ActivityProfile {
        ActivityProfile {
            aies,
            dsps,
            plio_channels,
            dram_gbs,
            aie_occupancy: 1.0,
        }
    }

    /// Price an activity profile at a given throughput and runtime.
    ///
    /// Pure: the estimate is fully determined by `(tops, seconds, act)`
    /// and the model coefficients, which is what lets `serve::persist`
    /// recompute power on snapshot load instead of serializing it.
    pub fn estimate(&self, tops: f64, seconds: f64, act: &ActivityProfile) -> PowerEstimate {
        let watts = self.total_w(act);
        PowerEstimate {
            watts,
            tops_per_watt: tops / watts,
            energy_j: watts * seconds,
        }
    }
}

/// Derive the activity profile of a mapped WideSA design from the
/// numbers a `PerfEstimate` already carries. One derivation shared by
/// the cost model, the simulator, and the energy eval tables: active
/// AIEs, merged PLIO channels (post port-model), the per-dtype mover
/// DSP budget from Table IV, and achieved DRAM GB/s capped at the
/// board's practical ceiling.
pub fn design_activity(
    dtype: DType,
    aies: u64,
    plio_channels: u32,
    dram_bytes: u64,
    seconds: f64,
    occupancy: f64,
) -> ActivityProfile {
    let dram_gbs = if seconds > 0.0 {
        (dram_bytes as f64 / seconds / 1e9).min(100.0)
    } else {
        0.0
    };
    ActivityProfile {
        aies: aies.min(u32::MAX as u64) as u32,
        dsps: widesa_mover_dsps(dtype),
        plio_channels,
        dram_gbs,
        aie_occupancy: occupancy,
    }
}

/// Calibration sanity targets from Table IV.
pub const PAPER_PL_ONLY_W: [(f64, f64); 4] = [
    (0.59, 19.5),  // fp32
    (5.77, 18.8),  // int8
    (2.16, 18.6),  // int16
    (0.60, 19.5),  // int32
];
pub const PAPER_WIDESA_W: [(f64, f64); 4] = [
    (4.15, 55.8),
    (32.49, 54.4),
    (8.10, 54.9),
    (3.92, 55.6),
];

/// DSP counts Table IV lists for the PL-only designs per dtype.
pub fn pl_only_dsps(dtype: DType) -> u32 {
    match dtype {
        DType::F32 => 1536,
        DType::I8 => 1528,
        DType::I16 => 1516,
        DType::I32 => 1536,
        _ => 1536,
    }
}

/// DSP counts Table IV lists for WideSA's PL-side movers per dtype.
pub fn widesa_mover_dsps(dtype: DType) -> u32 {
    match dtype {
        DType::F32 => 152,
        DType::I8 => 60,
        DType::I16 => 67,
        DType::I32 => 65,
        _ => 100,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pl_only_power_near_19w() {
        let m = PowerModel::default();
        let act = ActivityProfile {
            aies: 0,
            dsps: 1536,
            plio_channels: 0,
            dram_gbs: 80.0,
            aie_occupancy: 0.0,
        };
        let w = m.total_w(&act);
        assert!((w - 19.5).abs() < 1.5, "PL-only power {w} W");
    }

    #[test]
    fn widesa_power_near_55w() {
        let m = PowerModel::default();
        let act = PowerModel::widesa_activity(400, 78, 152, 90.0);
        let w = m.total_w(&act);
        assert!((w - 55.8).abs() < 3.0, "WideSA power {w} W");
    }

    #[test]
    fn tops_per_watt_ratio_reproduces_fp32_row() {
        // Table IV fp32: PL-only 0.03, WideSA 0.07 → 2.25× normalised.
        let m = PowerModel::default();
        let pl = m.tops_per_watt(
            0.59,
            &ActivityProfile {
                dsps: 1536,
                dram_gbs: 80.0,
                ..Default::default()
            },
        );
        let ws = m.tops_per_watt(4.15, &PowerModel::widesa_activity(400, 78, 152, 90.0));
        let norm = ws / pl;
        assert!(norm > 1.8 && norm < 2.8, "normalised TOPS/W {norm}");
    }

    #[test]
    fn power_monotone_in_activity() {
        let m = PowerModel::default();
        let small = m.total_w(&PowerModel::widesa_activity(100, 20, 60, 10.0));
        let large = m.total_w(&PowerModel::widesa_activity(400, 78, 152, 90.0));
        assert!(large > small);
    }

    #[test]
    fn estimate_is_consistent_with_total_w() {
        let m = PowerModel::default();
        let act = PowerModel::widesa_activity(400, 78, 152, 90.0);
        let est = m.estimate(4.15, 2.0, &act);
        assert_eq!(est.watts, m.total_w(&act));
        assert_eq!(est.tops_per_watt, 4.15 / est.watts);
        assert_eq!(est.energy_j, est.watts * 2.0);
    }

    #[test]
    fn design_activity_caps_dram_and_uses_mover_dsps() {
        // 1 TB moved in 1 s would be 1000 GB/s; the profile caps at the
        // board's practical 100 GB/s ceiling.
        let act = design_activity(DType::F32, 400, 78, 1_000_000_000_000, 1.0, 0.9);
        assert_eq!(act.aies, 400);
        assert_eq!(act.dsps, widesa_mover_dsps(DType::F32));
        assert_eq!(act.plio_channels, 78);
        assert_eq!(act.dram_gbs, 100.0);
        assert_eq!(act.aie_occupancy, 0.9);
        // Degenerate zero-runtime designs draw no DRAM power rather
        // than dividing by zero.
        assert_eq!(design_activity(DType::I8, 1, 1, 100, 0.0, 1.0).dram_gbs, 0.0);
    }
}
