//! AIE core micro-architecture model (VC1902 first-generation AIE).
//!
//! Each core is a 7-way VLIW vector processor at 1.25 GHz with a 32 KB
//! data memory, DMA access to the four neighbouring memory tiles (256-bit
//! per cycle), and one 32-bit NoC stream port in each direction
//! (paper §II-A-1, Table I).

use crate::recurrence::dtype::DType;


#[derive(Debug, Clone, Copy)]
pub struct AieCore {
    /// Core clock (Hz). VCK5000 runs 1.25 GHz; the DPU baseline 1.33 GHz.
    pub freq_hz: f64,
    /// Local data memory bytes (own tile).
    pub local_mem_bytes: u64,
    /// DMA width to neighbour buffers, bits per cycle per port.
    pub dma_bits: u64,
    /// Number of DMA-reachable neighbour buffers.
    pub dma_ports: u64,
    /// NoC stream width, bits per cycle per direction.
    pub stream_bits: u64,
    /// Accumulator registers available for latency hiding (vector lanes
    /// worth of independent accumulation chains).
    pub acc_registers: u64,
    /// MAC pipeline depth in cycles (the carried-accumulation latency
    /// that §III-B-3's latency hiding must cover).
    pub mac_pipeline_depth: u64,
}

impl Default for AieCore {
    fn default() -> Self {
        Self {
            freq_hz: 1.25e9,
            local_mem_bytes: 32 * 1024,
            dma_bits: 256,
            dma_ports: 4,
            stream_bits: 32,
            acc_registers: 4,
            mac_pipeline_depth: 4,
        }
    }
}

impl AieCore {
    /// Peak MACs per cycle for a data type.
    pub fn macs_per_cycle(&self, dtype: DType) -> u64 {
        dtype.macs_per_cycle_aie()
    }

    /// Peak arithmetic throughput in ops/s for a data type.
    pub fn peak_ops(&self, dtype: DType) -> f64 {
        self.macs_per_cycle(dtype) as f64 * dtype.ops_per_mac() as f64 * self.freq_hz
    }

    /// DMA bandwidth (bytes/s) of one core across all neighbour ports.
    pub fn dma_bandwidth(&self) -> f64 {
        self.dma_bits as f64 / 8.0 * self.dma_ports as f64 * self.freq_hz
    }

    /// Stream bandwidth (bytes/s) in one direction.
    pub fn stream_bandwidth(&self) -> f64 {
        self.stream_bits as f64 / 8.0 * self.freq_hz
    }

    /// Pipeline efficiency of an accumulation chain of length `chain` with
    /// `parallel_chains` interleaved independent accumulators — the
    /// quantity latency hiding (§III-B-3) maximises. With enough
    /// independent chains the MAC pipeline stays full; with one chain the
    /// core stalls `mac_pipeline_depth` cycles per MAC.
    pub fn accumulation_efficiency(&self, parallel_chains: u64) -> f64 {
        let chains = parallel_chains.max(1) as f64;
        let depth = self.mac_pipeline_depth as f64;
        (chains / depth).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_numbers_match_paper() {
        let core = AieCore::default();
        // 128 int8 MACs/cycle × 2 ops × 1.25 GHz = 320 Gops
        assert!((core.peak_ops(DType::I8) - 320e9).abs() < 1e3);
        // fp32: 8 MACs/cycle → 20 Gops
        assert!((core.peak_ops(DType::F32) - 20e9).abs() < 1e3);
    }

    #[test]
    fn dma_bandwidth_matches_table1_per_core() {
        let core = AieCore::default();
        // Table I: 400 channels × 256 b × 1.25 GHz = 15.6 TB/s total ⇒ the
        // per-core aggregate here is 4 ports × 32 B × 1.25 GHz = 160 GB/s.
        assert!((core.dma_bandwidth() - 160e9).abs() < 1e3);
        assert!((core.stream_bandwidth() - 5e9).abs() < 1e-3);
    }

    #[test]
    fn accumulation_efficiency_saturates() {
        let core = AieCore::default();
        assert!((core.accumulation_efficiency(1) - 0.25).abs() < 1e-9);
        assert!((core.accumulation_efficiency(4) - 1.0).abs() < 1e-9);
        assert!((core.accumulation_efficiency(16) - 1.0).abs() < 1e-9);
    }
}
