//! Host-level blocked-GEMM planner (GotoBLAS2-on-Versal).
//!
//! A compiled WideSA artifact is **one fixed array pass** over a
//! `tile × tile` graph-tile edge. Arbitrarily large (N, M, K) MM
//! problems therefore replay the artifact in a host loop — and the host
//! loop's blocking decides how many times every operand crosses DRAM.
//! This module is the planner above the mapper: it enumerates
//! GotoBLAS2-style panel loop orders and block sizes (the mc/kc/nc
//! analogues of the DRAM → PL buffer → AIE tile hierarchy), prices each
//! choice's DRAM traffic through
//! [`CostModel::blocked_mm_dram_bytes`] — the *same* model the DSE's
//! `dram_traffic` uses, so DSE and planner price with one model — and
//! emits a deterministic [`BlockingPlan`] that
//! [`crate::coordinator::exec`]'s double-buffered replay driver walks.
//!
//! ## Hierarchy levels
//!
//! * **DRAM → PL buffer**: one `kc × span` operand panel stays resident
//!   across the inner loop ([`PanelOrder`] picks which operand); the
//!   other operand streams through in `mc`-row blocks and re-reads once
//!   per panel step. C round-trips once per k-segment.
//! * **PL buffer → AIE tiles**: the compiled artifact consumes
//!   `tile × tile` graph tiles; the replay driver slices them out of the
//!   packed panels. Ragged edges are padded up to tile multiples
//!   (zero-filled — mathematically a no-op for MM).
//!
//! Shapes the hierarchy cannot place at all (zero extents, or padded
//! matrices past the 1 TiB staging cap) return the typed
//! [`Unplannable`] error — `widesa map` and the serve protocol surface
//! it as a structured non-500 response, never a panic.

use crate::mapping::cost::CostModel;
use crate::util::json::Json;

/// Artifact graph-tile edges the host replay can drive, largest first
/// (the stub and PJRT runtimes both serve `mm_f32_256` / `mm_f32_128`).
pub const HOST_TILES: [u64; 2] = [256, 128];

/// Padded staging cap: a plan whose largest padded matrix exceeds this
/// is rejected as [`Unplannable`] instead of letting the replay driver
/// attempt an allocation that can only die.
pub const MAX_MATRIX_BYTES: u128 = 1 << 40; // 1 TiB

/// Which operand's panels stay resident in the PL buffer across the
/// inner loop (the GotoBLAS2 loop-order choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PanelOrder {
    /// B panels (`kc × span` of K×M) resident; A streams in `mc`-row
    /// blocks and re-reads once per `span`-wide panel of M. The
    /// classic GotoBLAS2 GEBP order.
    BResident,
    /// A panels (`span × kc` of N×K) resident; B streams and re-reads
    /// once per `span`-tall panel of N (GEPB).
    AResident,
}

impl std::fmt::Display for PanelOrder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PanelOrder::BResident => write!(f, "b-resident"),
            PanelOrder::AResident => write!(f, "a-resident"),
        }
    }
}

/// One priced host-blocking choice. Deterministic: same problem + same
/// model → bit-identical plan (the planner keeps the *first* minimum in
/// a canonical enumeration order).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockingPlan {
    /// Original (unpadded) problem extents: C(n×m) += A(n×k)·B(k×m).
    pub n: u64,
    pub m: u64,
    pub k: u64,
    /// Artifact graph-tile edge the replay drives (`mm_f32_<tile>`).
    pub tile: u64,
    /// Padded extents (tile multiples; ragged edges zero-padded).
    pub n_pad: u64,
    pub m_pad: u64,
    pub k_pad: u64,
    /// Loop order: which operand's panels stay PL-resident.
    pub order: PanelOrder,
    /// Resident panel depth along K (tile multiple).
    pub kc: u64,
    /// Resident panel width along the resident operand's free dimension
    /// (M for [`PanelOrder::BResident`], N for `AResident`).
    pub span: u64,
    /// Streamed-operand block rows per packing step (tile multiple).
    pub mc: u64,
    /// Artifact invocations the replay will make:
    /// `(n_pad/tile)·(m_pad/tile)·(k_pad/tile)`.
    pub rounds: u64,
    /// DRAM bytes the plan predicts the replay moves
    /// ([`CostModel::blocked_mm_dram_bytes`]).
    pub predicted_dram_bytes: u64,
    /// `predicted_dram_bytes / dram_bandwidth` under the plan's board.
    pub predicted_dram_s: f64,
}

impl BlockingPlan {
    /// Artifact name the replay driver runs per tile round.
    pub fn artifact(&self) -> String {
        format!("mm_f32_{}", self.tile)
    }

    /// One-line human summary (`widesa map` / `run-mm` print this).
    pub fn summary(&self) -> String {
        format!(
            "blocking: {}x{}x{} -> pad {}x{}x{} tile {} | {} kc={} span={} mc={} | {} rounds, predicted DRAM {:.1} MB",
            self.n,
            self.m,
            self.k,
            self.n_pad,
            self.m_pad,
            self.k_pad,
            self.tile,
            self.order,
            self.kc,
            self.span,
            self.mc,
            self.rounds,
            self.predicted_dram_bytes as f64 / 1e6
        )
    }

    /// Structured form for protocol responses / trend snapshots.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num_u64(self.n)),
            ("m", Json::num_u64(self.m)),
            ("k", Json::num_u64(self.k)),
            ("tile", Json::num_u64(self.tile)),
            ("n_pad", Json::num_u64(self.n_pad)),
            ("m_pad", Json::num_u64(self.m_pad)),
            ("k_pad", Json::num_u64(self.k_pad)),
            ("order", Json::str(self.order.to_string())),
            ("kc", Json::num_u64(self.kc)),
            ("span", Json::num_u64(self.span)),
            ("mc", Json::num_u64(self.mc)),
            ("rounds", Json::num_u64(self.rounds)),
            ("predicted_dram_bytes", Json::num_u64(self.predicted_dram_bytes)),
            ("predicted_dram_s", Json::Num(self.predicted_dram_s)),
        ])
    }
}

/// Typed "the planner cannot place this shape" error. Surfaced as a
/// structured protocol response (`"unplannable": true`) and a clean CLI
/// error — never a panic or a silent truncation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Unplannable {
    pub n: u64,
    pub m: u64,
    pub k: u64,
    pub reason: String,
}

impl std::fmt::Display for Unplannable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "no host-blocking plan for {}x{}x{} MM: {}",
            self.n, self.m, self.k, self.reason
        )
    }
}

impl std::error::Error for Unplannable {}

fn pad_to(x: u64, tile: u64) -> u64 {
    x.div_ceil(tile) * tile
}

/// Shape validation + tile/padding choice shared by [`plan_mm`] and
/// [`plan_mm_candidates`]: smallest padded volume wins, ties go to the
/// larger tile (fewer rounds for the same traffic).
fn choose_tile(n: u64, m: u64, k: u64) -> Result<(u64, u64, u64, u64), Unplannable> {
    let fail = |reason: &str| Unplannable {
        n,
        m,
        k,
        reason: reason.to_string(),
    };
    if n == 0 || m == 0 || k == 0 {
        return Err(fail("every extent must be >= 1"));
    }
    let mut best: Option<(u64, u64, u64, u64, u128)> = None;
    for &tile in &HOST_TILES {
        let (np, mp, kp) = (pad_to(n, tile), pad_to(m, tile), pad_to(k, tile));
        let vol = np as u128 * mp as u128 * kp as u128;
        // HOST_TILES is largest-first, so strict `<` keeps the larger
        // tile on equal padded volume.
        if best.map_or(true, |b| vol < b.4) {
            best = Some((tile, np, mp, kp, vol));
        }
    }
    let (tile, np, mp, kp, _) = best.expect("HOST_TILES is non-empty");
    let eb = 4u128; // f32 replay
    let biggest = (np as u128 * kp as u128)
        .max(kp as u128 * mp as u128)
        .max(np as u128 * mp as u128)
        * eb;
    if biggest > MAX_MATRIX_BYTES {
        return Err(fail(&format!(
            "padded matrix needs {biggest} bytes, past the {MAX_MATRIX_BYTES}-byte staging cap"
        )));
    }
    Ok((tile, np, mp, kp))
}

/// Every feasible blocking choice for the problem, priced, in canonical
/// enumeration order (B-resident before A-resident, `kc` ascending,
/// `span` ascending). Exposed so tests — the mutation-seam guard in
/// particular — can re-price the whole candidate set independently.
pub fn plan_mm_candidates(
    model: &CostModel,
    n: u64,
    m: u64,
    k: u64,
) -> Result<Vec<BlockingPlan>, Unplannable> {
    let (tile, n_pad, m_pad, k_pad) = choose_tile(n, m, k)?;
    let eb = 4u64;
    // Same residency convention as the cost model's k-segmentation arm:
    // half the PL buffer holds the resident panel, the rest stages the
    // streamed blocks + C tiles.
    let panel_budget = model.board.pl.buffer_bytes() / 2;
    let dram_bw = model.board.pl.dram_bandwidth();
    let mut out = Vec::new();
    for order in [PanelOrder::BResident, PanelOrder::AResident] {
        let free_pad = match order {
            PanelOrder::BResident => m_pad,
            PanelOrder::AResident => n_pad,
        };
        let streamed_pad = match order {
            PanelOrder::BResident => n_pad,
            PanelOrder::AResident => m_pad,
        };
        let mut kc = tile;
        while kc <= k_pad {
            let mut span = tile;
            while span <= free_pad {
                if kc.saturating_mul(span).saturating_mul(eb) > panel_budget {
                    break; // span ascends: nothing larger fits either
                }
                // mc: largest tile multiple of streamed rows whose
                // (mc × kc) block fits a quarter-buffer — deterministic,
                // traffic-neutral (only pack granularity, not reuse).
                let mc_cap = (model.board.pl.buffer_bytes() / 4) / (kc * eb);
                let mc = ((mc_cap / tile) * tile).clamp(tile, streamed_pad.max(tile));
                let bytes =
                    model.blocked_mm_dram_bytes(n_pad, m_pad, k_pad, eb, kc, span, matches!(order, PanelOrder::BResident));
                out.push(BlockingPlan {
                    n,
                    m,
                    k,
                    tile,
                    n_pad,
                    m_pad,
                    k_pad,
                    order,
                    kc,
                    span,
                    mc,
                    rounds: (n_pad / tile) * (m_pad / tile) * (k_pad / tile),
                    predicted_dram_bytes: bytes,
                    predicted_dram_s: bytes as f64 / dram_bw,
                });
                span += tile;
            }
            kc += tile;
        }
    }
    if out.is_empty() {
        // tile × tile × eb always fits the 10 MB half-buffer, so this is
        // unreachable on any real board config — but a hand-shrunk board
        // must degrade to a typed error, not an empty unwrap downstream.
        return Err(Unplannable {
            n,
            m,
            k,
            reason: format!(
                "no {tile}-multiple panel fits half the PL buffer ({panel_budget} bytes)"
            ),
        });
    }
    Ok(out)
}

/// The deterministic host-blocking plan: the candidate with the least
/// predicted DRAM traffic (strict `<`, so the first minimum in the
/// canonical enumeration order wins — bit-identical across runs).
pub fn plan_mm(model: &CostModel, n: u64, m: u64, k: u64) -> Result<BlockingPlan, Unplannable> {
    let mut cands = plan_mm_candidates(model, n, m, k)?;
    let mut best = 0usize;
    for (i, c) in cands.iter().enumerate() {
        if c.predicted_dram_bytes < cands[best].predicted_dram_bytes {
            best = i;
        }
    }
    Ok(cands.swap_remove(best))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vck5000::BoardConfig;

    fn model() -> CostModel {
        CostModel::new(BoardConfig::vck5000())
    }

    #[test]
    fn plan_is_deterministic_and_pins_small_shapes() {
        let m = model();
        let a = plan_mm(&m, 2048, 2048, 2048).unwrap();
        let b = plan_mm(&m, 2048, 2048, 2048).unwrap();
        assert_eq!(a, b);
        // divisible-by-both shapes keep the 256 tile (fewer rounds)
        let p = plan_mm(&m, 256, 256, 256).unwrap();
        assert_eq!((p.tile, p.rounds), (256, 1));
        // 128-granular shapes fall back to the 128 tile
        let p = plan_mm(&m, 256, 128, 128).unwrap();
        assert_eq!((p.tile, p.rounds), (128, 2));
        // ragged/prime/sub-tile shapes pad, never error
        for (n, mm, k) in [(10, 10, 10), (127, 131, 7), (300, 260, 200)] {
            let p = plan_mm(&m, n, mm, k).unwrap();
            assert_eq!(p.n_pad % p.tile, 0);
            assert_eq!(p.m_pad % p.tile, 0);
            assert_eq!(p.k_pad % p.tile, 0);
            assert!(p.n_pad >= n && p.m_pad >= mm && p.k_pad >= k);
            assert!(p.rounds >= 1);
        }
    }

    #[test]
    fn plans_respect_the_panel_budget_and_model_pricing() {
        let m = model();
        let budget = m.board.pl.buffer_bytes() / 2;
        for p in plan_mm_candidates(&m, 4096, 4096, 4096).unwrap() {
            assert!(p.kc * p.span * 4 <= budget, "{}", p.summary());
            assert_eq!(p.kc % p.tile, 0);
            assert_eq!(p.span % p.tile, 0);
            assert_eq!(p.mc % p.tile, 0);
            // the plan's price is the shared cost-model formula, verbatim
            assert_eq!(
                p.predicted_dram_bytes,
                m.blocked_mm_dram_bytes(
                    p.n_pad,
                    p.m_pad,
                    p.k_pad,
                    4,
                    p.kc,
                    p.span,
                    matches!(p.order, PanelOrder::BResident)
                )
            );
            assert!(p.predicted_dram_s > 0.0);
        }
    }

    #[test]
    fn unplannable_shapes_return_typed_errors() {
        let m = model();
        for (n, mm, k) in [(0, 8, 8), (8, 0, 8), (8, 8, 0)] {
            let e = plan_mm(&m, n, mm, k).unwrap_err();
            assert!(e.to_string().contains("every extent"), "{e}");
        }
        // 1e9³ pads to a >1 TiB matrix: typed rejection, no allocation
        let e = plan_mm(&m, 1_000_000_000, 1_000_000_000, 1_000_000_000).unwrap_err();
        assert_eq!((e.n, e.m, e.k), (1_000_000_000, 1_000_000_000, 1_000_000_000));
        assert!(e.to_string().contains("staging cap"), "{e}");
        // std::error::Error + Display carry the shape for protocol use
        let dyn_err: &dyn std::error::Error = &e;
        assert!(dyn_err.to_string().contains("1000000000x1000000000"));
    }

    /// Mutation-seam guard (`WIDESA_MUTATE=blocking-reuse` must flip
    /// this): the planner's predicted bytes equal an independently
    /// written reuse-accounting reference, and its chosen plan attains
    /// the reference minimum over the whole candidate set. Under the
    /// seam the streamed operand's reload factor is mis-counted as 1,
    /// the planner maximizes kc instead of balancing kc against span,
    /// and both assertions fail at 4096³.
    #[test]
    fn blocking_planner_prices_true_reuse() {
        let m = model();
        let (n, mm, k) = (4096u64, 4096u64, 4096u64);
        // Independent reference: priced from the plan geometry alone.
        let reference = |p: &BlockingPlan| -> u128 {
            let (np, mp, kp, eb) = (p.n_pad as u128, p.m_pad as u128, p.k_pad as u128, 4u128);
            let segments = kp.div_ceil(p.kc as u128);
            let free = match p.order {
                PanelOrder::BResident => mp,
                PanelOrder::AResident => np,
            };
            let reload = free.div_ceil(p.span as u128);
            let resident = match p.order {
                PanelOrder::BResident => kp * mp * eb,
                PanelOrder::AResident => np * kp * eb,
            };
            let streamed = match p.order {
                PanelOrder::BResident => np * kp * eb,
                PanelOrder::AResident => kp * mp * eb,
            };
            resident + streamed * reload + np * mp * eb * (2 * segments - 1)
        };
        let cands = plan_mm_candidates(&m, n, mm, k).unwrap();
        let chosen = plan_mm(&m, n, mm, k).unwrap();
        // (a) the chosen plan's predicted bytes match the reference
        assert_eq!(
            chosen.predicted_dram_bytes as u128,
            reference(&chosen),
            "planner pricing diverged from the reuse-accounting reference for {}",
            chosen.summary()
        );
        // (b) the chosen plan attains the reference minimum
        let best_ref = cands.iter().map(|c| reference(c)).min().unwrap();
        assert_eq!(
            reference(&chosen),
            best_ref,
            "planner picked a traffic-pessimal order: {} (reference best {best_ref})",
            chosen.summary()
        );
        // sanity: at 4096³ real reuse matters — the optimum balances kc
        // against span rather than maxing either
        assert!(chosen.span > chosen.tile, "{}", chosen.summary());
        assert!(chosen.kc < chosen.k_pad, "{}", chosen.summary());
    }

    #[test]
    fn json_and_artifact_round_trip() {
        let m = model();
        let p = plan_mm(&m, 300, 260, 200).unwrap();
        assert_eq!(p.artifact(), format!("mm_f32_{}", p.tile));
        let j = p.to_json();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(300));
        assert_eq!(j.get("rounds").unwrap().as_u64(), Some(p.rounds));
        assert_eq!(
            j.get("predicted_dram_bytes").unwrap().as_u64(),
            Some(p.predicted_dram_bytes)
        );
        assert_eq!(j.get("order").unwrap().as_str(), Some(p.order.to_string().as_str()));
        assert!(p.summary().contains("blocking:"));
    }
}
