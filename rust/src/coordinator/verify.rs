//! Host-side oracles for functional verification of replayed designs.
//!
//! These are deliberately naive (triple loop, textbook DFT recursion) —
//! the trusted baseline the mapped execution must reproduce. They mirror
//! the pure-jnp oracles in `python/compile/kernels/ref.py`.

/// C' = C + A·B, row-major.
pub fn mm_ref(a: &[f32], b: &[f32], c: &[f32], n: usize, m: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), n * k);
    assert_eq!(b.len(), k * m);
    assert_eq!(c.len(), n * m);
    let mut out = c.to_vec();
    for i in 0..n {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            for j in 0..m {
                out[i * m + j] += av * b[kk * m + j];
            }
        }
    }
    out
}

/// Valid 2D correlation: x is (h + p - 1) × (w + q - 1), kernel p × q.
pub fn conv2d_ref(x: &[f32], k: &[f32], h: usize, w: usize, p: usize, q: usize) -> Vec<f32> {
    let xw = w + q - 1;
    let mut out = vec![0f32; h * w];
    for i in 0..h {
        for j in 0..w {
            let mut acc = 0f32;
            for a in 0..p {
                for b in 0..q {
                    acc += x[(i + a) * xw + (j + b)] * k[a * q + b];
                }
            }
            out[i * w + j] = acc;
        }
    }
    out
}

/// y[i] = Σ_t h[t] · x[i + t]; x has n + taps - 1 samples.
pub fn fir_ref(x: &[f32], h: &[f32], n: usize) -> Vec<f32> {
    let taps = h.len();
    assert_eq!(x.len(), n + taps - 1);
    (0..n)
        .map(|i| (0..taps).map(|t| h[t] * x[i + t]).sum())
        .collect()
}

/// In-place iterative radix-2 DIT FFT over (re, im) of power-of-two len.
pub fn fft_ref(re: &mut [f32], im: &mut [f32]) {
    let n = re.len();
    assert!(n.is_power_of_two());
    assert_eq!(im.len(), n);
    // bit reversal
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut m = 1;
    while m < n {
        let theta = -std::f64::consts::PI / m as f64;
        for g in (0..n).step_by(2 * m) {
            for j in 0..m {
                let ang = theta * j as f64;
                let (twr, twi) = (ang.cos() as f32, ang.sin() as f32);
                let (br, bi) = (re[g + m + j], im[g + m + j]);
                let (tr, ti) = (br * twr - bi * twi, br * twi + bi * twr);
                let (ar, ai) = (re[g + j], im[g + j]);
                re[g + j] = ar + tr;
                im[g + j] = ai + ti;
                re[g + m + j] = ar - tr;
                im[g + m + j] = ai - ti;
            }
        }
        m *= 2;
    }
}

/// 2D FFT oracle over a rows×cols grid (row-major re/im planes).
pub fn fft2d_ref(re: &mut [f32], im: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        fft_ref(&mut re[r * cols..(r + 1) * cols], &mut im[r * cols..(r + 1) * cols]);
    }
    // transpose, row FFTs, transpose back
    let mut tre = vec![0f32; rows * cols];
    let mut tim = vec![0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            tre[c * rows + r] = re[r * cols + c];
            tim[c * rows + r] = im[r * cols + c];
        }
    }
    for c in 0..cols {
        fft_ref(&mut tre[c * rows..(c + 1) * rows], &mut tim[c * rows..(c + 1) * rows]);
    }
    for r in 0..rows {
        for c in 0..cols {
            re[r * cols + c] = tre[c * rows + r];
            im[r * cols + c] = tim[c * rows + r];
        }
    }
}

/// Depthwise (grouped) 2D correlation: one independent p×q filter per
/// channel. `x` is `[c, h+p-1, w+q-1]` row-major, `k` is `[c, p, q]`,
/// output `[c, h, w]`.
pub fn dw_conv2d_ref(
    x: &[f32],
    k: &[f32],
    c: usize,
    h: usize,
    w: usize,
    p: usize,
    q: usize,
) -> Vec<f32> {
    let (xh, xw) = (h + p - 1, w + q - 1);
    assert_eq!(x.len(), c * xh * xw);
    assert_eq!(k.len(), c * p * q);
    let mut out = vec![0f32; c * h * w];
    for g in 0..c {
        let xg = &x[g * xh * xw..(g + 1) * xh * xw];
        let kg = &k[g * p * q..(g + 1) * p * q];
        for i in 0..h {
            for j in 0..w {
                let mut acc = 0f32;
                for a in 0..p {
                    for b in 0..q {
                        acc += xg[(i + a) * xw + (j + b)] * kg[a * q + b];
                    }
                }
                out[g * h * w + i * w + j] = acc;
            }
        }
    }
    out
}

/// Forward substitution `x = L⁻¹ b`: `l` is row-major n×n with the
/// strictly upper part ignored (the rectangular hull's dead half).
pub fn trsv_ref(l: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(l.len(), n * n);
    assert_eq!(b.len(), n);
    let mut x = vec![0f32; n];
    for i in 0..n {
        let mut s = b[i];
        for (j, xj) in x.iter().enumerate().take(i) {
            s -= l[i * n + j] * xj;
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// `stages` Jacobi sweeps of the 5-point stencil over a row-major n×m
/// grid with coefficients `[centre, north, south, west, east]`; values
/// beyond the boundary are zero.
pub fn stencil2d_chain_ref(a: &[f32], n: usize, m: usize, stages: usize, coef: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), n * m);
    assert_eq!(coef.len(), 5);
    let mut cur = a.to_vec();
    for _ in 0..stages {
        let mut next = vec![0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                let mut s = coef[0] * cur[i * m + j];
                if i > 0 {
                    s += coef[1] * cur[(i - 1) * m + j];
                }
                if i + 1 < n {
                    s += coef[2] * cur[(i + 1) * m + j];
                }
                if j > 0 {
                    s += coef[3] * cur[i * m + j - 1];
                }
                if j + 1 < m {
                    s += coef[4] * cur[i * m + j + 1];
                }
                next[i * m + j] = s;
            }
        }
        cur = next;
    }
    cur
}

/// Communication-avoiding MM reference: split the reduction into `rep`
/// k-slabs, compute each slab's partial product independently, then
/// reduce the partials in slab order — the host-side mirror of the
/// on-chip broadcast-reduction schedule (`rep` row-replicas each walk
/// one slab; partial C tiles reduce down the replication axis).
/// Numerically this reassociates the k sum, so it agrees with
/// [`mm_ref`] to accumulation tolerance, not bit-exactly.
pub fn ca_mm_ref(
    a: &[f32],
    b: &[f32],
    c: &[f32],
    n: usize,
    m: usize,
    k: usize,
    rep: usize,
) -> Vec<f32> {
    assert!(rep >= 1 && k % rep == 0, "reduction must divide across replicas");
    let slab = k / rep;
    let mut out = c.to_vec();
    for s in 0..rep {
        let mut partial = vec![0f32; n * m];
        for i in 0..n {
            for kk in s * slab..(s + 1) * slab {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..m {
                    partial[i * m + j] += av * b[kk * m + j];
                }
            }
        }
        for (o, p) in out.iter_mut().zip(&partial) {
            *o += p;
        }
    }
    out
}

/// `stages` Gauss–Seidel-style sweeps over a row-major n×m grid: each
/// stage updates in place with rows traversed bottom-up, so the south
/// neighbour is the *current* stage's freshly updated value while the
/// remaining neighbours come from the previous stage. Coefficients are
/// `[centre, south_new, south_old, west, east]`; values beyond the
/// boundary are zero. This realises the
/// [`crate::recurrence::library::seidel2d`] dependence set — the
/// same-sweep `(0, -1, 0)` flow is the `south_new` term.
pub fn seidel2d_ref(a: &[f32], n: usize, m: usize, stages: usize, coef: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), n * m);
    assert_eq!(coef.len(), 5);
    let mut cur = a.to_vec();
    for _ in 0..stages {
        let prev = cur.clone();
        for i in (0..n).rev() {
            for j in 0..m {
                let mut s = coef[0] * prev[i * m + j];
                if i + 1 < n {
                    s += coef[1] * cur[(i + 1) * m + j]; // fresh, this sweep
                    s += coef[2] * prev[(i + 1) * m + j];
                }
                if j > 0 {
                    s += coef[3] * prev[i * m + j - 1];
                }
                if j + 1 < m {
                    s += coef[4] * prev[i * m + j + 1];
                }
                cur[i * m + j] = s;
            }
        }
    }
    cur
}

/// Max |a - b| over two buffers.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_ref_identity() {
        // A = I: C' = C + B
        let n = 4;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f32> = (0..n * n).map(|i| i as f32).collect();
        let c = vec![1f32; n * n];
        let out = mm_ref(&a, &b, &c, n, n, n);
        for i in 0..n * n {
            assert_eq!(out[i], b[i] + 1.0);
        }
    }

    #[test]
    fn conv_delta_kernel_passthrough() {
        let h = 3;
        let w = 3;
        let x: Vec<f32> = (0..5 * 5).map(|i| i as f32).collect();
        let mut k = vec![0f32; 9];
        k[0] = 1.0;
        let out = conv2d_ref(&x, &k, h, w, 3, 3);
        for i in 0..h {
            for j in 0..w {
                assert_eq!(out[i * w + j], x[i * 5 + j]);
            }
        }
    }

    #[test]
    fn fir_moving_average() {
        let x = vec![1f32; 10 + 2];
        let h = vec![1.0 / 3.0; 3];
        let y = fir_ref(&x, &h, 10);
        for v in y {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let n = 16;
        let mut re = vec![0f32; n];
        let mut im = vec![0f32; n];
        re[0] = 1.0;
        fft_ref(&mut re, &mut im);
        for i in 0..n {
            assert!((re[i] - 1.0).abs() < 1e-5);
            assert!(im[i].abs() < 1e-5);
        }
    }

    #[test]
    fn fft_parseval() {
        let n = 64;
        let mut re: Vec<f32> = (0..n).map(|i| ((i * 7 + 3) % 11) as f32 - 5.0).collect();
        let mut im = vec![0f32; n];
        let time_energy: f32 = re.iter().map(|x| x * x).sum();
        fft_ref(&mut re, &mut im);
        let freq_energy: f32 =
            re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f32>() / n as f32;
        assert!((time_energy - freq_energy).abs() / time_energy < 1e-4);
    }

    #[test]
    fn dwconv_delta_kernel_is_per_channel_passthrough() {
        let (c, h, w, p) = (3usize, 4usize, 4usize, 3usize);
        let xw = w + p - 1;
        let x: Vec<f32> = (0..c * (h + p - 1) * xw).map(|i| i as f32).collect();
        // channel 1 gets a delta kernel at (0,0); others all-zero
        let mut k = vec![0f32; c * p * p];
        k[p * p] = 1.0;
        let out = dw_conv2d_ref(&x, &k, c, h, w, p, p);
        for i in 0..h {
            for j in 0..w {
                assert_eq!(out[h * w + i * w + j], x[(h + p - 1) * xw + i * xw + j]);
                assert_eq!(out[i * w + j], 0.0, "zero kernel must give zero");
            }
        }
    }

    #[test]
    fn trsv_identity_and_hand_case() {
        // L = I: x = b
        let n = 4;
        let mut l = vec![0f32; n * n];
        for i in 0..n {
            l[i * n + i] = 1.0;
        }
        let b: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        assert_eq!(trsv_ref(&l, &b, n), b);
        // hand case: [[2,0],[1,4]] x = [2, 9] → x = [1, 2]
        let l2 = vec![2.0, 0.0, 1.0, 4.0];
        let x = trsv_ref(&l2, &[2.0, 9.0], 2);
        assert!((x[0] - 1.0).abs() < 1e-6 && (x[1] - 2.0).abs() < 1e-6);
        // the strictly upper half is ignored
        let l3 = vec![2.0, 77.0, 1.0, 4.0];
        assert_eq!(trsv_ref(&l3, &[2.0, 9.0], 2), x);
    }

    #[test]
    fn stencil_identity_and_averaging() {
        let (n, m) = (4usize, 5usize);
        let a: Vec<f32> = (0..n * m).map(|i| i as f32).collect();
        // centre-only kernel is the identity for any number of sweeps
        let id = stencil2d_chain_ref(&a, n, m, 3, &[1.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(id, a);
        // one averaging sweep on a constant interior keeps the value
        let ones = vec![1f32; n * m];
        let avg = stencil2d_chain_ref(&ones, n, m, 1, &[0.2, 0.2, 0.2, 0.2, 0.2]);
        assert!((avg[m + 2] - 1.0).abs() < 1e-6);
        // boundary cells lose mass to the zero halo
        assert!(avg[0] < 1.0);
    }

    #[test]
    fn ca_mm_ref_agrees_with_mm_ref() {
        let (n, m, k) = (6usize, 5usize, 8usize);
        let a: Vec<f32> = (0..n * k).map(|i| ((i * 13 + 5) % 7) as f32 - 3.0).collect();
        let b: Vec<f32> = (0..k * m).map(|i| ((i * 11 + 2) % 9) as f32 - 4.0).collect();
        let c: Vec<f32> = (0..n * m).map(|i| (i % 3) as f32).collect();
        let base = mm_ref(&a, &b, &c, n, m, k);
        for rep in [1, 2, 4, 8] {
            let ca = ca_mm_ref(&a, &b, &c, n, m, k, rep);
            assert!(
                max_abs_diff(&base, &ca) < 1e-3,
                "rep {rep}: reassociated reduction drifted"
            );
        }
    }

    #[test]
    #[should_panic(expected = "divide across replicas")]
    fn ca_mm_ref_rejects_indivisible_slabs() {
        ca_mm_ref(&[0.0; 6], &[0.0; 6], &[0.0; 4], 2, 2, 3, 2);
    }

    #[test]
    fn seidel_identity_and_fresh_south() {
        let (n, m) = (4usize, 5usize);
        let a: Vec<f32> = (0..n * m).map(|i| i as f32).collect();
        // centre-only kernel is the identity for any number of sweeps
        let id = seidel2d_ref(&a, n, m, 3, &[1.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(id, a);
        // a pure fresh-south kernel drains to zero in ONE sweep: the
        // bottom row reads the zero halo and every row above reads the
        // already-updated (zero) row below — the Jacobi chain
        // (stencil2d_chain_ref's old-south term) would take n sweeps
        let fresh = seidel2d_ref(&a, n, m, 1, &[0.0, 1.0, 0.0, 0.0, 0.0]);
        assert!(fresh.iter().all(|v| *v == 0.0), "fresh south must chain within a sweep");
        let old = seidel2d_ref(&a, n, m, 1, &[0.0, 0.0, 1.0, 0.0, 0.0]);
        assert!(old[0] != 0.0, "old south is the previous sweep's value");
    }

    #[test]
    fn fft2d_impulse() {
        let (rows, cols) = (8, 8);
        let mut re = vec![0f32; rows * cols];
        let mut im = vec![0f32; rows * cols];
        re[0] = 1.0;
        fft2d_ref(&mut re, &mut im, rows, cols);
        for i in 0..rows * cols {
            assert!((re[i] - 1.0).abs() < 1e-4);
            assert!(im[i].abs() < 1e-4);
        }
    }
}
