//! The automatic mapping framework (paper Figure 5): one call takes a
//! uniform recurrence to a fully compiled design — mapping, mapped graph,
//! placement + PLIO assignment + routes, performance estimate, simulation
//! report and generated backend code.

use crate::arch::vck5000::BoardConfig;
use crate::codegen::{self, CodeBundle};
use crate::graph::builder::{build, MappedGraph};
use crate::graph::packet::{merge_ports_with_budget, MergeStats};
use crate::mapping::cost::{CostModel, PerfEstimate};
use crate::mapping::dse::{explore_all, DseConstraints};
use crate::mapping::MappingCandidate;
use crate::place_route::compiler::{compile, CompileOutcome};
use crate::recurrence::spec::UniformRecurrence;
use crate::sim::engine::{simulate, SimConfig};
use crate::sim::metrics::SimReport;
use anyhow::{anyhow, Result};

/// Framework configuration.
#[derive(Debug, Clone)]
pub struct WideSaConfig {
    pub board: BoardConfig,
    pub constraints: DseConstraints,
    /// DMA mover datapath width (bits) — see cost-model docs.
    pub mover_bits: u64,
    /// Simulate cold-DRAM end-to-end in the sim report.
    pub cold_dram: bool,
}

impl Default for WideSaConfig {
    fn default() -> Self {
        Self {
            board: BoardConfig::vck5000(),
            constraints: DseConstraints::default(),
            mover_bits: 512,
            cold_dram: false,
        }
    }
}

/// Everything the framework produces for one recurrence.
pub struct CompiledDesign {
    pub candidate: MappingCandidate,
    pub estimate: PerfEstimate,
    pub graph: MappedGraph,
    pub merge_stats: MergeStats,
    pub compile: CompileOutcome,
    pub sim: SimReport,
    pub code: CodeBundle,
}

impl CompiledDesign {
    pub fn report(&self) -> String {
        format!(
            "{}\n  mapping : {}\n  est     : {:.3} TOPS ({:.4}/AIE), bound {}\n  sim     : {}\n  ports   : {} in / {} out (merged from {} / {})\n  compile : success={} congestion={} in {:.3}s\n",
            self.candidate.rec.name,
            self.candidate.summary(),
            self.estimate.tops,
            self.estimate.tops_per_aie,
            self.estimate.bound,
            self.sim.summary(),
            self.merge_stats.in_ports_after,
            self.merge_stats.out_ports_after,
            self.merge_stats.in_ports_before,
            self.merge_stats.out_ports_before,
            self.compile.success,
            self.compile.max_congestion,
            self.compile.wall_s,
        )
    }
}

/// The WideSA framework entry point.
///
/// ```
/// use widesa::{library, DType, DseConstraints, WideSa, WideSaConfig};
///
/// // Map a small FIR onto a 32-core budget and inspect the decisions.
/// let ws = WideSa::new(WideSaConfig {
///     constraints: DseConstraints {
///         max_aies: Some(32),
///         ..Default::default()
///     },
///     ..Default::default()
/// });
/// let design = ws.compile(&library::fir(65536, 15, DType::F32)).unwrap();
/// assert!(design.compile.success);
/// assert!(design.candidate.aies_used() <= 32);
/// assert!(design.sim.tops > 0.0);
/// ```
pub struct WideSa {
    pub config: WideSaConfig,
}

impl WideSa {
    pub fn new(config: WideSaConfig) -> Self {
        Self { config }
    }

    pub fn vck5000() -> Self {
        Self::new(WideSaConfig::default())
    }

    /// Map, place, route, simulate and generate code for a recurrence.
    ///
    /// Candidates are tried in cost order until one passes place & route
    /// — a throughput-optimal schedule that the compiler cannot realise
    /// is useless, so P&R feasibility is part of the search (the paper's
    /// "routing-aware" theme applied at the framework level). If nothing
    /// compiles, the best estimate is returned with `compile.success =
    /// false` so callers can inspect why.
    pub fn compile(&self, rec: &UniformRecurrence) -> Result<CompiledDesign> {
        let model =
            CostModel::new(self.config.board.clone()).with_mover_bits(self.config.mover_bits);
        let ranked = explore_all(rec, &self.config.board, &self.config.constraints);
        if ranked.is_empty() {
            return Err(anyhow!("no legal mapping for {}", rec.name));
        }
        let mut fallback: Option<CompiledDesign> = None;
        for (candidate, _) in ranked.into_iter().take(8) {
            // re-estimate under this framework's mover configuration (the
            // DSE ranking assumes the default 512-bit movers)
            let estimate = model.estimate(&candidate);
            let raw = build(&candidate, &model);
            let (graph, merge_stats) = merge_ports_with_budget(
                &raw,
                model.channel_bw(),
                self.config.board.plio.in_channels as usize,
                self.config.board.plio.out_channels as usize,
            );
            let compile_out = compile(&graph, &self.config.board);
            let success = compile_out.success;
            let (sim, _) = simulate(
                &candidate,
                &model,
                &SimConfig {
                    cold_dram: self.config.cold_dram,
                    keep_trace: false,
                },
            );
            let code = codegen::generate(&candidate, &graph, &compile_out);
            let design = CompiledDesign {
                candidate,
                estimate,
                graph,
                merge_stats,
                compile: compile_out,
                sim,
                code,
            };
            if success {
                return Ok(design);
            }
            if fallback.is_none() {
                fallback = Some(design);
            }
        }
        Ok(fallback.expect("at least one candidate evaluated"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn full_pipeline_mm() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(8192, 8192, 8192, DType::F32)).unwrap();
        assert!(d.compile.success, "place & route must succeed");
        assert!(d.estimate.tops > 3.0);
        assert!(d.sim.tops > 3.0);
        assert!(d.merge_stats.in_ports_after <= 78);
        assert!(d.merge_stats.out_ports_after <= 78);
        assert!(!d.code.aie_kernel.is_empty());
        let report = d.report();
        assert!(report.contains("TOPS"));
    }

    #[test]
    fn fallback_finds_compilable_candidate() {
        // At 512³ the throughput-ranked top candidate is a 1D+threading
        // mapping whose P&R fails; the framework must fall back to the
        // next candidate and still return a compiled design.
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(512, 512, 512, DType::F32)).unwrap();
        assert!(d.compile.success, "fallback should yield a compilable design");
    }

    #[test]
    fn full_pipeline_all_benchmarks() {
        for (rec, cap) in [
            (library::mm(2048, 2048, 2048, DType::I8), 400u64),
            (library::conv2d(1024, 1024, 4, 4, DType::I16), 400),
            (library::fir(65536, 15, DType::F32), 256),
            (library::fft2d(512, 512, DType::CF32), 320),
        ] {
            let ws = WideSa::new(WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(cap),
                    ..Default::default()
                },
                ..Default::default()
            });
            let d = ws.compile(&rec).unwrap();
            assert!(d.compile.success, "{} failed P&R", rec.name);
            assert!(d.sim.tops > 0.0);
        }
    }
}
