//! The automatic mapping framework (paper Figure 5): one call takes a
//! uniform recurrence to a fully compiled design — mapping, mapped graph,
//! placement + PLIO assignment + routes, performance estimate, simulation
//! report and generated backend code.

use crate::arch::vck5000::BoardConfig;
use crate::codegen::{self, CodeBundle};
use crate::graph::builder::{build, MappedGraph};
use crate::graph::packet::{merge_ports_with_budget, MergeStats};
use crate::mapping::cost::{CostModel, PerfEstimate};
use crate::mapping::dse::{explore_all, explore_all_parallel, DseConstraints};
use crate::mapping::MappingCandidate;
use crate::place_route::compiler::{compile, CompileOutcome};
use crate::recurrence::spec::UniformRecurrence;
use crate::sim::engine::{simulate, SimConfig};
use crate::sim::metrics::SimReport;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Framework configuration.
#[derive(Debug, Clone)]
pub struct WideSaConfig {
    pub board: BoardConfig,
    pub constraints: DseConstraints,
    /// DMA mover datapath width (bits) — see cost-model docs.
    pub mover_bits: u64,
    /// Simulate cold-DRAM end-to-end in the sim report.
    pub cold_dram: bool,
    /// Threads to shard DSE candidate scoring across (1 = serial). The
    /// parallel path returns bit-identical rankings — see
    /// [`explore_all_parallel`].
    pub dse_threads: usize,
}

impl Default for WideSaConfig {
    fn default() -> Self {
        Self {
            board: BoardConfig::vck5000(),
            constraints: DseConstraints::default(),
            mover_bits: 512,
            cold_dram: false,
            dse_threads: 1,
        }
    }
}

/// Everything the framework produces for one recurrence.
pub struct CompiledDesign {
    pub candidate: MappingCandidate,
    /// Analytic performance estimate (the DSE's ranking view).
    pub estimate: PerfEstimate,
    /// The same model evaluated with the *exact* merged PLIO port counts
    /// of [`CompiledDesign::merge_stats`] — the estimate that agrees with
    /// what place & route actually sees. For compute-bound designs this
    /// matches [`CompiledDesign::estimate`]; it diverges exactly when
    /// port packing is the binding resource.
    pub estimate_exact: PerfEstimate,
    pub graph: MappedGraph,
    pub merge_stats: MergeStats,
    pub compile: CompileOutcome,
    pub sim: SimReport,
    pub code: CodeBundle,
}

impl CompiledDesign {
    pub fn report(&self) -> String {
        format!(
            "{}\n  mapping : {}\n  est     : {:.3} TOPS ({:.4}/AIE), bound {}\n  exact   : {:.3} TOPS with merged ports, bound {}\n  sim     : {}\n  ports   : {} in / {} out (merged from {} / {})\n  compile : success={} congestion={} in {:.3}s\n",
            self.candidate.rec.name,
            self.candidate.summary(),
            self.estimate.tops,
            self.estimate.tops_per_aie,
            self.estimate.bound,
            self.estimate_exact.tops,
            self.estimate_exact.bound,
            self.sim.summary(),
            self.merge_stats.in_ports_after,
            self.merge_stats.out_ports_after,
            self.merge_stats.in_ports_before,
            self.merge_stats.out_ports_before,
            self.compile.success,
            self.compile.max_congestion,
            self.compile.wall_s,
        )
    }
}

/// The WideSA framework entry point.
///
/// ```
/// use widesa::{library, DType, DseConstraints, WideSa, WideSaConfig};
///
/// // Map a small FIR onto a 32-core budget and inspect the decisions.
/// let ws = WideSa::new(WideSaConfig {
///     constraints: DseConstraints {
///         max_aies: Some(32),
///         ..Default::default()
///     },
///     ..Default::default()
/// });
/// let design = ws.compile(&library::fir(65536, 15, DType::F32)).unwrap();
/// assert!(design.compile.success);
/// assert!(design.candidate.aies_used() <= 32);
/// assert!(design.sim.tops > 0.0);
/// ```
pub struct WideSa {
    pub config: WideSaConfig,
}

impl WideSa {
    pub fn new(config: WideSaConfig) -> Self {
        Self { config }
    }

    pub fn vck5000() -> Self {
        Self::new(WideSaConfig::default())
    }

    /// Map, place, route, simulate and generate code for a recurrence.
    ///
    /// Candidates are tried in cost order until one passes place & route
    /// — a throughput-optimal schedule that the compiler cannot realise
    /// is useless, so P&R feasibility is part of the search (the paper's
    /// "routing-aware" theme applied at the framework level). If nothing
    /// compiles, the best estimate is returned with `compile.success =
    /// false` so callers can inspect why.
    pub fn compile(&self, rec: &UniformRecurrence) -> Result<CompiledDesign> {
        let ranked = if self.config.dse_threads > 1 {
            explore_all_parallel(
                rec,
                &self.config.board,
                &self.config.constraints,
                self.config.dse_threads,
            )
        } else {
            explore_all(rec, &self.config.board, &self.config.constraints)
        };
        self.compile_ranked(rec, ranked)
    }

    /// As [`WideSa::compile`], returning the design behind an [`Arc`] so
    /// it can be shared across threads (the serve layer's cache hands the
    /// same compiled design to many concurrent requests).
    pub fn compile_arc(&self, rec: &UniformRecurrence) -> Result<Arc<CompiledDesign>> {
        self.compile(rec).map(Arc::new)
    }

    /// The back half of [`WideSa::compile`]: take an already-ranked
    /// candidate list (from any `explore_all` variant — serial, scoped
    /// threads, or the serve layer's worker pool) through graph build,
    /// port merging, place & route, simulation and codegen.
    pub fn compile_ranked(
        &self,
        rec: &UniformRecurrence,
        ranked: Vec<(MappingCandidate, PerfEstimate)>,
    ) -> Result<CompiledDesign> {
        let model =
            CostModel::new(self.config.board.clone()).with_mover_bits(self.config.mover_bits);
        if ranked.is_empty() {
            return Err(anyhow!("no legal mapping for {}", rec.name));
        }
        let mut fallback: Option<CompiledDesign> = None;
        for (candidate, _) in ranked.into_iter().take(8) {
            // re-estimate under this framework's mover configuration (the
            // DSE ranking assumes the default 512-bit movers)
            let estimate = model.estimate(&candidate);
            let raw = build(&candidate, &model);
            let (graph, merge_stats) = merge_ports_with_budget(
                &raw,
                model.channel_bw(),
                self.config.board.plio.in_channels as usize,
                self.config.board.plio.out_channels as usize,
            );
            // exact-port estimate: same model, but with the port counts
            // the packet-switch merge actually realised
            let estimate_exact = model.estimate_with_ports(
                &candidate,
                merge_stats.in_ports_after as u64,
                merge_stats.out_ports_after as u64,
            );
            let compile_out = compile(&graph, &self.config.board);
            let success = compile_out.success;
            let (sim, _) = simulate(
                &candidate,
                &model,
                &SimConfig {
                    cold_dram: self.config.cold_dram,
                    keep_trace: false,
                },
            );
            let code = codegen::generate(&candidate, &graph, &compile_out);
            let design = CompiledDesign {
                candidate,
                estimate,
                estimate_exact,
                graph,
                merge_stats,
                compile: compile_out,
                sim,
                code,
            };
            if success {
                return Ok(design);
            }
            if fallback.is_none() {
                fallback = Some(design);
            }
        }
        Ok(fallback.expect("at least one candidate evaluated"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn full_pipeline_mm() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(8192, 8192, 8192, DType::F32)).unwrap();
        assert!(d.compile.success, "place & route must succeed");
        assert!(d.estimate.tops > 3.0);
        assert!(d.sim.tops > 3.0);
        assert!(d.merge_stats.in_ports_after <= 78);
        assert!(d.merge_stats.out_ports_after <= 78);
        assert!(!d.code.aie_kernel.is_empty());
        let report = d.report();
        assert!(report.contains("TOPS"));
    }

    #[test]
    fn fallback_finds_compilable_candidate() {
        // At 512³ the throughput-ranked top candidate is a 1D+threading
        // mapping whose P&R fails; the framework must fall back to the
        // next candidate and still return a compiled design.
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(512, 512, 512, DType::F32)).unwrap();
        assert!(d.compile.success, "fallback should yield a compilable design");
    }

    #[test]
    fn parallel_dse_compile_matches_serial() {
        let serial = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let parallel = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            dse_threads: 4,
            ..Default::default()
        });
        let rec = library::mm(2048, 2048, 2048, DType::F32);
        let a = serial.compile(&rec).unwrap();
        let b = parallel.compile(&rec).unwrap();
        assert_eq!(a.candidate.summary(), b.candidate.summary());
        assert_eq!(a.estimate.tops.to_bits(), b.estimate.tops.to_bits());
        assert_eq!(a.merge_stats, b.merge_stats);
    }

    #[test]
    fn exact_estimate_present_and_bounded() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(8192, 8192, 8192, DType::F32)).unwrap();
        assert_eq!(d.estimate_exact.plio_in_ports as usize, d.merge_stats.in_ports_after);
        assert_eq!(d.estimate_exact.plio_out_ports as usize, d.merge_stats.out_ports_after);
        assert!(d.estimate_exact.tops > 0.0);
        let report = d.report();
        assert!(report.contains("exact"));
    }

    #[test]
    fn full_pipeline_all_benchmarks() {
        for (rec, cap) in [
            (library::mm(2048, 2048, 2048, DType::I8), 400u64),
            (library::conv2d(1024, 1024, 4, 4, DType::I16), 400),
            (library::fir(65536, 15, DType::F32), 256),
            (library::fft2d(512, 512, DType::CF32), 320),
        ] {
            let ws = WideSa::new(WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(cap),
                    ..Default::default()
                },
                ..Default::default()
            });
            let d = ws.compile(&rec).unwrap();
            assert!(d.compile.success, "{} failed P&R", rec.name);
            assert!(d.sim.tops > 0.0);
        }
    }
}
