//! The automatic mapping framework (paper Figure 5): one call takes a
//! uniform recurrence to a fully compiled design — mapping, mapped graph,
//! placement + PLIO assignment + routes, performance estimate, simulation
//! report and generated backend code.

use crate::arch::vck5000::BoardConfig;
use crate::codegen::{self, CodeBundle};
use crate::graph::builder::{build, MappedGraph};
use crate::graph::packet::{merge_ports_with_budget, MergeStats};
use crate::mapping::cost::{CostModel, Estimate};
use crate::mapping::dse::{
    explore_all, explore_all_parallel, frontier_size, scoring_model, DseConstraints, Ranked,
};
use crate::mapping::MappingCandidate;
use crate::obs::trace::{self, Span, TraceCtx};
use crate::place_route::compiler::{compile, CompileOutcome};
use crate::recurrence::spec::UniformRecurrence;
use crate::sim::engine::{simulate, SimConfig};
use crate::sim::metrics::SimReport;
use anyhow::Result;
use std::sync::Arc;

/// How many ranked candidates the framework back half will take through
/// place & route before settling for the best-ranked failure.
pub const FALLBACK_CANDIDATES: usize = 8;

/// Typed error: the DSE produced no legal candidate (a tiny recurrence
/// with no space loops, or [`DseConstraints`] too tight to fit a single
/// core). Travels as the source of the returned [`anyhow::Error`], so
/// callers can `err.downcast_ref::<NoLegalMapping>()` instead of matching
/// message text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NoLegalMapping {
    pub recurrence: String,
}

impl std::fmt::Display for NoLegalMapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "no legal mapping for {}", self.recurrence)
    }
}

impl std::error::Error for NoLegalMapping {}

/// Framework configuration.
#[derive(Debug, Clone)]
pub struct WideSaConfig {
    pub board: BoardConfig,
    pub constraints: DseConstraints,
    /// DMA mover datapath width (bits) — see cost-model docs.
    pub mover_bits: u64,
    /// Simulate cold-DRAM end-to-end in the sim report.
    pub cold_dram: bool,
    /// Threads to shard DSE candidate scoring **and** the framework back
    /// half (P&R per fallback candidate) across (1 = serial). Both
    /// parallel paths are deterministic: scoring returns bit-identical
    /// rankings ([`explore_all_parallel`]) and the back half picks the
    /// same design as the serial first-success loop
    /// ([`WideSa::select_design`]).
    pub dse_threads: usize,
}

impl Default for WideSaConfig {
    fn default() -> Self {
        Self {
            board: BoardConfig::vck5000(),
            constraints: DseConstraints::default(),
            mover_bits: 512,
            cold_dram: false,
            dse_threads: 1,
        }
    }
}

/// What the DSE ranking's throughput/efficiency tradeoff looked like at
/// compile time: how many of the scored candidates sat on the Pareto
/// frontier. Carried on every [`CompiledDesign`]; `(0, 0)` when the
/// design was built directly from a candidate without a ranking.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrontierSummary {
    /// Candidates no rival beat on both TOPS and TOPS/W.
    pub frontier: usize,
    /// Total candidates the DSE ranked.
    pub candidates: usize,
}

/// Everything the framework produces for one recurrence.
pub struct CompiledDesign {
    pub candidate: MappingCandidate,
    /// The DSE's ranking view of this design (perf + power), re-priced
    /// under the framework's mover configuration. Under the default
    /// [`crate::mapping::cost::PortModel::Exact`] this already uses the
    /// predicted merged port counts.
    pub estimate: Estimate,
    /// The same model evaluated with the merged PLIO port counts that
    /// packet merging *actually realised* on the built graph
    /// ([`CompiledDesign::merge_stats`]). Under
    /// [`crate::mapping::cost::PortModel::Exact`] the
    /// predictor is bit-identical to the merge, so this coincides with
    /// [`CompiledDesign::estimate`]; under the legacy analytic ranking
    /// ([`DseConstraints::analytic_ranking`]) it diverges exactly when
    /// port packing is the binding resource.
    pub estimate_exact: Estimate,
    /// Pareto-frontier summary of the ranking this design was selected
    /// from (see [`FrontierSummary`]).
    pub frontier: FrontierSummary,
    pub graph: MappedGraph,
    pub merge_stats: MergeStats,
    pub compile: CompileOutcome,
    pub sim: SimReport,
    pub code: CodeBundle,
}

impl CompiledDesign {
    pub fn report(&self) -> String {
        format!(
            "{}\n  mapping : {}\n  est     : {:.3} TOPS ({:.4}/AIE), bound {}\n  exact   : {:.3} TOPS with merged ports, bound {}\n  power   : {:.1} W, {:.4} TOPS/W, {:.2} J/pass ({} of {} candidates Pareto-optimal)\n  sim     : {}\n  ports   : {} in / {} out (merged from {} / {})\n  compile : success={} congestion={} in {:.3}s (place {:.1} ms, assign {:.1} ms, route {:.1} ms)\n",
            self.candidate.rec.name,
            self.candidate.summary(),
            self.estimate.perf.tops,
            self.estimate.perf.tops_per_aie,
            self.estimate.perf.bound,
            self.estimate_exact.perf.tops,
            self.estimate_exact.perf.bound,
            self.estimate_exact.power.watts,
            self.estimate_exact.power.tops_per_watt,
            self.estimate_exact.power.energy_j,
            self.frontier.frontier,
            self.frontier.candidates,
            self.sim.summary(),
            self.merge_stats.in_ports_after,
            self.merge_stats.out_ports_after,
            self.merge_stats.in_ports_before,
            self.merge_stats.out_ports_before,
            self.compile.success,
            self.compile
                .max_congestion
                .map_or_else(|| "-".to_string(), |c| c.to_string()),
            self.compile.wall_s,
            self.compile.stages.place_ms,
            self.compile.stages.assign_ms,
            self.compile.stages.route_ms,
        )
    }
}

/// The WideSA framework entry point.
///
/// ```
/// use widesa::{library, DType, DseConstraints, WideSa, WideSaConfig};
///
/// // Map a small FIR onto a 32-core budget and inspect the decisions.
/// let ws = WideSa::new(WideSaConfig {
///     constraints: DseConstraints {
///         max_aies: Some(32),
///         ..Default::default()
///     },
///     ..Default::default()
/// });
/// let design = ws.compile(&library::fir(65536, 15, DType::F32)).unwrap();
/// assert!(design.compile.success);
/// assert!(design.candidate.aies_used() <= 32);
/// assert!(design.sim.tops > 0.0);
/// ```
pub struct WideSa {
    pub config: WideSaConfig,
}

impl WideSa {
    pub fn new(config: WideSaConfig) -> Self {
        Self { config }
    }

    pub fn vck5000() -> Self {
        Self::new(WideSaConfig::default())
    }

    /// Map, place, route, simulate and generate code for a recurrence.
    ///
    /// Candidates are tried in cost order until one passes place & route
    /// — a throughput-optimal schedule that the compiler cannot realise
    /// is useless, so P&R feasibility is part of the search (the paper's
    /// "routing-aware" theme applied at the framework level). If nothing
    /// compiles, the best estimate is returned with `compile.success =
    /// false` so callers can inspect why.
    pub fn compile(&self, rec: &UniformRecurrence) -> Result<CompiledDesign> {
        let ranked = if self.config.dse_threads > 1 {
            explore_all_parallel(
                rec,
                &self.config.board,
                &self.config.constraints,
                self.config.dse_threads,
            )
        } else {
            explore_all(rec, &self.config.board, &self.config.constraints)
        };
        self.compile_ranked(rec, ranked)
    }

    /// As [`WideSa::compile`], returning the design behind an [`Arc`] so
    /// it can be shared across threads (the serve layer's cache hands the
    /// same compiled design to many concurrent requests).
    pub fn compile_arc(&self, rec: &UniformRecurrence) -> Result<Arc<CompiledDesign>> {
        self.compile(rec).map(Arc::new)
    }

    /// The cost model this framework prices with: the DSE's
    /// [`scoring_model`] (exact merged counts unless
    /// [`DseConstraints::analytic_ranking`] asks for the legacy A/B
    /// ranking) under this framework's mover width — one construction
    /// site, so the back half can never price with a different port
    /// model than the ranking used. Shared with the serve layer's pooled
    /// back half.
    pub fn cost_model(&self) -> CostModel {
        scoring_model(&self.config.board, &self.config.constraints)
            .with_mover_bits(self.config.mover_bits)
    }

    /// Take one ranked candidate through the framework back half: graph
    /// build, packet merge, exact re-pricing, place & route, simulation
    /// and code generation. A pure function of its inputs — shardable
    /// across threads or a worker pool with no ordering concerns.
    pub fn evaluate_candidate(
        &self,
        model: &CostModel,
        candidate: MappingCandidate,
    ) -> CompiledDesign {
        // re-estimate under this framework's mover configuration (the
        // DSE ranking assumes the default 512-bit movers)
        let estimate = model.estimate(&candidate);
        let build_span = Span::begin("graph.build", "graph");
        let raw = build(&candidate, model);
        drop(build_span);
        let merge_span = Span::begin("graph.merge", "graph");
        let (graph, merge_stats) = merge_ports_with_budget(
            &raw,
            model.channel_bw(),
            self.config.board.plio.in_channels as usize,
            self.config.board.plio.out_channels as usize,
        );
        drop(merge_span);
        // post-merge re-pricing: same model, with the port counts the
        // packet-switch merge actually realised (== `estimate` under the
        // exact port model; diverges under the legacy analytic ranking)
        let estimate_exact = model.estimate_with_ports(
            &candidate,
            merge_stats.in_ports_after as u64,
            merge_stats.out_ports_after as u64,
        );
        // the compile runs under its own "pnr" span (see
        // `place_route::compiler`), which also feeds `StageTimings`
        let compile_out = compile(&graph, &self.config.board);
        let sim_span = Span::begin("sim", "sim");
        let (sim, _) = simulate(
            &candidate,
            model,
            &SimConfig {
                cold_dram: self.config.cold_dram,
                keep_trace: false,
            },
        );
        drop(sim_span);
        let codegen_span = Span::begin("codegen", "codegen");
        let code = codegen::generate(&candidate, &graph, &compile_out);
        drop(codegen_span);
        CompiledDesign {
            candidate,
            estimate,
            estimate_exact,
            frontier: FrontierSummary::default(),
            graph,
            merge_stats,
            compile: compile_out,
            sim,
            code,
        }
    }

    /// Deterministic first-success selection over rank-ordered evaluated
    /// designs: the best-ranked candidate that passed place & route, else
    /// the best-ranked failure as the diagnostic fallback. Shared by the
    /// scoped-thread and serve-pool back halves so every driver returns
    /// the same design the serial short-circuit loop would, regardless of
    /// scheduling.
    pub fn select_design(mut designs: Vec<CompiledDesign>) -> Option<CompiledDesign> {
        if designs.is_empty() {
            return None;
        }
        let pos = designs.iter().position(|d| d.compile.success).unwrap_or(0);
        Some(designs.swap_remove(pos))
    }

    /// The back half of [`WideSa::compile`]: take an already-ranked
    /// candidate list (from any `explore_all` variant — serial, scoped
    /// threads, or the serve layer's worker pool) through graph build,
    /// port merging, place & route, simulation and codegen.
    ///
    /// With `dse_threads > 1` the top candidate is evaluated eagerly
    /// (the common first-success case costs exactly one evaluation, like
    /// the serial loop); only when it fails P&R are the remaining
    /// fallback candidates evaluated concurrently on scoped threads, and
    /// [`WideSa::select_design`] picks the same design the serial
    /// first-success loop would. Returns a typed [`NoLegalMapping`] error
    /// when the DSE produced no candidates.
    pub fn compile_ranked(&self, rec: &UniformRecurrence, ranked: Ranked) -> Result<CompiledDesign> {
        let model = self.cost_model();
        // Frontier summary of the full ranking, attached to whichever
        // design the back half settles on (the serve layer surfaces it).
        let summary = FrontierSummary {
            frontier: frontier_size(&ranked),
            candidates: ranked.len(),
        };
        let attach = |mut d: CompiledDesign| {
            d.frontier = summary;
            d
        };
        let mut top: Vec<MappingCandidate> = ranked
            .into_iter()
            .take(FALLBACK_CANDIDATES)
            .map(|(candidate, _)| candidate)
            .collect();
        if self.config.dse_threads <= 1 || top.len() <= 1 {
            // serial path: short-circuits at the first success without
            // evaluating lower-ranked candidates
            let mut fallback: Option<CompiledDesign> = None;
            for candidate in top {
                let design = self.evaluate_candidate(&model, candidate);
                if design.compile.success {
                    return Ok(attach(design));
                }
                if fallback.is_none() {
                    fallback = Some(design);
                }
            }
            return fallback.map(attach).ok_or_else(|| {
                NoLegalMapping {
                    recurrence: rec.name.clone(),
                }
                .into()
            });
        }
        // Evaluate the top-ranked candidate first: in the common case it
        // passes P&R and speculatively evaluating the fallbacks would be
        // pure waste (slower than the serial short-circuit).
        let first = self.evaluate_candidate(&model, top.remove(0));
        if first.compile.success || top.is_empty() {
            return Ok(attach(first));
        }
        let mut designs = self.evaluate_all(&model, top);
        designs.insert(0, first);
        Self::select_design(designs).map(attach).ok_or_else(|| {
            NoLegalMapping {
                recurrence: rec.name.clone(),
            }
            .into()
        })
    }

    /// Evaluate every candidate's back half sharded over
    /// `config.dse_threads` scoped threads, results in rank order.
    fn evaluate_all(
        &self,
        model: &CostModel,
        candidates: Vec<MappingCandidate>,
    ) -> Vec<CompiledDesign> {
        let threads = self.config.dse_threads.min(candidates.len()).max(1);
        let indexed: Vec<(usize, MappingCandidate)> =
            candidates.into_iter().enumerate().collect();
        let chunk = indexed.len().div_ceil(threads);
        let mut slots: Vec<Option<CompiledDesign>> = Vec::new();
        slots.resize_with(indexed.len(), || None);
        // propagate the request's trace ID into the P&R shards so their
        // spans correlate with the caller's trace
        let trace_id = trace::current_trace();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for shard in indexed.chunks(chunk) {
                handles.push(s.spawn(move || {
                    let _ctx = TraceCtx::set(trace_id);
                    shard
                        .iter()
                        .map(|(i, candidate)| {
                            (*i, self.evaluate_candidate(model, candidate.clone()))
                        })
                        .collect::<Vec<_>>()
                }));
            }
            for h in handles {
                for (i, design) in h.join().expect("P&R shard panicked") {
                    slots[i] = Some(design);
                }
            }
        });
        slots.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recurrence::dtype::DType;
    use crate::recurrence::library;

    #[test]
    fn full_pipeline_mm() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(8192, 8192, 8192, DType::F32)).unwrap();
        assert!(d.compile.success, "place & route must succeed");
        assert!(d.estimate.perf.tops > 3.0);
        assert!(d.sim.tops > 3.0);
        assert!(d.merge_stats.in_ports_after <= 78);
        assert!(d.merge_stats.out_ports_after <= 78);
        assert!(!d.code.aie_kernel.is_empty());
        // power flows with the design: full-array MM draws well above
        // the static rail, and the report publishes W and TOPS/W
        assert!(d.estimate.power.watts > 20.0);
        assert!(d.estimate.power.tops_per_watt > 0.0);
        assert!(d.frontier.candidates > 0);
        assert!(d.frontier.frontier >= 1);
        assert!(d.frontier.frontier <= d.frontier.candidates);
        let report = d.report();
        assert!(report.contains("TOPS"));
        assert!(report.contains("W,"), "report must print watts: {report}");
        assert!(report.contains("TOPS/W"), "report must print TOPS/W: {report}");
    }

    #[test]
    fn fallback_finds_compilable_candidate() {
        // At 512³ the throughput-ranked top candidate is a 1D+threading
        // mapping whose P&R fails; the framework must fall back to the
        // next candidate and still return a compiled design.
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(512, 512, 512, DType::F32)).unwrap();
        assert!(d.compile.success, "fallback should yield a compilable design");
    }

    #[test]
    fn parallel_dse_compile_matches_serial() {
        let serial = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let parallel = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            dse_threads: 4,
            ..Default::default()
        });
        let rec = library::mm(2048, 2048, 2048, DType::F32);
        let a = serial.compile(&rec).unwrap();
        let b = parallel.compile(&rec).unwrap();
        assert_eq!(a.candidate.summary(), b.candidate.summary());
        assert_eq!(a.estimate.perf.tops.to_bits(), b.estimate.perf.tops.to_bits());
        assert_eq!(a.estimate.power.watts.to_bits(), b.estimate.power.watts.to_bits());
        assert_eq!(a.frontier, b.frontier);
        assert_eq!(a.merge_stats, b.merge_stats);
    }

    #[test]
    fn exact_estimate_present_and_bounded() {
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        let d = ws.compile(&library::mm(8192, 8192, 8192, DType::F32)).unwrap();
        assert_eq!(d.estimate_exact.perf.plio_in_ports as usize, d.merge_stats.in_ports_after);
        assert_eq!(d.estimate_exact.perf.plio_out_ports as usize, d.merge_stats.out_ports_after);
        assert!(d.estimate_exact.perf.tops > 0.0);
        let report = d.report();
        assert!(report.contains("exact"));
    }

    #[test]
    fn empty_candidate_list_is_a_typed_error() {
        // max_aies = 0 rejects every candidate (a single core already
        // exceeds the budget), so the DSE hands the back half an empty
        // ranking — previously a panic site, now a typed error.
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(0),
                ..Default::default()
            },
            ..Default::default()
        });
        let err = ws
            .compile(&library::mm(64, 64, 64, DType::F32))
            .expect_err("no candidate fits a 0-AIE budget");
        let typed = err
            .downcast_ref::<NoLegalMapping>()
            .expect("error should be typed NoLegalMapping");
        assert!(typed.recurrence.starts_with("mm_64x64x64"));
        assert!(err.to_string().contains("no legal mapping"));
        // the sharded back half returns the same typed error
        let ws_par = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(0),
                ..Default::default()
            },
            dse_threads: 4,
            ..Default::default()
        });
        let err = ws_par
            .compile(&library::mm(64, 64, 64, DType::F32))
            .expect_err("parallel path must error identically");
        assert!(err.downcast_ref::<NoLegalMapping>().is_some());
    }

    #[test]
    fn sharded_back_half_matches_serial_selection() {
        // 512³ exercises the fallback (top-ranked candidate fails P&R);
        // 2048³ exercises the first-success fast path. Both must pick the
        // identical design with and without back-half sharding.
        for rec in [
            library::mm(512, 512, 512, DType::F32),
            library::mm(2048, 2048, 2048, DType::F32),
        ] {
            let mk = |threads: usize| {
                WideSa::new(WideSaConfig {
                    constraints: DseConstraints {
                        max_aies: Some(400),
                        ..Default::default()
                    },
                    dse_threads: threads,
                    ..Default::default()
                })
            };
            let serial = mk(1).compile(&rec).unwrap();
            for threads in [2, 4, 16] {
                let sharded = mk(threads).compile(&rec).unwrap();
                assert_eq!(
                    serial.candidate.summary(),
                    sharded.candidate.summary(),
                    "{} × {threads} threads",
                    rec.name
                );
                assert_eq!(serial.compile.success, sharded.compile.success);
                assert_eq!(serial.merge_stats, sharded.merge_stats);
                assert_eq!(
                    serial.estimate.perf.tops.to_bits(),
                    sharded.estimate.perf.tops.to_bits()
                );
                assert_eq!(
                    serial.estimate_exact.perf.tops.to_bits(),
                    sharded.estimate_exact.perf.tops.to_bits()
                );
            }
        }
    }

    #[test]
    fn ranking_estimate_coincides_with_post_merge_exact() {
        // the one-port-model invariant at the framework level: under the
        // default exact port model, the estimate the DSE ranked with IS
        // the post-merge exact estimate
        let ws = WideSa::new(WideSaConfig {
            constraints: DseConstraints {
                max_aies: Some(400),
                ..Default::default()
            },
            ..Default::default()
        });
        for rec in [
            library::mm(8192, 8192, 8192, DType::F32),
            library::conv2d(1024, 1024, 4, 4, DType::I16),
            library::fir(65536, 15, DType::F32),
        ] {
            let d = ws.compile(&rec).unwrap();
            assert_eq!(
                d.estimate.perf.plio_in_ports, d.estimate_exact.perf.plio_in_ports,
                "{}",
                rec.name
            );
            assert_eq!(d.estimate.perf.plio_out_ports, d.estimate_exact.perf.plio_out_ports);
            assert_eq!(
                d.estimate.perf.tops.to_bits(),
                d.estimate_exact.perf.tops.to_bits(),
                "{}: ranked estimate must equal post-merge exact estimate",
                rec.name
            );
            // the one-power-model invariant rides along: identical perf
            // and ports → identical watts
            assert_eq!(
                d.estimate.power.watts.to_bits(),
                d.estimate_exact.power.watts.to_bits()
            );
        }
    }

    #[test]
    fn full_pipeline_all_benchmarks() {
        for (rec, cap) in [
            (library::mm(2048, 2048, 2048, DType::I8), 400u64),
            (library::conv2d(1024, 1024, 4, 4, DType::I16), 400),
            (library::fir(65536, 15, DType::F32), 256),
            (library::fft2d(512, 512, DType::CF32), 320),
        ] {
            let ws = WideSa::new(WideSaConfig {
                constraints: DseConstraints {
                    max_aies: Some(cap),
                    ..Default::default()
                },
                ..Default::default()
            });
            let d = ws.compile(&rec).unwrap();
            assert!(d.compile.success, "{} failed P&R", rec.name);
            assert!(d.sim.tops > 0.0);
        }
    }
}
