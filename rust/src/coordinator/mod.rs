//! The WideSA coordinator (L3): the automatic mapping framework of the
//! paper's Figure 5, plus the functional executor that replays mapped
//! designs through the AOT-compiled kernels.
//!
//! [`framework`] wires the full pipeline — demarcation → DSE → graph →
//! packet merge → placement → Algorithm 1 → routing → simulation →
//! codegen. [`blocking`] is the host-blocking planner above the mapper:
//! it prices GotoBLAS2-style panel loop orders through `mapping::cost`
//! and emits the deterministic [`blocking::BlockingPlan`] the replay
//! walks. [`exec`] is the host program: it walks the plan's outer
//! (DRAM-level) tile schedule with a double-buffered prefetch pipeline
//! and calls the PJRT runtime per graph tile, exactly as the generated
//! host.cpp would drive the board. [`verify`] holds the host-side
//! oracles.

pub mod blocking;
pub mod exec;
pub mod framework;
pub mod verify;

pub use framework::{WideSa, WideSaConfig, CompiledDesign};
