//! Functional executor: replay a mapped design tile-by-tile through the
//! AOT-compiled kernels — the rust incarnation of the generated host
//! program. The outer loops here are exactly the host-level schedule
//! (DRAM blocking + k-chaining + inter-pass transposes); each graph tile
//! executes on the PJRT runtime, standing in for one round of the AIE
//! array.
//!
//! The MM driver is planned: [`run_mm`] asks
//! [`crate::coordinator::blocking`] for a GotoBLAS2-style
//! [`BlockingPlan`] (panel loop order + kc/span/mc block sizes, priced
//! through `mapping::cost`), then walks it with a double-buffered
//! pipeline — one prefetch thread packs the next operand panel while the
//! array runs the current rounds. Packing is pure `memcpy`; all
//! arithmetic stays on the calling thread and every per-C-tile k-chain
//! accumulates in strictly ascending k order, so the blocked replay is
//! bit-identical to the serial [`run_mm_naive`] oracle (the law in
//! `tests/testkit/laws.rs` holds this). Ragged shapes are handled with
//! zero-padded tail tiles — mathematically a no-op for MM.

use crate::arch::vck5000::BoardConfig;
use crate::coordinator::blocking::{self, BlockingPlan, PanelOrder};
use crate::mapping::cost::CostModel;
use crate::obs::metrics;
use crate::obs::trace::Span;
use crate::runtime::client::Runtime;
use crate::runtime::executor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::sync::mpsc;
use std::time::Instant;

/// Statistics from a functional run.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Graph-tile kernel invocations (≙ array rounds).
    pub rounds: u64,
    /// Elements produced.
    pub elements: u64,
    /// Wall time of the replay.
    pub seconds: f64,
    /// Host "DRAM" bytes the driver actually moved: operand panel/block
    /// packs plus C-tile round-trips, counted with the same convention
    /// as [`CostModel::blocked_mm_dram_bytes`] (first C read of a zero
    /// accumulator is free). Compare against
    /// `plan.predicted_dram_bytes` — `make blocking-smoke` gates the
    /// two within 10%.
    pub dram_bytes: u64,
    /// Time the prefetch thread spent packing panels and blocks.
    pub pack_ms: f64,
    /// Packing time hidden behind compute by the double buffer:
    /// `max(0, pack_ms − recv-stall time)`.
    pub overlap_hidden_ms: f64,
    /// The blocking plan the driver walked (planned MM drivers only).
    pub plan: Option<BlockingPlan>,
}

/// The array as the host program sees it: run one artifact over a set of
/// graph tiles. [`Runtime`] is the real thing (stub or PJRT);
/// [`NullArray`] isolates the host path for benchmarking.
pub trait ArrayBackend {
    fn run_tiles(&mut self, artifact: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;
}

impl ArrayBackend for Runtime {
    fn run_tiles(&mut self, artifact: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.run_ref(artifact, inputs)
    }
}

/// Backend that skips the array entirely and returns the accumulator
/// unchanged. The "result" is numerically WRONG (no multiply happens) —
/// this exists only so `benches/bench_blocking.rs` can time the host
/// packing/blocking path by itself, with the kernel cost held at one
/// tile-sized copy per round for both drivers under test.
pub struct NullArray;

impl ArrayBackend for NullArray {
    fn run_tiles(&mut self, _artifact: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        Ok(vec![(*inputs.last().expect("at least one input")).clone()])
    }
}

/// Copy a `rows × cols` window at (`row0`, `col0`) out of a row-major
/// `src_rows × stride` matrix, zero-filling cells past the source extent
/// (the padded tail tiles of a ragged problem).
fn pack_window(
    src: &[f32],
    src_rows: usize,
    stride: usize,
    row0: usize,
    col0: usize,
    rows: usize,
    cols: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; rows * cols];
    let avail = stride.saturating_sub(col0).min(cols);
    if avail > 0 {
        for r in 0..rows {
            let sr = row0 + r;
            if sr >= src_rows {
                break;
            }
            out[r * cols..r * cols + avail]
                .copy_from_slice(&src[sr * stride + col0..sr * stride + col0 + avail]);
        }
    }
    out
}

fn validate_mm_inputs(a: &[f32], b: &[f32], n: usize, m: usize, k: usize) -> Result<()> {
    if a.len() != n * k {
        bail!("A must have n·k = {} elements, got {}", n * k, a.len());
    }
    if b.len() != k * m {
        bail!("B must have k·m = {} elements, got {}", k * m, b.len());
    }
    Ok(())
}

/// Plan the host blocking for an (n, m, k) MM under the default board.
/// The typed [`blocking::Unplannable`] travels inside the `anyhow` error
/// (serve downcasts it into a structured protocol response).
pub fn plan_for(n: usize, m: usize, k: usize) -> Result<BlockingPlan> {
    let span = Span::begin("blocking.plan", "exec");
    let model = CostModel::new(BoardConfig::vck5000());
    let plan =
        blocking::plan_mm(&model, n as u64, m as u64, k as u64).map_err(anyhow::Error::new);
    span.end_ms();
    plan
}

/// One prefetch unit travelling the double-buffer channel: a packed
/// operand panel or streamed block, pre-sliced into graph-tile tensors
/// so the compute thread touches no operand bytes at all.
enum Packed {
    /// Resident-operand panel tiles, indexed `[kt · ftiles + ft]`.
    Panel(Vec<Tensor>),
    /// Streamed-operand block tiles, indexed `[st · ktiles + kt]`.
    Block(Vec<Tensor>),
}

/// Shared packing context (both schedule walkers — the prefetch thread
/// and the serial oracle — pack through this, so tile bytes are
/// identical by construction).
struct Packer<'a> {
    order: PanelOrder,
    a: &'a [f32],
    b: &'a [f32],
    n: usize,
    m: usize,
    k: usize,
    t: usize,
}

impl Packer<'_> {
    /// Resident panel (`kd × fw` of B for b-resident, of A transposed
    /// roles for a-resident), sliced into `tile × tile` tensors.
    fn panel(&self, pc: usize, kd: usize, free0: usize, fw: usize) -> Vec<Tensor> {
        let (ktiles, ftiles) = (kd / self.t, fw / self.t);
        let mut tiles = Vec::with_capacity(ktiles * ftiles);
        for kt in 0..ktiles {
            for ft in 0..ftiles {
                let data = match self.order {
                    PanelOrder::BResident => pack_window(
                        self.b,
                        self.k,
                        self.m,
                        pc + kt * self.t,
                        free0 + ft * self.t,
                        self.t,
                        self.t,
                    ),
                    PanelOrder::AResident => pack_window(
                        self.a,
                        self.n,
                        self.k,
                        free0 + ft * self.t,
                        pc + kt * self.t,
                        self.t,
                        self.t,
                    ),
                };
                tiles.push(Tensor::f32(vec![self.t, self.t], data));
            }
        }
        tiles
    }

    /// Streamed block (`sw` rows of A for b-resident, columns of B for
    /// a-resident), sliced into `tile × tile` tensors.
    fn block(&self, pc: usize, kd: usize, s0: usize, sw: usize) -> Vec<Tensor> {
        let (ktiles, stiles) = (kd / self.t, sw / self.t);
        let mut tiles = Vec::with_capacity(stiles * ktiles);
        for st in 0..stiles {
            for kt in 0..ktiles {
                let data = match self.order {
                    PanelOrder::BResident => pack_window(
                        self.a,
                        self.n,
                        self.k,
                        s0 + st * self.t,
                        pc + kt * self.t,
                        self.t,
                        self.t,
                    ),
                    PanelOrder::AResident => pack_window(
                        self.b,
                        self.k,
                        self.m,
                        pc + kt * self.t,
                        s0 + st * self.t,
                        self.t,
                        self.t,
                    ),
                };
                tiles.push(Tensor::f32(vec![self.t, self.t], data));
            }
        }
        tiles
    }
}

/// One resident k-segment within a free-dimension panel group.
struct PanelStep {
    pc: usize,
    kd: usize,
    /// Streamed blocks `(s0, sw)` in schedule order.
    blocks: Vec<(usize, usize)>,
}

/// All k-segments sharing one resident free-dimension range
/// (`[free0, free0 + fw)` of M for b-resident, of N for a-resident).
/// The partial C panel for the range lives across the whole group.
struct FreeGroup {
    free0: usize,
    fw: usize,
    panels: Vec<PanelStep>,
}

/// The plan's deterministic schedule walk, precomputed once so the
/// prefetch thread and the compute loop traverse the exact same order.
fn mm_schedule(plan: &BlockingPlan) -> Vec<FreeGroup> {
    let (kc, span, mc) = (plan.kc as usize, plan.span as usize, plan.mc as usize);
    let (n_pad, m_pad, k_pad) = (
        plan.n_pad as usize,
        plan.m_pad as usize,
        plan.k_pad as usize,
    );
    let (free_total, streamed_total) = match plan.order {
        PanelOrder::BResident => (m_pad, n_pad),
        PanelOrder::AResident => (n_pad, m_pad),
    };
    let mut groups = Vec::new();
    for free0 in (0..free_total).step_by(span) {
        let fw = span.min(free_total - free0);
        let mut panels = Vec::new();
        for pc in (0..k_pad).step_by(kc) {
            let kd = kc.min(k_pad - pc);
            let blocks = (0..streamed_total)
                .step_by(mc)
                .map(|s0| (s0, mc.min(streamed_total - s0)))
                .collect();
            panels.push(PanelStep { pc, kd, blocks });
        }
        groups.push(FreeGroup { free0, fw, panels });
    }
    groups
}

/// C = A·B via the accumulate-form MM artifact: plan the host blocking,
/// then replay the plan with the double-buffered driver. Accepts
/// arbitrary (n, m, k) ≥ 1 up to the planner's staging cap — ragged and
/// sub-tile shapes are zero-padded.
pub fn run_mm<B: ArrayBackend>(
    rt: &mut B,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    k: usize,
) -> Result<(Vec<f32>, ExecStats)> {
    validate_mm_inputs(a, b, n, m, k)?;
    let plan = plan_for(n, m, k)?;
    run_mm_planned(rt, a, b, n, m, k, &plan)
}

/// Serial naive replay of the same plan geometry — the oracle the
/// blocked driver must match bit-for-bit, and the baseline
/// `make blocking-smoke` measures against. One B tile is packed per
/// (j, k) step and reused across the whole i loop (the old driver
/// re-packed it n/tile times); each C tile's k-chain ascends strictly,
/// exactly like the blocked driver's.
pub fn run_mm_naive<B: ArrayBackend>(
    rt: &mut B,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    k: usize,
) -> Result<(Vec<f32>, ExecStats)> {
    validate_mm_inputs(a, b, n, m, k)?;
    let plan = plan_for(n, m, k)?;
    let t = plan.tile as usize;
    let (n_pad, m_pad, k_pad) = (
        plan.n_pad as usize,
        plan.m_pad as usize,
        plan.k_pad as usize,
    );
    let artifact = plan.artifact();
    let t0 = Instant::now();
    let mut stats = ExecStats::default();
    let mut c_pad = vec![0f32; n_pad * m_pad];
    for j in (0..m_pad).step_by(t) {
        for kk in (0..k_pad).step_by(t) {
            // hoisted: one B tile per (j, kk), shared across the i loop
            let bt = Tensor::f32(vec![t, t], pack_window(b, k, m, kk, j, t, t));
            stats.dram_bytes += (t * t * 4) as u64;
            for i in (0..n_pad).step_by(t) {
                let at = Tensor::f32(vec![t, t], pack_window(a, n, k, i, kk, t, t));
                let acc = Tensor::f32(vec![t, t], pack_window(&c_pad, n_pad, m_pad, i, j, t, t));
                let out = rt.run_tiles(&artifact, &[&at, &bt, &acc])?;
                let out = out.into_iter().next().expect("mm artifact returns C'");
                let data = out.data.as_f32().expect("mm artifact returns f32");
                for r in 0..t {
                    c_pad[(i + r) * m_pad + j..(i + r) * m_pad + j + t]
                        .copy_from_slice(&data[r * t..(r + 1) * t]);
                }
                stats.rounds += 1;
                stats.dram_bytes += (3 * t * t * 4) as u64; // A pack + C r/w
            }
        }
    }
    let mut c = vec![0f32; n * m];
    for r in 0..n {
        c[r * m..(r + 1) * m].copy_from_slice(&c_pad[r * m_pad..r * m_pad + m]);
    }
    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    stats.plan = Some(plan);
    Ok((c, stats))
}

/// Replay a specific [`BlockingPlan`] with the double-buffered driver:
/// a prefetch thread packs panels/blocks (pure `memcpy`, no arithmetic)
/// one schedule step ahead through a bounded channel while the calling
/// thread runs the array rounds. Bit-identical to [`run_mm_naive`].
pub fn run_mm_planned<B: ArrayBackend>(
    rt: &mut B,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    k: usize,
    plan: &BlockingPlan,
) -> Result<(Vec<f32>, ExecStats)> {
    validate_mm_inputs(a, b, n, m, k)?;
    let t = plan.tile as usize;
    let (n_pad, m_pad) = (plan.n_pad as usize, plan.m_pad as usize);
    let artifact = plan.artifact();
    let sched = mm_schedule(plan);
    let t0 = Instant::now();

    let (c, mut stats) = std::thread::scope(|s| -> Result<(Vec<f32>, ExecStats)> {
        // Depth 2: the packer stays exactly one panel/block ahead — the
        // "next" buffer of a classic double buffer — and panel memory
        // stays bounded by the plan's PL-budget-sized units.
        let (tx, rx) = mpsc::sync_channel::<Packed>(2);
        let packer_ctx = Packer {
            order: plan.order,
            a,
            b,
            n,
            m,
            k,
            t,
        };
        let sched_ref = &sched;
        let packer = s.spawn(move || -> f64 {
            let mut pack_ms = 0.0;
            'sched: for group in sched_ref {
                for panel in &group.panels {
                    let sp = Span::begin("exec.pack", "exec");
                    let tiles = packer_ctx.panel(panel.pc, panel.kd, group.free0, group.fw);
                    pack_ms += sp.end_ms();
                    if tx.send(Packed::Panel(tiles)).is_err() {
                        break 'sched; // compute side bailed: stop packing
                    }
                    for &(s0, sw) in &panel.blocks {
                        let sp = Span::begin("exec.pack", "exec");
                        let tiles = packer_ctx.block(panel.pc, panel.kd, s0, sw);
                        pack_ms += sp.end_ms();
                        if tx.send(Packed::Block(tiles)).is_err() {
                            break 'sched;
                        }
                    }
                }
            }
            pack_ms
        });

        let mut compute = |rx: mpsc::Receiver<Packed>| -> Result<(Vec<f32>, ExecStats, f64)> {
            let mut stats = ExecStats::default();
            let mut stall_s = 0f64;
            let mut c = vec![0f32; n * m];
            for group in sched_ref {
                // Partial C panel for this free-range, zero-initialised,
                // accumulated across the group's k segments.
                let (pr, pcw) = match plan.order {
                    PanelOrder::BResident => (n_pad, group.fw),
                    PanelOrder::AResident => (group.fw, m_pad),
                };
                let mut c_panel = vec![0f32; pr * pcw];
                for panel in &group.panels {
                    let (ktiles, ftiles) = (panel.kd / t, group.fw / t);
                    let rcv = Instant::now();
                    let Ok(Packed::Panel(ptiles)) = rx.recv() else {
                        bail!("prefetch pipeline ended before panel k={}", panel.pc);
                    };
                    stall_s += rcv.elapsed().as_secs_f64();
                    stats.dram_bytes += (panel.kd * group.fw * 4) as u64;
                    for &(s0, sw) in &panel.blocks {
                        let rcv = Instant::now();
                        let Ok(Packed::Block(btiles)) = rx.recv() else {
                            bail!("prefetch pipeline ended before block s={s0}");
                        };
                        stall_s += rcv.elapsed().as_secs_f64();
                        stats.dram_bytes += (sw * panel.kd * 4) as u64;
                        for st in 0..sw / t {
                            for ft in 0..ftiles {
                                // C tile origin within the panel frame
                                let (r0, c0) = match plan.order {
                                    PanelOrder::BResident => (s0 + st * t, ft * t),
                                    PanelOrder::AResident => (ft * t, s0 + st * t),
                                };
                                // First segment starts from a zero
                                // accumulator (no C read — matching the
                                // cost model's 2·segs−1 convention);
                                // later segments reload the partial.
                                let mut acc = if panel.pc == 0 {
                                    Tensor::f32(vec![t, t], vec![0f32; t * t])
                                } else {
                                    stats.dram_bytes += (t * t * 4) as u64;
                                    Tensor::f32(
                                        vec![t, t],
                                        pack_window(&c_panel, pr, pcw, r0, c0, t, t),
                                    )
                                };
                                for kt in 0..ktiles {
                                    let (at, bt) = match plan.order {
                                        PanelOrder::BResident => {
                                            (&btiles[st * ktiles + kt], &ptiles[kt * ftiles + ft])
                                        }
                                        PanelOrder::AResident => {
                                            (&ptiles[kt * ftiles + ft], &btiles[st * ktiles + kt])
                                        }
                                    };
                                    let round = Span::begin("exec.round", "exec");
                                    let out = rt.run_tiles(&artifact, &[at, bt, &acc])?;
                                    round.end_ms();
                                    acc = out.into_iter().next().expect("mm artifact returns C'");
                                    stats.rounds += 1;
                                }
                                let data = acc.data.as_f32().expect("mm artifact returns f32");
                                for r in 0..t {
                                    c_panel[(r0 + r) * pcw + c0..(r0 + r) * pcw + c0 + t]
                                        .copy_from_slice(&data[r * t..(r + 1) * t]);
                                }
                                stats.dram_bytes += (t * t * 4) as u64;
                            }
                        }
                    }
                }
                // flush the finished panel into the unpadded output
                match plan.order {
                    PanelOrder::BResident => {
                        let cols = group.fw.min(m.saturating_sub(group.free0));
                        for r in 0..n {
                            c[r * m + group.free0..r * m + group.free0 + cols]
                                .copy_from_slice(&c_panel[r * pcw..r * pcw + cols]);
                        }
                    }
                    PanelOrder::AResident => {
                        let rows = group.fw.min(n.saturating_sub(group.free0));
                        for r in 0..rows {
                            c[(group.free0 + r) * m..(group.free0 + r) * m + m]
                                .copy_from_slice(&c_panel[r * pcw..r * pcw + m]);
                        }
                    }
                }
            }
            Ok((c, stats, stall_s))
        };
        // compute consumes rx; when it returns (ok or err) the channel
        // closes, the packer's next send fails, and join can't block.
        let compute_res = compute(rx);
        let pack_ms = packer
            .join()
            .map_err(|_| anyhow!("prefetch thread panicked"))?;
        let (c, mut stats, stall_s) = compute_res?;
        stats.pack_ms = pack_ms;
        stats.overlap_hidden_ms = (pack_ms - stall_s * 1e3).max(0.0);
        Ok((c, stats))
    })?;

    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    stats.plan = Some(plan.clone());
    debug_assert_eq!(stats.rounds, plan.rounds);
    let reg = metrics::global();
    reg.counter("exec.rounds").add(stats.rounds);
    reg.counter("exec.dram_bytes").add(stats.dram_bytes);
    reg.histogram("exec.overlap_hidden_ms")
        .record(stats.overlap_hidden_ms.max(0.0) as u64);
    Ok((c, stats))
}

/// C = A·B via the communication-avoiding schedule: the reduction
/// dimension splits into `rep` k-slabs, each slab's partial product runs
/// through the planned MM driver (one slab ≙ one row-replica of the
/// array), and the partials merge in ascending slab order through the
/// `ca_mm_f32_4x128` reduction artifact — the same schedule as
/// [`crate::coordinator::verify::ca_mm_ref`], so the two agree to
/// accumulation tolerance. Like the fft2d/stencil drivers, this replay
/// is specialised to the artifact's shape: 4 replicas, 128-edge C tiles.
pub fn run_ca_mm(
    rt: &mut Runtime,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    k: usize,
    rep: usize,
) -> Result<(Vec<f32>, ExecStats)> {
    const REP: usize = 4;
    const TILE: usize = 128;
    if rep != REP {
        bail!("CA replay is specialised to the artifact's {REP} replicas");
    }
    if k % rep != 0 {
        bail!("reduction extent k={k} must divide across {rep} replicas");
    }
    if n % TILE != 0 || m % TILE != 0 {
        bail!("CA output must divide by the {TILE}-edge reduction tile");
    }
    validate_mm_inputs(a, b, n, m, k)?;
    let slab = k / rep;
    let t0 = Instant::now();
    let mut stats = ExecStats::default();
    // each replica's partial product: a full planned-MM replay over its
    // k-slab (A columns / B rows [s·slab, (s+1)·slab))
    let mut partials = Vec::with_capacity(rep);
    for s in 0..rep {
        let mut a_slab = vec![0f32; n * slab];
        for i in 0..n {
            a_slab[i * slab..(i + 1) * slab]
                .copy_from_slice(&a[i * k + s * slab..i * k + (s + 1) * slab]);
        }
        let b_slab = &b[s * slab * m..(s + 1) * slab * m];
        let (p, st) = run_mm(rt, &a_slab, b_slab, n, m, slab)?;
        stats.rounds += st.rounds;
        stats.dram_bytes += st.dram_bytes;
        partials.push(p);
    }
    // replication-axis merge, one 128×128 C tile per artifact round
    let mut c_out = vec![0f32; n * m];
    for i in (0..n).step_by(TILE) {
        for j in (0..m).step_by(TILE) {
            let mut stack = vec![0f32; rep * TILE * TILE];
            for (s, p) in partials.iter().enumerate() {
                for r in 0..TILE {
                    let dst = s * TILE * TILE + r * TILE;
                    let src = (i + r) * m + j;
                    stack[dst..dst + TILE].copy_from_slice(&p[src..src + TILE]);
                }
            }
            let out = rt.run(
                "ca_mm_f32_4x128",
                &[Tensor::f32(vec![rep, TILE, TILE], stack)],
            )?;
            let tile_out = out.into_iter().next().expect("reduce artifact returns C");
            let data = tile_out.data.as_f32().expect("reduce artifact returns f32");
            for r in 0..TILE {
                c_out[(i + r) * m + j..(i + r) * m + j + TILE]
                    .copy_from_slice(&data[r * TILE..(r + 1) * TILE]);
            }
            stats.rounds += 1;
            stats.dram_bytes += ((REP + 1) * TILE * TILE * 4) as u64;
        }
    }
    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((c_out, stats))
}

/// `stages` Gauss–Seidel sweeps over a 64×64 grid by chaining the
/// 2-sweep `seidel2d_f32_2x64` artifact (stages must be even); coef =
/// [centre, south_new, south_old, west, east]. Like the stencil driver,
/// specialised to the artifact's grid.
pub fn run_seidel2d(
    rt: &mut Runtime,
    a: &[f32],
    n: usize,
    m: usize,
    stages: usize,
    coef: &[f32],
) -> Result<(Vec<f32>, ExecStats)> {
    const N: usize = 64;
    if n != N || m != N {
        bail!("seidel2d replay is specialised to {N}×{N} grids");
    }
    if stages == 0 || stages % 2 != 0 {
        bail!("stages must be a positive multiple of the artifact's 2 sweeps");
    }
    if coef.len() != 5 {
        bail!("seidel takes 5 coefficients [centre, s_new, s_old, w, e]");
    }
    let t0 = Instant::now();
    let mut stats = ExecStats::default();
    let mut cur = a.to_vec();
    for _ in 0..stages / 2 {
        let out = rt.run(
            "seidel2d_f32_2x64",
            &[
                Tensor::f32(vec![N, N], cur),
                Tensor::f32(vec![5], coef.to_vec()),
            ],
        )?;
        cur = out.into_iter().next().unwrap().data.as_f32().unwrap().to_vec();
        stats.rounds += 1;
    }
    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((cur, stats))
}

/// Y = conv2d_valid(X, K) with a 4×4 kernel; output sizes must divide by
/// the 128-edge conv artifact.
pub fn run_conv2d(rt: &mut Runtime, x: &[f32], k: &[f32], h: usize, w: usize) -> Result<(Vec<f32>, ExecStats)> {
    const P: usize = 4;
    const TILE: usize = 128;
    if k.len() != P * P {
        bail!("conv artifact is specialised for 4×4 kernels");
    }
    if h % TILE != 0 || w % TILE != 0 {
        bail!("conv output must divide by {TILE}");
    }
    let xw = w + P - 1;
    let t0 = Instant::now();
    let mut y = vec![0f32; h * w];
    let mut stats = ExecStats::default();
    // kernel and zero accumulator are loop-invariant: pack once
    let kt = Tensor::f32(vec![P, P], k.to_vec());
    let zero_acc = Tensor::f32(vec![TILE, TILE], vec![0.0; TILE * TILE]);
    for i in (0..h).step_by(TILE) {
        for j in (0..w).step_by(TILE) {
            // halo-extended input block
            let bh = TILE + P - 1;
            let bw = TILE + P - 1;
            let mut xt = vec![0f32; bh * bw];
            for r in 0..bh {
                xt[r * bw..(r + 1) * bw]
                    .copy_from_slice(&x[(i + r) * xw + j..(i + r) * xw + j + bw]);
            }
            let xt = Tensor::f32(vec![bh, bw], xt);
            let out = rt.run_ref("conv2d_f32_128x4", &[&xt, &kt, &zero_acc])?;
            let tile_out = out.into_iter().next().unwrap();
            let data = tile_out.data.as_f32().unwrap();
            for r in 0..TILE {
                y[(i + r) * w + j..(i + r) * w + j + TILE]
                    .copy_from_slice(&data[r * TILE..(r + 1) * TILE]);
            }
            stats.rounds += 1;
        }
    }
    stats.elements = (h * w) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((y, stats))
}

/// y = FIR(x, h) with 15 taps; n must divide by the 4096-sample artifact.
pub fn run_fir(rt: &mut Runtime, x: &[f32], h: &[f32], n: usize) -> Result<(Vec<f32>, ExecStats)> {
    const TAPS: usize = 15;
    const CHUNK: usize = 4096;
    if h.len() != TAPS {
        bail!("FIR artifact is specialised for 15 taps");
    }
    if n % CHUNK != 0 {
        bail!("FIR length must divide by {CHUNK}");
    }
    if x.len() != n + TAPS - 1 {
        bail!("x must have n + taps - 1 samples");
    }
    let t0 = Instant::now();
    let mut y = vec![0f32; n];
    let mut stats = ExecStats::default();
    for off in (0..n).step_by(CHUNK) {
        let xt = x[off..off + CHUNK + TAPS - 1].to_vec();
        let out = rt.run(
            "fir_f32_4096x15",
            &[
                Tensor::f32(vec![CHUNK + TAPS - 1], xt),
                Tensor::f32(vec![TAPS], h.to_vec()),
            ],
        )?;
        y[off..off + CHUNK].copy_from_slice(out[0].data.as_f32().unwrap());
        stats.rounds += 1;
    }
    stats.elements = n as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((y, stats))
}

/// 2D FFT over a rows×256 grid: batched row FFTs through the fft1d
/// artifact, transpose on the host (the PL data-mover's job), second
/// pass, transpose back. rows must divide by 64 and cols must be 256.
pub fn run_fft2d(
    rt: &mut Runtime,
    re: &[f32],
    im: &[f32],
    rows: usize,
    cols: usize,
) -> Result<(Vec<f32>, Vec<f32>, ExecStats)> {
    const BATCH: usize = 64;
    const N: usize = 256;
    if cols != N || rows % BATCH != 0 || rows < N && N % rows != 0 {
        // second pass runs over columns of length `rows`; the artifact is
        // fixed at 256, so rows must equal 256 too for the full 2D pass.
    }
    if cols != N || rows != N {
        bail!("fft2d replay is specialised to 256×256 grids");
    }
    let t0 = Instant::now();
    let mut stats = ExecStats::default();

    // Bit-reversal permutation (host-side data movement — on the board
    // the PL mover reorders samples while staging rows into the array;
    // the artifact computes the butterfly stages on reversed-order rows).
    let bits = N.trailing_zeros();
    let rev: Vec<usize> = (0..N)
        .map(|i| ((i as u32).reverse_bits() >> (32 - bits)) as usize)
        .collect();

    let pass = |rt: &mut Runtime, re: &[f32], im: &[f32], stats: &mut ExecStats| -> Result<(Vec<f32>, Vec<f32>)> {
        let mut ore = vec![0f32; rows * cols];
        let mut oim = vec![0f32; rows * cols];
        for b in (0..rows).step_by(BATCH) {
            let mut rt_in = vec![0f32; BATCH * cols];
            let mut it_in = vec![0f32; BATCH * cols];
            for r in 0..BATCH {
                for (i, &s) in rev.iter().enumerate() {
                    rt_in[r * cols + i] = re[(b + r) * cols + s];
                    it_in[r * cols + i] = im[(b + r) * cols + s];
                }
            }
            let out = rt.run(
                "fft1d_f32_64x256",
                &[
                    Tensor::f32(vec![BATCH, N], rt_in),
                    Tensor::f32(vec![BATCH, N], it_in),
                ],
            )?;
            ore[b * cols..(b + BATCH) * cols].copy_from_slice(out[0].data.as_f32().unwrap());
            oim[b * cols..(b + BATCH) * cols].copy_from_slice(out[1].data.as_f32().unwrap());
            stats.rounds += 1;
        }
        Ok((ore, oim))
    };
    let transpose = |v: &[f32]| {
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = v[r * cols + c];
            }
        }
        out
    };

    let (re1, im1) = pass(rt, re, im, &mut stats)?;
    let (rt2, it2) = (transpose(&re1), transpose(&im1));
    let (re2, im2) = pass(rt, &rt2, &it2, &mut stats)?;
    let (ore, oim) = (transpose(&re2), transpose(&im2));
    stats.elements = (rows * cols) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((ore, oim, stats))
}

/// Depthwise conv: tile over 8-channel groups and 64×64 spatial tiles of
/// the `dwconv2d_f32_8x64x3` artifact. `x` is `[c, h+2, w+2]` row-major
/// (2-pixel halo for the 3×3 kernels), `k` is `[c, 3, 3]`.
pub fn run_dwconv2d(
    rt: &mut Runtime,
    x: &[f32],
    k: &[f32],
    c: usize,
    h: usize,
    w: usize,
) -> Result<(Vec<f32>, ExecStats)> {
    const G: usize = 8;
    const TILE: usize = 64;
    const P: usize = 3;
    if c % G != 0 || h % TILE != 0 || w % TILE != 0 {
        bail!("dwconv sizes must divide by {G} channels / {TILE} pixels");
    }
    if k.len() != c * P * P || x.len() != c * (h + P - 1) * (w + P - 1) {
        bail!("dwconv input shapes inconsistent with c={c} h={h} w={w}");
    }
    let (xh, xw) = (h + P - 1, w + P - 1);
    let (bh, bw) = (TILE + P - 1, TILE + P - 1);
    let t0 = Instant::now();
    let mut y = vec![0f32; c * h * w];
    let mut stats = ExecStats::default();
    // zero accumulator is loop-invariant: pack once
    let zero_acc = Tensor::f32(vec![G, TILE, TILE], vec![0.0; G * TILE * TILE]);
    for g0 in (0..c).step_by(G) {
        // hoisted: the kernel group only changes with g0, not per tile —
        // the old driver re-packed it (h/64)·(w/64) times per group
        let kt = Tensor::f32(vec![G, P, P], k[g0 * P * P..(g0 + G) * P * P].to_vec());
        for i in (0..h).step_by(TILE) {
            for j in (0..w).step_by(TILE) {
                let mut xt = vec![0f32; G * bh * bw];
                for g in 0..G {
                    for r in 0..bh {
                        let src = (g0 + g) * xh * xw + (i + r) * xw + j;
                        xt[g * bh * bw + r * bw..g * bh * bw + (r + 1) * bw]
                            .copy_from_slice(&x[src..src + bw]);
                    }
                }
                let xt = Tensor::f32(vec![G, bh, bw], xt);
                let out = rt.run_ref("dwconv2d_f32_8x64x3", &[&xt, &kt, &zero_acc])?;
                let data = out.into_iter().next().unwrap();
                let data = data.data.as_f32().unwrap();
                for g in 0..G {
                    for r in 0..TILE {
                        let dst = (g0 + g) * h * w + (i + r) * w + j;
                        y[dst..dst + TILE].copy_from_slice(
                            &data[g * TILE * TILE + r * TILE..g * TILE * TILE + (r + 1) * TILE],
                        );
                    }
                }
                stats.rounds += 1;
            }
        }
    }
    stats.elements = (c * h * w) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((y, stats))
}

/// Blocked forward substitution `x = L⁻¹ b` over the 256-row
/// `trsv_f32_256` artifact: the host applies the off-diagonal updates
/// (the PL mover's k-chain role), the artifact solves each diagonal
/// block. `l` is row-major n×n; n must divide by 256.
pub fn run_trsv(rt: &mut Runtime, l: &[f32], b: &[f32], n: usize) -> Result<(Vec<f32>, ExecStats)> {
    const BLK: usize = 256;
    if n % BLK != 0 {
        bail!("trsv size must divide by {BLK}");
    }
    if l.len() != n * n || b.len() != n {
        bail!("trsv input shapes inconsistent with n={n}");
    }
    let t0 = Instant::now();
    let mut x = vec![0f32; n];
    let mut stats = ExecStats::default();
    for bi in (0..n).step_by(BLK) {
        // rhs_I = b_I − Σ_{j < bi} L[I, j] · x[j]  (host-level chaining)
        let mut rhs = b[bi..bi + BLK].to_vec();
        for (i, r) in rhs.iter_mut().enumerate() {
            let row = (bi + i) * n;
            for (j, xj) in x[..bi].iter().enumerate() {
                *r -= l[row + j] * xj;
            }
        }
        // diagonal-block solve on the array
        let mut lt = vec![0f32; BLK * BLK];
        for r in 0..BLK {
            lt[r * BLK..(r + 1) * BLK]
                .copy_from_slice(&l[(bi + r) * n + bi..(bi + r) * n + bi + BLK]);
        }
        let out = rt.run(
            "trsv_f32_256",
            &[Tensor::f32(vec![BLK, BLK], lt), Tensor::f32(vec![BLK], rhs)],
        )?;
        x[bi..bi + BLK].copy_from_slice(out[0].data.as_f32().unwrap());
        stats.rounds += 1;
    }
    stats.elements = n as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((x, stats))
}

/// Stencil chain: `stages` Jacobi sweeps over a 128×128 grid by chaining
/// the 2-sweep `stencil2d_f32_2x128` artifact (stages must be even).
/// Larger grids need halo-exchange tiling between sweeps — like the
/// fft2d replay, this driver is specialised to the artifact's grid.
pub fn run_stencil2d(
    rt: &mut Runtime,
    a: &[f32],
    n: usize,
    m: usize,
    stages: usize,
    coef: &[f32],
) -> Result<(Vec<f32>, ExecStats)> {
    const N: usize = 128;
    if n != N || m != N {
        bail!("stencil2d replay is specialised to {N}×{N} grids");
    }
    if stages == 0 || stages % 2 != 0 {
        bail!("stages must be a positive multiple of the artifact's 2 sweeps");
    }
    if coef.len() != 5 {
        bail!("stencil takes 5 coefficients [centre, n, s, w, e]");
    }
    let t0 = Instant::now();
    let mut stats = ExecStats::default();
    let mut cur = a.to_vec();
    for _ in 0..stages / 2 {
        let out = rt.run(
            "stencil2d_f32_2x128",
            &[
                Tensor::f32(vec![N, N], cur),
                Tensor::f32(vec![5], coef.to_vec()),
            ],
        )?;
        cur = out.into_iter().next().unwrap().data.as_f32().unwrap().to_vec();
        stats.rounds += 1;
    }
    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((cur, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify;
    use crate::runtime::artifact::Manifest;
    use crate::util::rng::XorShift64;

    fn runtime() -> Option<Runtime> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(Runtime::new().unwrap())
    }

    #[test]
    fn mm_replay_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let (n, m, k) = (256, 128, 128);
        let mut rng = XorShift64::new(1);
        let mut a = vec![0f32; n * k];
        let mut b = vec![0f32; k * m];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        let (c, stats) = run_mm(&mut rt, &a, &b, n, m, k).unwrap();
        let want = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
        assert!(verify::max_abs_diff(&c, &want) < 1e-2);
        assert_eq!(stats.rounds, 2); // (256/128)·(128/128)·(128/128)
        let plan = stats.plan.expect("planned driver records its plan");
        assert_eq!(plan.tile, 128);
        // measured host traffic equals the plan's prediction exactly on
        // this driver (same accounting convention on both sides)
        assert_eq!(stats.dram_bytes, plan.predicted_dram_bytes);
    }

    #[test]
    fn fir_replay_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let n = 8192;
        let mut rng = XorShift64::new(2);
        let mut x = vec![0f32; n + 14];
        let mut h = vec![0f32; 15];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut h);
        let (y, stats) = run_fir(&mut rt, &x, &h, n).unwrap();
        let want = verify::fir_ref(&x, &h, n);
        assert!(verify::max_abs_diff(&y, &want) < 1e-3);
        assert_eq!(stats.rounds, 2);
    }

    #[test]
    fn size_validation_errors() {
        let Some(mut rt) = runtime() else { return };
        // operand lengths must match the declared extents
        assert!(run_mm(&mut rt, &[0.0; 99], &[0.0; 100], 10, 10, 10).is_err());
        assert!(run_mm(&mut rt, &[0.0; 100], &[0.0; 99], 10, 10, 10).is_err());
        // zero extents are Unplannable, surfaced as a typed error
        let err = run_mm(&mut rt, &[], &[], 0, 16, 0).unwrap_err();
        assert!(err.downcast_ref::<blocking::Unplannable>().is_some());
        assert!(run_fir(&mut rt, &[0.0; 114], &[0.0; 15], 100).is_err());
    }

    /// The replay loops must work on the default stub backend with no
    /// artifacts on disk (planning, blocking, double buffering, ragged
    /// padding, k-chaining).
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn mm_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (n, m, k) = (256, 128, 128);
        let mut rng = XorShift64::new(51);
        let mut a = vec![0f32; n * k];
        let mut b = vec![0f32; k * m];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        let (c, stats) = run_mm(&mut rt, &a, &b, n, m, k).unwrap();
        assert_eq!(stats.rounds, 2);
        let want = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
        assert!(verify::max_abs_diff(&c, &want) < 1e-2);
        // operand-length validation fires on the stub path too
        assert!(run_mm(&mut rt, &[0.0; 99], &[0.0; 100], 10, 10, 10).is_err());
    }

    /// Ragged, prime, and smaller-than-one-tile shapes replay through
    /// padded tail tiles; the blocked driver is bit-identical to the
    /// serial oracle (the full law lives in tests/testkit/laws.rs).
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn mm_ragged_shapes_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        for (n, m, k) in [(10usize, 10usize, 10usize), (127, 131, 7), (300, 260, 200)] {
            let mut rng = XorShift64::new((n * 1000 + m) as u64);
            let mut a = vec![0f32; n * k];
            let mut b = vec![0f32; k * m];
            rng.fill_f32(&mut a);
            rng.fill_f32(&mut b);
            let (blocked, stats) = run_mm(&mut rt, &a, &b, n, m, k).unwrap();
            let (serial, _) = run_mm_naive(&mut rt, &a, &b, n, m, k).unwrap();
            assert_eq!(blocked.len(), n * m);
            let identical = blocked
                .iter()
                .zip(&serial)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(identical, "blocked != serial for {n}x{m}x{k}");
            let want = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
            assert!(verify::max_abs_diff(&blocked, &want) < 1e-2, "{n}x{m}x{k}");
            let plan = stats.plan.unwrap();
            assert_eq!(stats.rounds, plan.rounds);
            assert_eq!(stats.dram_bytes, plan.predicted_dram_bytes);
        }
    }

    /// The NullArray backend isolates the host path: results are
    /// (deliberately) zeros, but the pipeline, stats, and plan flow.
    #[test]
    fn null_array_exercises_host_path() {
        let (n, m, k) = (300usize, 260usize, 200usize);
        let a = vec![1.0f32; n * k];
        let b = vec![1.0f32; k * m];
        let (c, stats) = run_mm(&mut NullArray, &a, &b, n, m, k).unwrap();
        assert!(c.iter().all(|&v| v == 0.0));
        let plan = stats.plan.unwrap();
        assert_eq!(stats.rounds, plan.rounds);
        assert_eq!(stats.dram_bytes, plan.predicted_dram_bytes);
        assert!(stats.pack_ms >= 0.0 && stats.overlap_hidden_ms >= 0.0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn dwconv_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (c, h, w) = (16usize, 128usize, 64usize);
        let mut rng = XorShift64::new(61);
        let mut x = vec![0f32; c * (h + 2) * (w + 2)];
        let mut k = vec![0f32; c * 9];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut k);
        let (y, stats) = run_dwconv2d(&mut rt, &x, &k, c, h, w).unwrap();
        // (16/8) groups × (128/64) × (64/64) spatial tiles
        assert_eq!(stats.rounds, 4);
        let want = verify::dw_conv2d_ref(&x, &k, c, h, w, 3, 3);
        assert!(verify::max_abs_diff(&y, &want) < 1e-4);
        // size validation
        assert!(run_dwconv2d(&mut rt, &x, &k, 10, h, w).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn trsv_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let n = 512usize;
        let mut rng = XorShift64::new(67);
        let mut l = vec![0f32; n * n];
        let mut b = vec![0f32; n];
        rng.fill_f32(&mut l);
        rng.fill_f32(&mut b);
        for i in 0..n {
            for j in 0..n {
                l[i * n + j] /= n as f32;
            }
            l[i * n + i] = 4.0 + l[i * n + i].abs();
        }
        let (x, stats) = run_trsv(&mut rt, &l, &b, n).unwrap();
        assert_eq!(stats.rounds, 2);
        let want = verify::trsv_ref(&l, &b, n);
        assert!(verify::max_abs_diff(&x, &want) < 1e-4);
        assert!(run_trsv(&mut rt, &l, &b, 100).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stencil_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let n = 128usize;
        let mut rng = XorShift64::new(71);
        let mut a = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        let coef = [0.5f32, 0.125, 0.125, 0.125, 0.125];
        let (out, stats) = run_stencil2d(&mut rt, &a, n, n, 4, &coef).unwrap();
        assert_eq!(stats.rounds, 2); // two chained 2-sweep tiles
        let want = verify::stencil2d_chain_ref(&a, n, n, 4, &coef);
        assert!(verify::max_abs_diff(&out, &want) < 1e-4);
        // odd sweep counts and foreign grids are rejected
        assert!(run_stencil2d(&mut rt, &a, n, n, 3, &coef).is_err());
        assert!(run_stencil2d(&mut rt, &a, 64, 64, 2, &coef).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn ca_mm_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (n, m, k, rep) = (256usize, 128usize, 512usize, 4usize);
        let mut rng = XorShift64::new(73);
        let mut a = vec![0f32; n * k];
        let mut b = vec![0f32; k * m];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        let (c, stats) = run_ca_mm(&mut rt, &a, &b, n, m, k, rep).unwrap();
        // (n/128)·(m/128) reduction rounds on top of the per-slab MM rounds
        let reduce_rounds = (n / 128 * m / 128) as u64;
        assert!(stats.rounds > reduce_rounds);
        let want = verify::ca_mm_ref(&a, &b, &vec![0.0; n * m], n, m, k, rep);
        assert!(verify::max_abs_diff(&c, &want) < 1e-2);
        // and the CA schedule agrees with the standard form within
        // accumulation tolerance (the reassociated k sum)
        let std = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
        assert!(verify::max_abs_diff(&c, &std) < 1e-1);
        // replication factor and tiling are validated
        assert!(run_ca_mm(&mut rt, &a, &b, n, m, k, 2).is_err());
        assert!(run_ca_mm(&mut rt, &a[..64 * k], &b, 64, m, k, rep).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn seidel_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let n = 64usize;
        let mut rng = XorShift64::new(79);
        let mut a = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        let coef = [0.4f32, 0.2, 0.1, 0.15, 0.15];
        let (out, stats) = run_seidel2d(&mut rt, &a, n, n, 4, &coef).unwrap();
        assert_eq!(stats.rounds, 2); // two chained 2-sweep tiles
        let want = verify::seidel2d_ref(&a, n, n, 4, &coef);
        assert!(verify::max_abs_diff(&out, &want) < 1e-4);
        // odd sweep counts and foreign grids are rejected
        assert!(run_seidel2d(&mut rt, &a, n, n, 3, &coef).is_err());
        assert!(run_seidel2d(&mut rt, &a[..32 * 32], 32, 32, 2, &coef).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn fft2d_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (rows, cols) = (256usize, 256usize);
        let mut rng = XorShift64::new(53);
        let mut re = vec![0f32; rows * cols];
        let mut im = vec![0f32; rows * cols];
        rng.fill_f32(&mut re);
        rng.fill_f32(&mut im);
        let (gre, gim, stats) = run_fft2d(&mut rt, &re, &im, rows, cols).unwrap();
        assert_eq!(stats.rounds, 2 * (rows / 64) as u64);
        let mut wre = re.clone();
        let mut wim = im.clone();
        verify::fft2d_ref(&mut wre, &mut wim, rows, cols);
        let scale = wre.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(verify::max_abs_diff(&gre, &wre) / scale < 1e-3);
        assert!(verify::max_abs_diff(&gim, &wim) / scale < 1e-3);
    }
}
