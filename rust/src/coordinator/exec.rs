//! Functional executor: replay a mapped design tile-by-tile through the
//! AOT-compiled kernels — the rust incarnation of the generated host
//! program. The outer loops here are exactly the host-level schedule
//! (DRAM tiling + k-chaining + inter-pass transposes); each graph tile
//! executes on the PJRT runtime, standing in for one round of the AIE
//! array.

use crate::runtime::client::Runtime;
use crate::runtime::executor::Tensor;
use anyhow::{bail, Result};

/// Statistics from a functional run.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Graph-tile kernel invocations (≙ array rounds).
    pub rounds: u64,
    /// Elements produced.
    pub elements: u64,
    /// Wall time of the replay.
    pub seconds: f64,
}

/// C = A·B via the accumulate-form MM artifact with host k-chaining.
/// Sizes must divide by the artifact's graph-tile edge (256 or 128).
pub fn run_mm(rt: &mut Runtime, a: &[f32], b: &[f32], n: usize, m: usize, k: usize) -> Result<(Vec<f32>, ExecStats)> {
    let tile = if n % 256 == 0 && m % 256 == 0 && k % 256 == 0 {
        256
    } else if n % 128 == 0 && m % 128 == 0 && k % 128 == 0 {
        128
    } else {
        bail!("MM sizes must divide by 128 (got {n}×{m}×{k})");
    };
    let artifact = if tile == 256 { "mm_f32_256" } else { "mm_f32_128" };
    let t0 = std::time::Instant::now();
    let mut c = vec![0f32; n * m];
    let mut stats = ExecStats::default();

    let sub = |src: &[f32], row0: usize, col0: usize, rows: usize, cols: usize, stride: usize| {
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            out[r * cols..(r + 1) * cols]
                .copy_from_slice(&src[(row0 + r) * stride + col0..(row0 + r) * stride + col0 + cols]);
        }
        out
    };

    for i in (0..n).step_by(tile) {
        for j in (0..m).step_by(tile) {
            // accumulate across k tiles (the systolic k-chain, hosted)
            let mut acc = vec![0f32; tile * tile];
            for kk in (0..k).step_by(tile) {
                let at = sub(a, i, kk, tile, tile, k);
                let bt = sub(b, kk, j, tile, tile, m);
                let out = rt.run(
                    artifact,
                    &[
                        Tensor::f32(vec![tile, tile], at),
                        Tensor::f32(vec![tile, tile], bt),
                        Tensor::f32(vec![tile, tile], acc),
                    ],
                )?;
                acc = out.into_iter().next().unwrap().data.as_f32().unwrap().to_vec();
                stats.rounds += 1;
            }
            for r in 0..tile {
                c[(i + r) * m + j..(i + r) * m + j + tile]
                    .copy_from_slice(&acc[r * tile..(r + 1) * tile]);
            }
        }
    }
    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((c, stats))
}

/// Y = conv2d_valid(X, K) with a 4×4 kernel; output sizes must divide by
/// the 128-edge conv artifact.
pub fn run_conv2d(rt: &mut Runtime, x: &[f32], k: &[f32], h: usize, w: usize) -> Result<(Vec<f32>, ExecStats)> {
    const P: usize = 4;
    const TILE: usize = 128;
    if k.len() != P * P {
        bail!("conv artifact is specialised for 4×4 kernels");
    }
    if h % TILE != 0 || w % TILE != 0 {
        bail!("conv output must divide by {TILE}");
    }
    let xw = w + P - 1;
    let t0 = std::time::Instant::now();
    let mut y = vec![0f32; h * w];
    let mut stats = ExecStats::default();
    for i in (0..h).step_by(TILE) {
        for j in (0..w).step_by(TILE) {
            // halo-extended input block
            let bh = TILE + P - 1;
            let bw = TILE + P - 1;
            let mut xt = vec![0f32; bh * bw];
            for r in 0..bh {
                xt[r * bw..(r + 1) * bw]
                    .copy_from_slice(&x[(i + r) * xw + j..(i + r) * xw + j + bw]);
            }
            let out = rt.run(
                "conv2d_f32_128x4",
                &[
                    Tensor::f32(vec![bh, bw], xt),
                    Tensor::f32(vec![P, P], k.to_vec()),
                    Tensor::f32(vec![TILE, TILE], vec![0.0; TILE * TILE]),
                ],
            )?;
            let tile_out = out.into_iter().next().unwrap();
            let data = tile_out.data.as_f32().unwrap();
            for r in 0..TILE {
                y[(i + r) * w + j..(i + r) * w + j + TILE]
                    .copy_from_slice(&data[r * TILE..(r + 1) * TILE]);
            }
            stats.rounds += 1;
        }
    }
    stats.elements = (h * w) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((y, stats))
}

/// y = FIR(x, h) with 15 taps; n must divide by the 4096-sample artifact.
pub fn run_fir(rt: &mut Runtime, x: &[f32], h: &[f32], n: usize) -> Result<(Vec<f32>, ExecStats)> {
    const TAPS: usize = 15;
    const CHUNK: usize = 4096;
    if h.len() != TAPS {
        bail!("FIR artifact is specialised for 15 taps");
    }
    if n % CHUNK != 0 {
        bail!("FIR length must divide by {CHUNK}");
    }
    if x.len() != n + TAPS - 1 {
        bail!("x must have n + taps - 1 samples");
    }
    let t0 = std::time::Instant::now();
    let mut y = vec![0f32; n];
    let mut stats = ExecStats::default();
    for off in (0..n).step_by(CHUNK) {
        let xt = x[off..off + CHUNK + TAPS - 1].to_vec();
        let out = rt.run(
            "fir_f32_4096x15",
            &[
                Tensor::f32(vec![CHUNK + TAPS - 1], xt),
                Tensor::f32(vec![TAPS], h.to_vec()),
            ],
        )?;
        y[off..off + CHUNK].copy_from_slice(out[0].data.as_f32().unwrap());
        stats.rounds += 1;
    }
    stats.elements = n as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((y, stats))
}

/// 2D FFT over a rows×256 grid: batched row FFTs through the fft1d
/// artifact, transpose on the host (the PL data-mover's job), second
/// pass, transpose back. rows must divide by 64 and cols must be 256.
pub fn run_fft2d(
    rt: &mut Runtime,
    re: &[f32],
    im: &[f32],
    rows: usize,
    cols: usize,
) -> Result<(Vec<f32>, Vec<f32>, ExecStats)> {
    const BATCH: usize = 64;
    const N: usize = 256;
    if cols != N || rows % BATCH != 0 || rows < N && N % rows != 0 {
        // second pass runs over columns of length `rows`; the artifact is
        // fixed at 256, so rows must equal 256 too for the full 2D pass.
    }
    if cols != N || rows != N {
        bail!("fft2d replay is specialised to 256×256 grids");
    }
    let t0 = std::time::Instant::now();
    let mut stats = ExecStats::default();

    // Bit-reversal permutation (host-side data movement — on the board
    // the PL mover reorders samples while staging rows into the array;
    // the artifact computes the butterfly stages on reversed-order rows).
    let bits = N.trailing_zeros();
    let rev: Vec<usize> = (0..N)
        .map(|i| ((i as u32).reverse_bits() >> (32 - bits)) as usize)
        .collect();

    let pass = |rt: &mut Runtime, re: &[f32], im: &[f32], stats: &mut ExecStats| -> Result<(Vec<f32>, Vec<f32>)> {
        let mut ore = vec![0f32; rows * cols];
        let mut oim = vec![0f32; rows * cols];
        for b in (0..rows).step_by(BATCH) {
            let mut rt_in = vec![0f32; BATCH * cols];
            let mut it_in = vec![0f32; BATCH * cols];
            for r in 0..BATCH {
                for (i, &s) in rev.iter().enumerate() {
                    rt_in[r * cols + i] = re[(b + r) * cols + s];
                    it_in[r * cols + i] = im[(b + r) * cols + s];
                }
            }
            let out = rt.run(
                "fft1d_f32_64x256",
                &[
                    Tensor::f32(vec![BATCH, N], rt_in),
                    Tensor::f32(vec![BATCH, N], it_in),
                ],
            )?;
            ore[b * cols..(b + BATCH) * cols].copy_from_slice(out[0].data.as_f32().unwrap());
            oim[b * cols..(b + BATCH) * cols].copy_from_slice(out[1].data.as_f32().unwrap());
            stats.rounds += 1;
        }
        Ok((ore, oim))
    };
    let transpose = |v: &[f32]| {
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = v[r * cols + c];
            }
        }
        out
    };

    let (re1, im1) = pass(rt, re, im, &mut stats)?;
    let (rt2, it2) = (transpose(&re1), transpose(&im1));
    let (re2, im2) = pass(rt, &rt2, &it2, &mut stats)?;
    let (ore, oim) = (transpose(&re2), transpose(&im2));
    stats.elements = (rows * cols) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((ore, oim, stats))
}

/// Depthwise conv: tile over 8-channel groups and 64×64 spatial tiles of
/// the `dwconv2d_f32_8x64x3` artifact. `x` is `[c, h+2, w+2]` row-major
/// (2-pixel halo for the 3×3 kernels), `k` is `[c, 3, 3]`.
pub fn run_dwconv2d(
    rt: &mut Runtime,
    x: &[f32],
    k: &[f32],
    c: usize,
    h: usize,
    w: usize,
) -> Result<(Vec<f32>, ExecStats)> {
    const G: usize = 8;
    const TILE: usize = 64;
    const P: usize = 3;
    if c % G != 0 || h % TILE != 0 || w % TILE != 0 {
        bail!("dwconv sizes must divide by {G} channels / {TILE} pixels");
    }
    if k.len() != c * P * P || x.len() != c * (h + P - 1) * (w + P - 1) {
        bail!("dwconv input shapes inconsistent with c={c} h={h} w={w}");
    }
    let (xh, xw) = (h + P - 1, w + P - 1);
    let (bh, bw) = (TILE + P - 1, TILE + P - 1);
    let t0 = std::time::Instant::now();
    let mut y = vec![0f32; c * h * w];
    let mut stats = ExecStats::default();
    for g0 in (0..c).step_by(G) {
        for i in (0..h).step_by(TILE) {
            for j in (0..w).step_by(TILE) {
                let mut xt = vec![0f32; G * bh * bw];
                for g in 0..G {
                    for r in 0..bh {
                        let src = (g0 + g) * xh * xw + (i + r) * xw + j;
                        xt[g * bh * bw + r * bw..g * bh * bw + (r + 1) * bw]
                            .copy_from_slice(&x[src..src + bw]);
                    }
                }
                let kt = k[g0 * P * P..(g0 + G) * P * P].to_vec();
                let out = rt.run(
                    "dwconv2d_f32_8x64x3",
                    &[
                        Tensor::f32(vec![G, bh, bw], xt),
                        Tensor::f32(vec![G, P, P], kt),
                        Tensor::f32(vec![G, TILE, TILE], vec![0.0; G * TILE * TILE]),
                    ],
                )?;
                let data = out.into_iter().next().unwrap();
                let data = data.data.as_f32().unwrap();
                for g in 0..G {
                    for r in 0..TILE {
                        let dst = (g0 + g) * h * w + (i + r) * w + j;
                        y[dst..dst + TILE].copy_from_slice(
                            &data[g * TILE * TILE + r * TILE..g * TILE * TILE + (r + 1) * TILE],
                        );
                    }
                }
                stats.rounds += 1;
            }
        }
    }
    stats.elements = (c * h * w) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((y, stats))
}

/// Blocked forward substitution `x = L⁻¹ b` over the 256-row
/// `trsv_f32_256` artifact: the host applies the off-diagonal updates
/// (the PL mover's k-chain role), the artifact solves each diagonal
/// block. `l` is row-major n×n; n must divide by 256.
pub fn run_trsv(rt: &mut Runtime, l: &[f32], b: &[f32], n: usize) -> Result<(Vec<f32>, ExecStats)> {
    const BLK: usize = 256;
    if n % BLK != 0 {
        bail!("trsv size must divide by {BLK}");
    }
    if l.len() != n * n || b.len() != n {
        bail!("trsv input shapes inconsistent with n={n}");
    }
    let t0 = std::time::Instant::now();
    let mut x = vec![0f32; n];
    let mut stats = ExecStats::default();
    for bi in (0..n).step_by(BLK) {
        // rhs_I = b_I − Σ_{j < bi} L[I, j] · x[j]  (host-level chaining)
        let mut rhs = b[bi..bi + BLK].to_vec();
        for (i, r) in rhs.iter_mut().enumerate() {
            let row = (bi + i) * n;
            for (j, xj) in x[..bi].iter().enumerate() {
                *r -= l[row + j] * xj;
            }
        }
        // diagonal-block solve on the array
        let mut lt = vec![0f32; BLK * BLK];
        for r in 0..BLK {
            lt[r * BLK..(r + 1) * BLK]
                .copy_from_slice(&l[(bi + r) * n + bi..(bi + r) * n + bi + BLK]);
        }
        let out = rt.run(
            "trsv_f32_256",
            &[Tensor::f32(vec![BLK, BLK], lt), Tensor::f32(vec![BLK], rhs)],
        )?;
        x[bi..bi + BLK].copy_from_slice(out[0].data.as_f32().unwrap());
        stats.rounds += 1;
    }
    stats.elements = n as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((x, stats))
}

/// Stencil chain: `stages` Jacobi sweeps over a 128×128 grid by chaining
/// the 2-sweep `stencil2d_f32_2x128` artifact (stages must be even).
/// Larger grids need halo-exchange tiling between sweeps — like the
/// fft2d replay, this driver is specialised to the artifact's grid.
pub fn run_stencil2d(
    rt: &mut Runtime,
    a: &[f32],
    n: usize,
    m: usize,
    stages: usize,
    coef: &[f32],
) -> Result<(Vec<f32>, ExecStats)> {
    const N: usize = 128;
    if n != N || m != N {
        bail!("stencil2d replay is specialised to {N}×{N} grids");
    }
    if stages == 0 || stages % 2 != 0 {
        bail!("stages must be a positive multiple of the artifact's 2 sweeps");
    }
    if coef.len() != 5 {
        bail!("stencil takes 5 coefficients [centre, n, s, w, e]");
    }
    let t0 = std::time::Instant::now();
    let mut stats = ExecStats::default();
    let mut cur = a.to_vec();
    for _ in 0..stages / 2 {
        let out = rt.run(
            "stencil2d_f32_2x128",
            &[
                Tensor::f32(vec![N, N], cur),
                Tensor::f32(vec![5], coef.to_vec()),
            ],
        )?;
        cur = out.into_iter().next().unwrap().data.as_f32().unwrap().to_vec();
        stats.rounds += 1;
    }
    stats.elements = (n * m) as u64;
    stats.seconds = t0.elapsed().as_secs_f64();
    Ok((cur, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify;
    use crate::runtime::artifact::Manifest;
    use crate::util::rng::XorShift64;

    fn runtime() -> Option<Runtime> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(Runtime::new().unwrap())
    }

    #[test]
    fn mm_replay_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let (n, m, k) = (256, 128, 128);
        let mut rng = XorShift64::new(1);
        let mut a = vec![0f32; n * k];
        let mut b = vec![0f32; k * m];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        let (c, stats) = run_mm(&mut rt, &a, &b, n, m, k).unwrap();
        let want = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
        assert!(verify::max_abs_diff(&c, &want) < 1e-2);
        assert_eq!(stats.rounds, 2); // (256/128)·(128/128)·(128/128)
    }

    #[test]
    fn fir_replay_matches_oracle() {
        let Some(mut rt) = runtime() else { return };
        let n = 8192;
        let mut rng = XorShift64::new(2);
        let mut x = vec![0f32; n + 14];
        let mut h = vec![0f32; 15];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut h);
        let (y, stats) = run_fir(&mut rt, &x, &h, n).unwrap();
        let want = verify::fir_ref(&x, &h, n);
        assert!(verify::max_abs_diff(&y, &want) < 1e-3);
        assert_eq!(stats.rounds, 2);
    }

    #[test]
    fn size_validation_errors() {
        let Some(mut rt) = runtime() else { return };
        assert!(run_mm(&mut rt, &[0.0; 100], &[0.0; 100], 10, 10, 10).is_err());
        assert!(run_fir(&mut rt, &[0.0; 114], &[0.0; 15], 100).is_err());
    }

    /// The replay loops must work on the default stub backend with no
    /// artifacts on disk (tiling, k-chaining, halo staging, transposes).
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn mm_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (n, m, k) = (256, 128, 128);
        let mut rng = XorShift64::new(51);
        let mut a = vec![0f32; n * k];
        let mut b = vec![0f32; k * m];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        let (c, stats) = run_mm(&mut rt, &a, &b, n, m, k).unwrap();
        assert_eq!(stats.rounds, 2);
        let want = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
        assert!(verify::max_abs_diff(&c, &want) < 1e-2);
        // size validation fires on the stub path too
        assert!(run_mm(&mut rt, &[0.0; 100], &[0.0; 100], 10, 10, 10).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn dwconv_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (c, h, w) = (16usize, 128usize, 64usize);
        let mut rng = XorShift64::new(61);
        let mut x = vec![0f32; c * (h + 2) * (w + 2)];
        let mut k = vec![0f32; c * 9];
        rng.fill_f32(&mut x);
        rng.fill_f32(&mut k);
        let (y, stats) = run_dwconv2d(&mut rt, &x, &k, c, h, w).unwrap();
        // (16/8) groups × (128/64) × (64/64) spatial tiles
        assert_eq!(stats.rounds, 4);
        let want = verify::dw_conv2d_ref(&x, &k, c, h, w, 3, 3);
        assert!(verify::max_abs_diff(&y, &want) < 1e-4);
        // size validation
        assert!(run_dwconv2d(&mut rt, &x, &k, 10, h, w).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn trsv_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let n = 512usize;
        let mut rng = XorShift64::new(67);
        let mut l = vec![0f32; n * n];
        let mut b = vec![0f32; n];
        rng.fill_f32(&mut l);
        rng.fill_f32(&mut b);
        for i in 0..n {
            for j in 0..n {
                l[i * n + j] /= n as f32;
            }
            l[i * n + i] = 4.0 + l[i * n + i].abs();
        }
        let (x, stats) = run_trsv(&mut rt, &l, &b, n).unwrap();
        assert_eq!(stats.rounds, 2);
        let want = verify::trsv_ref(&l, &b, n);
        assert!(verify::max_abs_diff(&x, &want) < 1e-4);
        assert!(run_trsv(&mut rt, &l, &b, 100).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stencil_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let n = 128usize;
        let mut rng = XorShift64::new(71);
        let mut a = vec![0f32; n * n];
        rng.fill_f32(&mut a);
        let coef = [0.5f32, 0.125, 0.125, 0.125, 0.125];
        let (out, stats) = run_stencil2d(&mut rt, &a, n, n, 4, &coef).unwrap();
        assert_eq!(stats.rounds, 2); // two chained 2-sweep tiles
        let want = verify::stencil2d_chain_ref(&a, n, n, 4, &coef);
        assert!(verify::max_abs_diff(&out, &want) < 1e-4);
        // odd sweep counts and foreign grids are rejected
        assert!(run_stencil2d(&mut rt, &a, n, n, 3, &coef).is_err());
        assert!(run_stencil2d(&mut rt, &a, 64, 64, 2, &coef).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn fft2d_replay_on_stub_backend() {
        let mut rt = Runtime::with_builtin();
        let (rows, cols) = (256usize, 256usize);
        let mut rng = XorShift64::new(53);
        let mut re = vec![0f32; rows * cols];
        let mut im = vec![0f32; rows * cols];
        rng.fill_f32(&mut re);
        rng.fill_f32(&mut im);
        let (gre, gim, stats) = run_fft2d(&mut rt, &re, &im, rows, cols).unwrap();
        assert_eq!(stats.rounds, 2 * (rows / 64) as u64);
        let mut wre = re.clone();
        let mut wim = im.clone();
        verify::fft2d_ref(&mut wre, &mut wim, rows, cols);
        let scale = wre.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(verify::max_abs_diff(&gre, &wre) / scale < 1e-3);
        assert!(verify::max_abs_diff(&gim, &wim) / scale < 1e-3);
    }
}
