//! WideSA CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables and figures, run the mapping
//! pipeline on any benchmark, emit backend code bundles, and functionally
//! replay designs through the PJRT runtime. `widesa help` lists them.

use anyhow::{bail, Context, Result};
use widesa::coordinator::framework::{WideSa, WideSaConfig};
use widesa::coordinator::{exec, verify};
use widesa::eval;
use widesa::arch::vck5000::BoardConfig;
use widesa::mapping::dse::{self, DseConstraints, Objective};
use widesa::obs::trace::{self, Span, TraceCtx};
use widesa::obs::trend;
use widesa::recurrence::dtype::DType;
use widesa::recurrence::library;
use widesa::recurrence::spec::UniformRecurrence;
use widesa::runtime::client::Runtime;
use widesa::serve::lifecycle::{self, LifecycleConfig};
use widesa::serve::{serve_stdin, serve_tcp, ServeConfig, ServeHandle};
use widesa::util::json::Json;
use widesa::util::rng::XorShift64;

const HELP: &str = "\
widesa — WideSA reproduction: high array-utilization mapping on a simulated Versal ACAP

USAGE: widesa <COMMAND> [ARGS]

COMMANDS (evaluation):
  table1                 regenerate Table I  (bandwidth profile)
  table3                 regenerate Table III (throughput + AIE efficiency, 14 rows)
  table4                 regenerate Table IV (PL-only vs WideSA energy efficiency)
  figure6                regenerate Figure 6 (AIE / PLIO / buffer scalability sweeps)
  pnr-ablation           E5: constrained vs unconstrained place & route
  ablations              E7: technique ablations (latency hiding, threading, merge, movers)
  workloads              workload-coverage table: every library workload end to end
                         (mapping shape, AIEs, TOPS, sim agreement, P&R, ports)
  energy                 energy table: Table IV's TOPS-vs-W tradeoff across the
                         workload catalog (W, TOPS/W, J/pass, Pareto frontier)
                         vs the AutoSA PL-only baseline; see docs/ENERGY.md
  scalability            large-N MM sweep past the single-artifact staging
                         ceiling: chosen blocking plan, predicted vs measured
                         host DRAM traffic per size; see docs/BLOCKING.md
  ca                     standard-vs-communication-avoiding form selection
                         across PLIO channel budgets (78/16/8); writes
                         BENCH_ca.json at the repo root; see docs/CA_VARIANTS.md

COMMANDS (framework):
  map <bench> <dtype> [--aies N] [--dims NxMxK] [--trace-out PATH]
                                    run the mapping pipeline, print the design report
                                    (--dims overrides the mm problem size and prints
                                    the host blocking plan; --trace-out writes Chrome
                                    trace-event JSON)
  codegen <bench> <dtype> <outdir>  emit AIE kernel / ADF graph / PL movers / host code
  run-mm [n m k]                    functional replay of MM (default 512³) through the
                                    blocked, double-buffered host driver; prints the
                                    plan and predicted-vs-measured DRAM traffic
  selftest                          quick end-to-end smoke test

COMMANDS (service):
  serve --stdin                     JSON-lines compile service on stdin/stdout (EOF exits)
  serve --tcp ADDR                  same protocol on a TCP listener (e.g. 127.0.0.1:7171)
    options: --cache N (design-cache entries, default 64)
             --workers N (concurrent requests), --dse-threads N (scoring shards),
             --aies N / --mover-bits N / --cold-dram (base compile config)
             --objective throughput|efficiency|pareto (default ranking goal;
                              requests may override per compile)
             --max-power-w X (drop candidates whose estimate exceeds X watts)
             --snapshot PATH (warm-start the cache from PATH; stdin mode
                              writes the cache back to PATH at EOF)
             --snapshot-interval-s N (periodic background snapshots; also
                              written on SIGTERM/SIGINT)
             --max-inflight N (shed cold compiles beyond N in flight)
             --quota-rps X --quota-burst X (per-tenant token-bucket quota;
                              burst <= 0 disables admission)
             --metrics-out PATH (dump the metric registries as JSON at shutdown)
             --trace-out PATH (record spans; write Chrome trace JSON at shutdown)
    request:  {\"id\":1,\"bench\":\"mm\",\"dtype\":\"f32\",\"dims\":[8192,8192,8192],\"max_aies\":400}
    response: {\"id\":1,\"ok\":true,\"cached\":false,\"key\":\"…\",\"tops\":4.13,…}
    stats:    {\"cmd\":\"stats\"} returns counters + registry snapshots in-band

COMMANDS (observability):
  obs-check --trace PATH [--metrics PATH] [--min-coverage F]
                                    validate a --trace-out file (well-formed events,
                                    span nesting, trace IDs, root coverage >= F,
                                    default 0.95) and optionally a --metrics-out file
  trend [--commit SHA] [--serve PATH] [--compile PATH] [--blocking PATH] [--out PATH]
                                    append one per-commit trend line (p50/p99/p999,
                                    stage ms, overhead, fp32 MM TOPS/W, large-N
                                    blocked-replay speedup + GF/s) from the
                                    BENCH_*.json files to BENCH_trend.jsonl;
                                    SHA defaults to $GITHUB_SHA

  <bench>: mm | conv2d | fft2d | fir | dwconv2d | trsv | stencil2d | ca_mm | seidel2d
  <dtype>: f32 | i8 | i16 | i32 | cf32 | ci16

The functional replay runs on the in-process stub executor by default;
build with `--features pjrt` (plus `make artifacts`) to execute the real
AOT-lowered HLO through the PJRT runtime.
";

fn parse_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "f32" => DType::F32,
        "i8" => DType::I8,
        "i16" => DType::I16,
        "i32" => DType::I32,
        "cf32" => DType::CF32,
        "ci16" => DType::CI16,
        _ => bail!("unknown dtype {s} (f32|i8|i16|i32|cf32|ci16)"),
    })
}

fn parse_bench(bench: &str, dtype: DType) -> Result<UniformRecurrence> {
    Ok(match bench {
        "mm" => library::mm(8192, 8192, 8192, dtype),
        "conv2d" => library::conv2d(10240, 10240, 4, 4, dtype),
        "fft2d" => library::fft2d(8192, 8192, dtype),
        "fir" => library::fir(1048576, 15, dtype),
        "dwconv2d" => library::dw_conv2d(64, 2048, 2048, 3, 3, dtype),
        "trsv" => library::trsv(8192, dtype),
        "stencil2d" => library::stencil2d_chain(2, 4096, 4096, dtype),
        "ca_mm" => library::ca_mm_25d(1024, 1024, 1024, 4, dtype),
        "seidel2d" => library::seidel2d(2, 64, 64, dtype),
        _ => bail!(
            "unknown benchmark {bench} (mm|conv2d|fft2d|fir|dwconv2d|trsv|stencil2d|ca_mm|seidel2d)"
        ),
    })
}

fn framework(max_aies: Option<u64>) -> WideSa {
    WideSa::new(WideSaConfig {
        constraints: DseConstraints {
            max_aies,
            ..Default::default()
        },
        ..Default::default()
    })
}

fn cmd_map(args: &[String]) -> Result<()> {
    let (bench, dtype) = (args.first(), args.get(1));
    let (Some(bench), Some(dtype)) = (bench, dtype) else {
        bail!("usage: widesa map <bench> <dtype> [--aies N] [--dims NxMxK] [--trace-out PATH]");
    };
    let mut aies = None;
    if let Some(i) = args.iter().position(|a| a == "--aies") {
        aies = Some(args.get(i + 1).map(|v| v.parse()).transpose()?.unwrap_or(400));
    }
    let mut trace_out: Option<std::path::PathBuf> = None;
    if let Some(i) = args.iter().position(|a| a == "--trace-out") {
        let path = args.get(i + 1).ok_or_else(|| anyhow::anyhow!("--trace-out needs a path"))?;
        trace_out = Some(path.into());
        trace::set_enabled(true);
    }
    let mut dims: Option<Vec<u64>> = None;
    if let Some(i) = args.iter().position(|a| a == "--dims") {
        let v = args.get(i + 1).ok_or_else(|| anyhow::anyhow!("--dims needs NxMxK"))?;
        dims = Some(
            v.split('x')
                .map(|s| s.parse::<u64>().with_context(|| format!("bad --dims part {s:?}")))
                .collect::<Result<_>>()?,
        );
    }
    let rec = match &dims {
        None => parse_bench(bench, parse_dtype(dtype)?)?,
        Some(d) => {
            if bench != "mm" || d.len() != 3 {
                bail!("--dims NxMxK is only supported for mm");
            }
            library::mm(d[0], d[1], d[2], parse_dtype(dtype)?)
        }
    };
    // mm designs replay under a host-level blocking plan: report it with
    // the design, and reject unplannable shapes with the typed error
    // before spending any compile time.
    let blocking_plan = if bench == "mm" {
        let d = dims.as_deref().unwrap_or(&[8192, 8192, 8192]);
        let model = widesa::mapping::cost::CostModel::new(BoardConfig::vck5000());
        Some(
            widesa::coordinator::blocking::plan_mm(&model, d[0], d[1], d[2])
                .map_err(anyhow::Error::new)?,
        )
    } else {
        None
    };
    // The whole compile runs under one root span with its own trace ID,
    // so the exported trace attributes wall time the way a serve request
    // would (dse under map; dse.score fan-out correlated by the ID).
    let _ctx = TraceCtx::set(trace::next_trace_id());
    let root = Span::begin("map", "cli");
    let d = framework(aies).compile(&rec)?;
    drop(root);
    println!("{}", d.report());
    if let Some(plan) = &blocking_plan {
        println!("  {}", plan.summary());
    }
    if let Some(path) = trace_out {
        let doc = trace::export_chrome(&trace::drain_events());
        std::fs::write(&path, format!("{doc}\n"))
            .with_context(|| format!("writing trace to {}", path.display()))?;
        eprintln!("widesa map: trace written to {}", path.display());
    }
    Ok(())
}

fn cmd_codegen(args: &[String]) -> Result<()> {
    let (Some(bench), Some(dtype), Some(outdir)) = (args.first(), args.get(1), args.get(2))
    else {
        bail!("usage: widesa codegen <bench> <dtype> <outdir>");
    };
    let rec = parse_bench(bench, parse_dtype(dtype)?)?;
    let d = framework(Some(400)).compile(&rec)?;
    d.code.write_to(std::path::Path::new(outdir))?;
    println!(
        "wrote kernel.cc, graph.cpp, dma_mover.cpp, host.cpp, constraints.json to {outdir}"
    );
    Ok(())
}

fn cmd_run_mm(args: &[String]) -> Result<()> {
    let n: usize = args.first().map(|v| v.parse()).transpose()?.unwrap_or(512);
    let m: usize = args.get(1).map(|v| v.parse()).transpose()?.unwrap_or(n);
    let k: usize = args.get(2).map(|v| v.parse()).transpose()?.unwrap_or(n);
    println!("functional MM replay: {n}×{m}×{k} f32");
    // Plan before allocating operands: an unplannable shape gets the
    // typed error without first trying to stage petabyte inputs.
    let plan = exec::plan_for(n, m, k)?;
    println!("{}", plan.summary());
    let mut rt = Runtime::new()?;
    println!("runtime backend: {}", rt.platform());
    let mut rng = XorShift64::new(1234);
    let mut a = vec![0f32; n * k];
    let mut b = vec![0f32; k * m];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let (c, stats) = exec::run_mm(&mut rt, &a, &b, n, m, k)?;
    let want = verify::mm_ref(&a, &b, &vec![0.0; n * m], n, m, k);
    let err = verify::max_abs_diff(&c, &want);
    let gflops = 2.0 * (n as f64) * (m as f64) * (k as f64) / stats.seconds / 1e9;
    println!(
        "rounds={} wall={:.3}s functional-throughput={:.2} GFLOP/s max|Δ|={err:.2e}",
        stats.rounds, stats.seconds, gflops
    );
    println!(
        "host DRAM: predicted {:.1} MB, measured {:.1} MB | pack {:.1} ms ({:.1} ms hidden by overlap)",
        plan.predicted_dram_bytes as f64 / 1e6,
        stats.dram_bytes as f64 / 1e6,
        stats.pack_ms,
        stats.overlap_hidden_ms
    );
    if err > 1e-2 {
        bail!("verification FAILED (max|Δ| = {err})");
    }
    println!("verification OK");
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let mut cfg = ServeConfig::default();
    let mut lc = LifecycleConfig::default();
    let mut stdin_mode = false;
    let mut tcp_addr: Option<String> = None;
    let flag_val = |args: &[String], i: usize, flag: &str| -> Result<String> {
        args.get(i + 1)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("{flag} needs a value"))
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--stdin" => stdin_mode = true,
            "--tcp" => {
                tcp_addr = Some(flag_val(args, i, "--tcp")?);
                i += 1;
            }
            "--cache" => {
                cfg.cache_capacity = flag_val(args, i, "--cache")?.parse()?;
                i += 1;
            }
            "--workers" => {
                cfg.request_workers = flag_val(args, i, "--workers")?.parse()?;
                i += 1;
            }
            "--dse-threads" => {
                cfg.dse_threads = flag_val(args, i, "--dse-threads")?.parse()?;
                i += 1;
            }
            "--aies" => {
                cfg.base.constraints.max_aies = Some(flag_val(args, i, "--aies")?.parse()?);
                i += 1;
            }
            "--mover-bits" => {
                cfg.base.mover_bits = flag_val(args, i, "--mover-bits")?.parse()?;
                i += 1;
            }
            "--cold-dram" => cfg.base.cold_dram = true,
            "--objective" => {
                let v = flag_val(args, i, "--objective")?;
                cfg.base.constraints.objective = Objective::parse(&v).ok_or_else(|| {
                    anyhow::anyhow!("unknown objective {v:?} (throughput|efficiency|pareto)")
                })?;
                i += 1;
            }
            "--max-power-w" => {
                let w: f64 = flag_val(args, i, "--max-power-w")?.parse()?;
                if !w.is_finite() || w <= 0.0 {
                    bail!("--max-power-w must be a positive number");
                }
                cfg.base.constraints.max_power_w = Some(w);
                i += 1;
            }
            "--snapshot" => {
                cfg.snapshot = Some(flag_val(args, i, "--snapshot")?.into());
                i += 1;
            }
            "--max-inflight" => {
                cfg.max_inflight = flag_val(args, i, "--max-inflight")?.parse()?;
                i += 1;
            }
            "--quota-rps" => {
                cfg.quota_rps = flag_val(args, i, "--quota-rps")?.parse()?;
                i += 1;
            }
            "--quota-burst" => {
                cfg.quota_burst = flag_val(args, i, "--quota-burst")?.parse()?;
                i += 1;
            }
            "--snapshot-interval-s" => {
                let secs: f64 = flag_val(args, i, "--snapshot-interval-s")?.parse()?;
                if secs.is_finite() && secs >= 0.0 {
                    lc.snapshot_interval = Some(std::time::Duration::from_secs_f64(secs));
                } else {
                    bail!("--snapshot-interval-s must be a non-negative number");
                }
                i += 1;
            }
            "--metrics-out" => {
                lc.metrics_out = Some(flag_val(args, i, "--metrics-out")?.into());
                i += 1;
            }
            "--trace-out" => {
                lc.trace_out = Some(flag_val(args, i, "--trace-out")?.into());
                i += 1;
            }
            other => bail!("unknown serve option {other:?} (see `widesa help`)"),
        }
        i += 1;
    }
    if stdin_mode == tcp_addr.is_some() {
        bail!("serve needs exactly one of --stdin or --tcp ADDR");
    }
    if lc.trace_out.is_some() {
        trace::set_enabled(true);
    }
    let handle = ServeHandle::new(cfg);
    // SIGTERM/SIGINT → watchdog writes snapshot + metrics + trace and
    // exits; the same watchdog writes periodic snapshots in between.
    lifecycle::install_signal_handlers();
    lifecycle::spawn_watchdog(handle.clone(), lc.clone(), true);
    if let Some(addr) = tcp_addr {
        let listener = std::net::TcpListener::bind(&addr)?;
        serve_tcp(&handle, listener)?;
    } else {
        serve_stdin(&handle)?;
        let s = handle.stats();
        eprintln!(
            "widesa serve: done — {} hits, {} misses, {} deduped, {} errors, {} shed, {} cached designs",
            s.hits, s.misses, s.deduped, s.errors, s.shed, s.cache.len
        );
    }
    // EOF path (and TCP loop exit): same artifacts as the signal path.
    lifecycle::final_export(&handle, &lc)?;
    Ok(())
}

fn cmd_trend(args: &[String]) -> Result<()> {
    let flag = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let commit = flag("--commit")
        .or_else(|| std::env::var("GITHUB_SHA").ok())
        .unwrap_or_else(|| "local".to_string());
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .to_path_buf();
    let serve_path = flag("--serve").map_or_else(|| root.join("BENCH_serve.json"), Into::into);
    let compile_path =
        flag("--compile").map_or_else(|| root.join("BENCH_compile.json"), Into::into);
    let blocking_path =
        flag("--blocking").map_or_else(|| root.join("BENCH_blocking.json"), Into::into);
    let out = flag("--out").map_or_else(|| root.join("BENCH_trend.jsonl"), Into::into);
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let serve = trend::read_bench(&serve_path);
    let compile = trend::read_bench(&compile_path);
    let blocking = trend::read_bench(&blocking_path);
    // Deterministic fp32 MM TOPS/W datum straight from the shared cost +
    // power model (analytic explore only — no P&R, so this is cheap and
    // bit-stable across runs on the same commit).
    let mm_tpw = dse::explore(
        &library::mm(8192, 8192, 8192, DType::F32),
        &BoardConfig::vck5000(),
        &DseConstraints {
            max_aies: Some(400),
            ..Default::default()
        },
    )
    .map(|(_, est)| est.power.tops_per_watt);
    let line = trend::trend_line(
        &commit,
        ts,
        serve.as_ref(),
        compile.as_ref(),
        mm_tpw,
        blocking.as_ref(),
    );
    trend::append_trend(&out, &line)?;
    println!("{line}");
    eprintln!("widesa trend: appended to {}", out.display());
    Ok(())
}

fn cmd_obs_check(args: &[String]) -> Result<()> {
    let flag = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let Some(trace_path) = flag("--trace") else {
        bail!("usage: widesa obs-check --trace PATH [--metrics PATH] [--min-coverage F]");
    };
    let min_coverage: f64 = flag("--min-coverage").map(|v| v.parse()).transpose()?.unwrap_or(0.95);
    let text = std::fs::read_to_string(&trace_path)
        .with_context(|| format!("reading trace {trace_path}"))?;
    let doc = widesa::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("trace {trace_path}: {e}"))?;
    let report = trace::validate_chrome(&doc)?;
    println!(
        "trace ok: {} events, {} trace ids, root {:?} ({:.1} ms) {:.1}% covered by children",
        report.events,
        report.trace_ids,
        report.root_name,
        report.root_dur_us as f64 / 1e3,
        report.root_coverage * 100.0
    );
    if report.root_coverage < min_coverage {
        bail!(
            "root span {:?} only {:.1}% covered by child spans (need >= {:.1}%)",
            report.root_name,
            report.root_coverage * 100.0,
            min_coverage * 100.0
        );
    }
    if let Some(metrics_path) = flag("--metrics") {
        let text = std::fs::read_to_string(&metrics_path)
            .with_context(|| format!("reading metrics {metrics_path}"))?;
        let doc = widesa::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("metrics {metrics_path}: {e}"))?;
        for section in ["serve", "pipeline"] {
            let s = doc
                .get(section)
                .ok_or_else(|| anyhow::anyhow!("metrics missing {section:?} registry"))?;
            for kind in ["counters", "gauges", "histograms"] {
                if s.get(kind).and_then(Json::as_obj).is_none() {
                    bail!("metrics {section:?} registry missing {kind:?} object");
                }
            }
        }
        println!("metrics ok: serve + pipeline registries present");
    }
    println!("obs-check OK");
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    println!("1/3 mapping pipeline ...");
    let d = framework(Some(400)).compile(&library::mm(2048, 2048, 2048, DType::F32))?;
    if !d.compile.success {
        bail!("place & route failed");
    }
    println!("    ok: {}", d.sim.summary());
    println!("2/3 runtime backend ...");
    let mut rt = Runtime::new()?;
    rt.executable("mm_f32_128")?;
    println!("    ok: backend {}", rt.platform());
    println!("3/3 functional replay ...");
    cmd_run_mm(&["256".into()])?;
    println!("selftest OK");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("table1") => {
            let (_, table) = eval::table1::run();
            println!("{table}");
        }
        Some("table3") => {
            let (_, table) = eval::table3::run();
            println!("{table}");
        }
        Some("table4") => {
            let (_, table) = eval::table4::run();
            println!("{table}");
        }
        Some("figure6") => {
            let (_, _, rendered) = eval::figure6::run();
            println!("{rendered}");
        }
        Some("pnr-ablation") => {
            let (_, table) = eval::pnr_ablation::run();
            println!("{table}");
        }
        Some("ablations") => {
            let (_, table) = eval::ablations::run();
            println!("{table}");
        }
        Some("workloads") => {
            let (_, table) = eval::workloads::run();
            println!("{table}");
        }
        Some("energy") => {
            let (_, table) = eval::energy::run();
            println!("{table}");
        }
        Some("scalability") => {
            let (_, table) = eval::scalability::run();
            println!("{table}");
        }
        Some("ca") => {
            let (rows, table) = eval::ca::run();
            println!("{table}");
            let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .expect("workspace root")
                .join("BENCH_ca.json");
            std::fs::write(&out, format!("{}\n", eval::ca::bench_json(&rows)))
                .with_context(|| format!("writing {}", out.display()))?;
            eprintln!("widesa ca: selection table written to {}", out.display());
        }
        Some("map") => cmd_map(&args[1..])?,
        Some("codegen") => cmd_codegen(&args[1..])?,
        Some("run-mm") => cmd_run_mm(&args[1..])?,
        Some("serve") => cmd_serve(&args[1..])?,
        Some("trend") => cmd_trend(&args[1..])?,
        Some("obs-check") => cmd_obs_check(&args[1..])?,
        Some("selftest") => cmd_selftest()?,
        Some("help") | None => print!("{HELP}"),
        Some(other) => {
            eprint!("unknown command {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    }
    Ok(())
}
